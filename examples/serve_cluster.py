"""End-to-end serving driver: REAL model, batched requests, QoS scheduling,
driven through the northbound session API.

    PYTHONPATH=src python examples/serve_cluster.py [--requests 24]

Runs the edge-tiny LM on actual engines at every execution site (continuous
batching with per-slot positions), establishes AI Sessions for a mix of
premium/best-effort invokers — each one a SessionClient speaking JSON to
the NorthboundGateway — pushes batched requests through the per-site
QoS-scheduled ServingPlanes (class-ordered admission, premium reservation,
deadline fast-fail), and prints per-class boundary telemetry — the
end-to-end driver for the paper's serving scenario.
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import dataclasses

import numpy as np

from repro.api.client import SessionClient
from repro.core import Orchestrator, default_asp
from repro.core.asp import QualityTier
from repro.core.clock import Clock
from repro.serving.server import AIaaSServer


def cpu_scaled_asp(tier):
    """The demo runs real models on ONE CPU core (~1000× slower than the
    production target), so the boundary objectives scale accordingly —
    the contract machinery is identical."""
    asp = default_asp(tier=tier)
    o = dataclasses.replace(asp.objectives, ttfb_ms=30_000.0,
                            p95_ms=90_000.0, p99_ms=120_000.0,
                            t_max_ms=300_000.0, nu_min=1.0)
    return dataclasses.replace(asp, objectives=o)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=8)
    args = ap.parse_args()

    clock = Clock()
    orch = Orchestrator(clock=clock)
    server = AIaaSServer(orch, "edge-tiny", slots=args.slots, max_len=192)
    rng = np.random.default_rng(0)

    # establish sessions northbound: premium tier and basic tier invokers
    clients = []
    for i in range(6):
        tier = QualityTier.PREMIUM if i % 2 == 0 else QualityTier.BASIC
        c = SessionClient(server.gateway, cpu_scaled_asp(tier),
                          invoker=f"ue-{i}", zone="zone-a").establish()
        clients.append((c, tier))
        print(f"established {c.session_id} tier={tier.name} "
              f"anchor={c.record['anchor']} qfi={c.record['qfi']}")

    # submit a burst of requests through the northbound API — the per-site
    # planes decide admission order (premium first, reserved share)
    for r in range(args.requests):
        c, _ = clients[r % len(clients)]
        c.submit(prompt_tokens=int(rng.integers(8, 48)), gen_tokens=8)

    t0 = time.perf_counter()
    results = server.drain()
    done = sum(1 for res in results.values() if res.failed is None)
    wall = time.perf_counter() - t0

    print(f"\nserved {done} requests in {wall:.2f}s "
          f"({done / wall:.1f} req/s on 1 CPU core)")
    for plane in server.planes.values():
        for klass, waits in plane.scheduler.stats.per_class_wait_ms.items():
            if waits:
                print(f"  {plane.site_id}/{klass:12s} "
                      f"admitted={len(waits):3d} "
                      f"mean wait={np.mean(waits):7.2f}ms")
    for c, tier in clients:
        rep = c.compliance()
        if rep.n:
            print(f"  {c.session_id} tier={tier.name:8s} "
                  f"q99={rep.z['q99_ms']:8.1f}ms "
                  f"ρ̂={rep.z['rho']:.2f} compliant={rep.in_compliance}")
        c.release()


if __name__ == "__main__":
    main()
