"""Quickstart: establish an AI Session and serve requests through it.

    PYTHONPATH=src python examples/quickstart.py

Walks the full NE-AIaaS lifecycle on a laptop: an ASP with measurable
objectives → DISCOVER (annotated candidates) → AI PAGING (risk-minimising
anchor) → atomic PREPARE/COMMIT → SERVE with boundary telemetry →
compliance report (Eq. 5/16) → consent revocation (Eq. 6) → release.
"""

import sys

sys.path.insert(0, "src")

from repro.core import Orchestrator, default_asp, SessionError
from repro.core.asp import MobilityClass
from repro.core.clock import VirtualClock
from repro.core.discovery import discover


def main():
    clock = VirtualClock()
    orch = Orchestrator(clock=clock)
    asp = default_asp(mobility=MobilityClass.NOMADIC)
    print(f"ASP digest {asp.digest()}  objectives: ttfb≤{asp.objectives.ttfb_ms}ms "
          f"p99≤{asp.objectives.p99_ms}ms ρ≥{asp.objectives.rho_min} "
          f"T_max={asp.objectives.t_max_ms}ms")

    # 1. DISCOVER — annotated candidate set 𝒦 (Eq. 7/8)
    cands = discover(asp, orch.catalog, orch.sites, orch.predictors, "zone-a",
                     analytics=orch.analytics)
    print("\nDISCOVER: top candidates by slack Δ(m,e):")
    for c in [c for c in cands if c.admissible][:5]:
        p = c.prediction
        print(f"  {c.model.model_id:22s} @ {c.site_id:10s} "
              f"T̂ff={p.t_ff_ms:7.1f}ms L̂99={p.l99_ms:7.1f}ms "
              f"Γ̂={p.cost_per_1k:.3f}/1k Δ={c.slack:8.1f}")

    # 2-4. PAGE + PREPARE/COMMIT (atomic co-reservation)
    session = orch.establish(asp, invoker="alice", zone="zone-a")
    rec = session.record()
    print(f"\nAIS {rec['session_id']} COMMITTED: model={rec['model']} "
          f"anchor={rec['anchor']} qfi={rec['qfi']}")
    print(f"  Committed(t) = v_cmp ∧ v_qos = {session.committed()}")

    # 5. SERVE with boundary telemetry
    for i in range(20):
        orch.serve(session, prompt_tokens=256, gen_tokens=48)
    rep = orch.compliance(session)
    z = rep.z
    print(f"\nSERVE ×20 → Z(t): ttfb={z.t_ff_ms:.1f}ms q95={z.q95_ms:.1f}ms "
          f"q99={z.q99_ms:.1f}ms ρ̂={z.rho:.3f} ν̂={z.nu_tokens_per_s:.1f} tok/s")
    print(f"  in compliance with ASP: {rep.in_compliance}")
    charge = orch.policy.charging(session.charging_ref)
    print(f"  metered: {charge.tokens} tokens, cost {charge.cost:.4f} "
          f"(session-scoped accounting, R8)")

    # 6. consent revocation ⇒ ServeDisabled (Eq. 6)
    orch.policy.revoke(session.authz_ref)
    try:
        orch.serve(session)
    except SessionError as e:
        print(f"\nafter revocation: serve denied with cause "
              f"'{e.cause.value}' (Eq. 6 holds)")
    orch.release(session)
    print(f"released: state={session.state.value}")


if __name__ == "__main__":
    main()
