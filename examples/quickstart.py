"""Quickstart: establish an AI Session over the northbound API and stream
generations through it.

    PYTHONPATH=src python examples/quickstart.py

Walks the full NE-AIaaS lifecycle the way a remote application-service-
provider would — every step a JSON message through the NorthboundGateway:
DISCOVER (annotated candidates) → AI PAGING (risk-minimising anchor) →
idempotent PREPARE/COMMIT → streaming SERVE with boundary telemetry →
compliance report (Eq. 5/16) → consent revocation (Eq. 6, typed error) →
release.
"""

import sys

sys.path.insert(0, "src")

from repro.api import NorthboundGateway, SessionClient, ConsentRevoked
from repro.core import default_asp
from repro.core.asp import MobilityClass
from repro.core.clock import VirtualClock


def main():
    gw = NorthboundGateway(clock=VirtualClock())
    asp = default_asp(mobility=MobilityClass.NOMADIC)
    print(f"ASP digest {asp.digest()}  objectives: ttfb≤{asp.objectives.ttfb_ms}ms "
          f"p99≤{asp.objectives.p99_ms}ms ρ≥{asp.objectives.rho_min} "
          f"T_max={asp.objectives.t_max_ms}ms")

    client = SessionClient(gw, asp, invoker="alice", zone="zone-a")
    with client:
        # 1. DISCOVER ran as its own wire message — annotated 𝒦 (Eq. 7/8)
        print("\nDISCOVER: top candidates by slack Δ(m,e):")
        for c in [c for c in client.candidates if c["admissible"]][:5]:
            print(f"  {c['model_id']:22s} @ {c['site_id']:10s} "
                  f"class={c['klass']:11s} Δ={c['slack']:8.1f}")

        # 2-4. PAGE + idempotent PREPARE/COMMIT happened inside establish()
        rec = client.record
        print(f"\nAIS {rec['session_id']} COMMITTED: model={rec['model']} "
              f"anchor={rec['anchor']} qfi={rec['qfi']}")

        # 5. streaming SERVE: chunk-by-chunk over the wire
        stream = client.generate(prompt_tokens=256, gen_tokens=48)
        n = sum(1 for _ in stream)
        print(f"\nfirst generation streamed {n} chunks "
              f"(ttfb={stream.complete.ttfb_ms:.1f}ms "
              f"latency={stream.complete.latency_ms:.1f}ms)")
        for _ in range(19):
            list(client.generate(prompt_tokens=256, gen_tokens=48))
        rep = client.compliance()
        z = rep.z
        print(f"SERVE ×20 → Z(t): ttfb={z['t_ff_ms']:.1f}ms "
              f"q95={z['q95_ms']:.1f}ms q99={z['q99_ms']:.1f}ms "
              f"ρ̂={z['rho']:.3f} ν̂={z['nu_tokens_per_s']:.1f} tok/s")
        print(f"  in compliance with ASP: {rep.in_compliance}")

        # lifecycle notifications delivered on the invoker's subscription
        print("  events:", [e.state or e.event for e in client.events()])

        # 6. consent revocation ⇒ ServeDisabled (Eq. 6) as a TYPED error
        gw.orch.policy.revoke(gw.orch.sessions[client.session_id].authz_ref)
        try:
            list(client.generate())
        except ConsentRevoked as e:
            print(f"\nafter revocation: serve denied with code {e.code} "
                  f"cause '{e.cause.value}' (Eq. 6 holds)")
        ack = client.release()
        print(f"released: state={ack.state} "
              f"metered {ack.tokens} tokens, cost {ack.total_cost:.4f} "
              f"(session-scoped accounting, R8)")


if __name__ == "__main__":
    main()
