"""Train a small LM end-to-end for a few hundred steps with checkpoints,
restart, and gradient compression.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

Uses the fault-tolerant training driver: trains, kills itself at the
midpoint, restarts from the latest sharded checkpoint (including the data
cursor) and verifies the loss curve continues downward.
"""

import argparse
import shutil
import sys
import tempfile

sys.path.insert(0, "src")

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="edge-tiny")
    args = ap.parse_args()

    ckpt = tempfile.mkdtemp(prefix="neaiaas-ckpt-")
    half = args.steps // 2
    print(f"=== phase 1: {half} steps (checkpointing to {ckpt}) ===")
    _, losses1 = train(args.arch, steps=half, batch=8, seq=128,
                       ckpt_dir=ckpt, ckpt_every=max(10, half // 4),
                       compress=True, log_every=20)

    print(f"\n=== simulated failure; restarting from checkpoint ===")
    _, losses2 = train(args.arch, steps=args.steps - half, batch=8, seq=128,
                       ckpt_dir=ckpt, resume=True, compress=True,
                       log_every=20)

    print(f"\nloss: start={losses1[0]:.3f} mid={losses1[-1]:.3f} "
          f"end={losses2[-1]:.3f}")
    assert losses2[-1] < losses1[0], "training did not make progress"
    print("restart-continuity + convergence ✓")
    shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
