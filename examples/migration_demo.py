"""Make-before-break migration with REAL state transfer, driven and
observed through the northbound session API.

    PYTHONPATH=src python examples/migration_demo.py

A vehicular session decodes on an edge engine; a heartbeat with tightened
Eq. (14) trigger thresholds fires a LIVE migration to another site (KV
cache exported → fingerprint-verified → imported; target committed BEFORE
source release), generation continues bit-identically, and the invoker is
notified with a migration SessionEvent on its subscription. Also
demonstrates the abort path: an injected transfer failure leaves the source
binding committed (the session never leaves the Committed(t) domain) and
surfaces its Eq. (12) cause on the wire.
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.api.client import SessionClient
from repro.core import Orchestrator, default_asp
from repro.core.asp import MobilityClass
from repro.core.clock import VirtualClock
from repro.serving.server import AIaaSServer
from repro.serving import state_transfer


def main():
    clock = VirtualClock()
    orch = Orchestrator(clock=clock)
    server = AIaaSServer(orch, "edge-tiny", slots=4, max_len=128)
    asp = default_asp(mobility=MobilityClass.VEHICULAR)
    client = SessionClient(server.gateway, asp, invoker="car-7",
                           zone="zone-a").establish()
    session = orch.sessions[client.session_id]
    src_site = client.record["anchor"]
    print(f"session {client.session_id} committed at {src_site}")

    # start generating on the source engine (data-plane view of the stream)
    eng_src = server.fleet.engine_for(src_site)
    prompt = np.arange(16, dtype=np.int32)
    pre = eng_src.prefill_session(client.session_id, prompt)
    toks = [pre["first_token"]]
    for _ in range(5):
        toks.append(eng_src.decode_round()[client.session_id])
    print(f"generated on source: {toks}")

    # oracle: what the NEXT 5 tokens would be without migration — captured
    # on a probe engine BEFORE the swap (the source slot is released at
    # commit, so the source can't be replayed afterwards)
    probe = type(eng_src)(eng_src.cfg, params=eng_src.params, slots=2,
                          max_len=128)
    state_transfer.transfer(eng_src, probe, client.session_id)
    src_cont = [probe.decode_round()[client.session_id] for _ in range(5)]

    # make-before-break migration, fired northbound: a heartbeat with
    # δ = δ' = 0 makes the Eq. (14) risk check trigger unconditionally
    ack = client.heartbeat(trigger_l99=0.0, trigger_ttfb=0.0)
    out = ack.migration
    print(f"migration: migrated={out['migrated']} {out['from_site']} → "
          f"{out['to_site']} interruption={out['interruption_ms']:.1f}ms "
          f"transfer={out['transfer_ms']:.2f}ms")
    assert session.committed(), "never left the committed domain"
    events = [e for e in client.events() if e.event == "migration"]
    assert events and events[0].detail["to_site"] == out["to_site"]
    print(f"invoker notified: SessionEvent(migration) → "
          f"anchor now {client.anchor}")

    dst = server.fleet.engine_for(client.anchor)
    cont = [dst.decode_round()[client.session_id] for _ in range(5)]
    print(f"continued on target:   {cont}")
    print(f"source would have said: {src_cont}")
    assert cont == src_cont, "migration changed the generation!"
    assert not eng_src.has_slot(client.session_id), \
        "source slot must be released after the swap"
    print("bit-identical continuation ✓ (make-before-break preserved state, "
          "source slot released)")

    # abort path: injected failure keeps the source committed
    from repro.core.failures import FailureCause, SessionError

    def always_fail(session_, src_, dst_):
        raise SessionError(FailureCause.STATE_TRANSFER_FAILURE, "injected")

    orch.migrations.transfer_fn = always_fail
    ack2 = client.heartbeat(trigger_l99=0.0, trigger_ttfb=0.0)
    out2 = ack2.migration
    print(f"\ninjected failure: migrated={out2['migrated']} "
          f"cause={out2['cause']} — still committed: {session.committed()}")


if __name__ == "__main__":
    main()
