"""Make-before-break migration with REAL state transfer.

    PYTHONPATH=src python examples/migration_demo.py

A vehicular session decodes on an edge engine; mid-generation the session is
migrated to another site (KV cache exported → fingerprint-verified →
imported; target committed BEFORE source release), and generation continues
bit-identically. Also demonstrates the abort path: an injected transfer
failure leaves the source binding committed (the session never leaves the
Committed(t) domain).
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import Orchestrator, default_asp
from repro.core.asp import MobilityClass
from repro.core.clock import VirtualClock
from repro.serving.server import AIaaSServer
from repro.serving import state_transfer


def main():
    clock = VirtualClock()
    orch = Orchestrator(clock=clock)
    server = AIaaSServer(orch, "edge-tiny", slots=4, max_len=128)
    asp = default_asp(mobility=MobilityClass.VEHICULAR)
    session = orch.establish(asp, invoker="car-7", zone="zone-a")
    src_site = session.binding.site_id
    print(f"session {session.session_id} committed at {src_site}")

    # start generating on the source engine
    eng_src = server.fleet.engine_for(src_site)
    prompt = np.arange(16, dtype=np.int32)
    pre = eng_src.prefill_session(session.session_id, prompt)
    toks = [pre["first_token"]]
    for _ in range(5):
        toks.append(eng_src.decode_round()[session.session_id])
    print(f"generated on source: {toks}")

    # oracle: what the NEXT 5 tokens would be without migration — captured
    # on a probe engine BEFORE the swap (the source slot is released at
    # commit, so the source can't be replayed afterwards)
    probe = type(eng_src)(eng_src.cfg, params=eng_src.params, slots=2,
                          max_len=128)
    state_transfer.transfer(eng_src, probe, session.session_id)
    src_cont = [probe.decode_round()[session.session_id] for _ in range(5)]

    # make-before-break migration through the control plane
    out = orch.migrations.migrate(session, "zone-a")
    print(f"migration: migrated={out.migrated} {out.from_site} → {out.to_site} "
          f"interruption={out.interruption_ms:.1f}ms "
          f"transfer={out.transfer_ms:.2f}ms")
    assert session.committed(), "never left the committed domain"

    dst = server.fleet.engine_for(session.binding.site_id)
    cont = [dst.decode_round()[session.session_id] for _ in range(5)]
    print(f"continued on target:   {cont}")
    print(f"source would have said: {src_cont}")
    assert cont == src_cont, "migration changed the generation!"
    assert not eng_src.has_slot(session.session_id), \
        "source slot must be released after the swap"
    print("bit-identical continuation ✓ (make-before-break preserved state, "
          "source slot released)")

    # abort path: injected failure keeps the source committed
    from repro.core.failures import FailureCause, SessionError

    def always_fail(session_, src_, dst_):
        raise SessionError(FailureCause.STATE_TRANSFER_FAILURE, "injected")

    orch.migrations.transfer_fn = always_fail
    out2 = orch.migrations.migrate(session, "zone-a")
    print(f"\ninjected failure: migrated={out2.migrated} "
          f"cause={out2.cause.value} — still committed: {session.committed()}")


if __name__ == "__main__":
    main()
