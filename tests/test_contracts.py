"""ASP / failure-semantics / timer contract tests (paper Section III)."""

import pytest
from hypothesis import given, strategies as st

from repro.core.asp import (ASP, InteractionMode, Modality, MobilityClass,
                            Objectives, QualityTier, default_asp)
from repro.core.failures import (REMEDIATION, FailureCause, SessionError,
                                 Timers)


class TestObjectives:
    def test_valid(self):
        Objectives(100, 300, 500, 0.99, 1000, 10).validate()

    @pytest.mark.parametrize("kw", [
        dict(ttfb_ms=0),                      # no early-response bound
        dict(p95_ms=600),                     # p95 > p99
        dict(p99_ms=1500),                    # p99 > T_max
        dict(rho_min=0.0),                    # not a valid probability
        dict(rho_min=1.5),
        dict(nu_min=-1),
    ])
    def test_invalid(self, kw):
        base = dict(ttfb_ms=100, p95_ms=300, p99_ms=500, rho_min=0.99,
                    t_max_ms=1000, nu_min=10)
        base.update(kw)
        with pytest.raises(ValueError):
            Objectives(**base).validate()

    @given(p95=st.floats(1, 1e4), p99=st.floats(1, 1e4),
           tmax=st.floats(1, 1e4))
    def test_ordering_is_total(self, p95, p99, tmax):
        """validate() accepts exactly the orderings Eq. (3) allows."""
        o = Objectives(min(p95, p99, 1.0), p95, p99, 0.9, tmax, 0.0)
        ok = p95 <= p99 <= tmax and o.ttfb_ms <= p99
        if ok:
            o.validate()
        else:
            with pytest.raises(ValueError):
                o.validate()


class TestASP:
    def test_digest_stable_and_sensitive(self):
        a1 = default_asp()
        a2 = default_asp()
        assert a1.digest() == a2.digest()
        import dataclasses
        a3 = dataclasses.replace(
            a1, objectives=dataclasses.replace(a1.objectives, p99_ms=901.0))
        assert a3.digest() != a1.digest()

    def test_empty_sovereignty_scope_rejected(self):
        import dataclasses
        asp = dataclasses.replace(default_asp(), allowed_regions=())
        with pytest.raises(ValueError):
            asp.validate()

    def test_continuity_classes(self):
        assert not default_asp(mobility=MobilityClass.STATIC).continuity_required()
        assert default_asp(mobility=MobilityClass.VEHICULAR).continuity_required()

    @pytest.mark.parametrize("kw", [
        dict(max_cost_per_1k_tokens=0.0),     # degenerate cost envelope
        dict(max_cost_per_1k_tokens=-1.0),
        dict(max_session_cost=0.0),
        dict(max_session_cost=-5.0),
        dict(fallback_ladder=(("edge-tiny", 0),)),    # no such tier
        dict(fallback_ladder=(("edge-tiny", 4),)),
        dict(fallback_ladder=(("a", 2), ("b", -1))),  # one bad entry taints
    ])
    def test_invalid_envelope_or_ladder_rejected(self, kw):
        import dataclasses
        asp = dataclasses.replace(default_asp(), **kw)
        with pytest.raises(ValueError):
            asp.validate()

    def test_valid_ladder_accepted(self):
        import dataclasses
        asp = dataclasses.replace(
            default_asp(),
            fallback_ladder=(("minitron-8b", 3), ("edge-tiny", 1)))
        asp.validate()


class TestFailureSemantics:
    def test_exact_cause_partition(self):
        """Eq. (12) partition (nine) + the unreliable-transport pair."""
        assert len(FailureCause) == 11
        expected = {"consent violation", "policy denial",
                    "sovereignty violation", "model unavailable",
                    "no feasible binding", "compute scarcity",
                    "QoS scarcity", "state transfer failure",
                    "deadline expiry", "transport failure",
                    "deadline exceeded"}
        assert {c.value for c in FailureCause} == expected

    def test_distinct_remediations(self):
        """Causes must not be conflated: distinct remediation per cause."""
        assert len(set(REMEDIATION.values())) == len(FailureCause)

    def test_every_cause_classified_and_coded(self):
        """Exhaustive: each cause has a remediation, a retryable/terminal
        classification, and a northbound error code."""
        from repro.api import messages as m
        from repro.core.failures import RETRYABLE, is_retryable
        for cause in FailureCause:
            assert cause in REMEDIATION, cause
            assert cause in m.ERROR_CODE_TABLE, cause
            assert is_retryable(cause) == (cause in RETRYABLE)
        # the retryable set is exactly the causes where a fresh attempt at
        # the same request can still succeed
        assert RETRYABLE == {FailureCause.COMPUTE_SCARCITY,
                             FailureCause.QOS_SCARCITY,
                             FailureCause.DEADLINE_EXPIRY,
                             FailureCause.TRANSPORT_FAILURE}
        # DEADLINE_EXCEEDED is terminal (the budget itself ran out) even
        # though DEADLINE_EXPIRY (a phase timer tripped) is retryable
        assert not is_retryable(FailureCause.DEADLINE_EXCEEDED)

    def test_session_error_carries_cause(self):
        e = SessionError(FailureCause.QOS_SCARCITY, "no flows")
        assert e.cause is FailureCause.QOS_SCARCITY


class TestTimers:
    def test_default_ordering_valid(self):
        Timers().validate(t_max_s=2.0)

    @given(td=st.floats(0.001, 10), tp=st.floats(0.001, 10),
           tr=st.floats(0.001, 10), tc=st.floats(0.001, 10),
           tm=st.floats(0.001, 10))
    def test_eq11_ordering(self, td, tp, tr, tc, tm):
        t = Timers(tau_disc=td, tau_page=tp, tau_prep=tr, tau_com=tc,
                   tau_mig=tm, lease_s=30.0)
        ok = td <= tp <= tr <= tc and tm <= min(100.0, 30.0)
        if ok:
            t.validate(t_max_s=100.0)
        else:
            with pytest.raises(ValueError):
                t.validate(t_max_s=100.0)
