"""Boundary telemetry Z(t) (Eq. 13), compliance (Eq. 5/16), policy/charging."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.asp import default_asp
from repro.core.clock import VirtualClock
from repro.core.failures import FailureCause, SessionError
from repro.core.policy import PolicyControl
from repro.core.telemetry import BoundaryTelemetry, RequestRecord


def fill(tele, latencies, *, ttfb=None, completed=None, tokens=10):
    for i, lat in enumerate(latencies):
        tele.record(RequestRecord(
            t_submit=float(i), ttfb_ms=ttfb[i] if ttfb else lat / 4,
            latency_ms=lat,
            completed=completed[i] if completed else True,
            tokens=tokens))


class TestTelemetry:
    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.floats(1.0, 1e4), min_size=5, max_size=400))
    def test_quantiles_match_numpy(self, lats):
        tele = BoundaryTelemetry()
        fill(tele, lats)
        z = tele.snapshot()
        assert z.q95_ms == pytest.approx(np.quantile(lats, 0.95), rel=1e-6)
        assert z.q99_ms == pytest.approx(np.quantile(lats, 0.99), rel=1e-6)
        assert z.rho == 1.0
        assert z.n == len(lats)

    def test_window_slides(self):
        tele = BoundaryTelemetry(window=100)
        fill(tele, [10.0] * 150)
        assert len(tele) == 100

    def test_compliance_eq5(self):
        asp = default_asp()     # p99 ≤ 900, T_max = 2000
        tele = BoundaryTelemetry()
        fill(tele, [100.0] * 99 + [800.0])
        rep = tele.compliance(asp)
        assert rep.p99_ok and rep.in_compliance
        fill(tele, [1500.0] * 30)    # push the tail over ℓ99
        rep = tele.compliance(asp)
        assert not rep.p99_ok and not rep.in_compliance

    def test_violation_rate_eq16(self):
        """Violation ⟺ (L > ℓ99) ∨ (L > T_max) — per request."""
        asp = default_asp()
        tele = BoundaryTelemetry()
        fill(tele, [100.0, 950.0, 2500.0, 100.0],
             completed=[True, True, False, True])
        # 950 > ℓ99=900 violates; 2500 violates (both bounds); 2 of 4
        assert tele.violation_rate(asp) == pytest.approx(0.5)

    def test_incomplete_requests_hit_rho(self):
        asp = default_asp()
        tele = BoundaryTelemetry()
        fill(tele, [100.0] * 10, completed=[True] * 5 + [False] * 5)
        rep = tele.compliance(asp)
        assert rep.z.rho == pytest.approx(0.5)
        assert not rep.rho_ok


class TestPolicy:
    def test_consent_lifecycle(self):
        p = PolicyControl(VirtualClock())
        ref = p.grant_consent("alice", ("eu",))
        assert p.consent_valid(ref)
        p.check_region(ref, "eu")
        with pytest.raises(SessionError) as ei:
            p.check_region(ref, "us")
        assert ei.value.cause is FailureCause.SOVEREIGNTY_VIOLATION
        p.revoke(ref)
        assert not p.consent_valid(ref)
        with pytest.raises(SessionError) as ei:
            p.check_region(ref, "eu")
        assert ei.value.cause is FailureCause.CONSENT_VIOLATION

    def test_charging_attribution(self):
        p = PolicyControl(VirtualClock())
        ref = p.open_charging("ais-42")
        p.meter(ref, tokens=1000, chip_s=1.0, unit_price=0.5)
        p.meter(ref, tokens=500, chip_s=0.4, unit_price=0.5)
        rec = p.charging(ref)
        assert rec.session_id == "ais-42"
        assert rec.tokens == 1500
        assert rec.cost == pytest.approx(0.75)
        assert len(rec.events) == 2

    def test_cost_envelope(self):
        p = PolicyControl(VirtualClock())
        asp = default_asp()
        p.admit_cost(asp, asp.max_cost_per_1k_tokens * 0.5)
        with pytest.raises(SessionError) as ei:
            p.admit_cost(asp, asp.max_cost_per_1k_tokens * 2.0)
        assert ei.value.cause is FailureCause.POLICY_DENIAL
