"""Minimal, deterministic stand-in for the `hypothesis` API surface the
test-suite uses, installed by conftest.py ONLY when the real package is
unavailable (this container has no network access; the `test` extra in
pyproject.toml pulls the real hypothesis wherever pip can reach an index).

Implements: `given`, `settings`, `strategies.{integers,floats,lists,
sampled_from}`. Draws are pseudo-random but seeded from the test's qualified
name, so runs are reproducible. The first two examples of every bounded
numeric strategy pin the interval endpoints, which is where most of the
boundary bugs hypothesis would catch actually live.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types

_DEFAULT_MAX_EXAMPLES = 10


class SearchStrategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rnd: random.Random, index: int):
        return self._draw(rnd, index)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    def draw(rnd, index):
        if index == 0:
            return min_value
        if index == 1:
            return max_value
        return rnd.randint(min_value, max_value)
    return SearchStrategy(draw)


def floats(min_value: float, max_value: float, **_kw) -> SearchStrategy:
    def draw(rnd, index):
        if index == 0:
            return float(min_value)
        if index == 1:
            return float(max_value)
        return rnd.uniform(min_value, max_value)
    return SearchStrategy(draw)


def sampled_from(elements) -> SearchStrategy:
    seq = list(elements)

    def draw(rnd, index):
        return seq[index % len(seq)] if index < len(seq) else rnd.choice(seq)
    return SearchStrategy(draw)


def lists(elements: SearchStrategy, *, min_size: int = 0,
          max_size: int = 10) -> SearchStrategy:
    def draw(rnd, index):
        size = min_size if index == 0 else rnd.randint(min_size, max_size)
        return [elements.example(rnd, 2 + i) for i in range(size)]
    return SearchStrategy(draw)


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def run(*fixed_args):
            n = getattr(run, "_hyp_max_examples", _DEFAULT_MAX_EXAMPLES)
            rnd = random.Random(hash(fn.__qualname__) & 0xFFFFFFFF)
            for i in range(n):
                args = [s.example(rnd, i) for s in arg_strategies]
                kwargs = {k: s.example(rnd, i)
                          for k, s in kw_strategies.items()}
                fn(*fixed_args, *args, **kwargs)
        # pytest plugins (anyio, pytest-asyncio) probe `.hypothesis.inner_test`
        run.hypothesis = types.SimpleNamespace(inner_test=fn)
        # hide strategy-supplied parameters from pytest's fixture resolution:
        # positional strategies fill the rightmost params, kw strategies by name
        params = list(inspect.signature(fn).parameters.values())
        if arg_strategies:
            params = params[:-len(arg_strategies)]
        params = [p for p in params if p.name not in kw_strategies]
        run.__signature__ = inspect.Signature(params)
        return run
    return deco


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._hyp_max_examples = max_examples
        return fn
    return deco


def install() -> None:
    """Register stub modules as `hypothesis` / `hypothesis.strategies`."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "lists", "sampled_from",
                 "SearchStrategy"):
        setattr(strategies, name, globals()[name])
    mod.strategies = strategies
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
