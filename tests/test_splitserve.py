"""Split serving: tier budgets, draft pairings, spec-decode identity,
dual-anchor 2PC atomicity, degrade/recover/collapse, northbound events.

The identity property is the whole point of the subsystem: every token a
split session commits must be EXACTLY the token target-only greedy decode
would have produced — speculative decode buys latency, never quality.
"""

import dataclasses
import types

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.registry import (ARCH_TIERS, DRAFT_PAIRINGS, arch_tier,
                                    draft_compatible, draft_targets,
                                    get_config, get_smoke_config)
from repro.core import Orchestrator, default_asp
from repro.core.asp import ASP, SPLIT_POLICIES, QualityTier
from repro.core.budget import (SLABudget, apply_budget, decompose_budget,
                               decompose_tiers)
from repro.core.catalog import Catalog, default_catalog
from repro.core.clock import VirtualClock
from repro.core.failures import FailureCause, SessionError
from repro.core.sites import ExecutionSite, SiteSpec
from repro.serving.engine import InferenceEngine
from repro.splitserve import (SpecDecoder, SplitManager, propose_split,
                              expected_round_tokens, spec_speedup)

SPEC_ARCHS = ("edge-tiny", "recurrentgemma-2b", "mamba2-1.3b")
PROMPT = (np.arange(1, 13, dtype=np.int32) * 7) % 500


# ======================================================================
# tier-budget helper (shared by east-west federation and split placement)
# ======================================================================
class TestTierBudget:
    def test_zero_transit_passthrough(self):
        asp = default_asp()
        b = decompose_budget(asp, 0.0)
        o = asp.objectives
        assert (b.ttfb_ms, b.p95_ms, b.p99_ms, b.t_max_ms) == \
            (o.ttfb_ms, o.p95_ms, o.p99_ms, o.t_max_ms)

    def test_infeasibility_boundary(self):
        """home transport ≥ the tightest bound ⇒ attributable refusal, not
        a negative budget."""
        asp = default_asp()
        with pytest.raises(SessionError) as ei:
            decompose_budget(asp, asp.objectives.ttfb_ms)
        assert ei.value.cause is FailureCause.NO_FEASIBLE_BINDING
        assert "exhausts" in str(ei.value)
        # one epsilon inside the boundary is feasible
        b = decompose_budget(asp, asp.objectives.ttfb_ms - 0.5)
        assert b.ttfb_ms == pytest.approx(0.5)

    def test_home_cost_share_validated_after_feasibility(self):
        asp = default_asp()
        with pytest.raises(ValueError):
            decompose_budget(asp, 1.0, home_cost_share=1.0)
        # infeasible transport wins over a bad share: the SessionError is
        # the attributable failure the invoker can act on
        with pytest.raises(SessionError):
            decompose_budget(asp, asp.objectives.ttfb_ms + 1,
                             home_cost_share=1.0)

    def test_decompose_tiers_names_offending_tier(self):
        asp = default_asp()
        with pytest.raises(SessionError) as ei:
            decompose_tiers(asp, {"edge": 2.0,
                                  "verify": asp.objectives.ttfb_ms})
        assert "tier 'verify'" in str(ei.value)

    def test_decompose_tiers_share_validation(self):
        asp = default_asp()
        with pytest.raises(ValueError):
            decompose_tiers(asp, {})
        with pytest.raises(ValueError):
            decompose_tiers(asp, {"a": 1.0, "b": 1.0},
                            cost_shares={"a": 0.8, "b": 0.8})

    def test_decompose_tiers_splits_cost_envelope(self):
        asp = default_asp()
        b = decompose_tiers(asp, {"edge": 2.0, "verify": 12.0})
        total = sum(x.max_cost_per_1k for x in b.values())
        assert total == pytest.approx(asp.max_cost_per_1k_tokens)

    def test_eastwest_reexports_canonical_impl(self):
        from repro.federation import eastwest
        assert eastwest.decompose_budget is decompose_budget
        assert eastwest.SLABudget is SLABudget
        assert eastwest.apply_budget is apply_budget

    def test_budget_wire_roundtrip(self):
        b = decompose_budget(default_asp(), 7.0)
        assert SLABudget.from_wire(b.to_wire()) == b

    def test_apply_budget_rewrites_objectives(self):
        asp = default_asp()
        b = decompose_budget(asp, 10.0)
        tight = apply_budget(asp, b)
        assert tight.objectives.p99_ms == asp.objectives.p99_ms - 10.0
        assert tight.max_cost_per_1k_tokens == b.max_cost_per_1k


# ======================================================================
# registry: draft pairings + tier metadata
# ======================================================================
class TestDraftPairings:
    def test_every_pairing_shares_vocab(self):
        """The coverage guarantee: a declared pairing can NEVER be
        rejected mid-stream for token-space mismatch — identical vocab is
        checked here against the full configs."""
        for draft, targets in DRAFT_PAIRINGS.items():
            dcfg = get_config(draft)
            for target in targets:
                assert draft_compatible(dcfg, get_config(target)), \
                    f"{draft} -> {target}"

    def test_pairing_drafts_are_edge_tier(self):
        for draft in DRAFT_PAIRINGS:
            assert arch_tier(draft) == "edge"

    def test_tiers_cover_all_archs(self):
        from repro.configs.registry import ARCH_IDS
        assert set(ARCH_TIERS) == set(ARCH_IDS)
        assert set(ARCH_TIERS.values()) == {"edge", "region", "central"}
        assert arch_tier("no-such-model") == "central"

    def test_vocab_mismatch_detected(self):
        assert not draft_compatible(get_config("edge-tiny"),
                                    get_config("minitron-8b"))
        assert draft_targets("edge-tiny") == ()

    def test_spec_decoder_rejects_mismatch_before_streaming(self):
        a = types.SimpleNamespace(cfg=get_config("edge-tiny"))
        b = types.SimpleNamespace(cfg=get_config("minitron-8b"))
        with pytest.raises(ValueError, match="pairing rejected"):
            SpecDecoder(a, b)


# ======================================================================
# spec-decode identity (the tentpole's acceptance bar)
# ======================================================================
def _target_only(cfg, seed, prompt, n):
    eng = InferenceEngine(cfg, slots=2, max_len=128, seed=seed)
    pre = eng.prefill_session("s", prompt)
    toks = [pre["first_token"]]
    while len(toks) < n:
        toks.append(eng.decode_round()["s"])
    return toks[:n]


def _decoder(verify_arch, draft_arch, *, seed_v=0, seed_d=7, gamma=4,
             paged=False):
    ver = InferenceEngine(get_smoke_config(verify_arch), slots=2,
                          max_len=128, seed=seed_v, paged=paged)
    dra = InferenceEngine(get_smoke_config(draft_arch), slots=2,
                          max_len=128, seed=seed_d)
    return SpecDecoder(dra, ver, gamma=gamma, session_id="s")


class TestSpecIdentity:
    @settings(max_examples=6, deadline=None)
    @given(st.sampled_from(SPEC_ARCHS), st.sampled_from((1, 2, 4)))
    def test_bitwise_identity_with_target_only(self, arch, gamma):
        """Dense/hybrid/ssm verify, γ ∈ {1,2,4}, a genuinely disagreeing
        draft (different arch/seed): the committed stream is bitwise the
        target-only greedy stream."""
        base = _target_only(get_smoke_config(arch), 0, PROMPT, 20)
        dec = _decoder(arch, "edge-tiny", gamma=gamma)
        dec.start(PROMPT)
        dec.decode(19)
        assert dec.tokens[:20] == base

    def test_twin_draft_accepts_full_window(self):
        """A draft identical to the target accepts γ per round — the
        accept rule's upper bound is reachable, not just safe."""
        arch = "edge-tiny"
        base = _target_only(get_smoke_config(arch), 0, PROMPT, 20)
        dec = _decoder(arch, arch, seed_v=0, seed_d=0, gamma=4)
        dec.start(PROMPT)
        dec.decode(19)
        assert dec.tokens[:20] == base
        assert dec.stats.acceptance == 1.0
        assert dec.stats.tokens_per_round == pytest.approx(5.0)

    def test_identity_through_verify_migration(self):
        """Mid-stream make-before-break re-anchor of the verify tier:
        export/import the slot into a fresh engine, keep decoding — still
        bitwise identical."""
        arch = "recurrentgemma-2b"
        base = _target_only(get_smoke_config(arch), 0, PROMPT, 24)
        dec = _decoder(arch, "edge-tiny", gamma=2)
        dec.start(PROMPT)
        dec.decode(9)
        fresh = InferenceEngine(get_smoke_config(arch), slots=2,
                                max_len=128, seed=0)
        dec.migrate_verify(fresh)
        dec.decode(24 - len(dec.tokens))
        assert dec.tokens[:24] == base

    def test_identity_with_oracle_proposals(self):
        """External proposals (the bench's acceptance-sweep arm): feeding
        the known greedy continuation with corruptions still commits the
        exact target stream."""
        arch = "mamba2-1.3b"
        base = _target_only(get_smoke_config(arch), 0, PROMPT, 20)
        rng = np.random.default_rng(3)
        corrupted = [t if rng.random() < 0.6 else (t + 1) % 512
                     for t in base[1:]]
        dec = _decoder(arch, "edge-tiny", gamma=4)
        first = dec.start(PROMPT)
        assert first == base[0]
        dec.decode(19, proposals=corrupted)
        assert dec.tokens[:20] == base
        assert 0.0 < dec.stats.acceptance < 1.0

    def test_degraded_mode_and_reattach(self):
        """Airplane mode: losing the verifier keeps the stream alive at
        draft quality; re-attaching a verifier makes every SUBSEQUENT
        token target-greedy given the mixed prefix."""
        dec = _decoder("edge-tiny", "mamba2-1.3b", seed_d=5, gamma=2)
        dec.start(PROMPT)
        dec.decode(4)
        dec.degrade()
        assert dec.degraded
        dec.decode(4)                      # edge-only rounds still stream
        assert dec.stats.degraded_rounds > 0
        n_before = len(dec.tokens)
        fresh = InferenceEngine(get_smoke_config("edge-tiny"), slots=2,
                                max_len=128, seed=0)
        dec.reattach_verify(fresh)
        assert not dec.degraded
        dec.decode(6)
        # oracle: target-only continuation of the full committed prefix
        oracle = InferenceEngine(get_smoke_config("edge-tiny"), slots=2,
                                 max_len=128, seed=0)
        stream = np.concatenate(
            [PROMPT, np.asarray(dec.tokens[:n_before - 1], np.int32)])
        oracle.prefill_session("s", stream)
        oracle.override_last_token("s", dec.tokens[n_before - 1])
        want = []
        while len(want) < len(dec.tokens) - n_before:
            want.append(oracle.decode_round()["s"])
        assert dec.tokens[n_before:] == want

    def test_predictor_formulas(self):
        assert expected_round_tokens(0.0, 4) == pytest.approx(1.0)
        assert expected_round_tokens(1.0, 4) == pytest.approx(5.0)
        assert expected_round_tokens(0.5, 1) == pytest.approx(1.5)
        # network-dominated regime: backhaul RTT ≫ access RTT makes the
        # split win grow with acceptance
        lo = spec_speedup(0.3, 4, rtt_verify_ms=55.0, rtt_edge_ms=2.0)
        hi = spec_speedup(0.9, 4, rtt_verify_ms=55.0, rtt_edge_ms=2.0)
        assert hi > lo > 0.5
        assert hi > 2.0


# ======================================================================
# control plane: dual-anchor 2PC, degrade/recover/collapse, events
# ======================================================================
def _mk_site(clock, sid, kind, rtt, slots, hosted):
    v5e_flops, v5e_bw, hbm = 197e12, 819e9, 16e9
    return ExecutionSite(SiteSpec(
        sid, kind, "eu", chips=16, hbm_bytes_total=16 * hbm,
        peak_flops=16 * v5e_flops, hbm_bw=16 * v5e_bw, decode_slots=slots,
        rtt_ms=dict(rtt), hosted_models=hosted,
        price_per_chip_s=2.0e-4), clock)


def _split_orch(*, with_edge=True):
    clock = VirtualClock()
    full = default_catalog()
    cat = Catalog()
    cat.register(full.get("recurrentgemma-2b"))
    cat.register(full.get("minitron-8b"))
    sites = {
        "regional-1": _mk_site(clock, "regional-1", "regional",
                               {"zone-a": 12.0}, 64, ("minitron-8b@1.0",)),
        "regional-2": _mk_site(clock, "regional-2", "regional",
                               {"zone-a": 30.0}, 64, ("minitron-8b@1.0",)),
    }
    if with_edge:
        sites["edge-a"] = _mk_site(
            clock, "edge-a", "edge", {"zone-a": 2.0}, 32,
            ("recurrentgemma-2b@1.0",))
        # the regional tier also hosts the edge-class model so an
        # auto-policy fallback single anchor is resolvable
        sites["regional-1"].spec.hosted_models += ("recurrentgemma-2b@1.0",)
    orch = Orchestrator(clock=clock, catalog=cat, sites=sites)
    mgr = SplitManager(orch)
    events = []
    orch.split_event_sinks.append(
        lambda sid, ev, d: events.append((sid, ev, d)))
    return orch, mgr, events, clock


def _split_asp(policy="require"):
    return dataclasses.replace(
        default_asp(tier=QualityTier.STANDARD), split_policy=policy,
        max_cost_per_1k_tokens=4.0)


class TestSplitControl:
    def test_establish_dual_anchor(self):
        orch, mgr, events, _ = _split_orch()
        s = orch.establish(_split_asp(), invoker="u", zone="zone-a")
        st = mgr.states[s.session_id]
        # data plane = edge draft anchor; verify half held separately
        assert s.binding.site_id == "edge-a"
        assert s.binding.model_id == "recurrentgemma-2b"
        assert st.verify_binding.site_id == "regional-1"
        assert st.verify_binding.model_id == "minitron-8b"
        # both legs carry a decomposed (strictly tighter) budget
        assert st.placement.draft_budget.p99_ms < \
            s.asp.objectives.p99_ms
        assert st.placement.verify_budget.p99_ms < \
            s.asp.objectives.p99_ms
        assert [e[1] for e in events] == ["split-established"]
        # one slot held on each anchor
        assert orch.sites["edge-a"].slots_in_use() == 1
        assert orch.sites["regional-1"].slots_in_use() == 1

    def test_auto_policy_falls_back_without_edge_tier(self):
        orch, mgr, events, _ = _split_orch(with_edge=False)
        s = orch.establish(_split_asp("auto"), invoker="u", zone="zone-a")
        assert s.committed() and not mgr.is_split(s.session_id)
        assert events == []

    def test_require_policy_propagates_refusal(self):
        orch, _, _, _ = _split_orch(with_edge=False)
        with pytest.raises(SessionError) as ei:
            orch.establish(_split_asp("require"), invoker="u",
                           zone="zone-a")
        assert "edge-tier" in str(ei.value)

    def test_never_policy_ignores_split_manager(self):
        orch, mgr, _, _ = _split_orch()
        asp = dataclasses.replace(_split_asp(), split_policy="never")
        s = orch.establish(asp, invoker="u", zone="zone-a")
        assert s.committed() and not mgr.is_split(s.session_id)

    def test_2pc_atomicity_on_verify_prepare_failure(self):
        """PREPARE(verify) failing must roll back the already-prepared
        edge half — no half-split leaks a lease."""
        orch, mgr, _, _ = _split_orch()
        real = orch.coordinator.prepare

        def boom(model, site_id, *a, **kw):
            if site_id.startswith("regional"):
                raise SessionError(FailureCause.COMPUTE_SCARCITY,
                                   "injected: verify PREPARE refused")
            return real(model, site_id, *a, **kw)

        orch.coordinator.prepare = boom
        with pytest.raises(SessionError):
            orch.establish(_split_asp(), invoker="u", zone="zone-a")
        assert all(site.slots_in_use() == 0
                   for site in orch.sites.values())
        assert mgr.states == {}

    def test_2pc_atomicity_on_verify_commit_failure(self):
        orch, mgr, _, _ = _split_orch()
        real = orch.coordinator.commit

        def boom(prepared, model):
            if prepared.site_id.startswith("regional"):
                raise SessionError(FailureCause.COMPUTE_SCARCITY,
                                   "injected: verify COMMIT refused")
            return real(prepared, model)

        orch.coordinator.commit = boom
        with pytest.raises(SessionError):
            orch.establish(_split_asp(), invoker="u", zone="zone-a")
        assert all(site.slots_in_use() == 0
                   for site in orch.sites.values())
        assert mgr.states == {}

    def test_prepare_time_vocab_rejection(self):
        """A hand-forged placement pairing mismatched vocabs is refused
        at PREPARE with zero leases taken."""
        orch, mgr, _, _ = _split_orch()
        orch.catalog.register(default_catalog().get("edge-tiny"))
        asp = _split_asp()
        s = orch.begin_session(asp, "u", "zone-a")
        placement = propose_split(asp, orch.catalog, orch.sites,
                                  orch.predictors, "zone-a")
        bad = dataclasses.replace(
            placement,
            draft=dataclasses.replace(
                placement.draft,
                model=orch.catalog.get("edge-tiny")))
        with pytest.raises(SessionError, match="vocab"):
            mgr.establish_split(s, bad)
        assert all(site.slots_in_use() == 0
                   for site in orch.sites.values())

    def test_heartbeat_renews_verify_and_lapse_degrades(self):
        orch, mgr, events, clock = _split_orch()
        s = orch.establish(_split_asp(), invoker="u", zone="zone-a")
        st = mgr.states[s.session_id]
        clock.advance(orch.timers.lease_s * 0.9)
        orch.heartbeat(s)
        assert not st.degraded          # renewed through the beat
        # void the verify compute lease out-of-band: next beat degrades
        orch.sites[st.verify_binding.site_id].release(
            st.verify_binding.compute_lease_id)
        orch.heartbeat(s)
        assert st.degraded and st.verify_binding is None
        assert [e[1] for e in events][-1] == "split-degraded"
        assert s.committed()            # never a failure

    def test_low_acceptance_collapses_to_verify_anchor(self):
        orch, mgr, events, _ = _split_orch()
        s = orch.establish(_split_asp(), invoker="u", zone="zone-a")
        st = mgr.states[s.session_id]
        verify_site = st.verify_binding.site_id
        for _ in range(12):
            mgr.note_round(s.session_id, 4, 0)
        orch.heartbeat(s)
        orch.heartbeat(s)
        assert not mgr.is_split(s.session_id)
        assert s.committed() and s.binding.site_id == verify_site
        assert [e[1] for e in events][-1] == "split-collapsed"
        # MBB: the edge half released on collapse
        assert orch.sites["edge-a"].slots_in_use() == 0

    def test_verify_migration_is_make_before_break(self):
        orch, mgr, events, _ = _split_orch()
        s = orch.establish(_split_asp(), invoker="u", zone="zone-a")
        st = mgr.states[s.session_id]
        old = st.verify_binding.site_id
        new = mgr.migrate_verify(s)
        assert new != old
        assert st.verify_binding.site_id == new
        assert s.binding.site_id == "edge-a"      # edge never moved
        assert orch.sites[old].slots_in_use() == 0
        assert orch.sites[new].slots_in_use() == 1
        assert [e[1] for e in events][-1] == "verify-migrated"

    def test_recover_excludes_nothing_but_dead_sites(self):
        orch, mgr, events, _ = _split_orch()
        s = orch.establish(_split_asp(), invoker="u", zone="zone-a")
        st = mgr.states[s.session_id]
        dead = st.verify_binding.site_id
        orch.sites[dead].mark_dead("test")
        mgr.degrade(s, reason="test")
        mgr.recover(s)
        assert not st.degraded
        assert st.verify_binding.site_id != dead
        assert [e[1] for e in events][-1] == "split-recovered"

    def test_release_frees_both_anchors(self):
        orch, mgr, _, _ = _split_orch()
        s = orch.establish(_split_asp(), invoker="u", zone="zone-a")
        orch.release(s)
        assert mgr.states == {}
        assert all(site.slots_in_use() == 0
                   for site in orch.sites.values())

    def test_gateway_surfaces_tier_change_events(self):
        from repro.api.gateway import NorthboundGateway
        orch, mgr, _, _ = _split_orch()
        gw = NorthboundGateway(orch)
        gw.subscribe("u")
        s = orch.establish(_split_asp(), invoker="u", zone="zone-a")
        mgr.degrade(s, reason="test-degrade")
        evs = [e for e in gw.poll_events("u") if e.event == "tier-change"]
        kinds = [e.detail.get("event") for e in evs]
        assert "split-established" in kinds
        assert "split-degraded" in kinds
        deg = evs[kinds.index("split-degraded")]
        assert deg.detail["mode"] == "edge-only"
        assert deg.session_id == s.session_id


# ======================================================================
# ASP schema 1.2: split_policy on the wire
# ======================================================================
class TestASPSplitPolicy:
    def test_wire_roundtrip(self):
        asp = dataclasses.replace(default_asp(), split_policy="auto")
        back = ASP.from_wire(asp.to_wire())
        assert back.split_policy == "auto"

    def test_pre_12_peers_default_to_never(self):
        w = default_asp().to_wire()
        del w["split_policy"]
        assert ASP.from_wire(w).split_policy == "never"

    def test_validate_rejects_unknown_policy(self):
        asp = dataclasses.replace(default_asp(), split_policy="sometimes")
        with pytest.raises(ValueError, match="split_policy"):
            asp.validate()
        assert "sometimes" not in SPLIT_POLICIES

    def test_digest_binds_split_policy(self):
        a = default_asp()
        b = dataclasses.replace(a, split_policy="auto")
        assert a.digest() != b.digest()
