"""Multi-tenant adapter fleet: per-session LoRA multiplexing over a
shared base model.

Correctness bar mirrors the serving hot path's: batched adapter decode
(one fused chunk, slots bound to different adapters) must be
TOKEN-IDENTICAL to applying each adapter individually, on both the
gather (XLA) and grouped (Pallas moe_gemm) routes; and the adapter
binding is part of the session contract — it must survive migration and
hibernate/resume with matching fingerprints, and a target that cannot
realise it must refuse the transfer."""

import dataclasses

import numpy as np
import pytest

from repro.adapters import (AdapterCatalog, AdapterRuntime, AdapterSpec,
                            init_adapter_weights, version_key,
                            weight_fingerprint)
from repro.adapters.runtime import lora_delta
from repro.api import NorthboundGateway
from repro.api import messages as m
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core.asp import (ASP, InteractionMode, Modality, MobilityClass,
                            Objectives, QualityTier, default_asp)
from repro.core.catalog import (MODALITY_FAMILIES, Catalog, ModelEntry,
                                default_catalog)
from repro.core.clock import VirtualClock
from repro.core.failures import FailureCause, SessionError
from repro.serving import state_transfer
from repro.serving.engine import InferenceEngine
from repro.serving.state_transfer import AdmissionDenied

CFG = get_config("edge-tiny")


def spec_for(adapter_id, *, version="1.0", base="edge-tiny", rank=4,
             seed=0, regions=("eu", "us", "apac")):
    return AdapterSpec(adapter_id=adapter_id, version=version,
                       base_model_id=base, base_model_version="1.0",
                       rank=rank, regions=tuple(regions), seed=seed)


def weights_for(adapter_id, d_model, **kw):
    return init_adapter_weights(spec_for(adapter_id, **kw), d_model)


# ----------------------------------------------------------------------
# control plane: catalog + versioning
# ----------------------------------------------------------------------
class TestAdapterCatalog:
    def test_get_picks_highest_numeric_version(self):
        cat = AdapterCatalog()
        for v in ("9.0", "10.0", "2.1"):
            cat.register(spec_for("acme", version=v), d_model=32)
        assert cat.get("acme").version == "10.0"
        assert cat.get("acme", "9.0").version == "9.0"

    def test_model_catalog_get_is_numeric_aware_too(self):
        """Catalog.get used lexicographic max, so "9.0" outranked
        "10.0" — the same version_key now orders both catalogs."""
        cat = Catalog()
        for v in ("9.0", "10.0"):
            cat.register(ModelEntry(model_id="edge-tiny", version=v,
                                    cfg=CFG, tier=QualityTier.BASIC,
                                    modalities=(Modality.TEXT_GEN,)))
        assert cat.get("edge-tiny").version == "10.0"
        assert version_key("10.0") > version_key("9.0")
        assert version_key("1.0rc1") > version_key("1.0")  # non-numeric tail

    def test_duplicate_and_unknown_base_refused(self):
        cat = Catalog()
        cat.register(ModelEntry(model_id="edge-tiny", version="1.0",
                                cfg=CFG, tier=QualityTier.BASIC,
                                modalities=(Modality.TEXT_GEN,)))
        cat.register_adapter(spec_for("acme"))
        with pytest.raises(ValueError, match="duplicate"):
            cat.register_adapter(spec_for("acme"))
        with pytest.raises(ValueError, match="unregistered base"):
            cat.register_adapter(spec_for("ghost", base="no-such-model"))

    def test_deterministic_weights_and_fingerprint(self):
        """Same spec materialises bit-identical weights in independent
        catalogs (fingerprints must agree across domains); a different
        seed yields different weights."""
        a1, b1 = weights_for("acme", CFG.d_model)
        a2, b2 = weights_for("acme", CFG.d_model)
        np.testing.assert_array_equal(a1, a2)
        np.testing.assert_array_equal(b1, b2)
        assert weight_fingerprint(a1, b1) == weight_fingerprint(a2, b2)
        a3, b3 = weights_for("acme", CFG.d_model, seed=1)
        assert weight_fingerprint(a3, b3) != weight_fingerprint(a1, b1)

    def test_register_stamps_fingerprint_and_tracks_sites(self):
        cat = AdapterCatalog()
        stored = cat.register(spec_for("acme"), d_model=CFG.d_model)
        assert stored.weight_fingerprint
        a, b = cat.weights("acme")
        assert stored.weight_fingerprint == weight_fingerprint(a, b)
        cat.mark_loaded("acme", "edge-a")
        cat.mark_loaded("acme", "edge-b")
        cat.mark_unloaded("acme", "edge-a")
        assert cat.loaded_sites("acme") == ("edge-b",)


# ----------------------------------------------------------------------
# data plane: runtime tables + delta routes
# ----------------------------------------------------------------------
class TestAdapterRuntime:
    def test_table_full_idempotent_load_and_unload(self):
        rt = AdapterRuntime(32, max_adapters=2, rank=4)
        a, b = weights_for("x", 32)
        idx = rt.load("x", a, b)
        assert rt.load("x", a, b) == idx            # idempotent
        rt.load("y", *weights_for("y", 32, seed=1))
        with pytest.raises(RuntimeError, match="table full"):
            rt.load("z", *weights_for("z", 32, seed=2))
        rt.unload("y")
        assert not rt.is_loaded("y")
        rt.load("z", *weights_for("z", 32, seed=2))  # slot reused
        assert rt.loaded() == ("x", "z")
        assert rt.index_of("") == 0
        with pytest.raises(KeyError):
            rt.index_of("y")

    def test_smaller_rank_zero_pads_without_numeric_change(self):
        """A rank-2 adapter in a rank-8 table: the extra A columns meet
        zero B rows, so the padded delta equals the unpadded one."""
        d = 32
        rt = AdapterRuntime(d, max_adapters=2, rank=8)
        a, b = weights_for("lo", d, rank=2)
        idx = rt.load("lo", a, b)
        h = np.random.default_rng(3).standard_normal((5, d)).astype(np.float32)
        want = (h @ a) @ b
        got = lora_delta(h, rt.A, rt.B, np.full(5, idx, np.int32))
        np.testing.assert_allclose(np.asarray(got), want,
                                   atol=5e-5, rtol=1e-4)

    def test_null_row_gives_exact_zero_delta(self):
        rt = AdapterRuntime(32, max_adapters=2, rank=4)
        rt.load("x", *weights_for("x", 32))
        h = np.ones((4, 32), np.float32)
        delta = lora_delta(h, rt.A, rt.B, np.zeros(4, np.int32))
        assert float(np.abs(np.asarray(delta)).max()) == 0.0

    @pytest.mark.parametrize("idx_mix", [
        [0, 0, 0, 0], [1, 1, 1, 1], [2, 0, 1, 2], [0, 2, 0, 1],
    ])
    def test_gather_and_grouped_routes_agree(self, idx_mix):
        """The Pallas grouped-GEMM route (slots grouped by adapter =
        tokens grouped by expert) matches the gather oracle on every
        batch composition, including all-base and empty groups."""
        d = 64
        rt = AdapterRuntime(d, max_adapters=3, rank=4)
        rt.load("x", *weights_for("x", d))
        rt.load("y", *weights_for("y", d, seed=1))
        h = np.random.default_rng(5).standard_normal((4, d)).astype(np.float32)
        idx = np.asarray(idx_mix, np.int32)
        g1 = lora_delta(h, rt.A, rt.B, idx, route="gather")
        g2 = lora_delta(h, rt.A, rt.B, idx, route="grouped")
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   atol=5e-5, rtol=1e-4)

    def test_unknown_route_refused(self):
        with pytest.raises(ValueError, match="unknown adapter route"):
            AdapterRuntime(32, route="banana")


# ----------------------------------------------------------------------
# engine: batched multiplexed decode == individual application
# ----------------------------------------------------------------------
def _adapter_engine(cfg, *, params=None, slots=4, route="gather",
                    adapters=("acme", "globex"), **kw):
    rt = AdapterRuntime(cfg.d_model, max_adapters=4, rank=4, route=route)
    eng = InferenceEngine(cfg, params=params, slots=slots, max_len=64,
                          adapters=rt, **kw)
    for aid in adapters:
        # weights are a function of the adapter id alone — engines that
        # load different subsets still agree per id
        eng.load_adapter(aid, *weights_for(aid, cfg.d_model))
    return eng


ADAPTER_ARCHS = ["edge-tiny", "qwen3-moe-30b-a3b"]   # dense + moe


def _cfg(arch):
    return CFG if arch == "edge-tiny" else get_smoke_config(arch)


class TestEngineAdapterDecode:
    @pytest.mark.parametrize("arch", ADAPTER_ARCHS)
    def test_mixed_batch_identical_to_individual(self, arch):
        """One fused chunk over {base, acme, globex} slots emits, for
        every session, the same tokens as an engine serving only that
        session with only its adapter — the tentpole acceptance bar."""
        cfg = _cfg(arch)
        mux = _adapter_engine(cfg)
        prompts = {"s-base": ("", np.arange(9, dtype=np.int32) * 5),
                   "s-acme": ("acme", np.arange(7, dtype=np.int32) * 3),
                   "s-glob": ("globex", np.arange(11, dtype=np.int32) * 2)}
        for sid, (aid, p) in prompts.items():
            mux.prefill_session(sid, p % cfg.vocab_size, adapter_id=aid)
        together = {}
        for k in (4, 3):                        # uneven chunking
            for sid, toks in mux.decode_round(steps=k).items():
                together.setdefault(sid, []).extend(toks)

        for sid, (aid, p) in prompts.items():
            solo = _adapter_engine(cfg, params=mux.params, slots=2,
                                   adapters=(aid,) if aid else ())
            solo.prefill_session(sid, p % cfg.vocab_size, adapter_id=aid)
            alone = []
            for k in (4, 3):
                alone.extend(solo.decode_round(steps=k)[sid])
            assert alone == together[sid], sid

    def test_base_sessions_bit_identical_to_adapter_free_engine(self):
        """Row 0 of the tables is all-zero: an engine with an adapter
        runtime (and other tenants' adapters loaded) serves base
        sessions exactly as an engine with no runtime at all."""
        plain = InferenceEngine(CFG, slots=2, max_len=64)
        mux = _adapter_engine(CFG, params=plain.params, slots=2)
        prompt = (np.arange(8, dtype=np.int32) * 7) % CFG.vocab_size
        plain.prefill_session("s", prompt)
        mux.prefill_session("s", prompt)
        assert plain.decode_round(steps=6)["s"] == \
            mux.decode_round(steps=6)["s"]

    def test_grouped_route_matches_gather_route_tokens(self):
        """Engine-level route identity: the Pallas grouped-GEMM decode
        emits the same tokens as the XLA gather fallback."""
        ga = _adapter_engine(CFG, route="gather")
        gr = _adapter_engine(CFG, params=ga.params, route="grouped")
        for eng in (ga, gr):
            eng.prefill_session("a", np.arange(6, dtype=np.int32),
                                adapter_id="acme")
            eng.prefill_session("b", np.arange(9, dtype=np.int32),
                                adapter_id="globex")
            eng.prefill_session("c", np.arange(4, dtype=np.int32))
        assert ga.decode_round(steps=4) == gr.decode_round(steps=4)

    def test_prefill_refuses_unloaded_adapter(self):
        eng = _adapter_engine(CFG, adapters=("acme",))
        with pytest.raises(ValueError, match="not loaded"):
            eng.prefill_session("s", np.arange(4, dtype=np.int32),
                                adapter_id="ghost")
        plain = InferenceEngine(CFG, params=eng.params, slots=2, max_len=64)
        with pytest.raises(ValueError, match="no adapter runtime"):
            plain.prefill_session("s", np.arange(4, dtype=np.int32),
                                  adapter_id="acme")

    def test_unload_refused_while_bound(self):
        eng = _adapter_engine(CFG, adapters=("acme",))
        eng.prefill_session("s", np.arange(4, dtype=np.int32),
                            adapter_id="acme")
        with pytest.raises(RuntimeError, match="still bound"):
            eng.unload_adapter("acme")
        eng.release_slot("s")
        eng.unload_adapter("acme")
        assert not eng.adapters.is_loaded("acme")


# ----------------------------------------------------------------------
# session contract: migration + hibernation carry the binding
# ----------------------------------------------------------------------
class TestAdapterSessionContract:
    def test_migration_preserves_binding_and_stream(self):
        """export→transfer→import between engines: fingerprints match
        (asserted inside transfer), the binding survives, and the
        stream continues token-identical to an unmigrated reference."""
        ref = _adapter_engine(CFG)
        prompt = (np.arange(10, dtype=np.int32) * 3) % CFG.vocab_size
        ref.prefill_session("m", prompt, adapter_id="acme")
        expect = []
        for k in (5, 6):
            expect.extend(ref.decode_round(steps=k)["m"])

        src = _adapter_engine(CFG, params=ref.params)
        dst = _adapter_engine(CFG, params=ref.params)
        src.prefill_session("m", prompt, adapter_id="acme")
        got = list(src.decode_round(steps=5)["m"])
        state_transfer.transfer(src, dst, "m")      # fingerprint-verified
        src.release_slot("m")
        assert dst.export_slot("m")["adapter_id"] == "acme"
        got.extend(dst.decode_round(steps=6)["m"])
        assert got == expect

    def test_import_refused_when_target_lacks_adapter(self):
        """An adapter binding the target cannot realise refuses the
        transfer instead of silently continuing on the base model."""
        src = _adapter_engine(CFG)
        src.prefill_session("m", np.arange(6, dtype=np.int32),
                            adapter_id="acme")
        payload = src.export_slot("m")
        bare = InferenceEngine(CFG, params=src.params, slots=2, max_len=64)
        with pytest.raises(AdmissionDenied, match="acme"):
            bare.import_slot("m", payload)
        wrong = _adapter_engine(CFG, params=src.params, adapters=("globex",))
        with pytest.raises(AdmissionDenied, match="acme"):
            wrong.import_slot("m", payload)

    def test_hibernate_resume_preserves_binding_and_fingerprint(self):
        ref = _adapter_engine(CFG)
        prompt = (np.arange(8, dtype=np.int32) * 5) % CFG.vocab_size
        ref.prefill_session("h", prompt, adapter_id="acme")
        expect = []
        for k in (4, 7):
            expect.extend(ref.decode_round(steps=k)["h"])

        eng = _adapter_engine(CFG, params=ref.params, hibernation=True)
        eng.prefill_session("h", prompt, adapter_id="acme")
        got = list(eng.decode_round(steps=4)["h"])
        fp = state_transfer.fingerprint(eng.export_slot("h"))
        assert eng.hibernate_slot("h")
        assert eng.has_hibernated("h")
        eng.resume_session("h")
        assert state_transfer.fingerprint(eng.export_slot("h")) == fp
        assert eng.export_slot("h")["adapter_id"] == "acme"
        got.extend(eng.decode_round(steps=7)["h"])
        assert got == expect

    def test_fingerprint_binds_adapter_id_and_stays_back_compat(self):
        eng = _adapter_engine(CFG)
        prompt = np.arange(6, dtype=np.int32)
        eng.prefill_session("a", prompt, adapter_id="acme")
        eng.prefill_session("b", prompt)
        pa, pb = eng.export_slot("a"), eng.export_slot("b")
        # same logical content except the binding ⇒ different identity
        stripped = dict(pa, adapter_id="")
        assert state_transfer.fingerprint(pa) != \
            state_transfer.fingerprint(stripped)
        # pre-adapter payloads (no key at all) fingerprint as empty
        legacy = {k: v for k, v in pb.items() if k != "adapter_id"}
        assert state_transfer.fingerprint(pb) == \
            state_transfer.fingerprint(legacy)


# ----------------------------------------------------------------------
# control plane: ASP binding, discovery, PREPARE fail-fast
# ----------------------------------------------------------------------
def asp_with_adapter(adapter_id, ladder=()):
    # BASIC tier: the demo base model edge-tiny must itself be admissible
    return dataclasses.replace(default_asp(tier=QualityTier.BASIC),
                               adapter_id=adapter_id,
                               fallback_ladder=tuple(ladder))


class TestAspAdapterBinding:
    def test_wire_round_trip_and_default(self):
        asp = asp_with_adapter("acme")
        again = ASP.from_wire(asp.to_wire())
        assert again == asp and again.adapter_id == "acme"
        wire = default_asp().to_wire()
        wire.pop("adapter_id")
        assert ASP.from_wire(wire).adapter_id == ""   # pre-1.1 peers

    def test_digest_binds_adapter_identity(self):
        base, bound = default_asp(), asp_with_adapter("acme")
        assert base.digest() != bound.digest()
        assert bound.digest() == asp_with_adapter("acme").digest()

    def test_discovery_excludes_by_adapter_constraints(self):
        from repro.core.analytics import Analytics
        from repro.core.discovery import admissible_set, discover
        from repro.core.predictors import Predictors
        from repro.core.sites import default_sites
        clock = VirtualClock()
        cat = default_catalog()
        sites = default_sites(clock, cat.keys())
        pred = Predictors(Analytics(clock))

        def reasons(asp):
            cands = discover(asp, cat, sites, pred, "zone-a")
            return ({c.exclusion_reason for c in cands
                     if not c.admissible and c.exclusion_reason},
                    [c for c in cands if c.admissible])

        excl, adm = reasons(asp_with_adapter("ghost"))
        assert "adapter-unknown" in excl and not adm
        with pytest.raises(SessionError) as ei:
            admissible_set(discover(asp_with_adapter("ghost"), cat, sites,
                                    pred, "zone-a"))
        assert ei.value.cause is FailureCause.NO_FEASIBLE_BINDING

        # us-only adapter on an eu-licensed base: edge/regional sites
        # (eu) are excluded by the ADAPTER's sovereignty tags
        cat.register_adapter(spec_for("us-only", regions=("us",)))
        excl, adm = reasons(asp_with_adapter("us-only"))
        assert "adapter-region" in excl
        assert {c.site_id for c in adm
                if c.model.model_id == "edge-tiny"} <= {"central-1"}

        # non-base models only admit as declared fallback-ladder rungs
        cat.register_adapter(spec_for("acme", seed=3))
        excl, adm = reasons(asp_with_adapter("acme"))
        assert "adapter-base-mismatch" in excl
        assert {c.model.model_id for c in adm} == {"edge-tiny"}
        _, adm = reasons(asp_with_adapter("acme",
                                          ladder=(("mamba2-1.3b", 1),)))
        assert "mamba2-1.3b" in {c.model.model_id for c in adm}

    def test_prepare_fails_fast_on_unknown_adapter(self):
        """Satellite: an unknown adapter_id surfaces at PREPARE as
        NO_FEASIBLE_BINDING, never as an opaque serve failure."""
        from repro.core.orchestrator import Orchestrator
        orch = Orchestrator(clock=VirtualClock())
        s = orch.begin_session(default_asp(), "u", "zone-a")
        chosen = orch.page_for(s, orch.discover_for(s))
        s.asp = dataclasses.replace(s.asp, adapter_id="ghost")
        with pytest.raises(SessionError) as ei:
            orch.prepare_for(s, chosen)
        assert ei.value.cause is FailureCause.NO_FEASIBLE_BINDING
        assert "ghost" in str(ei.value)

    def test_prepare_refuses_base_mismatch_outside_ladder(self):
        from repro.core.orchestrator import Orchestrator
        orch = Orchestrator(clock=VirtualClock())
        orch.catalog.register_adapter(
            spec_for("acme", base="mamba2-1.3b"))
        s = orch.begin_session(default_asp(), "u", "zone-a")
        chosen = orch.page_for(s, orch.discover_for(s))
        assert chosen.model.model_id != "mamba2-1.3b"
        s.asp = dataclasses.replace(s.asp, adapter_id="acme")
        with pytest.raises(SessionError) as ei:
            orch.prepare_for(s, chosen)
        assert ei.value.cause is FailureCause.NO_FEASIBLE_BINDING


# ----------------------------------------------------------------------
# northbound: the network-exposed adapter catalog
# ----------------------------------------------------------------------
def send(gw, msg):
    out = gw.handle_json(msg.to_json())
    if isinstance(out, list):
        return [m.from_json(o) for o in out]
    return m.from_json(out)


class TestGatewayAdapterLifecycle:
    @pytest.fixture
    def gw(self):
        return NorthboundGateway(clock=VirtualClock())

    def test_register_load_establish_serve(self, gw):
        reg = send(gw, m.RegisterAdapterRequest(
            adapter_id="acme", base_model_id="edge-tiny", rank=4))
        assert isinstance(reg, m.RegisterAdapterResponse)
        assert reg.weight_fingerprint
        assert gw.orch.catalog.adapters.has("acme")

        load = send(gw, m.LoadAdapterRequest(adapter_id="acme",
                                             site_id="edge-a"))
        assert isinstance(load, m.LoadAdapterResponse) and load.loaded
        assert gw.orch.catalog.adapters.loaded_sites("acme") == ("edge-a",)

        disc = send(gw, m.DiscoverRequest(
            invoker="t1", zone="zone-a", asp=asp_with_adapter("acme")))
        assert isinstance(disc, m.DiscoverResponse)
        admissible = [c for c in disc.candidates if c["admissible"]]
        assert admissible and all(c["model_id"] == "edge-tiny"
                                  for c in admissible)
        sid = disc.session_id
        send(gw, m.PageRequest(session_id=sid))
        prep = send(gw, m.PrepareRequest(session_id=sid,
                                         idempotency_key="p"))
        assert isinstance(prep, m.PrepareResponse)
        com = send(gw, m.CommitRequest(session_id=sid,
                                       prepared_ref=prep.prepared_ref,
                                       idempotency_key="c"))
        assert isinstance(com, m.CommitResponse)
        frames = send(gw, m.ServeRequest(session_id=sid, gen_tokens=4))
        assert frames[-1].completed

        # unload refused while the committed session is still bound
        refused = send(gw, m.UnloadAdapterRequest(adapter_id="acme",
                                                  site_id="edge-a"))
        assert isinstance(refused, m.ErrorResponse)
        assert refused.code == "E_BAD_REQUEST"
        assert "still bound" in refused.detail
        assert gw.orch.catalog.adapters.loaded_sites("acme") == ("edge-a",)

        send(gw, m.ReleaseRequest(session_id=sid))
        unload = send(gw, m.UnloadAdapterRequest(adapter_id="acme",
                                                 site_id="edge-a"))
        assert isinstance(unload, m.UnloadAdapterResponse) and unload.unloaded
        assert gw.orch.catalog.adapters.loaded_sites("acme") == ()

    def test_register_errors_are_bad_requests(self, gw):
        err = send(gw, m.RegisterAdapterRequest(
            adapter_id="x", base_model_id="no-such-model"))
        assert isinstance(err, m.ErrorResponse)
        assert err.code == "E_BAD_REQUEST"
        send(gw, m.RegisterAdapterRequest(adapter_id="x",
                                          base_model_id="edge-tiny"))
        dup = send(gw, m.RegisterAdapterRequest(adapter_id="x",
                                                base_model_id="edge-tiny"))
        assert isinstance(dup, m.ErrorResponse)
        assert dup.code == "E_BAD_REQUEST"

    def test_load_unknown_adapter_or_site_refused(self, gw):
        err = send(gw, m.LoadAdapterRequest(adapter_id="ghost",
                                            site_id="edge-a"))
        assert isinstance(err, m.ErrorResponse)
        assert err.cause == FailureCause.MODEL_UNAVAILABLE.value
        send(gw, m.RegisterAdapterRequest(adapter_id="x",
                                          base_model_id="edge-tiny"))
        err = send(gw, m.LoadAdapterRequest(adapter_id="x",
                                            site_id="no-such-site"))
        assert isinstance(err, m.ErrorResponse)
        assert err.code == "E_BAD_REQUEST"

    def test_load_respects_adapter_sovereignty(self, gw):
        send(gw, m.RegisterAdapterRequest(adapter_id="us-only",
                                          base_model_id="edge-tiny",
                                          regions=["us"]))
        err = send(gw, m.LoadAdapterRequest(adapter_id="us-only",
                                            site_id="edge-a"))   # eu site
        assert isinstance(err, m.ErrorResponse)
        assert err.cause == FailureCause.SOVEREIGNTY_VIOLATION.value
        ok = send(gw, m.LoadAdapterRequest(adapter_id="us-only",
                                           site_id="central-1"))
        assert isinstance(ok, m.LoadAdapterResponse) and ok.loaded

    def test_unknown_adapter_establish_fails_with_no_feasible_binding(
            self, gw):
        """DISCOVER annotates every candidate as adapter-excluded; the
        establish then fails with NO_FEASIBLE_BINDING, never an opaque
        serve failure."""
        disc = send(gw, m.DiscoverRequest(
            invoker="t1", zone="zone-a", asp=asp_with_adapter("ghost")))
        assert isinstance(disc, m.DiscoverResponse)
        assert not any(c["admissible"] for c in disc.candidates)
        assert any(c["exclusion_reason"] == "adapter-unknown"
                   for c in disc.candidates)
        err = send(gw, m.PageRequest(session_id=disc.session_id))
        assert isinstance(err, m.ErrorResponse)
        assert err.cause == FailureCause.NO_FEASIBLE_BINDING.value


# ----------------------------------------------------------------------
# coverage: every registered config resolves end-to-end
# ----------------------------------------------------------------------
REP_ASPS = {
    mod: ASP(modality=mod, interaction=InteractionMode.STREAMING,
             objectives=Objectives(ttfb_ms=300.0, p95_ms=600.0,
                                   p99_ms=900.0, rho_min=0.99,
                                   t_max_ms=2000.0, nu_min=20.0),
             tier=QualityTier.BASIC, mobility=MobilityClass.STATIC)
    for mod in MODALITY_FAMILIES
}


class TestConfigCatalogCoverage:
    CAT = default_catalog()

    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_every_config_resolves_through_default_catalog(self, arch):
        entry = self.CAT.get(arch)
        assert entry.model_id == arch and entry.version == "1.0"
        assert entry.cfg.d_model == get_config(arch).d_model
        assert get_smoke_config(arch).d_model > 0
        # every entry is reachable by at least one representative ASP
        matching = [mod for mod, asp in REP_ASPS.items()
                    if entry.matches(asp)]
        assert matching, f"{arch} matches no representative ASP"
        assert set(matching) == set(entry.modalities) & set(REP_ASPS)

    @pytest.mark.parametrize("mod", sorted(MODALITY_FAMILIES,
                                           key=lambda x: x.value))
    def test_every_advertised_modality_has_an_admissible_model(self, mod):
        advertised = {mo for e in self.CAT.entries() for mo in e.modalities}
        adm = self.CAT.admissible(REP_ASPS[mod])
        if mod in advertised:
            assert adm, f"no model admits {mod.value}"
        else:
            assert not adm          # honest: nothing claims this modality
        fams = MODALITY_FAMILIES[mod]
        assert all(e.cfg.family in fams for e in adm)


# ----------------------------------------------------------------------
# federation: digest advertises the adapter fleet
# ----------------------------------------------------------------------
class TestFederationAdapterDigest:
    def test_digest_carries_adapter_keys_and_round_trips(self):
        from repro.core.sites import default_sites
        from repro.federation.registry import CapabilityDigest, digest_of
        clock = VirtualClock()
        cat = default_catalog()
        cat.register_adapter(spec_for("acme"))
        cat.register_adapter(spec_for("acme", version="2.0", seed=1))
        sites = default_sites(clock, cat.keys())
        dig = digest_of("dom-a", cat, sites, clock, epoch=1)
        assert dig.adapter_keys == ("acme@1.0", "acme@2.0")
        again = CapabilityDigest.from_wire(dig.to_wire())
        assert again == dig
        # pre-adapter peers: absent key decodes to the empty fleet
        wire = dig.to_wire()
        wire.pop("adapter_keys")
        assert CapabilityDigest.from_wire(wire).adapter_keys == ()
