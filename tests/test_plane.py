"""ServingPlane + QoSScheduler + closed analytics loop.

Covers the QoS-contract enforcement mechanics (premium reserved share,
strict class ordering, deadline fast-fail accounting), plane-level
mixed-class admission under VirtualClock, the plane-driven §V scenarios,
and the regression the refactor exists for: measured congestion (queue
depth / arrival rate) flowing from the serving plane through
``Orchestrator.heartbeat`` into ``Analytics`` and changing Eq. (14)
migration-trigger behavior.
"""

import numpy as np
import pytest

from repro.core import Orchestrator, default_asp
from repro.core.asp import MobilityClass
from repro.core.clock import VirtualClock
from repro.core.failures import FailureCause
from repro.core.migration import MigrationTriggers
from repro.serving.engine import InferenceEngine
from repro.serving.plane import ServingPlane, SimulatedEngine
from repro.serving.scheduler import QoSScheduler, Request


def req(i, klass, *, t_max=10_000.0, gen=8, total_ms=None):
    return Request(f"r{i}", f"s{i}", klass, 16, gen, t_max,
                   hint_total_ms=total_ms)


class TestSchedulerContract:
    def test_premium_reserved_share_enforced(self):
        """Non-premium classes can NEVER occupy the reserved slots, even
        with an empty premium queue; premium can use the whole machine."""
        clock = VirtualClock()
        s = QoSScheduler(clock, slots=8, premium_reserved_frac=0.25)
        for i in range(12):
            s.submit(req(i, "best-effort"))
        batch = s.next_batch()
        assert len(batch) == 6                       # 2 of 8 held back
        for r in batch:
            s.complete(r.request_id)
        for i in range(20, 30):
            s.submit(req(i, "premium"))
        assert len(s.next_batch()) == 8              # premium takes all

    def test_strict_class_order_interleaved(self):
        clock = VirtualClock()
        s = QoSScheduler(clock, slots=3, premium_reserved_frac=0.0)
        s.submit(req(1, "best-effort"))
        s.submit(req(2, "assured"))
        s.submit(req(3, "premium"))
        s.submit(req(4, "premium"))
        assert [r.klass for r in s.next_batch()] == \
            ["premium", "premium", "assured"]

    def test_fast_fail_accounting_and_callback(self):
        clock = VirtualClock()
        s = QoSScheduler(clock, slots=2)
        dropped = []
        r1 = req(1, "premium", t_max=100.0)
        r2 = req(2, "premium", t_max=100_000.0)
        s.submit(r1)
        s.submit(r2)
        clock.advance(0.2)          # r1 has already waited 200 ms > T_max
        batch = s.next_batch(predicted_service_ms=50.0,
                             on_fast_fail=dropped.append)
        assert [r.request_id for r in batch] == ["r2"]
        assert r1.failed is FailureCause.DEADLINE_EXPIRY
        assert s.stats.fast_failed == 1 and dropped == [r1]

    def test_per_request_predicted_service(self):
        """A callable predictor fast-fails only the request whose OWN
        predicted work blows its deadline."""
        clock = VirtualClock()
        s = QoSScheduler(clock, slots=4)
        small = req(1, "premium", t_max=100.0, total_ms=50.0)
        big = req(2, "premium", t_max=100.0, total_ms=500.0)
        s.submit(small)
        s.submit(big)
        batch = s.next_batch(
            predicted_service_ms=lambda r: r.hint_total_ms)
        assert [r.request_id for r in batch] == ["r1"]
        assert big.failed is FailureCause.DEADLINE_EXPIRY


class TestPlaneVirtualTime:
    def mk(self, slots=2, **kw):
        clock = VirtualClock()
        plane = ServingPlane(clock, SimulatedEngine(clock), slots=slots,
                             site_id="t", **kw)
        return clock, plane

    def test_mixed_class_admission_order_under_load(self):
        """With the only slot busy, queued premium overtakes earlier-queued
        best-effort at the next slot release."""
        clock, plane = self.mk(slots=1, premium_reserved_frac=0.0)
        plane.submit(session_id="hold", klass="best-effort",
                     prompt_tokens=8, gen_tokens=4, t_max_ms=1e6,
                     hint_total_ms=100.0)
        plane.submit(session_id="late-be", klass="best-effort",
                     prompt_tokens=8, gen_tokens=4, t_max_ms=1e6,
                     hint_total_ms=10.0)
        plane.submit(session_id="prem", klass="premium",
                     prompt_tokens=8, gen_tokens=4, t_max_ms=1e6,
                     hint_total_ms=10.0)
        plane.drain()
        done = {r.session_id: r for r in plane.pop_results()}
        assert done["prem"].queue_wait_ms == pytest.approx(100.0)
        assert done["late-be"].queue_wait_ms == pytest.approx(110.0)
        assert all(r.completed for r in done.values())

    def test_queue_wait_measured_not_assumed(self):
        clock, plane = self.mk(slots=1)
        plane.submit(session_id="a", klass="premium", prompt_tokens=8,
                     gen_tokens=4, t_max_ms=1e6, hint_total_ms=250.0)
        plane.submit(session_id="b", klass="premium", prompt_tokens=8,
                     gen_tokens=4, t_max_ms=1e6, hint_total_ms=250.0)
        plane.drain()
        waits = {r.session_id: r.queue_wait_ms for r in plane.pop_results()}
        assert waits["a"] == pytest.approx(0.0)
        assert waits["b"] == pytest.approx(250.0)
        assert clock.now() == pytest.approx(0.5)

    def test_deadline_fast_fail_is_a_result(self):
        clock, plane = self.mk(slots=1)
        plane.submit(session_id="slow", klass="premium", prompt_tokens=8,
                     gen_tokens=4, t_max_ms=1e6, hint_total_ms=500.0)
        plane.submit(session_id="doomed", klass="premium", prompt_tokens=8,
                     gen_tokens=4, t_max_ms=100.0, hint_total_ms=200.0)
        plane.drain()
        res = {r.session_id: r for r in plane.pop_results()}
        assert res["doomed"].failed is FailureCause.DEADLINE_EXPIRY
        assert not res["doomed"].completed
        assert plane.scheduler.stats.fast_failed == 1
        assert res["slow"].completed

    def test_bounded_queue_rejects_and_accounts(self):
        clock, plane = self.mk(slots=1, max_queue=0)
        assert plane.submit(session_id="a", klass="premium", prompt_tokens=8,
                            gen_tokens=4, t_max_ms=1e6,
                            hint_total_ms=100.0) is not None
        assert plane.submit(session_id="b", klass="premium", prompt_tokens=8,
                            gen_tokens=4, t_max_ms=1e6,
                            hint_total_ms=100.0) is None
        assert plane.scheduler.stats.rejected == 1

    def test_load_snapshot(self):
        clock, plane = self.mk(slots=2)
        for i in range(6):
            clock.advance(0.01)
            plane.submit(session_id=f"s{i}", klass="premium",
                         prompt_tokens=8, gen_tokens=4, t_max_ms=1e6,
                         hint_total_ms=1000.0)
        load = plane.load()
        assert load.running == 2
        assert load.queue_depth == pytest.approx(4 / 2)
        assert load.arrival_rate > 0


class TestAnalyticsLoopClosed:
    """The refactor's acceptance criterion: Analytics.observe_site receives
    nonzero queue/arrival signals under load, and congestion changes
    migration-trigger behavior (heartbeat no longer reports zeros)."""

    def _orch_with_congested_anchor(self, backlog_per_slot):
        orch = Orchestrator(clock=VirtualClock())
        asp = default_asp(mobility=MobilityClass.NOMADIC)
        s = orch.establish(asp, "ue", "zone-a")
        site = orch.sites[s.binding.site_id]
        plane = orch.plane_for(site)
        # fill every slot, then pile `backlog_per_slot` waiting per slot
        n_queued = int(site.spec.decode_slots * (1 + backlog_per_slot))
        for i in range(n_queued):
            orch.clock.advance(1e-5)
            plane.submit(session_id=f"bg{i}", klass="premium",
                         prompt_tokens=128, gen_tokens=16, t_max_ms=1e9,
                         hint_total_ms=5e6)       # long-running: queue holds
        return orch, s, site

    def test_heartbeat_feeds_measured_congestion(self):
        orch, s, site = self._orch_with_congested_anchor(
            backlog_per_slot=2)
        orch.heartbeat(s, triggers=MigrationTriggers(1.1, 1.1))
        ctx = orch.analytics.site_context(site.spec.site_id)
        assert ctx.queue_depth > 0.0
        assert ctx.arrival_rate > 0.0

    def test_congestion_changes_migration_trigger(self):
        trig = MigrationTriggers(delta_l99=0.35, delta_ttfb=0.35)
        # idle anchor: no trigger
        orch = Orchestrator(clock=VirtualClock())
        s = orch.establish(default_asp(mobility=MobilityClass.NOMADIC),
                           "ue", "zone-a")
        orch.heartbeat(s, triggers=MigrationTriggers(1.1, 1.1))
        assert not orch.migrations.check_trigger(s, s.zone, trig)
        # same session shape, deeply congested anchor: heartbeat observes
        # the backlog and Eq. (14) fires
        orch2, s2, site2 = self._orch_with_congested_anchor(
            backlog_per_slot=40)
        for _ in range(4):          # EWMA warm-up
            orch2.heartbeat(s2, triggers=MigrationTriggers(1.1, 1.1))
        ctx = orch2.analytics.site_context(site2.spec.site_id)
        assert ctx.queue_depth > 1.0
        assert orch2.migrations.check_trigger(s2, s2.zone, trig)


class TestPlaneScenarios:
    @pytest.fixture(scope="class")
    def model(self):
        from repro.sim import LatencyModel, SimConfig
        return LatencyModel(SimConfig(n_requests=2000))

    def test_neaiaas_arm_runs_through_plane(self, model):
        from repro.sim import simulate_neaiaas
        r = simulate_neaiaas(0.95, model, ell99=400, t_max=1000)
        assert r.admitted_frac < 1.0          # admission rejected load
        assert r.violation_prob < 0.05        # served-and-failed stays low

    def test_multiclass_differentiation(self, model):
        from repro.sim import simulate_multiclass
        r = simulate_multiclass(0.95, model, n_requests=2000)
        prem = r.per_class["premium"]
        be = r.per_class["best-effort"]
        assert prem.p99_wait_ms < be.p99_wait_ms
        assert prem.p99_latency_ms < be.p99_latency_ms

    def test_bursty_arrivals_raise_tail_wait(self, model):
        from repro.sim import simulate_bursty
        flat = simulate_bursty(model, burst_factor=1.0, n_requests=2000)
        burst = simulate_bursty(model, burst_factor=5.0, n_requests=2000)
        assert burst.p99_wait_ms > flat.p99_wait_ms
        assert burst.completed_frac > 0.9

    def test_load_mobility_at_scale(self):
        from repro.sim import simulate_load_mobility
        r = simulate_load_mobility(n_sessions=10_000,
                                   requests_per_session=2)
        assert r.n_sessions == 10_000
        assert r.handovers > 100
        assert r.completed_frac > 0.95
        assert sum(r.per_site_served.values()) > 15_000


class TestPlaneRealEngine:
    """The same plane in front of a real continuous-batching engine."""

    @pytest.fixture(scope="class")
    def server(self):
        from repro.serving.server import AIaaSServer
        orch = Orchestrator(clock=VirtualClock())
        return AIaaSServer(orch, "edge-tiny", slots=4, max_len=96), orch

    def test_serve_through_plane_records_boundary(self, server):
        srv, orch = server
        s = orch.establish(default_asp(), "ue-a", "zone-a")
        r = orch.serve(s, prompt_tokens=12, gen_tokens=4)
        assert r.text_tokens == 4 and r.failed is None
        plane = srv.planes[s.binding.site_id]
        assert plane.scheduler.stats.completed == 1
        assert len(orch.telemetry[s.session_id]) == 1

    def test_batched_submit_drain_mixed_sessions(self, server):
        srv, orch = server
        a = orch.establish(default_asp(), "ue-b", "zone-a")
        b = orch.establish(default_asp(), "ue-c", "zone-a")
        for _ in range(2):
            srv.submit(a, prompt_tokens=8, gen_tokens=3)
            srv.submit(b, prompt_tokens=8, gen_tokens=3)
        results = srv.drain()
        mine = [r for r in results.values()
                if r.session_id in (a.session_id, b.session_id)]
        assert len(mine) == 4
        assert all(r.failed is None and r.tokens == 3 for r in mine)

    def test_request_serves_callers_prompt_tokens(self, server):
        """request() must generate from the SUPPLIED prompt and return the
        engine's real token ids (identical to driving the engine direct)."""
        srv, orch = server
        s = orch.establish(default_asp(), "ue-d", "zone-a")
        eng = srv.fleet.engine_for(s.binding.site_id)
        prompt = np.arange(9, dtype=np.int32)
        ref = InferenceEngine(eng.cfg, params=eng.params, slots=2,
                              max_len=96)
        pre = ref.prefill_session("ref", prompt)
        expect = [pre["first_token"]] + \
            [ref.decode_round()["ref"] for _ in range(3)]
        out = srv.request(s, prompt, gen_tokens=4)
        assert out["tokens"] == expect

    def test_migrated_session_can_still_be_served(self, server):
        """Regression: a make-before-break migration leaves the session's
        state in the target engine's slot map; subsequent plane requests
        must supersede it, not head-of-line block forever."""
        srv, orch = server
        s = orch.establish(default_asp(mobility=MobilityClass.VEHICULAR),
                           "ue-mig", "zone-a")
        eng = srv.fleet.engine_for(s.binding.site_id)
        eng.prefill_session(s.session_id, np.arange(7, dtype=np.int32))
        out = orch.migrations.migrate(s, "zone-a")
        assert out.migrated and s.committed()
        dst_eng = srv.fleet.engine_for(s.binding.site_id)
        assert s.session_id in dst_eng._slot_map    # migrated-in state
        r = orch.serve(s, prompt_tokens=8, gen_tokens=3)
        assert r.failed is None and r.text_tokens == 3
        # async path drains too
        srv.submit(s, prompt_tokens=8, gen_tokens=3)
        results = srv.drain()
        assert any(res.session_id == s.session_id and res.failed is None
                   for res in results.values())
