"""Training substrate: optimizer, convergence, checkpoint/restart,
gradient compression, fault tolerance."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import LM
from repro.training.optimizer import AdamWHyper, adamw_init, adamw_update, lr_at
from repro.training.train_step import init_train_state, make_train_step
from repro.training.data import DataConfig, SyntheticLMStream
from repro.training import checkpoint as ckpt
from repro.training import compression as comp
from repro.training.fault_tolerance import (StragglerPolicy, largest_grid,
                                            remesh_after_failure)


class TestOptimizer:
    def test_adamw_minimises_quadratic(self):
        params = {"w": jnp.array([5.0, -3.0, 2.0])}
        opt = adamw_init(params)
        h = AdamWHyper(lr=0.1, weight_decay=0.0, warmup_steps=1,
                       total_steps=300)
        for _ in range(300):
            grads = {"w": 2 * params["w"]}
            params, opt, _ = adamw_update(grads, opt, params, h)
        assert float(jnp.max(jnp.abs(params["w"]))) < 0.05

    def test_grad_clip(self):
        from repro.training.optimizer import clip_by_global_norm
        g = {"a": jnp.full((10,), 100.0)}
        clipped, gn = clip_by_global_norm(g, 1.0)
        assert float(gn) > 1.0
        assert np.isclose(
            float(jnp.sqrt(jnp.sum(clipped["a"] ** 2))), 1.0, atol=1e-5)

    def test_lr_schedule_shape(self):
        h = AdamWHyper(lr=1e-3, warmup_steps=10, total_steps=100)
        lrs = [float(lr_at(h, jnp.asarray(s))) for s in range(100)]
        assert lrs[0] < lrs[9] <= max(lrs)             # warmup
        assert lrs[-1] < lrs[20]                        # decay
        assert lrs[-1] >= 0.1 * h.lr * 0.9              # floor ~10%


class TestTrainingLoop:
    def test_loss_decreases(self):
        from repro.launch.train import train
        _, losses = train("edge-tiny", steps=30, batch=4, seq=64,
                          log_every=100)
        assert losses[-1] < losses[0] - 0.3

    def test_compression_still_converges(self):
        from repro.launch.train import train
        _, losses = train("edge-tiny", steps=30, batch=4, seq=64,
                          compress=True, log_every=100)
        assert losses[-1] < losses[0] - 0.25

    def test_microbatched_matches_unbatched_grads(self):
        cfg = get_config("edge-tiny")
        lm = LM(cfg)
        key = jax.random.key(3)
        state1 = init_train_state(lm, key)
        state2 = init_train_state(lm, key)
        stream = SyntheticLMStream(DataConfig(cfg.vocab_size, 32, 8))
        batch = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
        s1, m1 = jax.jit(make_train_step(lm, microbatches=1))(state1, batch)
        s2, m2 = jax.jit(make_train_step(lm, microbatches=4))(state2, batch)
        assert float(m1["loss"]) == pytest.approx(float(m2["loss"]),
                                                  abs=2e-2)
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         s1.params, s2.params)
        assert max(jax.tree.leaves(d)) < 5e-3


class TestCheckpoint:
    def test_roundtrip_and_integrity(self):
        cfg = get_config("edge-tiny")
        lm = LM(cfg)
        state = init_train_state(lm, jax.random.key(0))
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 7, state, extra={"data_step": 7})
            assert ckpt.latest_step(d) == 7
            like = jax.eval_shape(lambda k: init_train_state(lm, k),
                                  jax.random.key(0))
            restored, extra = ckpt.restore(d, 7, like)
            assert extra["data_step"] == 7
            for a, b in zip(jax.tree.leaves(state.params),
                            jax.tree.leaves(restored.params)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_corruption_detected(self):
        cfg = get_config("edge-tiny")
        lm = LM(cfg)
        state = init_train_state(lm, jax.random.key(0))
        with tempfile.TemporaryDirectory() as d:
            path = ckpt.save(d, 1, state)
            shard = os.path.join(path, "shard_0.npz")
            with open(shard, "r+b") as f:
                f.seek(100)
                f.write(b"\x00\x01\x02")
            like = jax.eval_shape(lambda k: init_train_state(lm, k),
                                  jax.random.key(0))
            with pytest.raises(IOError):
                ckpt.restore(d, 1, like)

    def test_restart_determinism(self):
        """train(2n) == train(n) + restore + train(n): same data, same loss."""
        from repro.launch.train import train
        with tempfile.TemporaryDirectory() as d:
            _, full = train("edge-tiny", steps=20, batch=4, seq=64,
                            log_every=100, seed=5)
            _, first = train("edge-tiny", steps=10, batch=4, seq=64,
                             ckpt_dir=d, ckpt_every=10, log_every=100, seed=5)
            _, second = train("edge-tiny", steps=10, batch=4, seq=64,
                              ckpt_dir=d, resume=True, log_every=100, seed=5)
        assert second[-1] == pytest.approx(full[-1], abs=1e-3)


class TestCompression:
    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.floats(-10, 10), min_size=4, max_size=64))
    def test_quantize_bounded_error(self, xs):
        x = jnp.asarray(xs, jnp.float32).reshape(1, -1)
        q, scale = comp.quantize(x)
        err = jnp.max(jnp.abs(comp.dequantize(q, scale) - x))
        assert float(err) <= float(scale) * 0.5 + 1e-6

    def test_error_feedback_accumulates(self):
        g = jnp.full((4, 4), 1e-6)          # below quantisation resolution…
        ef = jnp.zeros_like(g)
        total = jnp.zeros_like(g)
        for _ in range(2000):
            out, ef = comp.compress_leaf(g, ef)
            total = total + out
        # …but error feedback still delivers the mass over time
        assert float(jnp.mean(total)) == pytest.approx(2000 * 1e-6, rel=0.3)


class TestFaultTolerance:
    def test_straggler_policy(self):
        p = StragglerPolicy(factor=1.5, strikes_to_evict=2)
        for _ in range(20):
            assert p.observe("w0", 1.0) == "ok"
        assert p.observe("w1", 10.0) == "suspect"
        assert p.observe("w1", 10.0) == "evict"

    def test_remesh(self):
        devs = list(range(64))
        keep, (data, model) = remesh_after_failure(devs, {3, 17, 42}, 16)
        assert model == 16 and data == 3
        assert len(keep) == 48
        assert not {3, 17, 42} & set(keep)

    def test_remesh_insufficient(self):
        with pytest.raises(ValueError):
            largest_grid(8, 16)


class TestData:
    def test_resumable_and_deterministic(self):
        cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=4, seed=1)
        s1 = SyntheticLMStream(cfg)
        batches = [s1.next_batch() for _ in range(5)]
        s2 = SyntheticLMStream(cfg, start_step=3)
        b3 = s2.next_batch()
        np.testing.assert_array_equal(batches[3]["tokens"], b3["tokens"])

    def test_host_sharding_disjoint(self):
        cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=8, seed=1)
        h0 = SyntheticLMStream(cfg, host_id=0, num_hosts=2).next_batch()
        h1 = SyntheticLMStream(cfg, host_id=1, num_hosts=2).next_batch()
        assert h0["tokens"].shape == (4, 32)
        assert not np.array_equal(h0["tokens"], h1["tokens"])
