"""DISCOVER (Eq. 7/8) + AI PAGING (Eq. 9) behaviour."""

import dataclasses

import pytest

from repro.core.analytics import Analytics
from repro.core.asp import MobilityClass, QualityTier, default_asp
from repro.core.catalog import default_catalog
from repro.core.clock import VirtualClock
from repro.core.discovery import admissible_set, discover
from repro.core.failures import FailureCause, SessionError
from repro.core.paging import PagingWeights, page, risk
from repro.core.predictors import Predictors
from repro.core.sites import default_sites


@pytest.fixture()
def world():
    clock = VirtualClock()
    catalog = default_catalog()
    sites = default_sites(clock, tuple(catalog._entries.keys()))
    analytics = Analytics(clock)
    predictors = Predictors(analytics)
    return clock, catalog, sites, analytics, predictors


class TestDiscovery:
    def test_candidates_annotated_and_sorted(self, world):
        clock, catalog, sites, analytics, predictors = world
        cands = discover(default_asp(), catalog, sites, predictors, "zone-a")
        adm = [c for c in cands if c.admissible]
        assert adm, "no admissible binding"
        slacks = [c.slack for c in cands]
        assert slacks == sorted(slacks, reverse=True)
        for c in adm:
            assert c.prediction.t_ff_ms > 0 and c.prediction.l99_ms > 0

    def test_sovereignty_hard_filter(self, world):
        clock, catalog, sites, analytics, predictors = world
        asp = dataclasses.replace(default_asp(), allowed_regions=("mars",))
        cands = discover(asp, catalog, sites, predictors, "zone-a")
        assert all(not c.admissible for c in cands)
        assert all(c.exclusion_reason == "sovereignty" for c in cands)
        with pytest.raises(SessionError) as ei:
            admissible_set(cands)
        assert ei.value.cause is FailureCause.NO_FEASIBLE_BINDING

    def test_negative_slack_excluded(self, world):
        clock, catalog, sites, analytics, predictors = world
        o = default_asp().objectives
        tight = dataclasses.replace(
            default_asp(),
            objectives=dataclasses.replace(o, ttfb_ms=0.001, p95_ms=0.002,
                                           p99_ms=0.002, t_max_ms=1.0))
        cands = discover(tight, catalog, sites, predictors, "zone-a")
        assert all(not c.admissible for c in cands
                   if c.exclusion_reason == "negative-slack"
                   or c.admissible is False)

    def test_a1_deny_list_respected(self, world):
        clock, catalog, sites, analytics, predictors = world
        analytics.deny_site("edge-a")
        cands = discover(default_asp(), catalog, sites, predictors, "zone-a",
                         analytics=analytics)
        assert all(c.site_id != "edge-a" for c in cands if c.admissible)

    def test_tier_filter(self, world):
        clock, catalog, sites, analytics, predictors = world
        asp = default_asp(tier=QualityTier.PREMIUM)
        cands = discover(asp, catalog, sites, predictors, "zone-a")
        for c in cands:
            if c.admissible:
                assert c.model.tier >= QualityTier.PREMIUM


class TestPaging:
    def test_picks_min_risk(self, world):
        clock, catalog, sites, analytics, predictors = world
        asp = default_asp()
        cands = discover(asp, catalog, sites, predictors, "zone-a")
        chosen = page(asp, cands)
        w = PagingWeights(w3=0.25)
        adm = [c for c in cands if c.admissible]
        assert risk(chosen, w) == min(risk(c, w) for c in adm)

    def test_exclusion_for_migration(self, world):
        clock, catalog, sites, analytics, predictors = world
        asp = default_asp()
        cands = discover(asp, catalog, sites, predictors, "zone-a")
        first = page(asp, cands)
        second = page(asp, cands, exclude_sites=(first.site_id,))
        assert second.site_id != first.site_id

    def test_mobility_weights_migration_risk(self, world):
        """A vehicular ASP should prefer anchors with lower migration risk
        (central) relative to a static ASP, all else equal."""
        clock, catalog, sites, analytics, predictors = world
        static = default_asp(mobility=MobilityClass.STATIC)
        vehic = default_asp(mobility=MobilityClass.VEHICULAR)
        c_static = page(static, discover(static, catalog, sites, predictors,
                                         "zone-a"))
        c_vehic = page(vehic, discover(vehic, catalog, sites, predictors,
                                       "zone-a"))
        kinds = {"edge": 0, "regional": 1, "central": 2}
        assert kinds[sites[c_vehic.site_id].spec.kind] >= \
            kinds[sites[c_static.site_id].spec.kind]
