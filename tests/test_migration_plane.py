"""Live make-before-break migration through the ServingPlane (tentpole of
the migration-data-plane PR): mid-stream handover on real engines, the
plane-level failure-injection points, context-sized PREPARE reservations,
and deterministic VirtualClock scenario outcomes.

Continuity criterion (§IV-B, Eq. 14): a session mid-decode migrates between
two plane sites with zero contract-gap time, verified by fingerprint
equality and bit-exact stream continuation — and EVERY injected failure
mode aborts without tearing down the source.
"""

import itertools

import numpy as np
import pytest

from repro.core import Orchestrator, default_asp
from repro.core.asp import MobilityClass
from repro.core.clock import VirtualClock
from repro.core.failures import FailureCause
from repro.core.session import SessionState
from repro.serving.engine import InferenceEngine
from repro.serving.server import AIaaSServer
from repro.serving.state_transfer import TransferInjections


def mk_server(slots=4, max_len=96):
    orch = Orchestrator(clock=VirtualClock())
    # per-token decode chunks: these tests drive _round() by hand to catch a
    # session mid-stream at an exact token count (chunked-decode handover is
    # covered in tests/test_engine_fast.py)
    chunk = {"premium": 1, "assured": 1, "best-effort": 1}
    return AIaaSServer(orch, "edge-tiny", slots=slots, max_len=max_len,
                       decode_chunk=chunk), orch


def vehicular(orch, name="car"):
    return orch.establish(default_asp(mobility=MobilityClass.VEHICULAR),
                          invoker=name, zone="zone-a")


class TestMidStreamHandover:
    """The hard case: the session is DECODING when the anchor swaps."""

    def test_stream_continues_bit_exact_on_target(self):
        srv, orch = mk_server()
        s = vehicular(orch)
        src = s.binding.site_id
        src_plane = srv.planes[src]
        prompt = np.arange(9, dtype=np.int32)
        gen = 12

        # reference: the same stream with NO migration (shared weights)
        eng = srv.fleet.engine_for(src)
        ref = InferenceEngine(eng.cfg, params=eng.params, slots=1, max_len=96)
        pre = ref.prefill_session("ref", prompt)
        expect = [pre["first_token"]] + \
            [ref.decode_round()["ref"] for _ in range(gen - 1)]

        srv.submit(s, prompt=prompt, gen_tokens=gen)
        for _ in range(3):                     # tokens flow on the source
            src_plane._round()

        out = orch.migrations.migrate(s, "zone-a")
        assert out.migrated and out.mid_stream
        assert out.interruption_ms == 0.0      # zero contract-gap time
        assert out.fingerprint is not None     # verified state transfer
        assert out.transfer_bytes > 0
        dst = s.binding.site_id
        assert dst != src

        # the break: source slot released, occupancy followed the session
        assert not eng.has_slot(s.session_id)
        assert srv.fleet.engine_for(dst).has_slot(s.session_id)
        assert not any(r.session_id == s.session_id
                       for r in src_plane.scheduler.running.values())
        dst_plane = srv.planes[dst]
        assert any(r.session_id == s.session_id
                   for r in dst_plane.scheduler.running.values())

        # the stream finishes on the TARGET, bit-identical to no-migration
        dst_plane.drain()
        results = orch.record_results(orch.sites[dst])
        mine = [r for r in results if r.session_id == s.session_id]
        assert len(mine) == 1 and mine[0].failed is None
        assert mine[0].tokens == gen
        assert mine[0].token_ids == expect
        # completion (and charging context) accounted on the target plane
        assert dst_plane.scheduler.stats.completed >= 1
        assert len(orch.telemetry[s.session_id]) == 1
        assert s.context_tokens == len(prompt) + gen

    def test_queued_requests_follow_the_session(self):
        """A queued (not yet admitted) request of the migrating session must
        NOT be served at the old anchor: it re-queues on the target."""
        srv, orch = mk_server()
        s = vehicular(orch, "car-queued")
        src = s.binding.site_id
        src_plane = srv.planes[src]
        prompt = np.arange(5, dtype=np.int32)
        srv.submit(s, prompt=prompt, gen_tokens=6)      # running
        srv.submit(s, prompt=prompt, gen_tokens=4)      # queued (exclusive)
        assert src_plane.scheduler.queue_depth() == 1
        src_plane._round()

        out = orch.migrations.migrate(s, "zone-a")
        assert out.migrated and out.mid_stream
        dst = s.binding.site_id
        dst_plane = srv.planes[dst]
        # nothing of this session remains on the source plane
        assert src_plane.scheduler.queue_depth() == 0
        assert not any(r.session_id == s.session_id
                       for r in src_plane.scheduler.running.values())
        assert dst_plane.scheduler.queue_depth() == 1

        dst_plane.drain()
        results = orch.record_results(orch.sites[dst])
        mine = [r for r in results if r.session_id == s.session_id]
        assert len(mine) == 2
        assert sorted(r.tokens for r in mine) == [4, 6]
        assert all(r.failed is None for r in mine)
        # both served by the TARGET engine; source engine holds nothing
        assert not srv.fleet.engine_for(src).has_slot(s.session_id)

    def test_abort_resumes_stream_on_source(self):
        """A mid-stream abort re-attaches the in-flight request: the stream
        completes on the SOURCE as if migration was never attempted."""
        srv, orch = mk_server()
        s = vehicular(orch, "car-abort")
        src = s.binding.site_id
        src_plane = srv.planes[src]
        prompt = np.arange(7, dtype=np.int32)
        gen = 10

        eng = srv.fleet.engine_for(src)
        ref = InferenceEngine(eng.cfg, params=eng.params, slots=1, max_len=96)
        pre = ref.prefill_session("ref", prompt)
        expect = [pre["first_token"]] + \
            [ref.decode_round()["ref"] for _ in range(gen - 1)]

        srv.submit(s, prompt=prompt, gen_tokens=gen)
        for _ in range(2):
            src_plane._round()

        def boom(payload):
            raise IOError("injected export failure")

        src_plane.migration_inject = TransferInjections(on_export=boom)
        out = orch.migrations.migrate(s, "zone-a")
        assert out.aborted
        assert out.cause is FailureCause.STATE_TRANSFER_FAILURE
        assert s.committed() and s.binding.site_id == src
        assert eng.has_slot(s.session_id)

        src_plane.migration_inject = None
        src_plane.drain()
        results = orch.record_results(orch.sites[src])
        mine = [r for r in results if r.session_id == s.session_id]
        assert len(mine) == 1 and mine[0].tokens == gen
        assert mine[0].token_ids == expect


class TestFailureInjection:
    """Every plane-level failure mode must abort leaving: the source slot
    intact, the session COMMITTED on the source, and the target's
    provisional leases (and any provisionally imported state) rolled back."""

    def _armed(self):
        srv, orch = mk_server()
        s = vehicular(orch, "car-inj")
        eng = srv.fleet.engine_for(s.binding.site_id)
        eng.prefill_session(s.session_id, np.arange(9, dtype=np.int32))
        slots_before = {sid: site.slots_in_use()
                        for sid, site in orch.sites.items()}
        return srv, orch, s, eng, slots_before

    def _assert_clean_abort(self, orch, srv, s, eng, slots_before, out,
                            cause):
        src = s.binding.site_id
        assert out.aborted and not out.migrated
        assert out.cause is cause
        assert out.to_site is None
        assert out.interruption_ms == 0.0
        # session still COMMITTED on the source, slot intact
        assert s.state is SessionState.COMMITTED
        assert s.committed() and s.binding.site_id == src
        assert eng.has_slot(s.session_id)
        # target leases rolled back (no slots leaked anywhere)
        after = {sid: site.slots_in_use() for sid, site in orch.sites.items()}
        assert after == slots_before, "provisional target leases leaked"
        # no provisional state left on ANY other site's backend
        for sid, plane in srv.planes.items():
            if sid != src:
                assert not plane.backend.has_slot(s.session_id)

    def _inject(self, srv, s, side, inj):
        src = s.binding.site_id
        for sid, plane in srv.planes.items():
            if (side == "src") == (sid == src):
                plane.migration_inject = inj

    def test_export_failure(self):
        srv, orch, s, eng, before = self._armed()

        def boom(payload):
            raise IOError("injected export failure")

        self._inject(srv, s, "src", TransferInjections(on_export=boom))
        out = orch.migrations.migrate(s, "zone-a")
        self._assert_clean_abort(orch, srv, s, eng, before, out,
                                 FailureCause.STATE_TRANSFER_FAILURE)

    def test_import_failure_rolls_back_target_state(self):
        srv, orch, s, eng, before = self._armed()

        def boom(payload):
            raise IOError("injected import failure")

        self._inject(srv, s, "dst", TransferInjections(on_import=boom))
        out = orch.migrations.migrate(s, "zone-a")
        self._assert_clean_abort(orch, srv, s, eng, before, out,
                                 FailureCause.STATE_TRANSFER_FAILURE)

    def test_fingerprint_corruption(self):
        srv, orch, s, eng, before = self._armed()

        def corrupt(payload):
            payload = dict(payload)
            payload["position"] = payload["position"] + 1
            return payload

        self._inject(srv, s, "src", TransferInjections(corrupt=corrupt))
        out = orch.migrations.migrate(s, "zone-a")
        self._assert_clean_abort(orch, srv, s, eng, before, out,
                                 FailureCause.STATE_TRANSFER_FAILURE)

    def test_target_admission_denial_injected(self):
        srv, orch, s, eng, before = self._armed()
        self._inject(srv, s, "dst",
                     TransferInjections(deny_admission=True))
        out = orch.migrations.migrate(s, "zone-a")
        self._assert_clean_abort(orch, srv, s, eng, before, out,
                                 FailureCause.COMPUTE_SCARCITY)

    def test_target_admission_denial_real_slot_exhaustion(self):
        """Target engines genuinely full (not injected): import_slot raises
        and the abort maps to COMPUTE_SCARCITY."""
        srv, orch = mk_server(slots=2, max_len=64)
        s = vehicular(orch, "car-full")
        src = s.binding.site_id
        eng = srv.fleet.engine_for(src)
        eng.prefill_session(s.session_id, np.arange(5, dtype=np.int32))
        for sid in srv.planes:
            if sid != src:
                other = srv.fleet.engine_for(sid)
                for k in range(2):
                    other.prefill_session(f"hog-{sid}-{k}",
                                          np.arange(5, dtype=np.int32))
        before = {sid: site.slots_in_use()
                  for sid, site in orch.sites.items()}
        out = orch.migrations.migrate(s, "zone-a")
        self._assert_clean_abort(orch, srv, s, eng, before, out,
                                 FailureCause.COMPUTE_SCARCITY)
        # the hogs were untouched by the rollback
        for sid in srv.planes:
            if sid != src:
                assert srv.fleet.engine_for(sid).free_slots() == 0

    def test_tau_mig_expiry_mid_transfer(self):
        srv, orch, s, eng, before = self._armed()
        self._inject(srv, s, "src",
                     TransferInjections(extra_wire_s=orch.timers.tau_mig * 5))
        out = orch.migrations.migrate(s, "zone-a")
        self._assert_clean_abort(orch, srv, s, eng, before, out,
                                 FailureCause.STATE_TRANSFER_FAILURE)


class TestSimArmMigration:
    """The §V VirtualClock arm migrates REAL (simulated-engine) state."""

    def test_sim_state_follows_session(self):
        from repro.serving import state_transfer
        orch = Orchestrator(clock=VirtualClock())
        s = vehicular(orch, "sim-ue")
        orch.serve(s, prompt_tokens=64, gen_tokens=16)
        src = s.binding.site_id
        src_backend = orch.plane_for(orch.sites[src]).backend
        assert src_backend.has_slot(s.session_id)
        fp0 = state_transfer.fingerprint(
            src_backend.export_slot(s.session_id))
        out = orch.migrations.migrate(s, "zone-a")
        assert out.migrated and out.interruption_ms == 0.0
        assert out.fingerprint == fp0
        dst_backend = orch.plane_for(orch.sites[s.binding.site_id]).backend
        assert dst_backend.has_slot(s.session_id)
        assert not src_backend.has_slot(s.session_id)
        fp1 = state_transfer.fingerprint(
            dst_backend.export_slot(s.session_id))
        assert fp1 == fp0

    def test_release_frees_backend_session_state(self):
        """Orchestrator.release drops the anchor backend's serialized
        session state along with the leases (no unbounded growth)."""
        orch = Orchestrator(clock=VirtualClock())
        s = vehicular(orch, "sim-release")
        orch.serve(s, prompt_tokens=64, gen_tokens=16)
        backend = orch.plane_for(orch.sites[s.binding.site_id]).backend
        assert backend.has_slot(s.session_id)
        orch.release(s)
        assert not backend.has_slot(s.session_id)

    def test_sim_plane_injection_aborts(self):
        orch = Orchestrator(clock=VirtualClock())
        s = vehicular(orch, "sim-inj")
        orch.serve(s, prompt_tokens=64, gen_tokens=16)
        src = s.binding.site_id
        for sid, site in orch.sites.items():
            if sid != src:
                orch.plane_for(site).migration_inject = \
                    TransferInjections(deny_admission=True)
        out = orch.migrations.migrate(s, "zone-a")
        assert out.aborted and out.cause is FailureCause.COMPUTE_SCARCITY
        assert s.committed() and s.binding.site_id == src
        assert orch.plane_for(orch.sites[src]).backend.has_slot(s.session_id)


class TestContextSizedPrepare:
    """Regression: migrate() must size the PREPARE cache reservation and
    transfer payload from the session's ACTUAL context length, not a
    hardcoded 2048."""

    def test_prepare_reservation_tracks_served_context(self):
        orch = Orchestrator(clock=VirtualClock())
        s = vehicular(orch, "ctx-ue")
        orch.serve(s, prompt_tokens=300, gen_tokens=100)
        assert s.context_tokens == 400
        out = orch.migrations.migrate(s, "zone-a")
        assert out.migrated
        model = orch.catalog.get(s.binding.model_id, s.binding.model_version)
        lease = orch.sites[s.binding.site_id]._leases[
            s.binding.compute_lease_id]
        assert lease.hbm_bytes == model.session_state_bytes(400)
        assert lease.hbm_bytes != model.session_state_bytes(2048)

    def test_default_transfer_scales_with_context(self):
        orch = Orchestrator(clock=VirtualClock())
        s = vehicular(orch, "ctx-wire")
        ctrl = orch.migrations
        short = ctrl._default_transfer(s, None, None, context_tokens=256)
        long = ctrl._default_transfer(s, None, None, context_tokens=8192)
        assert long > short > 0.0

    def test_transfer_wire_time_tracks_context(self):
        """The modeled wire time of the plane path grows with the served
        context (the payload is not a constant)."""
        outs = []
        for p, g in ((64, 16), (2048, 512)):
            orch = Orchestrator(clock=VirtualClock())
            s = vehicular(orch, f"ctx-{p}")
            orch.serve(s, prompt_tokens=p, gen_tokens=g)
            outs.append(orch.migrations.migrate(s, "zone-a"))
        assert all(o.migrated for o in outs)
        assert outs[1].transfer_ms > outs[0].transfer_ms


class TestDeterministicOutcomes:
    """Same trace + seed ⇒ byte-identical MigrationOutcome sequences (CI
    reproducibility). Session ids are the only process-global state, so the
    test pins the counter the way two fresh CI processes would see it."""

    def _run(self, seed):
        import repro.core.session as session_mod
        session_mod._ids = itertools.count(50_000)
        from repro.sim import simulate_migration_under_load
        return simulate_migration_under_load(
            n_sessions=16, rounds=2, handover_prob=0.5,
            export_fail_prob=0.25, seed=seed)

    def test_same_seed_identical_outcomes(self):
        a = self._run(seed=11)
        b = self._run(seed=11)
        assert len(a.outcomes) > 0
        assert a.outcomes == b.outcomes          # dataclass field equality
        assert a.causes == b.causes
        assert a.bytes_moved == b.bytes_moved

    def test_different_seed_differs(self):
        a = self._run(seed=11)
        c = self._run(seed=12)
        assert a.outcomes != c.outcomes


class TestMigrationScenarios:
    def test_under_load_all_make_before_break(self):
        from repro.sim import simulate_migration_under_load
        r = simulate_migration_under_load(n_sessions=24, rounds=2,
                                          handover_prob=0.5, seed=0)
        assert r.n_attempts > 5
        assert r.abort_rate == 0.0
        assert r.max_interruption_ms == 0.0
        assert r.bytes_moved > 0

    def test_target_pressure_forces_clean_aborts(self):
        from repro.sim import simulate_migration_under_load
        r = simulate_migration_under_load(n_sessions=10, rounds=2,
                                          handover_prob=0.9,
                                          target_pressure=1.0, seed=1)
        assert r.n_attempts > 0
        assert r.abort_rate == 1.0
        assert set(r.causes) == {"compute scarcity"}
        assert r.max_interruption_ms == 0.0      # aborts gap nothing

    def test_payload_asymmetry_ssm_always_fits(self):
        from repro.sim import simulate_payload_asymmetry
        rows = simulate_payload_asymmetry(
            context_tokens=(4_096, 131_072),
            models=("minitron-8b", "mamba2-1.3b"))
        dense = [r for r in rows if r.family == "dense"]
        ssm = [r for r in rows if r.family == "ssm"]
        # dense KV grows with context and eventually blows τ_mig
        assert dense[0].migrated and not dense[1].migrated
        assert dense[1].cause == "state transfer failure"
        # SSM state is O(1) in context: same payload, always migrates
        assert all(r.migrated for r in ssm)
        assert ssm[0].payload_bytes == ssm[1].payload_bytes

    def test_mobility_mbb_plane_mechanism(self):
        from repro.sim import simulate_mobility
        r = simulate_mobility(90, "mbb-plane", n_sessions=6,
                              transfer_fail_prob=0.2)
        assert r.mechanism == "mbb-plane"
        assert r.interruption_prob == 0.0        # aborts keep the source
