"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode).

Shapes/dtypes swept per the assignment; hypothesis drives extra ragged
shapes for the decode kernel (continuous batching is shape-irregular)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.decode_attention.decode_attention import (
    decode_attention, paged_decode_attention)
from repro.kernels.decode_attention.ref import (decode_attention_ref,
                                                paged_decode_attention_ref)
from repro.kernels.rglru_scan.rglru_scan import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.ssd_chunk.ssd_chunk import ssd_chunk
from repro.kernels.ssd_chunk.ref import ssd_ref
from repro.kernels.moe_gemm.moe_gemm import moe_gemm, moe_ffn_fused
from repro.kernels.moe_gemm.ops import grouped_gemm
from repro.kernels.moe_gemm.ref import moe_gemm_ref, moe_ffn_fused_ref

KEY = jax.random.key(7)


def tol(dt):
    return 0.035 if dt == jnp.bfloat16 else 5e-5


class TestFlashAttention:
    @pytest.mark.parametrize("B,Hq,Hkv,Sq,Skv,D,causal,dt", [
        (2, 4, 2, 256, 256, 64, True, jnp.float32),
        (1, 8, 8, 130, 130, 128, True, jnp.bfloat16),
        (2, 4, 1, 128, 384, 64, False, jnp.float32),   # cross-shaped
        (1, 2, 2, 64, 64, 128, True, jnp.bfloat16),
        (1, 16, 4, 257, 257, 64, True, jnp.float32),   # ragged block edge
    ])
    def test_matches_ref(self, B, Hq, Hkv, Sq, Skv, D, causal, dt):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, Hq, Sq, D), jnp.float32).astype(dt)
        k = jax.random.normal(ks[1], (B, Hkv, Skv, D), jnp.float32).astype(dt)
        v = jax.random.normal(ks[2], (B, Hkv, Skv, D), jnp.float32).astype(dt)
        out = flash_attention(q, k, v, causal=causal, interpret=True)
        ref = attention_ref(q, k, v, causal=causal)
        err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                    - ref.astype(jnp.float32))))
        assert err < tol(dt), err


class TestDecodeAttention:
    @pytest.mark.parametrize("B,Hq,Hkv,S,D,dt", [
        (4, 8, 2, 1024, 64, jnp.float32),
        (2, 8, 8, 300, 128, jnp.bfloat16),
        (3, 4, 1, 2048, 128, jnp.float32),
    ])
    def test_matches_ref(self, B, Hq, Hkv, S, D, dt):
        ks = jax.random.split(KEY, 4)
        q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32).astype(dt)
        k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32).astype(dt)
        v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32).astype(dt)
        lengths = jax.random.randint(ks[3], (B,), 1, S + 1)
        out = decode_attention(q, k, v, lengths, interpret=True)
        ref = decode_attention_ref(q, k, v, lengths)
        err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                    - ref.astype(jnp.float32))))
        assert err < tol(dt), err

    @settings(max_examples=8, deadline=None)
    @given(B=st.integers(1, 4), g=st.integers(1, 4),
           S=st.integers(3, 200), D=st.sampled_from([64, 128]))
    def test_ragged_lengths_property(self, B, g, S, D):
        """Continuous batching: arbitrary per-row lengths stay exact."""
        Hkv = 2
        ks = jax.random.split(jax.random.key(B * 1000 + S), 4)
        q = jax.random.normal(ks[0], (B, Hkv * g, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32)
        lengths = jax.random.randint(ks[3], (B,), 1, S + 1)
        out = decode_attention(q, k, v, lengths, block_kv=64, interpret=True)
        ref = decode_attention_ref(q, k, v, lengths)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=5e-5, rtol=1e-4)


def _paged_case(seed, B, Hkv, S, D, page, *, extra_pages=3):
    """Linear k/v plus an equivalent page pool + block tables. Pool rows
    not referenced by any table (including the engine's page-0 scratch
    convention) are filled with garbage — the kernel must never let them
    reach the softmax."""
    PPS = S // page
    P = 1 + B * PPS + extra_pages
    ks = jax.random.split(jax.random.key(seed), 5)
    q = jax.random.normal(ks[0], (B, Hkv * 2, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32)
    lengths = jax.random.randint(ks[3], (B,), 1, S + 1)
    rows = 1 + jax.random.permutation(ks[4], B * PPS + extra_pages)
    tables = rows[:B * PPS].reshape(B, PPS).astype(jnp.int32)
    pool_k = jnp.full((P, page, Hkv, D), 1e9, jnp.float32)
    pool_v = jnp.full((P, page, Hkv, D), -1e9, jnp.float32)
    src_k = jnp.moveaxis(k, 2, 1).reshape(B * PPS, page, Hkv, D)
    src_v = jnp.moveaxis(v, 2, 1).reshape(B * PPS, page, Hkv, D)
    pool_k = pool_k.at[tables.reshape(-1)].set(src_k)
    pool_v = pool_v.at[tables.reshape(-1)].set(src_v)
    return q, k, v, lengths, pool_k, pool_v, tables


class TestPagedDecodeAttention:
    @pytest.mark.parametrize("B,Hkv,S,D,page", [
        (3, 4, 64, 32, 16),        # the engine smoke shape
        (2, 2, 256, 64, 32),
        (4, 1, 128, 128, 16),
    ])
    def test_matches_both_refs(self, B, Hkv, S, D, page):
        """Scattered pool + shuffled tables == its gather oracle == the
        dense (linear-layout) oracle on the same logical sequences."""
        q, k, v, lengths, pk, pv, tbl = _paged_case(11, B, Hkv, S, D, page)
        out = paged_decode_attention(q, pk, pv, lengths, tbl, interpret=True)
        for ref in (paged_decode_attention_ref(q, pk, pv, lengths, tbl),
                    decode_attention_ref(q, k, v, lengths)):
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       atol=5e-5, rtol=1e-4)

    @settings(max_examples=8, deadline=None)
    @given(B=st.integers(1, 4), Hkv=st.sampled_from([1, 2, 4]),
           pps=st.integers(1, 5), page=st.sampled_from([8, 16]))
    def test_ragged_lengths_property(self, B, Hkv, pps, page):
        """Arbitrary table permutations and ragged lengths stay exact:
        tail pages past each row's length are streamed but masked."""
        S, D = pps * page, 64
        q, k, v, lengths, pk, pv, tbl = _paged_case(
            B * 7919 + S, B, Hkv, S, D, page)
        out = paged_decode_attention(q, pk, pv, lengths, tbl, interpret=True)
        ref = decode_attention_ref(q, k, v, lengths)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=5e-5, rtol=1e-4)


class TestRGLRUScan:
    @pytest.mark.parametrize("B,T,W", [(2, 300, 256), (1, 128, 512),
                                       (3, 77, 130)])
    def test_matches_ref(self, B, T, W):
        ks = jax.random.split(KEY, 2)
        a = jax.random.uniform(ks[0], (B, T, W), jnp.float32, 0.8, 0.999)
        b = jax.random.normal(ks[1], (B, T, W), jnp.float32) * 0.1
        out = rglru_scan(a, b, interpret=True)
        ref = rglru_scan_ref(a, b)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4, rtol=1e-4)


class TestSSDChunk:
    @pytest.mark.parametrize("Bt,H,T,P,N,Q", [
        (1, 2, 256, 64, 32, 64), (2, 4, 130, 32, 16, 32),
        (1, 1, 64, 128, 64, 16),
    ])
    def test_matches_sequential_ref(self, Bt, H, T, P, N, Q):
        ks = jax.random.split(KEY, 4)
        x = jax.random.normal(ks[0], (Bt, H, T, P), jnp.float32)
        dt = jax.random.uniform(ks[1], (Bt, H, T), jnp.float32, 0.001, 0.1)
        B_ = jax.random.normal(ks[2], (Bt, H, T, N), jnp.float32)
        C_ = jax.random.normal(ks[3], (Bt, H, T, N), jnp.float32)
        A = -jnp.exp(jax.random.normal(KEY, (H,), jnp.float32))
        out = ssd_chunk(x, dt, B_, C_, A, chunk=Q, interpret=True)
        ref = ssd_ref(x, dt, B_, C_, A)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-3, rtol=1e-3)


class TestMoEGemm:
    @pytest.mark.parametrize("E,C,D,F,dt", [
        (4, 100, 64, 192, jnp.float32),
        (8, 256, 128, 384, jnp.bfloat16),
        (2, 17, 256, 128, jnp.float32),   # ragged capacity
    ])
    def test_matches_ref(self, E, C, D, F, dt):
        ks = jax.random.split(KEY, 3)
        x = (jax.random.normal(ks[0], (E, C, D), jnp.float32) / 8).astype(dt)
        wg = (jax.random.normal(ks[1], (E, D, F), jnp.float32) / 8).astype(dt)
        wu = (jax.random.normal(ks[2], (E, D, F), jnp.float32) / 8).astype(dt)
        e1 = float(jnp.max(jnp.abs(
            moe_gemm(x, wg, interpret=True).astype(jnp.float32)
            - moe_gemm_ref(x, wg).astype(jnp.float32))))
        e2 = float(jnp.max(jnp.abs(
            moe_ffn_fused(x, wg, wu, interpret=True).astype(jnp.float32)
            - moe_ffn_fused_ref(x, wg, wu).astype(jnp.float32))))
        assert e1 < tol(dt) and e2 < tol(dt), (e1, e2)

    @settings(max_examples=8, deadline=None)
    @given(E=st.integers(1, 5), D=st.sampled_from([32, 64]),
           F=st.sampled_from([64, 128]), seed=st.integers(0, 10_000))
    def test_ragged_and_empty_groups_property(self, E, D, F, seed):
        """Adapter-multiplexing dispatch shape: per-group row counts are
        ragged and may be ZERO, and rows past each group's count hold
        garbage. The kernel's result for the valid rows must match the
        oracle exactly — padding garbage must never leak into them."""
        rng = np.random.default_rng(seed)
        sizes = rng.integers(0, 7, size=E)          # empty groups allowed
        C = max(int(sizes.max()), 1)
        x = np.full((E, C, D), 1e6, np.float32)     # garbage padding
        for e, s in enumerate(sizes):
            x[e, :s] = rng.standard_normal((s, D)).astype(np.float32) / 8
        w = rng.standard_normal((E, D, F)).astype(np.float32) / 8
        out = np.asarray(grouped_gemm(jnp.asarray(x), jnp.asarray(w),
                                      block_c=64, block_f=64))
        ref = np.asarray(moe_gemm_ref(jnp.asarray(x), jnp.asarray(w)))
        for e, s in enumerate(sizes):
            np.testing.assert_allclose(out[e, :s], ref[e, :s],
                                       atol=5e-5, rtol=1e-4)
