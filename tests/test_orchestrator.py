"""End-to-end lifecycle integration (orchestrator + real engines)."""

import numpy as np
import pytest

from repro.core import Orchestrator, default_asp, SessionError
from repro.core.asp import MobilityClass, QualityTier
from repro.core.clock import VirtualClock
from repro.core.failures import FailureCause, Timers
from repro.core.session import SessionState


@pytest.fixture()
def orch():
    return Orchestrator(clock=VirtualClock())


class TestLifecycle:
    def test_establish_serve_release(self, orch):
        s = orch.establish(default_asp(), "alice", "zone-a")
        assert s.state is SessionState.COMMITTED
        for _ in range(10):
            r = orch.serve(s, prompt_tokens=128, gen_tokens=16)
            assert r.completed
        rep = orch.compliance(s)
        assert rep is not None and rep.z.n == 10
        charge = orch.policy.charging(s.charging_ref)
        assert charge.tokens == 160
        orch.release(s)
        assert s.state is SessionState.RELEASED
        with pytest.raises(SessionError):
            orch.serve(s)

    def test_establish_failure_has_cause_and_no_leak(self, orch):
        import dataclasses
        bad = dataclasses.replace(default_asp(), allowed_regions=("mars",))
        before = {sid: site.slots_in_use()
                  for sid, site in orch.sites.items()}
        with pytest.raises(SessionError) as ei:
            orch.establish(bad, "bob", "zone-a")
        assert ei.value.cause in (FailureCause.NO_FEASIBLE_BINDING,
                                  FailureCause.SOVEREIGNTY_VIOLATION)
        after = {sid: site.slots_in_use() for sid, site in orch.sites.items()}
        assert before == after

    def test_concurrent_sessions_capacity(self, orch):
        """Admit sessions up to edge capacity; the system must degrade by
        cause, not by partial allocation."""
        ok, failed = 0, 0
        for i in range(30):
            try:
                orch.establish(default_asp(), f"ue-{i}", "zone-a")
                ok += 1
            except SessionError as e:
                failed += 1
                assert e.cause in (FailureCause.COMPUTE_SCARCITY,
                                   FailureCause.QOS_SCARCITY,
                                   FailureCause.NO_FEASIBLE_BINDING)
        assert ok >= 20

    def test_heartbeat_renews(self, orch):
        orch.timers = Timers(lease_s=5.0)
        orch.coordinator.timers = orch.timers
        s = orch.establish(default_asp(), "c", "zone-a")
        for _ in range(4):
            orch.clock.advance(3.0)
            orch.heartbeat(s)
        assert s.committed()     # 12 s elapsed > lease; renewed via heartbeat

    def test_lease_lapse_without_heartbeat(self, orch):
        orch.timers = Timers(lease_s=5.0)
        orch.coordinator.timers = orch.timers
        s = orch.establish(default_asp(), "d", "zone-a")
        orch.clock.advance(6.0)
        assert not s.committed()
        with pytest.raises(SessionError) as ei:
            orch.serve(s)
        assert ei.value.cause is FailureCause.DEADLINE_EXPIRY


class TestRealEngineIntegration:
    def test_served_by_real_model_with_migration(self):
        from repro.serving.server import AIaaSServer
        orch = Orchestrator(clock=VirtualClock())
        server = AIaaSServer(orch, "edge-tiny", slots=4, max_len=96)
        asp = default_asp(mobility=MobilityClass.VEHICULAR)
        s = orch.establish(asp, "car", "zone-a")
        eng = server.fleet.engine_for(s.binding.site_id)
        prompt = np.arange(12, dtype=np.int32)
        eng.prefill_session(s.session_id, prompt)
        pre_tok = [eng.decode_round()[s.session_id] for _ in range(3)]
        # oracle: continuation the SOURCE would produce, captured on a probe
        # engine before the swap (the source slot is released at commit)
        from repro.serving import state_transfer
        from repro.serving.engine import InferenceEngine
        probe = InferenceEngine(eng.cfg, params=eng.params, slots=1,
                                max_len=96)
        state_transfer.transfer(eng, probe, s.session_id)
        src_would = [probe.decode_round()[s.session_id] for _ in range(3)]
        out = orch.migrations.migrate(s, "zone-a")
        assert out.migrated and s.committed()
        assert not eng.has_slot(s.session_id), \
            "source slot must be released after the MBB swap"
        dst = server.fleet.engine_for(s.binding.site_id)
        post = [dst.decode_round()[s.session_id] for _ in range(3)]
        assert post == src_would, "state transfer changed generation"
