import importlib.util
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

if importlib.util.find_spec("hypothesis") is None:
    # Offline fallback: the real hypothesis comes from the `test` extra
    # (pyproject.toml); on machines without an index this API-compatible
    # deterministic stub keeps the property-test modules collectable.
    from tests import _hypothesis_stub
    _hypothesis_stub.install()
