"""int8 weight-only serving (beyond-paper §Perf lever) correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.models import LM
from repro.models.frontends import make_batch
from repro.models.quant import (abstract_quantize_tree, as_weight,
                                is_quantized, quantize_tree, quantize_weight)


class TestQuantPrimitives:
    def test_roundtrip_error_bounded(self):
        w = jax.random.normal(jax.random.key(0), (64, 128), jnp.float32)
        q = quantize_weight(w)
        deq = as_weight(q, jnp.float32)
        err = jnp.max(jnp.abs(deq - w))
        assert float(err) <= float(jnp.max(q["s"])) * 0.51

    def test_stacked_scales_per_layer(self):
        w = jax.random.normal(jax.random.key(1), (4, 32, 64), jnp.float32)
        q = quantize_weight(w)
        assert q["q"].shape == (4, 32, 64)
        assert q["s"].shape == (4, 1, 64)   # per-(layer, out-channel)

    def test_exclusions(self):
        params = {"embed": jnp.ones((512, 64), jnp.bfloat16),
                  "mlp": {"w_gate": jnp.ones((64, 128), jnp.bfloat16)},
                  "norm1": {"scale": jnp.ones((4, 64), jnp.float32)}}
        qt = quantize_tree(params, min_size=16)
        assert not is_quantized(qt["embed"])
        assert not is_quantized(qt["norm1"]["scale"])
        assert is_quantized(qt["mlp"]["w_gate"])

    def test_abstract_matches_concrete(self):
        lm = LM(get_config("edge-tiny"))
        params = lm.init(jax.random.key(0))
        qt = quantize_tree(params)
        at = abstract_quantize_tree(lm.param_specs())
        s1 = jax.tree.map(lambda l: (l.shape, str(l.dtype)), qt)
        s2 = jax.tree.map(lambda l: (l.shape, str(l.dtype)), at)
        assert jax.tree.all(jax.tree.map(lambda a, b: a == b, s1, s2))


@pytest.mark.parametrize("arch", ["edge-tiny", "mixtral-8x7b",
                                  "mamba2-1.3b", "recurrentgemma-2b"])
def test_int8_forward_agrees(arch):
    cfg = get_config(arch) if arch == "edge-tiny" else get_smoke_config(arch)
    lm = LM(cfg)
    key = jax.random.key(0)
    params = lm.init(key)
    params_q = quantize_tree(params, min_size=256)
    batch = make_batch(cfg, key, 2, 16)
    lb, _ = jax.jit(lm.forward)(params, batch)
    lq, _ = jax.jit(lm.forward)(params_q, batch)
    agree = float(jnp.mean(jnp.argmax(lb, -1) == jnp.argmax(lq, -1)))
    assert agree > 0.8, f"{arch}: top-1 agreement {agree}"


def test_int8_decode_path(key=jax.random.key(3)):
    """Quantised weights through prefill + decode (the serving hot path)."""
    cfg = get_config("edge-tiny")
    lm = LM(cfg)
    params = quantize_tree(lm.init(key))
    batch = {"tokens": jax.random.randint(key, (1, 12), 0,
                                          cfg.vocab_size, jnp.int32)}
    logits, cache = jax.jit(lambda p, b: lm.prefill(p, b, 32))(params, batch)
    tok = jnp.argmax(logits, -1)[:, None]
    for _ in range(4):
        logits, cache = jax.jit(lm.decode_step)(params, cache, tok)
        tok = jnp.argmax(logits[:, 0], -1)[:, None]
        assert not bool(jnp.isnan(logits).any())
