"""Unreliable control plane: the netfault layer and everything wired to it.

Covers the deterministic lossy wire (drop/delay/duplicate/reorder/corrupt/
partition from one seed), the budget-aware retry engine, the circuit
breaker state machine, the orphan-lease reaper, the gateway's deadline
floors and idempotency-window eviction, typed renewal lapse on the client,
east-west PREPARE replay idempotency — and, as property tests over seeded
fault schedules, the paper's safety invariant: after ANY fault sequence a
session is fully established exactly once OR every lease is released and
no charging record stays open.
"""

import pytest

from repro.api import messages as m
from repro.api.client import (DeadlineExceeded, LeaseLapsed, NorthboundError,
                              SessionClient)
from repro.api.gateway import NorthboundGateway
from repro.core.asp import QualityTier, default_asp
from repro.core.clock import VirtualClock
from repro.core.failures import RETRYABLE, FailureCause, SessionError
from repro.netfault import (BOTH, REQUEST, RESPONSE, BreakerBoard,
                            CircuitBreaker, FaultPlan, LossyChannel,
                            OrphanReaper, RetryPolicy, TransportError,
                            TransportTimeout, attach)

from hypothesis import given, settings
from hypothesis import strategies as st


def send(gw, msg):
    out = gw.handle_json(msg.to_json())
    if isinstance(out, list):
        return [m.from_json(o) for o in out]
    return m.from_json(out)


class _Echo:
    """Recording endpoint: remembers every delivered payload."""

    def __init__(self):
        self.seen = []

    def __call__(self, payload):
        self.seen.append(payload)
        return f"ack:{payload}"


# ----------------------------------------------------------------------
# the lossy wire
# ----------------------------------------------------------------------
class TestFaultPlan:
    def test_validate_rejects_bad_probability(self):
        with pytest.raises(ValueError):
            LossyChannel(_Echo(), VirtualClock(),
                         FaultPlan(p_drop_request=1.5))

    def test_validate_rejects_bad_partition(self):
        with pytest.raises(ValueError):
            FaultPlan(partitions=((2.0, 1.0, BOTH),)).validate()
        with pytest.raises(ValueError):
            FaultPlan(partitions=((0.0, 1.0, "sideways"),)).validate()

    def _drive(self, plan, n=200):
        clock, server = VirtualClock(), _Echo()
        chan = LossyChannel(server, clock, plan)
        outcomes = []
        for i in range(n):
            try:
                outcomes.append(("ok", chan(f"msg-{i}")))
            except TransportError as e:
                outcomes.append(("err", type(e).__name__))
        return outcomes, dict(chan.stats), list(server.seen), clock.now()

    def test_same_seed_replays_identical_schedule(self):
        plan = FaultPlan.uniform(0.12, seed=42)
        a = self._drive(plan)
        b = self._drive(plan)
        assert a == b                     # outcomes, stats, deliveries, time

    def test_different_seed_differs(self):
        a = self._drive(FaultPlan.uniform(0.12, seed=1))
        b = self._drive(FaultPlan.uniform(0.12, seed=2))
        assert a[1] != b[1]

    def test_drop_response_is_a_lost_commit(self):
        """The defining 2PC ambiguity: the server processed the request,
        only the reply died — caller times out, state already mutated."""
        clock, server = VirtualClock(), _Echo()
        chan = LossyChannel(server, clock,
                            FaultPlan(p_drop_response=1.0, timeout_s=0.05))
        with pytest.raises(TransportTimeout):
            chan("commit")
        assert server.seen == ["commit"]
        assert clock.now() == pytest.approx(0.05)

    def test_corrupt_frame_never_reaches_the_server(self):
        clock, server = VirtualClock(), _Echo()
        chan = LossyChannel(server, clock, FaultPlan(p_corrupt=1.0))
        with pytest.raises(TransportTimeout):
            chan("payload")
        assert server.seen == []          # link-layer CRC discard

    def test_duplicate_delivers_twice_caller_sees_one_reply(self):
        clock, server = VirtualClock(), _Echo()
        chan = LossyChannel(server, clock, FaultPlan(p_duplicate=1.0))
        assert chan("a") == "ack:a"
        assert server.seen == ["a", "a"]

    def test_reorder_replays_the_previous_request_first(self):
        clock, server = VirtualClock(), _Echo()
        chan = LossyChannel(server, clock, FaultPlan(p_reorder=1.0))
        chan("first")                     # nothing held yet: clean delivery
        chan("second")
        assert server.seen == ["first", "first", "second"]

    def test_partition_window_drops_one_direction(self):
        clock, server = VirtualClock(), _Echo()
        chan = LossyChannel(
            server, clock,
            FaultPlan(partitions=((0.0, 10.0, REQUEST),), timeout_s=0.5))
        with pytest.raises(TransportTimeout):
            chan("in-window")
        assert server.seen == []
        clock.advance(10.0)               # window over (0.5 already burned)
        assert chan("after") == "ack:after"
        # response-direction partition: request still lands server-side
        chan2 = LossyChannel(
            server, clock,
            FaultPlan(partitions=((0.0, 1e9, RESPONSE),), timeout_s=0.5))
        with pytest.raises(TransportTimeout):
            chan2("one-way")
        assert server.seen[-1] == "one-way"


# ----------------------------------------------------------------------
# retry policy
# ----------------------------------------------------------------------
class TestRetryPolicy:
    def test_backoff_is_deterministic_and_capped(self):
        p = RetryPolicy(base_s=0.01, cap_s=0.5, seed=7)
        for attempt in range(1, 9):
            a = p.backoff_s(attempt, key="COMMIT")
            assert a == p.backoff_s(attempt, key="COMMIT")
            assert 0.0 <= a <= min(0.5, 0.01 * 2 ** (attempt - 1))
        assert p.backoff_s(3, key="COMMIT") != \
            RetryPolicy(base_s=0.01, cap_s=0.5, seed=8).backoff_s(
                3, key="COMMIT")

    def test_retryability_follows_the_remediation_classes(self):
        p = RetryPolicy()
        assert p.retryable(TransportTimeout("lost"))
        for cause in FailureCause:
            assert p.retryable(cause) == (cause in RETRYABLE)
            assert p.retryable(SessionError(cause, "x")) == \
                (cause in RETRYABLE)
        assert not p.retryable(ValueError("not a wire failure"))

    def test_budget_gates_the_next_sleep(self):
        p = RetryPolicy(max_attempts=10, base_s=0.1, cap_s=0.1, seed=3)
        err = TransportTimeout("lost")
        assert p.should_retry(err, 1, remaining_s=None)
        assert not p.should_retry(err, 1, remaining_s=0.0)
        # the drawn backoff must FIT in what remains
        assert not p.should_retry(err, 1,
                                  remaining_s=p.backoff_s(1) * 0.5)
        assert p.should_retry(err, 1, remaining_s=p.backoff_s(1) + 1.0)

    def test_attempt_cap_and_terminal_causes(self):
        p = RetryPolicy(max_attempts=3)
        err = TransportTimeout("lost")
        assert p.should_retry(err, 2)
        assert not p.should_retry(err, 3)
        assert not p.should_retry(
            SessionError(FailureCause.DEADLINE_EXCEEDED, "x"), 1)
        assert not p.should_retry(
            SessionError(FailureCause.POLICY_DENIAL, "x"), 1)

    def test_rejects_nonsense_configuration(self):
        with pytest.raises(ValueError):
            RetryPolicy(max_attempts=0)
        with pytest.raises(ValueError):
            RetryPolicy(base_s=0.5, cap_s=0.1)


# ----------------------------------------------------------------------
# circuit breaker
# ----------------------------------------------------------------------
class TestCircuitBreaker:
    def test_trips_after_consecutive_failures_only(self):
        clock = VirtualClock()
        b = CircuitBreaker(clock, failure_threshold=3, cooldown_s=5.0)
        b.record(False); b.record(False); b.record(True)   # streak broken
        b.record(False); b.record(False)
        assert b.state == "closed" and b.allow()
        b.record(False)
        assert b.state == "open" and not b.allow()

    def test_half_open_admits_exactly_one_probe(self):
        clock = VirtualClock()
        b = CircuitBreaker(clock, failure_threshold=1, cooldown_s=5.0)
        b.record(False)
        assert not b.allow()
        clock.advance(5.001)              # strictly past the cooldown
        assert b.allow()                  # the single probe
        assert b.state == "half-open"
        assert not b.allow()              # everyone else still blocked
        b.record(True)
        assert b.state == "closed" and b.allow()

    def test_failed_probe_reopens_with_fresh_cooldown(self):
        clock = VirtualClock()
        b = CircuitBreaker(clock, failure_threshold=1, cooldown_s=5.0)
        b.record(False)
        clock.advance(5.001)
        assert b.allow()
        b.record(False)                   # probe died
        assert b.state == "open" and not b.allow()
        clock.advance(5.001)
        assert b.allow()                  # next window, next probe
        states = [s for _, s in b.transitions]
        assert states == ["open", "half-open", "open", "half-open"]

    def test_board_keeps_targets_independent(self):
        clock = VirtualClock()
        board = BreakerBoard(clock, failure_threshold=1)
        board.record("site-a", False)
        assert not board.allow("site-a")
        assert board.allow("site-b")
        assert board.snapshot() == {"site-a": "open", "site-b": "closed"}
        assert board.state("never-seen") == "closed"

    def test_administrative_reset_closes_without_cooldown(self):
        """A fleet-ops heal verdict (mark_domain_alive) must not wait out
        the cooldown: reset() closes the circuit immediately."""
        clock = VirtualClock()
        board = BreakerBoard(clock, failure_threshold=1, cooldown_s=5.0)
        board.record("peer", False)
        assert not board.allow("peer")
        board.reset("peer")               # no clock.advance
        assert board.state("peer") == "closed" and board.allow("peer")
        board.reset("never-seen")         # unknown target is a no-op

    def test_mark_domain_alive_resets_peer_breaker(self):
        """End-to-end heal: a partition trips the peer breaker; the
        operator's mark_domain_alive verdict re-admits the peer at once
        instead of leaving post-heal establishes 'circuit-open'."""
        from tests.test_federation import make_federation

        _clock, home, visited = make_federation()
        for _ in range(3):                # trip (threshold 3)
            home.peer_breakers.record("visited", False)
        assert home.peer_breakers.state("visited") == "open"
        home.mark_domain_dead("visited")
        home.mark_domain_alive("visited")
        assert home.peer_breakers.state("visited") == "closed"
        assert home.peer_breakers.allow("visited")


# ----------------------------------------------------------------------
# orphan reaper
# ----------------------------------------------------------------------
class TestOrphanReaper:
    def test_sweep_aggregates_counts_and_lists(self):
        r = OrphanReaper()
        r.register("ints", lambda: 2)
        r.register("lists", lambda: ["a", "b", "c"])
        r.register("none", lambda: None)
        assert r.sweep() == {"ints": 2, "lists": 3, "none": 0}
        assert r.sweep() == {"ints": 2, "lists": 3, "none": 0}
        assert r.total_reaped == 10

    def test_attach_wires_every_plane(self):
        class Gateway:
            def reap_orphans(self):
                return ["s1"]

        class Coordinator:
            def reap(self):
                return 2

        class Domain:
            def __init__(self, domain_id):
                self.domain_id = domain_id

            def tick(self):
                return 1

        r = attach(gateway=Gateway(), coordinator=Coordinator(),
                   domains=[Domain("home"), Domain("visited")])
        assert r.sweep() == {"coordinator": 2, "gateway": 1,
                             "domain:home": 1, "domain:visited": 1}


# ----------------------------------------------------------------------
# gateway: deadline floors, eviction, failure re-reporting
# ----------------------------------------------------------------------
class TestGatewayDeadlines:
    def test_discover_floor_rejects_before_any_state_exists(self):
        gw = NorthboundGateway(clock=VirtualClock())
        err = send(gw, m.DiscoverRequest(invoker="ue", zone="zone-a",
                                         asp=default_asp(), deadline_ms=1.0))
        assert err.code == "E_DEADLINE_EXCEEDED"
        assert "[gateway]" in err.detail          # attributable per hop
        assert gw.orch.sessions == {}             # nothing to reap later

    def test_mid_establishment_floor_does_not_fail_the_session(self):
        """A budget too small for the NEXT phase is the CALLER's problem
        (send more budget, or give up) — the session must survive so a
        re-send with a sane budget can continue the establishment."""
        gw = NorthboundGateway(clock=VirtualClock())
        disc = send(gw, m.DiscoverRequest(invoker="ue", zone="zone-a",
                                          asp=default_asp()))
        sid = disc.session_id
        err = send(gw, m.PageRequest(session_id=sid, deadline_ms=0.5))
        assert err.code == "E_DEADLINE_EXCEEDED"
        assert "AI-PAGING" in err.detail
        paged = send(gw, m.PageRequest(session_id=sid, deadline_ms=5_000.0))
        assert isinstance(paged, m.PageResponse)  # same session, unharmed

    def test_retry_recarrying_less_budget_is_the_same_request(self):
        """At-least-once re-sends legitimately shrink deadline_ms; the
        idempotency fingerprint must NOT read that as a conflict."""
        gw = NorthboundGateway(clock=VirtualClock())
        disc = send(gw, m.DiscoverRequest(invoker="ue", zone="zone-a",
                                          asp=default_asp()))
        sid = disc.session_id
        send(gw, m.PageRequest(session_id=sid))
        prep = send(gw, m.PrepareRequest(session_id=sid,
                                         idempotency_key="p",
                                         deadline_ms=10_000.0))
        assert isinstance(prep, m.PrepareResponse)
        retry = send(gw, m.PrepareRequest(session_id=sid,
                                          idempotency_key="p",
                                          deadline_ms=3_000.0))
        assert isinstance(retry, m.PrepareResponse)
        assert retry.prepared_ref == prep.prepared_ref


class TestGatewayIdempotencyEviction:
    def _establish(self, gw, i):
        disc = send(gw, m.DiscoverRequest(invoker=f"ue-{i}", zone="zone-a",
                                          asp=default_asp()))
        sid = disc.session_id
        send(gw, m.PageRequest(session_id=sid))
        prep = send(gw, m.PrepareRequest(session_id=sid,
                                         idempotency_key=f"p-{i}"))
        com = send(gw, m.CommitRequest(session_id=sid,
                                       prepared_ref=prep.prepared_ref,
                                       idempotency_key=f"c-{i}"))
        assert isinstance(com, m.CommitResponse)
        return sid

    def test_evicted_key_refuses_attributably_not_by_replaying(self):
        """A retry whose key aged out of the bounded window must get
        E_IDEMPOTENCY_EVICTED — re-running the procedure could double
        -reserve, and E_BAD_REQUEST would lie about what happened."""
        gw = NorthboundGateway(clock=VirtualClock(), idempotency_window=2)
        sid0 = self._establish(gw, 0)
        used_before = sum(s.slots_in_use()
                          for s in gw.orch.sites.values())
        self._establish(gw, 1)            # four keyed ops: c-0 ages out
        retry = send(gw, m.CommitRequest(session_id=sid0,
                                         prepared_ref="prep-000001",
                                         idempotency_key="c-0"))
        assert retry.code == "E_IDEMPOTENCY_EVICTED"
        assert "aged out" in retry.detail
        # crucially: nothing re-ran — session 0 still holds exactly its
        # original reservation
        used_after = sum(s.slots_in_use() for s in gw.orch.sites.values())
        assert used_after == used_before + 1      # just session 1's slot
        assert gw.orch.sessions[sid0].committed()

    def test_fresh_keys_still_work_after_evictions(self):
        gw = NorthboundGateway(clock=VirtualClock(), idempotency_window=2)
        for i in range(4):
            self._establish(gw, i)


class TestFailedSessionRetryReporting:
    def test_retry_after_failed_page_re_reports_the_original_cause(self):
        """PAGE fails (every site excluded) and the RESPONSE is lost: the
        re-sent PAGE must re-report the original failure cause — not
        E_BAD_REQUEST 'PAGE before DISCOVER' just because the pending
        state was dropped when the session failed."""
        gw = NorthboundGateway(clock=VirtualClock())
        disc = send(gw, m.DiscoverRequest(invoker="ue", zone="zone-a",
                                          asp=default_asp()))
        sid = disc.session_id
        all_sites = list(gw.orch.sites.keys())
        first = send(gw, m.PageRequest(session_id=sid,
                                       exclude_sites=all_sites))
        assert isinstance(first, m.ErrorResponse)
        assert first.code != "E_BAD_REQUEST"
        retry = send(gw, m.PageRequest(session_id=sid,
                                       exclude_sites=all_sites))
        assert retry.code == first.code
        assert "re-reports the original outcome" in retry.detail


# ----------------------------------------------------------------------
# client: lossy establish, budget exhaustion, typed renewal lapse
# ----------------------------------------------------------------------
class _FlakyTransport:
    """Switchable wrapper: healthy until ``down`` is set."""

    def __init__(self, inner):
        self.inner = inner
        self.down = False
        self.heartbeats = 0

    def __call__(self, payload):
        if self.down:
            raise TransportTimeout("link down")
        if '"heartbeat_report"' in payload:
            self.heartbeats += 1
        return self.inner(payload)


class TestClientUnderLoss:
    def test_establish_retries_through_heavy_loss_exactly_once(self):
        clock = VirtualClock()
        gw = NorthboundGateway(clock=clock)
        chan = LossyChannel(gw.handle_json, clock,
                            FaultPlan.uniform(0.25, seed=5))
        client = SessionClient(gw, default_asp(tier=QualityTier.BASIC),
                               invoker="ue-loss", subscribe_events=False,
                               transport=chan, clock=clock,
                               retry=RetryPolicy(seed=5),
                               deadline_ms=60_000.0)
        client.establish()
        committed = [s for s in gw.orch.sessions.values() if s.committed()]
        assert len(committed) == 1        # exactly once, however many tries
        assert sum(s.slots_in_use()
                   for s in gw.orch.sites.values()) == 1

    def test_exhausted_budget_is_typed_and_leaves_nothing_behind(self):
        clock = VirtualClock()
        gw = NorthboundGateway(clock=clock)
        # every attempt times out; the budget drains 50ms at a time
        chan = LossyChannel(gw.handle_json, clock,
                            FaultPlan(p_drop_request=1.0, timeout_s=0.05))
        client = SessionClient(gw, default_asp(), invoker="ue-dead",
                               subscribe_events=False, transport=chan,
                               clock=clock, retry=RetryPolicy(seed=1),
                               deadline_ms=120.0)
        with pytest.raises((DeadlineExceeded, TransportError)):
            client.establish()
        assert all(not s.committed() for s in gw.orch.sessions.values())
        assert sum(s.slots_in_use() for s in gw.orch.sites.values()) == 0

    def test_sub_floor_budget_refused_by_the_first_hop(self):
        clock = VirtualClock()
        gw = NorthboundGateway(clock=clock)
        client = SessionClient(gw, default_asp(), invoker="ue-tiny",
                               subscribe_events=False, clock=clock,
                               deadline_ms=10.0)    # < 50ms DISCOVER floor
        with pytest.raises(DeadlineExceeded) as ei:
            client.establish()
        assert "[gateway]" in str(ei.value)
        assert gw.orch.sessions == {}

    def test_renewal_failure_after_retries_is_a_typed_lapse(self):
        clock = VirtualClock()
        gw = NorthboundGateway(clock=clock)
        flaky = _FlakyTransport(gw.handle_json)
        client = SessionClient(gw, default_asp(), invoker="ue-renew",
                               subscribe_events=False, transport=flaky,
                               clock=clock, retry=RetryPolicy(seed=2),
                               renew_margin=0.0, renew_skew_s=0.5)
        client.establish()
        flaky.down = True
        with pytest.raises(LeaseLapsed) as ei:
            client.generate(prompt_tokens=16, gen_tokens=4)
        assert "may have lapsed" in str(ei.value)

    def test_skew_allowance_renews_early(self):
        """renew_skew_s shifts the renewal point EARLIER by the tolerated
        clock skew — the lease is refreshed before a slow client clock
        would have let it lapse."""
        def run(skew):
            clock = VirtualClock()
            gw = NorthboundGateway(clock=clock)
            flaky = _FlakyTransport(gw.handle_json)
            c = SessionClient(gw, default_asp(), invoker="ue-skew",
                              subscribe_events=False, transport=flaky,
                              clock=clock, renew_margin=0.5,
                              renew_skew_s=skew)
            c.establish()                 # lease_s = 30 ⇒ due = 15 − skew
            clock.advance(14.0)
            c.generate(gen_tokens=2)      # observes server t≈14 afterwards
            clock.advance(0.5)
            c.generate(gen_tokens=2)      # _maybe_renew sees age ≈ 14
            return flaky.heartbeats
        assert run(7.5) == run(0.0) + 1   # due 7.5 fires, due 15 does not


# ----------------------------------------------------------------------
# east-west: PREPARE replay idempotency under at-least-once delivery
# ----------------------------------------------------------------------
class TestEastWestReplay:
    def _pair(self):
        from tests.test_federation import make_federation
        return make_federation()

    def test_prepare_key_replay_returns_original_without_reserving(self):
        from repro.federation import eastwest as ew
        clock, home, visited = self._pair()
        req = ew.EWPrepare(
            home_domain="home", session_ref="ais-x", model_id="edge-tiny",
            model_version="1.0", site_id="v-edge", klass="best-effort",
            zone="zone-a", prepare_key="home/ais-x/pk-000001")
        first = ew.from_json(visited.handle_eastwest_json(req.to_json()))
        assert isinstance(first, ew.EWPrepared)
        used = visited.core.sites["v-edge"].slots_in_use()
        replay = ew.from_json(visited.handle_eastwest_json(req.to_json()))
        assert isinstance(replay, ew.EWPrepared)
        assert replay.prepared_ref == first.prepared_ref
        assert visited.core.sites["v-edge"].slots_in_use() == used

    def test_lossy_eastwest_establish_converges(self):
        """Home saturated ⇒ every establish spills east-west over a lossy
        peer link; retries + prepare_key idempotency must converge to
        exactly-once without stranding visited guest state."""
        from tests.test_federation import make_federation, saturate
        clock, home, visited = make_federation(solicit="always")
        saturate(home.core.sites["h-edge"], home.core.catalog.get("edge-tiny"))
        real = home.peers["visited"]
        home.peers["visited"] = LossyChannel(
            real, clock, FaultPlan.uniform(0.10, seed=11), name="ew")
        gw = NorthboundGateway(home)
        ok = 0
        for i in range(8):
            client = SessionClient(
                gw, default_asp(tier=QualityTier.BASIC),
                invoker=f"ue-ew-{i}", subscribe_events=False, clock=clock,
                retry=RetryPolicy(seed=100 + i), deadline_ms=30_000.0)
            try:
                client.establish()
                ok += 1
            except NorthboundError:
                pass
            visited.tick()
        timers = home.core.timers
        clock.advance(timers.tau_prep + timers.tau_com + 1.0)
        home.core.coordinator.reap()
        visited.core.coordinator.reap()
        visited.tick()
        assert ok >= 6                    # loss hurts, it must not wedge
        committed_guests = sum(
            1 for g in visited._guest_by_ref.values() if g.committed)
        assert committed_guests == ok
        assert len(visited._guest_by_ref) == committed_guests
        assert visited.core.sites["v-edge"].slots_in_use() == ok


# ----------------------------------------------------------------------
# property tests: the safety invariant under seeded fault schedules
# ----------------------------------------------------------------------
class TestLossyControlPlaneProperties:
    @settings(max_examples=6)
    @given(st.integers(min_value=0, max_value=2 ** 16),
           st.sampled_from([0.02, 0.08, 0.15]))
    def test_established_exactly_once_or_fully_released(self, seed, loss):
        """Under drop/delay/duplicate/reorder/corrupt on BOTH the
        northbound and east-west paths: every offered session either
        establishes exactly once (its slot accounted) or leaves zero
        provisional leases and zero open charging after the sweeps."""
        from repro.sim.scenarios import simulate_lossy_control_plane
        r = simulate_lossy_control_plane(n_sessions=6, loss=loss, seed=seed)
        assert r.established + r.failed == r.n_offered
        assert r.orphaned_after_sweep == 0
        assert r.charging_open == 0

    def test_schedule_replays_deterministically_from_its_seed(self):
        from repro.sim.scenarios import simulate_lossy_control_plane
        a = simulate_lossy_control_plane(n_sessions=8, loss=0.1, seed=1234)
        b = simulate_lossy_control_plane(n_sessions=8, loss=0.1, seed=1234)
        assert (a.established, a.failed, a.causes, a.wire,
                a.p99_establish_ms) == \
            (b.established, b.failed, b.causes, b.wire, b.p99_establish_ms)

    def test_transient_all_excluded_classifies_as_retryable_scarcity(self):
        """A DISCOVER where every exclusion is transient (saturation,
        dead/unreachable peers, open breakers) must classify as
        COMPUTE_SCARCITY — retryable — not terminal NO_FEASIBLE_BINDING."""
        from repro.core.discovery import Candidate, admissible_set
        cands = [
            Candidate(None, "h-edge", None, 0.0, None, False,
                      "home:compute-saturated"),
            Candidate(None, "v-edge", None, 0.0, None, False,
                      "visited:offer-timeout"),
            Candidate(None, "w-edge", None, 0.0, None, False,
                      "west:domain-dead"),
        ]
        with pytest.raises(SessionError) as ei:
            admissible_set(cands)
        assert ei.value.cause is FailureCause.COMPUTE_SCARCITY
        # one structurally-excluded candidate flips the class: relaxing
        # the objectives is the only remediation retry cannot provide
        cands.append(Candidate(None, "x-edge", None, 0.0, None, False,
                               "sovereignty"))
        with pytest.raises(SessionError) as ei:
            admissible_set(cands)
        assert ei.value.cause is FailureCause.NO_FEASIBLE_BINDING
