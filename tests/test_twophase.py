"""PREPARE/COMMIT atomicity: no partial allocation is ever observable."""

import pytest

from repro.core.catalog import default_catalog
from repro.core.clock import VirtualClock
from repro.core.failures import FailureCause, SessionError, Timers
from repro.core.qos import BEST_EFFORT, PREMIUM, QoSFlowManager
from repro.core.sites import default_sites
from repro.core.twophase import TwoPhaseCoordinator


@pytest.fixture()
def world():
    clock = VirtualClock()
    catalog = default_catalog()
    sites = default_sites(clock, tuple(catalog._entries.keys()))
    return clock, catalog, sites


def coordinator(clock, sites, *, premium_flows=32, timers=None):
    qos = QoSFlowManager(clock, premium_flows_per_path=premium_flows)
    return TwoPhaseCoordinator(clock, sites, qos,
                               timers or Timers()), qos


class TestAtomicity:
    def test_qos_failure_rolls_back_compute(self, world):
        clock, catalog, sites = world
        coord, qos = coordinator(clock, sites, premium_flows=0)
        model = catalog.get("edge-tiny")
        site = sites["edge-a"]
        before = site.slots_in_use()
        with pytest.raises(SessionError) as ei:
            coord.prepare(model, "edge-a", "zone-a", PREMIUM, slots=1,
                          cache_bytes=1e6)
        assert ei.value.cause is FailureCause.QOS_SCARCITY
        assert site.slots_in_use() == before, "compute lease leaked"

    def test_compute_failure_leaves_qos_untouched(self, world):
        clock, catalog, sites = world
        coord, qos = coordinator(clock, sites)
        model = catalog.get("edge-tiny")
        with pytest.raises(SessionError) as ei:
            coord.prepare(model, "edge-a", "zone-a", PREMIUM,
                          slots=10 ** 6, cache_bytes=1e6)
        assert ei.value.cause is FailureCause.COMPUTE_SCARCITY
        assert qos.in_use(("zone-a", "edge-a"), "premium") == 0

    def test_commit_confirms_both(self, world):
        clock, catalog, sites = world
        coord, qos = coordinator(clock, sites)
        model = catalog.get("edge-tiny")
        prep = coord.prepare(model, "edge-a", "zone-a", PREMIUM, slots=1,
                             cache_bytes=1e6)
        binding = coord.commit(prep, model)
        assert sites["edge-a"].lease_valid(binding.compute_lease_id)
        assert qos.lease_valid(binding.qos_lease_id)
        assert binding.qfi == prep.qfi

    def test_commit_after_provisional_expiry_rolls_back_both(self, world):
        clock, catalog, sites = world
        timers = Timers(tau_prep=0.1, tau_com=0.2, lease_s=30)
        coord, qos = coordinator(clock, sites, timers=timers)
        model = catalog.get("edge-tiny")
        prep = coord.prepare(model, "edge-a", "zone-a", PREMIUM, slots=1,
                             cache_bytes=1e6)
        clock.advance(1.0)   # past τ_com AND provisional TTLs
        with pytest.raises(SessionError) as ei:
            coord.commit(prep, model)
        assert ei.value.cause is FailureCause.DEADLINE_EXPIRY
        assert sites["edge-a"].slots_in_use() == 0
        assert qos.in_use(("zone-a", "edge-a"), "premium") == 0

    def test_abort_idempotent(self, world):
        clock, catalog, sites = world
        coord, qos = coordinator(clock, sites)
        model = catalog.get("edge-tiny")
        prep = coord.prepare(model, "edge-a", "zone-a", BEST_EFFORT, slots=1,
                             cache_bytes=1e6)
        coord.abort(prep)
        coord.abort(prep)      # second abort is a no-op
        assert sites["edge-a"].slots_in_use() == 0

    def test_model_not_resident_is_distinct_cause(self, world):
        clock, catalog, sites = world
        coord, _ = coordinator(clock, sites)
        model = catalog.get("edge-tiny")
        # strip hosting from edge-a
        spec = sites["edge-a"].spec
        sites["edge-a"].spec = type(spec)(**{**spec.__dict__,
                                             "hosted_models": ()})
        with pytest.raises(SessionError) as ei:
            coord.prepare(model, "edge-a", "zone-a", PREMIUM, slots=1,
                          cache_bytes=1e6)
        assert ei.value.cause is FailureCause.MODEL_UNAVAILABLE

    def test_capacity_exhaustion_exact(self, world):
        """Fill the site to capacity; the N+1-th PREPARE fails cleanly and
        earlier leases stay valid (no partial state anywhere)."""
        clock, catalog, sites = world
        coord, qos = coordinator(clock, sites, premium_flows=1000)
        model = catalog.get("edge-tiny")
        cap = sites["edge-a"].spec.decode_slots
        preps = [coord.prepare(model, "edge-a", "zone-a", BEST_EFFORT,
                               slots=1, cache_bytes=1.0)
                 for _ in range(cap)]
        with pytest.raises(SessionError) as ei:
            coord.prepare(model, "edge-a", "zone-a", BEST_EFFORT, slots=1,
                          cache_bytes=1.0)
        assert ei.value.cause is FailureCause.COMPUTE_SCARCITY
        assert sites["edge-a"].slots_in_use() == cap
        for p in preps:
            coord.abort(p)
        assert sites["edge-a"].slots_in_use() == 0
