"""Validate the multi-pod dry-run artifacts (deliverable e/g).

These tests read artifacts/dryrun/*.json produced by
``python -m repro.launch.dryrun --all --both-meshes``; they are skipped when
the artifacts are absent (CI without the 30-minute sweep) — the small-mesh
compile path is covered by tests/test_sharding_plan.py instead.
"""

import glob
import json
import os

import pytest

from repro.configs import ARCH_IDS, get_config
from repro.sharding import SHAPES, cell_runnable

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
ASSIGNED = [a for a in ARCH_IDS if a != "edge-tiny"]


def _load(mesh):
    out = {}
    for f in glob.glob(os.path.join(ART, f"*__{mesh}.json")):
        r = json.load(open(f))
        out[(r["arch"], r["shape"])] = r
    return out


needs_artifacts = pytest.mark.skipif(
    len(glob.glob(os.path.join(ART, "*.json"))) < 80,
    reason="dry-run artifacts not generated (run repro.launch.dryrun --all "
           "--both-meshes)")


@needs_artifacts
@pytest.mark.parametrize("mesh", ["pod16x16", "pod2x16x16"])
def test_all_40_cells_present_and_clean(mesh):
    recs = _load(mesh)
    assert len(recs) == 40, f"{len(recs)} records for {mesh}"
    errors = [(k, r.get("error")) for k, r in recs.items()
              if r["status"] == "error"]
    assert not errors, errors
    # skips exactly match the sub-quadratic rule
    for arch in ASSIGNED:
        for shape in SHAPES:
            ok, _ = cell_runnable(get_config(arch), shape)
            r = recs[(arch, shape)]
            assert (r["status"] == "ok") == ok, (arch, shape, r["status"])


@needs_artifacts
@pytest.mark.parametrize("mesh", ["pod16x16", "pod2x16x16"])
def test_everything_fits_hbm(mesh):
    bad = [(k, round(r["memory"]["peak_bytes_per_device"] / 1e9, 1))
           for k, r in _load(mesh).items()
           if r["status"] == "ok" and not r["memory"]["fits_hbm"]]
    assert not bad, f"cells over 16 GB/chip: {bad}"


@needs_artifacts
def test_roofline_terms_sane():
    recs = _load("pod16x16")
    for k, r in recs.items():
        if r["status"] != "ok":
            continue
        roof = r["roofline"]
        assert roof["flops_global"] > 0, k
        assert roof["roofline_bound_s"] > 0, k
        assert roof["dominant"] in ("compute", "memory", "collective")
        # loop-aware dot flops must cover a sane fraction of 6ND/2ND —
        # attention/causal overhead can push HLO above MODEL_FLOPS, remat
        # recompute up to ~4×; anything outside [0.2, 30] is an accounting bug
        ratio = r["model_flops"] / roof["flops_global"]
        assert 1 / 30 < ratio < 5.0, (k, ratio)


@needs_artifacts
def test_multipod_shards_the_pod_axis():
    """The 2×16×16 pass proves the pod axis shards: per-device batch work
    halves for batch-sharded train cells vs single-pod."""
    single = _load("pod16x16")
    multi = _load("pod2x16x16")
    for arch in ASSIGNED:
        s, m = single[(arch, "train_4k")], multi[(arch, "train_4k")]
        if s["status"] != "ok":
            continue
        assert m["mesh"]["devices"] == 512 and s["mesh"]["devices"] == 256
        ratio = (m["roofline"]["flops_per_device"]
                 / max(s["roofline"]["flops_per_device"], 1))
        assert ratio < 0.75, (arch, ratio)
