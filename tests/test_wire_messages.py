"""Wire-layer property tests: every northbound message type survives the
JSON round trip bit-identically, and the Eq. (12) failure-cause ↔ error-code
mapping is exhaustive and bijective."""

import json

import pytest
from hypothesis import given, settings, strategies as st

from repro.api import messages as m
from repro.core.asp import (ASP, ASP_SCHEMA_VERSION, InteractionMode,
                            Modality, MobilityClass, Objectives, QualityTier,
                            default_asp)
from repro.core.failures import FailureCause


def make_asp(tier=2, mobility="static", cost=1.0, ladder=()):
    return ASP(
        modality=Modality.TEXT_GEN,
        interaction=InteractionMode.STREAMING,
        objectives=Objectives(ttfb_ms=100.0, p95_ms=300.0, p99_ms=500.0,
                              rho_min=0.99, t_max_ms=1000.0, nu_min=5.0),
        tier=QualityTier(tier), mobility=MobilityClass(mobility),
        max_cost_per_1k_tokens=cost,
        fallback_ladder=tuple(ladder))


#: one representative instance per wire type — the exhaustiveness test
#: fails when a new message type is registered without an example here
EXAMPLES = {
    "discover_request": m.DiscoverRequest(
        invoker="alice", zone="zone-a", asp=default_asp()),
    "discover_response": m.DiscoverResponse(
        session_id="ais-000001",
        candidates=[{"model_id": "edge-tiny", "model_version": "1.0",
                     "site_id": "edge-a", "klass": "premium",
                     "admissible": True, "slack": 212.5,
                     "exclusion_reason": ""}]),
    "page_request": m.PageRequest(session_id="ais-000001",
                                  exclude_sites=["edge-a"]),
    "page_response": m.PageResponse(
        session_id="ais-000001", model_id="edge-tiny", model_version="1.0",
        site_id="edge-b", klass="premium", predicted_cost_per_1k=0.07),
    "prepare_request": m.PrepareRequest(session_id="ais-000001",
                                        idempotency_key="k-1"),
    "prepare_response": m.PrepareResponse(
        session_id="ais-000001", prepared_ref="prep-000001",
        site_id="edge-b", qfi=7),
    "commit_request": m.CommitRequest(session_id="ais-000001",
                                      prepared_ref="prep-000001",
                                      idempotency_key="k-2"),
    "commit_response": m.CommitResponse(
        session_id="ais-000001", record={"anchor": "edge-b", "qfi": 7},
        lease_s=30.0, at_s=1.25),
    "serve_request": m.ServeRequest(
        session_id="ais-000001", prompt_tokens=64, gen_tokens=8,
        prompt=[1, 2, 3], stream=True, request_id="r-1"),
    "submit_ack": m.SubmitAck(session_id="ais-000001", request_id="r-1",
                              accepted=True, at_s=2.0),
    "serve_chunk": m.ServeChunk(session_id="ais-000001", request_id="r-1",
                                seq=3, token_id=1440),
    "serve_complete": m.ServeComplete(
        session_id="ais-000001", request_id="r-1", klass="premium",
        tokens=8, prompt_tokens=64, ttfb_ms=56.0, latency_ms=240.5,
        queue_wait_ms=12.5, completed=True, error_code=None,
        token_ids=[1, 2, 3], at_s=3.5),
    "heartbeat_report": m.HeartbeatReport(
        session_id="ais-000001", trigger_l99=0.0, trigger_ttfb=0.35),
    "heartbeat_ack": m.HeartbeatAck(
        session_id="ais-000001", committed=True, lease_s=30.0,
        migration={"migrated": True, "to_site": "edge-b"}, at_s=4.0),
    "session_event": m.SessionEvent(
        session_id="ais-000001", event="migration", state="committed",
        detail={"from_site": "edge-a", "to_site": "edge-b"}, at_s=5.0),
    "event_poll": m.EventPoll(invoker="alice"),
    "completion_poll": m.CompletionPoll(invoker="alice"),
    "release_request": m.ReleaseRequest(session_id="ais-000001"),
    "release_ack": m.ReleaseAck(session_id="ais-000001", state="released",
                                tokens=960, total_cost=0.21),
    "compliance_request": m.ComplianceRequest(session_id="ais-000001"),
    "compliance_report": m.ComplianceReport(
        session_id="ais-000001", in_compliance=True,
        z={"q99_ms": 59.0, "rho": 1.0}, n=20),
    "error": m.ErrorResponse(code="E_DEADLINE", cause="deadline expiry",
                             detail="PREPARE exceeded τ",
                             session_id="ais-000001"),
    "register_adapter_request": m.RegisterAdapterRequest(
        adapter_id="acme-support", base_model_id="edge-tiny",
        version="1.2", base_model_version="1.0", rank=8,
        regions=["eu", "us"], scale=2.0, seed=11),
    "register_adapter_response": m.RegisterAdapterResponse(
        adapter_id="acme-support", version="1.2",
        base_model_id="edge-tiny", weight_fingerprint="deadbeefcafe0123",
        at_s=1.0),
    "load_adapter_request": m.LoadAdapterRequest(
        adapter_id="acme-support", site_id="edge-a", version="1.2"),
    "load_adapter_response": m.LoadAdapterResponse(
        adapter_id="acme-support", site_id="edge-a", loaded=True,
        engine_loaded=True, at_s=2.0),
    "unload_adapter_request": m.UnloadAdapterRequest(
        adapter_id="acme-support", site_id="edge-a"),
    "unload_adapter_response": m.UnloadAdapterResponse(
        adapter_id="acme-support", site_id="edge-a", unloaded=True,
        at_s=3.0),
}


class TestRoundTrip:
    def test_examples_cover_every_registered_type(self):
        assert set(EXAMPLES) == set(m.message_types()), \
            "add a round-trip example for every registered wire type"

    @pytest.mark.parametrize("kind", sorted(EXAMPLES))
    def test_json_round_trip_identical(self, kind):
        msg = EXAMPLES[kind]
        again = m.from_json(msg.to_json())
        assert again == msg
        assert type(again) is type(msg)
        # the wire form is pure JSON (no object leaks through)
        json.loads(msg.to_json())

    @pytest.mark.parametrize("kind", sorted(EXAMPLES))
    def test_version_envelope_present(self, kind):
        wire = EXAMPLES[kind].to_wire()
        assert wire["type"] == kind
        assert wire["schema_version"] == m.SCHEMA_VERSION

    def test_unknown_type_rejected(self):
        with pytest.raises(ValueError):
            m.from_wire({"type": "no-such-message"})

    def test_non_object_frame_rejected(self):
        with pytest.raises(ValueError):
            m.from_wire([1, 2, 3])

    def test_minor_version_extra_fields_ignored(self):
        """Forward compatibility within a major: fields added by a newer
        1.x peer decode cleanly instead of failing the request."""
        wire = {"type": "page_request", "session_id": "s",
                "exclude_sites": [], "schema_version": "1.3",
                "priority": 7}                    # hypothetical 1.3 field
        msg = m.from_wire(wire)
        assert isinstance(msg, m.PageRequest)
        assert msg.session_id == "s" and msg.schema_version == "1.3"


class TestAspWire:
    @given(tier=st.sampled_from([1, 2, 3]),
           mobility=st.sampled_from(["static", "nomadic", "vehicular"]),
           cost=st.floats(0.01, 50.0),
           ladder_tier=st.sampled_from([1, 2, 3]))
    @settings(max_examples=30)
    def test_asp_round_trip(self, tier, mobility, cost, ladder_tier):
        asp = make_asp(tier, mobility, cost,
                       ladder=(("edge-tiny", ladder_tier),))
        again = ASP.from_wire(asp.to_wire())
        assert again == asp
        assert again.digest() == asp.digest()

    @given(tier=st.sampled_from([1, 2, 3]),
           mobility=st.sampled_from(["static", "nomadic", "vehicular"]))
    @settings(max_examples=10)
    def test_discover_request_round_trip(self, tier, mobility):
        req = m.DiscoverRequest(invoker="ue", zone="z",
                                asp=make_asp(tier, mobility))
        assert m.from_json(req.to_json()) == req

    def test_digest_binds_schema_version(self):
        wire = default_asp().to_wire()
        assert wire["schema_version"] == ASP_SCHEMA_VERSION
        # same fields under a different claimed version ⇒ different identity
        import hashlib, json as _json
        tampered = dict(wire, schema_version="999.0")
        h = hashlib.sha256(
            _json.dumps(tampered, sort_keys=True).encode()).hexdigest()[:16]
        assert h != default_asp().digest()

    def test_incompatible_major_rejected(self):
        wire = default_asp().to_wire()
        wire["schema_version"] = "2.0"
        with pytest.raises(ValueError, match="schema version"):
            ASP.from_wire(wire)

    def test_minor_bump_accepted(self):
        wire = default_asp().to_wire()
        wire["schema_version"] = "1.7"
        assert ASP.from_wire(wire) == default_asp()


@given(prompt=st.lists(st.integers(0, 50_000), min_size=0, max_size=32),
       prompt_tokens=st.integers(1, 4096), gen_tokens=st.integers(1, 1024))
@settings(max_examples=25)
def test_serve_request_round_trip(prompt, prompt_tokens, gen_tokens):
    req = m.ServeRequest(session_id="s", prompt_tokens=prompt_tokens,
                         gen_tokens=gen_tokens,
                         prompt=prompt or None, stream=False)
    assert m.from_json(req.to_json()) == req


@given(ttfb=st.floats(0.0, 1e5), latency=st.floats(0.0, 1e6),
       wait=st.floats(0.0, 1e5), tokens=st.integers(0, 100_000))
@settings(max_examples=25)
def test_serve_complete_round_trip(ttfb, latency, wait, tokens):
    res = m.ServeComplete(
        session_id="s", request_id="r", klass="assured", tokens=tokens,
        ttfb_ms=ttfb, latency_ms=latency, queue_wait_ms=wait,
        completed=latency <= 1e5, error_code="E_DEADLINE")
    assert m.from_json(res.to_json()) == res


class TestErrorCodes:
    def test_mapping_is_exhaustive(self):
        """Every Eq. (12) cause has a code — adding a cause without a code
        is a wire-protocol break and must fail here."""
        assert set(m.ERROR_CODE_TABLE) == set(FailureCause)

    def test_codes_distinct_and_bijective(self):
        codes = list(m.ERROR_CODE_TABLE.values())
        assert len(set(codes)) == len(codes)
        for cause in FailureCause:
            assert m.cause_for_code(m.code_for_cause(cause)) is cause

    def test_gateway_codes_disjoint(self):
        assert not set(m.GATEWAY_CODES) & set(m.ERROR_CODE_TABLE.values())
        for code in m.GATEWAY_CODES:
            assert m.cause_for_code(code) is None

    def test_error_response_from_session_error(self):
        from repro.core.failures import SessionError
        for cause in FailureCause:
            err = m.ErrorResponse.from_session_error(
                SessionError(cause, "why"), session_id="s")
            assert err.code == m.code_for_cause(cause)
            assert err.cause == cause.value
            assert m.from_json(err.to_json()) == err
