"""Site supervisor: health probes, graceful drain, crash re-anchoring —
plus the heartbeat-path crash fixes that ride along (store-full
degradation, hibernation timestamps, unknown-site-kind predictors,
hoisted hot-path imports)."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import Orchestrator, default_asp
from repro.core.asp import QualityTier
from repro.core.clock import VirtualClock
from repro.core.discovery import discover
from repro.core.failures import FailureCause
from repro.core.session import SessionError, SessionState
from repro.serving.supervisor import FleetSupervisor, SiteHealth

CFG = get_config("edge-tiny")
ASP = default_asp(tier=QualityTier.BASIC)


def _orch(clock=None):
    return Orchestrator(clock=clock or VirtualClock())


def _establish(orch, n, zone="zone-a", prefix="ue"):
    out = []
    for i in range(n):
        s = orch.establish(ASP, invoker=f"{prefix}-{i}", zone=zone)
        orch.clock.advance(0.001)
        orch.serve(s, prompt_tokens=32, gen_tokens=8)   # live engine state
        out.append(s)
    return out


# ----------------------------------------------------------------------
# satellite fixes on the heartbeat path
# ----------------------------------------------------------------------
class TestStoreFullDegradation:
    def test_tick_survives_full_store_and_reports(self):
        """A capacity-bounded HibernationStore refusing puts must degrade
        the heartbeat tick, never crash it — refusals surface through
        PlaneLoad.store_full as back-pressure."""
        from repro.core.clock import Clock
        from repro.serving.engine import InferenceEngine
        from repro.serving.hibernation import HibernationStore
        from repro.serving.plane import RealEngineBackend, ServingPlane

        store = HibernationStore(capacity_bytes=16)    # below any payload
        eng = InferenceEngine(CFG, slots=2, max_len=64, paged=True,
                              page_size=16, hibernation=store)
        clock = Clock()
        plane = ServingPlane(
            clock, RealEngineBackend(eng, clock, hibernate_idle_s=0.0),
            slots=2, site_id="s", premium_reserved_frac=0.0)
        rng = np.random.default_rng(0)
        for i in range(4):
            r = plane.serve(
                session_id=f"u{i}", klass="best-effort", prompt_tokens=8,
                gen_tokens=4, t_max_ms=1e12,
                prompt=rng.integers(0, CFG.vocab_size, 8).astype(np.int32))
            assert not r.failed
            load = plane.load()            # the tick that used to abort
        assert load.store_full > 0
        assert load.hibernated_sessions == 0
        assert store.store_full == load.store_full

    def test_hibernate_slot_returns_false_on_full_store(self):
        from repro.serving.engine import InferenceEngine
        from repro.serving.hibernation import HibernationStore

        eng = InferenceEngine(CFG, slots=2, max_len=64,
                              hibernation=HibernationStore(capacity_bytes=8))
        eng.prefill_session("a", np.arange(6, dtype=np.int32))
        assert eng.hibernate_slot("a") is False
        assert eng.has_slot("a")           # state intact, nothing freed
        assert eng.hibernation.store_full == 1


class TestHibernationTimestamps:
    def test_hibernated_at_tracks_clock(self):
        """HibernationRecord.hibernated_at was always 0.0 — the engine now
        threads its clock through so idle-TTL policy has real times."""
        from repro.serving.engine import InferenceEngine

        clock = VirtualClock()
        clock.advance(5.0)
        eng = InferenceEngine(CFG, slots=2, max_len=64, hibernation=True,
                              clock=clock)
        eng.prefill_session("a", np.arange(6, dtype=np.int32))
        eng.hibernate_slot("a")
        rec = eng.hibernation.record("a")
        assert rec.hibernated_at == pytest.approx(5.0)
        clock.advance(7.0)
        eng.resume_slot("a")
        eng.hibernate_slot("a")
        assert eng.hibernation.record("a").hibernated_at == pytest.approx(12.0)


class TestPredictorUnknownKind:
    def test_unknown_site_kind_predicts_like_regional(self):
        """A site kind outside {edge, regional, central} must not KeyError
        the feasibility predictor (Eq. 7-9) — it defaults to the regional
        arrival assumption."""
        from repro.core.qos import BEST_EFFORT
        from repro.core.sites import ExecutionSite, SiteSpec

        orch = _orch()
        metro = ExecutionSite(SiteSpec(
            "metro-1", "metro", "eu", chips=16, hbm_bytes_total=16 * 16e9,
            peak_flops=16 * 197e12, hbm_bw=16 * 819e9, decode_slots=64,
            rtt_ms={"zone-a": 4.0}, hosted_models=("edge-tiny@1.0",),
            price_per_chip_s=2.0e-4), orch.clock)
        model = orch.catalog.get("edge-tiny")
        pred = orch.predictors.predict(ASP, model, metro, "zone-a",
                                       BEST_EFFORT)
        assert pred.t_ff_ms > 0 and pred.l99_ms > pred.t_ff_ms


class TestHoistedImports:
    def test_plane_module_imports_at_module_level(self):
        """numpy/zlib were imported per-call inside admission hot paths;
        they now live at module scope."""
        import inspect

        import repro.serving.plane as plane_mod

        assert plane_mod.np is np
        assert hasattr(plane_mod, "zlib")
        src = inspect.getsource(plane_mod)
        body_lines = [ln for ln in src.splitlines()
                      if ln.startswith("        import ")
                      or ln.startswith("            import ")]
        assert not any("numpy" in ln or "zlib" in ln for ln in body_lines)


# ----------------------------------------------------------------------
# supervisor: probes
# ----------------------------------------------------------------------
class TestProbe:
    def test_healthy_probe_is_live_and_ready(self):
        orch = _orch()
        _establish(orch, 2)
        sup = FleetSupervisor(orch)
        res = sup.probe_all()
        assert set(res) == set(orch.sites)
        assert all(r.live and r.ready for r in res.values())
        assert res["edge-a"].load is not None

    def test_gated_plane_is_live_but_not_ready(self):
        orch = _orch()
        _establish(orch, 1)
        orch.sites["edge-a"].plane.admitting = False
        sup = FleetSupervisor(orch)
        r = sup["edge-a"].probe()
        assert r.live and not r.ready

    def test_probe_misses_escalate_to_crash(self):
        """miss_threshold consecutive heartbeat-tick failures declare the
        site dead and fire the full crash path — a probe itself never
        raises."""
        orch = _orch()
        sessions = _establish(orch, 3)
        on_a = [s for s in sessions if s.binding.site_id == "edge-a"]
        sup = FleetSupervisor(orch, miss_threshold=2)

        def broken_load():
            raise RuntimeError("device wedged")

        orch.sites["edge-a"].plane.load = broken_load
        r1 = sup["edge-a"].probe()
        assert not r1.live and r1.state is SiteHealth.SUSPECT
        r2 = sup["edge-a"].probe()
        assert r2.state is SiteHealth.DEAD
        assert orch.sites["edge-a"].dead
        # orphans were re-anchored by the fired crash path
        for s in on_a:
            assert s.committed() and s.binding.site_id != "edge-a"

    def test_probe_feeds_analytics(self):
        """Supervisor cadence reaches the ξ loop even when no session
        heartbeat lands on the site."""
        orch = _orch()
        sessions = _establish(orch, 1)
        sid = sessions[0].binding.site_id      # the one site with a plane
        sup = FleetSupervisor(orch)
        epoch0 = orch.analytics.load_epoch(sid)
        sup[sid].probe()
        assert orch.analytics.load_epoch(sid) != epoch0


# ----------------------------------------------------------------------
# supervisor: graceful drain
# ----------------------------------------------------------------------
class TestDrain:
    def test_drain_under_load_loses_nothing(self):
        """Every in-flight request finishes, every bound session leaves
        (migrated, hibernation fallback), the plane refuses new work."""
        orch = _orch()
        sessions = _establish(orch, 8)
        on_a = [s for s in sessions if s.binding.site_id == "edge-a"]
        assert on_a, "no sessions landed on edge-a"
        for s in on_a[:4]:
            assert orch.submit(s, prompt_tokens=16, gen_tokens=8)
        sup = FleetSupervisor(orch)
        rep = sup.drain("edge-a")
        assert rep.sessions == len(on_a)
        assert rep.failed_inflight == 0
        assert rep.stranded == 0
        assert rep.migrated + rep.hibernated == len(on_a)
        assert sup["edge-a"].state is SiteHealth.DRAINED
        # sessions serve on their new anchors; the drained plane is closed
        for s in on_a:
            if s.committed():
                assert s.binding.site_id != "edge-a"
                assert orch.serve(s, prompt_tokens=16, gen_tokens=8).completed
        plane = orch.sites["edge-a"].plane
        assert plane.submit(session_id="x", klass="best-effort",
                            prompt_tokens=8, gen_tokens=8,
                            t_max_ms=2000.0) is None

    def test_drain_keeps_lease_table(self):
        """Drain is an exit, not a crash: the site is denied, not dead."""
        orch = _orch()
        _establish(orch, 2)
        FleetSupervisor(orch).drain("edge-a")
        assert not orch.sites["edge-a"].dead
        assert not orch.analytics.site_context("edge-a").healthy
        assert orch.analytics.site_context("edge-a").alive


# ----------------------------------------------------------------------
# supervisor: crash + re-anchoring
# ----------------------------------------------------------------------
class TestCrash:
    def test_crash_attributes_and_reanchors(self):
        orch = _orch()
        sessions = _establish(orch, 8)
        on_a = [s for s in sessions if s.binding.site_id == "edge-a"]
        assert on_a
        n_inflight = 0
        for s in on_a[:3]:
            if orch.submit(s, prompt_tokens=16, gen_tokens=8):
                n_inflight += 1
        sup = FleetSupervisor(orch)
        rep = sup.crash("edge-a")
        assert rep.orphaned == len(on_a)
        assert rep.reanchored == len(on_a) and rep.lost == 0
        assert rep.survival_frac == 1.0
        assert rep.failed_inflight == n_inflight
        assert len(rep.recovery_ms) == rep.reanchored
        for s in on_a:
            assert s.committed() and s.binding.site_id != "edge-a"
            assert any("re-anchored:edge-a->" in ev for _, ev in s.history)

    def test_inflight_failure_is_compute_scarcity(self):
        """Requests queued on the crashed plane reach the invoker-visible
        record with the Eq. 12 cause, not a silent drop."""
        orch = _orch()
        seen = []
        orch.result_sinks.append(lambda site, res: seen.append(res))
        sessions = _establish(orch, 4)
        on_a = [s for s in sessions if s.binding.site_id == "edge-a"]
        req = orch.submit(on_a[0], prompt_tokens=16, gen_tokens=8)
        assert req is not None
        FleetSupervisor(orch).crash("edge-a")
        failed = [r for r in seen if r.failed is not None]
        assert any(r.request_id == req.request_id and
                   r.failed is FailureCause.COMPUTE_SCARCITY for r in failed)

    def test_dead_site_excluded_from_discover(self):
        orch = _orch()
        _establish(orch, 2)
        FleetSupervisor(orch).crash("edge-a")
        cands = discover(ASP, orch.catalog, orch.sites, orch.predictors,
                         "zone-a", analytics=orch.analytics)
        dead = [c for c in cands if c.site_id == "edge-a"]
        assert dead and all(c.exclusion_reason == "site-dead" for c in dead)
        # PREPARE against the dead site refuses with the same cause
        model = orch.catalog.get("edge-tiny")
        with pytest.raises(SessionError) as ei:
            orch.sites["edge-a"].prepare(model, slots=1, cache_bytes=0.0,
                                         ttl_s=2.0)
        assert ei.value.cause is FailureCause.COMPUTE_SCARCITY
        # fresh establishes still land — elsewhere
        s = orch.establish(ASP, invoker="post", zone="zone-a")
        assert s.binding.site_id != "edge-a"

    def test_no_surviving_candidate_is_attributable(self):
        """Crash with every other site already dead: orphans FAIL with an
        Eq. 12 cause instead of lingering half-bound."""
        orch = _orch()
        sessions = _establish(orch, 2)
        sup = FleetSupervisor(orch)
        for sid in orch.sites:
            if sid != "edge-a":
                orch.sites[sid].mark_dead()
                orch.analytics.mark_site_dead(sid)
        on_a = [s for s in sessions if s.binding.site_id == "edge-a"]
        rep = sup.crash("edge-a")
        assert rep.reanchored == 0 and rep.lost == len(on_a)
        assert set(rep.causes) <= {FailureCause.COMPUTE_SCARCITY.value,
                                   FailureCause.NO_FEASIBLE_BINDING.value}
        for s in on_a:
            assert s.state is SessionState.FAILED

    def test_revive_reopens_the_site(self):
        orch = _orch()
        _establish(orch, 2)
        sup = FleetSupervisor(orch)
        sup.crash("edge-a")
        sup.revive("edge-a")
        assert not orch.sites["edge-a"].dead
        assert sup["edge-a"].state is SiteHealth.HEALTHY
        s = orch.establish(ASP, invoker="back", zone="zone-a")
        assert s.committed()   # edge-a is a candidate again

    def test_reanchor_restores_from_surviving_store(self):
        """A hibernation store that outlives the crashed engine seeds the
        new anchor: position and state carry over bit-exactly."""
        from repro.serving.hibernation import HibernationStore

        orch = _orch()
        sessions = _establish(orch, 2)
        s = next(x for x in sessions if x.binding.site_id == "edge-a")
        src_backend = orch.plane_for(orch.sites["edge-a"]).backend
        store = HibernationStore()
        store.put(s.session_id, src_backend.export_slot(s.session_id))
        orch.sites["edge-a"].mark_dead()
        orch.analytics.mark_site_dead("edge-a")
        out = orch.reanchor(s, state_source=store)
        assert out.ok and out.restored
        assert not store.has(s.session_id)      # dropped after the import
        new_backend = orch.plane_for(orch.sites[out.to_site]).backend
        assert new_backend.has_slot(s.session_id)
        assert orch.serve(s, prompt_tokens=16, gen_tokens=8).completed

    def test_corrupt_store_copy_degrades_to_fresh_context(self):
        class CorruptStore:
            def has(self, sid):
                return True

            def restore(self, sid):
                raise IOError("fingerprint mismatch")

        orch = _orch()
        sessions = _establish(orch, 2)
        s = next(x for x in sessions if x.binding.site_id == "edge-a")
        orch.sites["edge-a"].mark_dead()
        orch.analytics.mark_site_dead("edge-a")
        out = orch.reanchor(s, state_source=CorruptStore())
        assert out.ok and not out.restored
        assert s.committed() and s.binding.site_id != "edge-a"


# ----------------------------------------------------------------------
# federation: dead domains
# ----------------------------------------------------------------------
class TestDeadDomain:
    def test_dead_domain_fast_fails_solicit(self):
        from repro.sim.scenarios import _federation_pair

        clock = VirtualClock()
        home, visited = _federation_pair(clock, home_slots=8,
                                         visited_slots=8)
        offers, notes = home.solicit_offers(ASP, "zone-b")
        assert offers and not notes
        home.mark_domain_dead("visited")
        offers, notes = home.solicit_offers(ASP, "zone-b")
        assert not offers and ("visited", "domain-dead") in notes
        home.mark_domain_alive("visited")
        home.connect(visited)              # re-registers the provider
        offers, notes = home.solicit_offers(ASP, "zone-b")
        assert offers and not notes


# ----------------------------------------------------------------------
# chaos scenarios (sim-scale integration of everything above)
# ----------------------------------------------------------------------
class TestChaosScenarios:
    def test_site_crash_scenario(self):
        from repro.sim.scenarios import simulate_site_crash

        r = simulate_site_crash(n_sessions=240, inflight=24,
                                serve_sample=8)
        assert r.survival_frac >= 0.99 and r.lost == 0
        assert r.failed_inflight == 24
        assert r.serve_ok_after == 8 and r.post_crash_establish_ok
        assert "edge-a" not in r.reanchor_sites

    def test_drain_under_load_scenario(self):
        from repro.sim.scenarios import simulate_drain_under_load

        r = simulate_drain_under_load(n_sessions=48, inflight=12)
        assert r.failed_inflight == 0 and r.stranded == 0
        assert r.migrated + r.hibernated == r.on_site
        assert r.rejects_after_drain

    def test_domain_partition_scenario(self):
        from repro.sim.scenarios import simulate_domain_partition

        r = simulate_domain_partition(n_sessions=8)
        assert r.partition_failures == 4
        assert r.timeout_notes == 1 and r.dead_notes == 1
        assert r.home_serve_ok_during == r.established_home
        assert r.healed_established == 4

    def test_registry_staleness_storm_scenario(self):
        from repro.sim.scenarios import simulate_registry_staleness_storm

        r = simulate_registry_staleness_storm(n_domains=3, n_sessions=18)
        assert r.established_pre == 18
        assert r.stale_notes == 3
        assert r.storm_failures == 3
        assert r.established_post_recovery > 0

    def test_verify_crash_degrades_to_edge_only(self):
        """Airplane-mode contract: losing the VERIFY anchor of a split
        session is a quality-tier event, not a failure. In-flight work
        rides the edge data plane (zero failed), the session stays
        COMMITTED at its edge binding (zero orphans), and recovery
        re-attaches a verify anchor on a surviving site."""
        from repro.sim.scenarios import simulate_verify_crash_degrade

        r = simulate_verify_crash_degrade(n_sessions=24, inflight=32,
                                          serve_sample=8)
        assert r.split_established == 24
        # the crash touched nothing on the interactive path
        assert r.failed_inflight == 0 and r.orphaned == 0
        assert r.still_committed == 24
        # every split degraded explicitly and kept serving
        assert r.degraded == 24 and r.serve_ok_degraded == 8
        assert r.events.get("split-degraded") == 24
        # full-quality recovery lands away from the dead site
        assert r.recovered == 24 and r.serve_ok_after == 8
        assert r.verify_site not in r.recovered_sites
        assert r.events.get("split-recovered") == 24
