"""Property tests for the migration data plane (hypothesis; the offline
stub from tests/_hypothesis_stub.py supplies a deterministic API-compatible
fallback — see conftest.py).

Properties (§IV-B continuity claim, Eq. 14):

* a migrate → migrate-back round trip preserves the state fingerprint and
  the cache position for all three payload families (dense KV, hybrid
  RG-LRU, SSM);
* ``interruption_ms == 0`` for EVERY successful make-before-break outcome,
  across random context shapes — on the real engine path and the
  VirtualClock simulation arm alike;
* an export → hibernate → resume round trip through the host tier
  preserves the state fingerprint and continues the token stream
  bit-exactly against an uninterrupted twin, for all three families.
"""

import itertools

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config, get_smoke_config
from repro.core import Orchestrator, default_asp
from repro.core.asp import MobilityClass
from repro.core.clock import VirtualClock
from repro.serving import state_transfer
from repro.serving.engine import InferenceEngine

FAMILIES = {
    "edge-tiny": "dense",
    "recurrentgemma-2b": "hybrid",
    "mamba2-1.3b": "ssm",
}

_uid = itertools.count()

# module-level lazy caches (the hypothesis stub's @given wrapper takes no
# pytest fixtures; engines/servers are expensive, so build each once)
_PAIRS = {}
_SERVER = []


def engine_pair(arch):
    """One (src, dst) engine pair per payload family, shared weights."""
    if arch not in _PAIRS:
        cfg = get_config(arch) if arch == "edge-tiny" \
            else get_smoke_config(arch)
        src = InferenceEngine(cfg, slots=2, max_len=64)
        dst = InferenceEngine(cfg, params=src.params, slots=2, max_len=64)
        _PAIRS[arch] = (src, dst)
    return _PAIRS[arch]


def real_server():
    if not _SERVER:
        from repro.serving.server import AIaaSServer
        orch = Orchestrator(clock=VirtualClock())
        # per-token decode chunks: the mid-stream property drives _round()
        # by hand and must catch the session before its budget completes
        chunk = {"premium": 1, "assured": 1, "best-effort": 1}
        _SERVER.append((AIaaSServer(orch, "edge-tiny", slots=4, max_len=96,
                                    decode_chunk=chunk),
                        orch))
    return _SERVER[0]


class TestRoundTripFingerprint:
    @settings(max_examples=5)
    @given(arch=st.sampled_from(sorted(FAMILIES)),
           prompt_len=st.integers(min_value=4, max_value=20),
           rounds=st.integers(min_value=0, max_value=5))
    def test_migrate_and_back_preserves_state(self, arch,
                                              prompt_len, rounds):
        src, dst = engine_pair(arch)
        sid = f"rt-{next(_uid)}"
        src.prefill_session(sid, np.arange(prompt_len, dtype=np.int32))
        for _ in range(rounds):
            src.decode_round()
        payload0 = src.export_slot(sid)
        fp0 = state_transfer.fingerprint(payload0)
        pos0 = payload0["position"]

        # migrate out ...
        meta = state_transfer.transfer(src, dst, sid)
        assert meta["fingerprint"] == fp0
        src.release_slot(sid)                    # the MBB break
        # ... and back
        meta_back = state_transfer.transfer(dst, src, sid)
        dst.release_slot(sid)

        payload1 = src.export_slot(sid)
        assert state_transfer.fingerprint(payload1) == fp0
        assert meta_back["fingerprint"] == fp0
        assert payload1["position"] == pos0
        assert payload1["last_token"] == payload0["last_token"]
        src.release_slot(sid)

    @settings(max_examples=6)
    @given(prompt=st.integers(min_value=16, max_value=256),
           gen=st.integers(min_value=4, max_value=48))
    def test_sim_round_trip_preserves_state(self, prompt, gen):
        """The SimulatedEngine arm: migrate twice (away and onward); the
        serialized session state is invariant under transfer."""
        orch = Orchestrator(clock=VirtualClock())
        s = orch.establish(default_asp(mobility=MobilityClass.VEHICULAR),
                           invoker=f"prop-{next(_uid)}", zone="zone-a")
        orch.serve(s, prompt_tokens=prompt, gen_tokens=gen)
        backend = orch.plane_for(orch.sites[s.binding.site_id]).backend
        payload0 = backend.export_slot(s.session_id)
        fp0 = state_transfer.fingerprint(payload0)
        for _ in range(2):
            out = orch.migrations.migrate(s, "zone-a")
            assert out.migrated
            assert out.fingerprint == fp0
        backend = orch.plane_for(orch.sites[s.binding.site_id]).backend
        payload1 = backend.export_slot(s.session_id)
        assert state_transfer.fingerprint(payload1) == fp0
        assert payload1["position"] == payload0["position"]


_HIB = {}


def hib_engine(arch):
    """One hibernation-capable engine per family (paged where the family
    supports it) plus an uninterrupted dense twin sharing its weights —
    the bit-exactness oracle for resumed token streams."""
    if arch not in _HIB:
        cfg = get_config(arch) if arch == "edge-tiny" \
            else get_smoke_config(arch)
        eng = InferenceEngine(cfg, slots=2, max_len=64,
                              paged=(arch == "edge-tiny"), page_size=16,
                              hibernation=True)
        twin = InferenceEngine(cfg, params=eng.params, slots=2, max_len=64)
        _HIB[arch] = (eng, twin)
    return _HIB[arch]


class TestHibernateRoundTrip:
    @settings(max_examples=6, deadline=None)
    @given(arch=st.sampled_from(sorted(FAMILIES)),
           prompt_len=st.integers(min_value=4, max_value=20),
           pre_rounds=st.integers(min_value=0, max_value=4),
           post_rounds=st.integers(min_value=1, max_value=5))
    def test_hibernate_resume_is_transparent(self, arch, prompt_len,
                                             pre_rounds, post_rounds):
        """Hibernating to host and resuming is invisible to the stream:
        same fingerprint on re-import, and the continued tokens match an
        identical session that never left the device."""
        eng, twin = hib_engine(arch)
        sid = f"hib-{next(_uid)}"
        r0 = eng.prefill_session(sid, np.arange(prompt_len, dtype=np.int32))
        r1 = twin.prefill_session(sid, np.arange(prompt_len, dtype=np.int32))
        assert r0["first_token"] == r1["first_token"]
        for _ in range(pre_rounds):
            assert eng.decode_round()[sid] == twin.decode_round()[sid]

        fp0 = state_transfer.fingerprint(eng.export_slot(sid))
        eng.hibernate_slot(sid)
        assert not eng.has_slot(sid) and eng.hibernation.has(sid)
        assert eng.bound_sessions() == eng.hibernated_sessions() + \
            eng.resident_sessions()
        eng.resume_slot(sid)
        assert state_transfer.fingerprint(eng.export_slot(sid)) == fp0
        assert not eng.hibernation.has(sid)      # dropped after re-import

        for _ in range(post_rounds):
            assert eng.decode_round()[sid] == twin.decode_round()[sid]
        eng.release_slot(sid)
        twin.release_slot(sid)


class TestZeroInterruption:
    @settings(max_examples=8)
    @given(prompt=st.integers(min_value=16, max_value=1024),
           gen=st.integers(min_value=1, max_value=128))
    def test_successful_mbb_never_gaps(self, prompt, gen):
        """Every successful make-before-break outcome has zero contract-gap
        time, whatever the served context shape."""
        orch = Orchestrator(clock=VirtualClock())
        s = orch.establish(default_asp(mobility=MobilityClass.VEHICULAR),
                           invoker=f"gap-{next(_uid)}", zone="zone-a")
        orch.serve(s, prompt_tokens=prompt, gen_tokens=gen)
        out = orch.migrations.migrate(s, "zone-a")
        if out.migrated:
            assert out.interruption_ms == 0.0
            assert s.committed() and s.binding.site_id == out.to_site
        else:
            # aborts never gap either: the source binding stays committed
            assert out.interruption_ms == 0.0
            assert s.committed() and s.binding.site_id == out.from_site

    @settings(max_examples=4)
    @given(pre_rounds=st.integers(min_value=0, max_value=4),
           gen=st.integers(min_value=8, max_value=16))
    def test_real_engine_mid_stream_never_gaps(self, pre_rounds, gen):
        """Real-engine arm: mid-decode migration keeps interruption at 0 and
        the stream completes with the full token budget on the target."""
        srv, orch = real_server()
        s = orch.establish(default_asp(mobility=MobilityClass.VEHICULAR),
                           invoker=f"real-{next(_uid)}", zone="zone-a")
        plane = srv.planes[s.binding.site_id]
        srv.submit(s, prompt=np.arange(6, dtype=np.int32), gen_tokens=gen)
        for _ in range(pre_rounds):
            plane._round()
        out = orch.migrations.migrate(s, "zone-a")
        assert out.migrated
        assert out.interruption_ms == 0.0
        dst_plane = srv.planes[s.binding.site_id]
        dst_plane.drain()
        results = orch.record_results(orch.sites[s.binding.site_id])
        mine = [r for r in results if r.session_id == s.session_id]
        assert len(mine) == 1 and mine[0].tokens == gen
        orch.release(s)
