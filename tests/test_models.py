"""Per-architecture smoke tests (assignment f): reduced same-family configs —
one forward + one train step on CPU, asserting shapes and no NaNs — plus
prefill/decode equivalence (the serving-correctness invariant)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import LM
from repro.models.frontends import make_batch

ASSIGNED = [a for a in ARCH_IDS if a != "edge-tiny"]


@pytest.fixture(scope="module")
def key():
    return jax.random.key(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch, key):
    cfg = get_smoke_config(arch)
    lm = LM(cfg)
    params = lm.init(key)
    batch = make_batch(cfg, key, batch=2, seq=32)
    logits, aux = jax.jit(lm.forward)(params, batch)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any()), "NaN logits"
    # one real train step must run and produce finite grads
    from repro.training.train_step import init_train_state, make_train_step
    state = init_train_state(lm, key)
    step = make_train_step(lm, microbatches=2)
    state, metrics = jax.jit(step, donate_argnums=(0,))(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch, key):
    cfg = get_smoke_config(arch)
    lm = LM(cfg)
    params = lm.init(key)
    S, PRE = 24, 16
    batch = make_batch(cfg, key, batch=2, seq=S)
    full_logits, _ = jax.jit(lm.forward)(params, batch)

    pre = {k: v for k, v in batch.items() if k != "labels"}
    pre["tokens"] = batch["tokens"][:, :PRE]
    if "vision_embeds" in pre:
        pre["vision_embeds"] = batch["vision_embeds"][:, :8]
    last, cache = jax.jit(lambda p, b: lm.prefill(p, b, S))(params, pre)
    errs = [float(jnp.max(jnp.abs(last - full_logits[:, PRE - 1])))]
    dec = jax.jit(lm.decode_step)
    for t in range(PRE, S):
        logits, cache = dec(params, cache, batch["tokens"][:, t:t + 1])
        errs.append(float(jnp.max(jnp.abs(logits[:, 0] - full_logits[:, t]))))
    assert max(errs) < 0.35, f"decode diverged: {errs}"   # bf16 tolerance


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyperparameters."""
    spec = {
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        c = get_config(arch)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.d_ff, c.vocab_size) == (L, d, h, kv, ff, v), arch
    m = get_config("mamba2-1.3b")
    assert (m.num_layers, m.d_model, m.vocab_size, m.ssm_state) == \
        (48, 2048, 50280, 128)
    q = get_config("qwen3-moe-30b-a3b")
    assert (q.num_experts, q.num_experts_per_tok) == (128, 8)
    x = get_config("mixtral-8x7b")
    assert (x.num_experts, x.num_experts_per_tok, x.sliding_window) == \
        (8, 2, 4096)


def test_param_counts_in_expected_range():
    """Analytic param counts land near the named sizes."""
    expect = {"phi3-medium-14b": (13e9, 16e9), "command-r-35b": (29e9, 37e9),
              "codeqwen1.5-7b": (6e9, 8.5e9), "minitron-8b": (7e9, 10e9),
              "qwen2-vl-72b": (65e9, 80e9), "qwen3-moe-30b-a3b": (29e9, 32e9),
              "mixtral-8x7b": (44e9, 49e9), "recurrentgemma-2b": (2e9, 3.3e9),
              "mamba2-1.3b": (1.1e9, 1.6e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"
    a3 = get_config("qwen3-moe-30b-a3b").active_param_count()
    assert 2.5e9 <= a3 <= 4e9, f"active {a3/1e9:.2f}B"


def test_moe_impls_agree(key):
    """einsum / scatter / dense MoE paths produce the same outputs when the
    capacity admits every token (correctness oracle for the dispatch math)."""
    base = get_smoke_config("qwen3-moe-30b-a3b")
    outs = {}
    batch = None
    for impl in ("einsum", "scatter", "dense"):
        cfg = dataclasses.replace(base, moe_impl=impl,
                                  moe_capacity_factor=8.0)
        lm = LM(cfg)
        params = lm.init(key)       # same key → same params
        if batch is None:
            batch = make_batch(cfg, key, batch=2, seq=16)
        logits, _ = jax.jit(lm.forward)(params, batch)
        outs[impl] = np.asarray(logits, np.float32)
    for impl in ("scatter", "dense"):
        err = np.max(np.abs(outs["einsum"] - outs[impl]))
        assert err < 0.15, f"einsum vs {impl}: {err}"


def test_long_500k_rule():
    from repro.sharding import cell_runnable
    runnable = {a: cell_runnable(get_config(a), "long_500k")[0]
                for a in ASSIGNED}
    assert runnable == {
        "phi3-medium-14b": False, "command-r-35b": False,
        "codeqwen1.5-7b": False, "minitron-8b": False,
        "qwen2-vl-72b": False, "qwen3-moe-30b-a3b": False,
        "mixtral-8x7b": True, "recurrentgemma-2b": True,
        "mamba2-1.3b": True, "seamless-m4t-medium": False,
    }
