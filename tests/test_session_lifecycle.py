"""AIS state machine + commitment-coupling invariants (Eq. 4/6/10)."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.asp import default_asp
from repro.core.catalog import default_catalog
from repro.core.clock import VirtualClock
from repro.core.failures import FailureCause, SessionError, Timers
from repro.core.policy import PolicyControl
from repro.core.qos import PREMIUM, QoSFlowManager
from repro.core.session import AISession, SessionState
from repro.core.sites import default_sites
from repro.core.twophase import TwoPhaseCoordinator


def make_world(lease_s=30.0):
    clock = VirtualClock()
    catalog = default_catalog()
    sites = default_sites(clock, tuple(catalog._entries.keys()))
    qos = QoSFlowManager(clock)
    policy = PolicyControl(clock)
    timers = Timers(lease_s=lease_s)
    coord = TwoPhaseCoordinator(clock, sites, qos, timers)
    return clock, catalog, sites, qos, policy, timers, coord


def committed_session(world):
    clock, catalog, sites, qos, policy, timers, coord = world
    asp = default_asp()
    s = AISession(asp, "ue", "zone-a", clock, sites=sites, qos=qos,
                  policy=policy)
    s.authz_ref = policy.grant_consent("ue", asp.allowed_regions)
    s.mark_discovered(); s.mark_anchored(); s.mark_preparing()
    model = catalog.get("edge-tiny")
    prep = coord.prepare(model, "edge-a", "zone-a", PREMIUM, slots=1,
                         cache_bytes=1e6)
    s.mark_prepared()
    binding = coord.commit(prep, model)
    s.bind(binding)
    return s


class TestStateMachine:
    def test_happy_path(self):
        s = committed_session(make_world())
        assert s.state is SessionState.COMMITTED
        assert s.committed() and s.serve_allowed()

    def test_illegal_transitions_rejected(self):
        world = make_world()
        clock, catalog, sites, qos, policy, *_ = world
        asp = default_asp()
        s = AISession(asp, "ue", "zone-a", clock, sites=sites, qos=qos,
                      policy=policy)
        with pytest.raises(SessionError):
            s.mark_prepared()          # IDLE -> PREPARED is not legal
        with pytest.raises(SessionError):
            s.mark_migrating()

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.sampled_from(
        ["discovered", "anchored", "preparing", "prepared", "migrating"]),
        max_size=6))
    def test_random_sequences_never_reach_committed(self, seq):
        """Property: no sequence of mark_* calls reaches COMMITTED — the
        ONLY path is bind() with both leases valid (partial states are
        unrepresentable)."""
        world = make_world()
        clock, catalog, sites, qos, policy, *_ = world
        s = AISession(default_asp(), "ue", "zone-a", clock, sites=sites,
                      qos=qos, policy=policy)
        for name in seq:
            try:
                getattr(s, f"mark_{name}")()
            except SessionError:
                pass
        assert s.state is not SessionState.COMMITTED
        assert not s.committed()


class TestCommitmentCoupling:
    def test_eq4_both_sides_required(self):
        world = make_world()
        s = committed_session(world)
        assert s.committed()
        # kill the QoS side only → Committed(t) must drop (Eq. 4)
        world[3].release(s.binding.qos_lease_id)
        assert s.v_cmp() and not s.v_qos()
        assert not s.committed()

    def test_lease_expiry_leaves_committed_domain(self):
        world = make_world(lease_s=5.0)
        clock = world[0]
        s = committed_session(world)
        assert s.committed()
        clock.advance(6.0)
        assert not s.committed()       # both leases expired

    def test_renew_extends_both(self):
        world = make_world(lease_s=5.0)
        clock = world[0]
        s = committed_session(world)
        clock.advance(4.0)
        assert s.renew(5.0)
        clock.advance(4.0)
        assert s.committed()

    def test_eq6_revocation_disables_serve(self):
        world = make_world()
        policy = world[4]
        s = committed_session(world)
        assert s.serve_allowed()
        policy.revoke(s.authz_ref)
        assert s.committed()           # resources still valid…
        assert not s.serve_allowed()   # …but service is disabled (Eq. 6)

    def test_bind_rejects_stale_leases(self):
        world = make_world()
        clock, catalog, sites, qos, policy, timers, coord = world
        s = committed_session(world)
        from repro.core.session import Binding
        stale = Binding("edge-tiny", "1.0", "edge-a", "ep", 9, "st",
                        "edge-a/cmp-999", "qos-999")
        s.state = SessionState.MIGRATING
        with pytest.raises(SessionError) as ei:
            s.bind(stale)
        assert ei.value.cause is FailureCause.DEADLINE_EXPIRY

    def test_release_idempotent_leases(self):
        world = make_world()
        sites = world[2]
        s = committed_session(world)
        lease = s.binding.compute_lease_id
        s.release()
        # double release of the underlying lease is a no-op
        sites["edge-a"].release(lease)
        assert s.state is SessionState.RELEASED

    def test_audit_record_fields(self):
        s = committed_session(make_world())
        rec = s.record()
        for key in ("session_id", "asp_digest", "model", "anchor",
                    "endpoint", "qfi", "steering"):
            assert rec[key], key
