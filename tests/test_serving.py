"""Serving plane: continuous batching, QoS scheduler, state transfer."""

import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.core.clock import VirtualClock
from repro.core.failures import FailureCause
from repro.serving.engine import InferenceEngine
from repro.serving.scheduler import QoSScheduler, Request
from repro.serving import state_transfer


@pytest.fixture(scope="module")
def engine():
    return InferenceEngine(get_config("edge-tiny"), slots=4, max_len=96)


class TestEngine:
    def test_staggered_sessions_independent(self, engine):
        """Continuous batching with per-slot positions: a session's output
        must not depend on who shares the batch."""
        cfg = engine.cfg
        p1 = np.arange(10, dtype=np.int32)
        # run s-alone: fresh engine, single session
        solo = InferenceEngine(cfg, params=engine.params, slots=4, max_len=96)
        solo.prefill_session("s", p1)
        toks_solo = [solo.decode_round()["s"] for _ in range(6)]

        shared = InferenceEngine(cfg, params=engine.params, slots=4,
                                 max_len=96)
        shared.prefill_session("other", np.arange(23, dtype=np.int32))
        shared.decode_round()
        shared.prefill_session("s", p1)        # joins mid-flight
        toks_shared = []
        for _ in range(6):
            out = shared.decode_round()
            toks_shared.append(out["s"])
        assert toks_solo == toks_shared

    def test_slot_exhaustion_is_lease_bug(self, engine):
        eng = InferenceEngine(engine.cfg, params=engine.params, slots=2,
                              max_len=64)
        eng.prefill_session("a", np.arange(5, dtype=np.int32))
        eng.prefill_session("b", np.arange(5, dtype=np.int32))
        with pytest.raises(RuntimeError):
            eng.prefill_session("c", np.arange(5, dtype=np.int32))

    @pytest.mark.parametrize("arch", ["edge-tiny", "recurrentgemma-2b",
                                      "mamba2-1.3b", "mixtral-8x7b"])
    def test_transfer_roundtrip_all_families(self, arch):
        cfg = get_smoke_config(arch) if arch != "edge-tiny" \
            else get_config(arch)
        src = InferenceEngine(cfg, slots=2, max_len=48)
        src.prefill_session("m", np.arange(9, dtype=np.int32))
        src_next = None
        dst = InferenceEngine(cfg, params=src.params, slots=2, max_len=48)
        meta = state_transfer.transfer(src, dst, "m")
        assert meta["bytes"] > 0
        # both engines continue identically after the transfer
        for _ in range(4):
            a = src.decode_round()["m"]
            b = dst.decode_round()["m"]
            assert a == b

    def test_transfer_failure_keeps_source(self):
        cfg = get_config("edge-tiny")
        src = InferenceEngine(cfg, slots=2, max_len=48)
        src.prefill_session("m", np.arange(9, dtype=np.int32))
        dst = InferenceEngine(cfg, params=src.params, slots=2, max_len=48)

        def boom(payload):
            raise IOError("wire cut")

        with pytest.raises(IOError):
            state_transfer.transfer(src, dst, "m", fail_injector=boom)
        assert "m" in src._slot_map          # source slot intact
        assert "m" not in dst._slot_map


class TestScheduler:
    def mk(self, clock, **kw):
        return QoSScheduler(clock, slots=4, **kw)

    def req(self, i, klass, t_max=1000.0):
        return Request(f"r{i}", f"s{i}", klass, 16, 8, t_max)

    def test_strict_class_order(self):
        clock = VirtualClock()
        s = self.mk(clock)
        s.submit(self.req(1, "best-effort"))
        s.submit(self.req(2, "premium"))
        s.submit(self.req(3, "assured"))
        batch = s.next_batch()
        assert [r.klass for r in batch[:3]] == ["premium", "assured",
                                                "best-effort"]

    def test_premium_reservation(self):
        clock = VirtualClock()
        s = self.mk(clock)    # 4 slots, 1 reserved for premium
        for i in range(6):
            s.submit(self.req(i, "best-effort"))
        batch = s.next_batch()
        assert len(batch) == 3           # one slot held back
        s.submit(self.req(99, "premium"))
        batch2 = s.next_batch()
        assert [r.klass for r in batch2] == ["premium"]

    def test_deadline_fast_fail(self):
        clock = VirtualClock()
        s = self.mk(clock)
        r = self.req(1, "premium", t_max=100.0)
        s.submit(r)
        clock.advance(0.2)               # 200 ms queued already
        batch = s.next_batch(predicted_service_ms=50.0)
        assert batch == []
        assert r.failed is FailureCause.DEADLINE_EXPIRY
        assert s.stats.fast_failed == 1

    def test_completion_accounting(self):
        clock = VirtualClock()
        s = self.mk(clock)
        s.submit(self.req(1, "premium"))
        batch = s.next_batch()
        s.complete(batch[0].request_id)
        assert s.stats.completed == 1
        assert not s.running
