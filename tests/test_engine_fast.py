"""Serving hot path: fused multi-step decode, bucketed prefill, donated
slot state, and the Pallas decode-attention route.

Correctness bar for every fast path: BIT-IDENTICAL tokens to the slow
path it replaces — fused K-step chunks vs K sequential single-step rounds
(including export→import migration between chunks), bucketed prefill vs
exact-length prefill, Pallas decode vs the reference attention.
"""

import dataclasses
import math

import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.serving import state_transfer
from repro.serving.engine import InferenceEngine, prefill_buckets
from repro.serving.plane import RealEngineBackend, ServingPlane
from repro.serving.scheduler import Request

ARCHS = ["edge-tiny", "recurrentgemma-2b", "mamba2-1.3b"]   # dense/hybrid/ssm


def cfg_for(arch):
    return get_config(arch) if arch == "edge-tiny" else get_smoke_config(arch)


@pytest.fixture(scope="module")
def engines():
    """One engine per family (weights reused across tests)."""
    return {arch: InferenceEngine(cfg_for(arch), slots=4, max_len=64)
            for arch in ARCHS}


class TestFusedDecode:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_fused_equals_sequential(self, engines, arch):
        """decode_round(steps=K) must be bit-identical to K sequential
        decode_round() calls — the fused scan IS the hot path, the
        sequential form is the oracle."""
        base = engines[arch]
        prompt = (np.arange(9, dtype=np.int32) * 5) % base.cfg.vocab_size

        seq = InferenceEngine(base.cfg, params=base.params, slots=4,
                              max_len=64)
        seq.prefill_session("s", prompt)
        toks_seq = [seq.decode_round()["s"] for _ in range(12)]

        fus = InferenceEngine(base.cfg, params=base.params, slots=4,
                              max_len=64)
        fus.prefill_session("s", prompt)
        toks_fus = []
        for k in (5, 4, 3):                      # uneven chunking
            toks_fus.extend(fus.decode_round(steps=k)["s"])
        assert toks_seq == toks_fus

    @pytest.mark.parametrize("arch", ARCHS)
    def test_fused_is_batch_composition_independent(self, engines, arch):
        """A fused chunk's tokens for one session must not depend on who
        shares the decode batch (per-slot positions + active mask)."""
        base = engines[arch]
        prompt = (np.arange(7, dtype=np.int32) * 3) % base.cfg.vocab_size

        solo = InferenceEngine(base.cfg, params=base.params, slots=4,
                               max_len=64)
        solo.prefill_session("s", prompt)
        alone = solo.decode_round(steps=6)["s"]

        shared = InferenceEngine(base.cfg, params=base.params, slots=4,
                                 max_len=64)
        shared.prefill_session("other", (np.arange(13, dtype=np.int32)
                                         % base.cfg.vocab_size))
        shared.decode_round(steps=2)
        shared.prefill_session("s", prompt)      # joins mid-flight
        together = shared.decode_round(steps=6)["s"]
        assert alone == together

    @pytest.mark.parametrize("arch", ARCHS)
    def test_migration_mid_chunk_bit_exact(self, engines, arch):
        """export_slot → import_slot between fused chunks: the stream
        continues bit-exact on the target, fingerprints match end-to-end."""
        base = engines[arch]
        prompt = (np.arange(11, dtype=np.int32) * 2) % base.cfg.vocab_size

        ref = InferenceEngine(base.cfg, params=base.params, slots=4,
                              max_len=64)
        ref.prefill_session("m", prompt)
        expect = []
        for k in (5, 7):
            expect.extend(ref.decode_round(steps=k)["m"])

        src = InferenceEngine(base.cfg, params=base.params, slots=4,
                              max_len=64)
        dst = InferenceEngine(base.cfg, params=base.params, slots=4,
                              max_len=64)
        src.prefill_session("m", prompt)
        got = list(src.decode_round(steps=5)["m"])
        meta = state_transfer.transfer(src, dst, "m")   # fingerprint-verified
        assert meta["bytes"] > 0
        src.release_slot("m")                           # the MBB break
        assert dst.position_of("m") == len(prompt) + 5
        got.extend(dst.decode_round(steps=7)["m"])
        assert got == expect

    def test_legacy_single_step_form_unchanged(self, engines):
        eng = InferenceEngine(engines["edge-tiny"].cfg,
                              params=engines["edge-tiny"].params,
                              slots=2, max_len=64)
        eng.prefill_session("s", np.arange(5, dtype=np.int32))
        out = eng.decode_round()
        assert isinstance(out["s"], int)
        out = eng.decode_round(steps=3)
        assert isinstance(out["s"], list) and len(out["s"]) == 3


class TestBucketedPrefill:
    def test_compile_count_bounded_over_mixed_lengths(self):
        """50 mixed-length prompts must trace at most len(buckets) prefill
        variants, and len(buckets) <= ceil(log2(max_len))."""
        cfg = get_config("edge-tiny")
        eng = InferenceEngine(cfg, slots=2, max_len=256)
        rng = np.random.default_rng(7)
        lengths = rng.integers(1, 256, size=50)
        for i, n in enumerate(lengths):
            sid = f"p{i}"
            eng.prefill_session(
                sid, (np.arange(n, dtype=np.int32) % cfg.vocab_size))
            eng.release_slot(sid)
        assert eng.prefill_compiles <= len(eng.buckets)
        assert len(eng.buckets) <= math.ceil(math.log2(eng.max_len))

    def test_buckets_cover_max_len(self):
        assert prefill_buckets(256) == [16, 32, 64, 128, 256]
        assert prefill_buckets(96) == [16, 32, 64, 96]
        assert all(b <= 512 for b in prefill_buckets(512))

    def test_oversized_prompt_rejected(self, engines):
        """A prompt longer than max_len must raise, not silently truncate —
        truncation would condition generation on a clipped prefix while
        position_of() (the migration payload size) reports the full
        length."""
        base = engines["edge-tiny"]
        eng = InferenceEngine(base.cfg, params=base.params, slots=2,
                              max_len=32)
        with pytest.raises(ValueError, match="exceeds engine max_len"):
            eng.prefill_session("big", np.arange(40, dtype=np.int32)
                                % base.cfg.vocab_size)
        assert not eng.has_slot("big")

    @pytest.mark.parametrize("arch", ARCHS)
    def test_bucketed_equals_exact_prefill(self, engines, arch):
        """The padded-bucket cache must continue the stream exactly like an
        exact-length (unpadded) prefill: same first token, same decode
        continuation — for KV, ring, RG-LRU, and SSD state alike."""
        import jax
        import jax.numpy as jnp
        base = engines[arch]
        lm = base.lm
        prompt = (np.arange(9, dtype=np.int32) * 7) % base.cfg.vocab_size

        # oracle: exact-length prefill straight through the LM
        logits, _ = jax.jit(lambda p, b: lm.prefill(p, b, 64))(
            base.params, {"tokens": jnp.asarray(prompt[None, :], jnp.int32)})
        first_exact = int(jnp.argmax(logits[0]))

        eng = InferenceEngine(base.cfg, params=base.params, slots=2,
                              max_len=64)
        pre = eng.prefill_session("s", prompt)     # padded to bucket 16
        assert pre["first_token"] == first_exact
        assert eng.position_of("s") == len(prompt)


class TestPallasDecodeRoute:
    def test_bit_close_to_reference_and_same_tokens(self):
        """cfg.use_pallas_decode must produce decode attention bit-close to
        the reference path (same math, same masking) and identical greedy
        tokens through the engine."""
        import jax
        import jax.numpy as jnp
        from repro.models import attention as A

        cfg = get_config("edge-tiny")
        ref_eng = InferenceEngine(cfg, slots=2, max_len=64)
        pal_cfg = dataclasses.replace(cfg, use_pallas_decode=True)
        pal_eng = InferenceEngine(pal_cfg, params=ref_eng.params,
                                  slots=2, max_len=64)
        prompt = np.arange(12, dtype=np.int32)
        a = ref_eng.prefill_session("s", prompt)
        b = pal_eng.prefill_session("s", prompt)
        assert a["first_token"] == b["first_token"]
        ta = ref_eng.decode_round(steps=8)["s"]
        tb = pal_eng.decode_round(steps=8)["s"]
        assert ta == tb

        # numeric closeness of the raw layer output (not just argmax)
        key = jax.random.key(0)
        p = A.attention_init(key, cfg)
        x = jax.random.normal(jax.random.key(1), (2, 1, cfg.d_model),
                              jnp.float32).astype(jnp.bfloat16)
        ck = jax.random.normal(jax.random.key(2),
                               (2, 32, cfg.num_kv_heads, cfg.head_dim),
                               jnp.float32).astype(jnp.bfloat16)
        cv = jax.random.normal(jax.random.key(3), ck.shape,
                               jnp.float32).astype(jnp.bfloat16)
        pos = jnp.array([5, 17], jnp.int32)
        o_ref, _, _ = A.decode_self_attention(p, cfg, x, ck, cv, pos)
        o_pal, _, _ = A.decode_self_attention(p, pal_cfg, x, ck, cv, pos)
        np.testing.assert_allclose(
            np.asarray(o_ref, np.float32), np.asarray(o_pal, np.float32),
            atol=2e-2, rtol=2e-2)   # bf16 accumulation-order tolerance

    def test_decode_past_buffer_stays_on_reference_mask(self):
        """Positions >= S (generation past the cache buffer): the kernel's
        ragged-length mask must clamp at S — unclamped it would admit the
        zero-padded KV rows the kernel's block_kv rounding appends, which
        showed up as ~0.15 max divergence vs the ~3e-3 bf16 noise floor."""
        import jax
        import jax.numpy as jnp
        from repro.models import attention as A

        cfg = get_config("edge-tiny")
        pal_cfg = dataclasses.replace(cfg, use_pallas_decode=True)
        p = A.attention_init(jax.random.key(0), cfg)
        S = 24
        x = jax.random.normal(jax.random.key(1), (2, 1, cfg.d_model),
                              jnp.float32).astype(jnp.bfloat16)
        ck = jax.random.normal(jax.random.key(2),
                               (2, S, cfg.num_kv_heads, cfg.head_dim),
                               jnp.float32).astype(jnp.bfloat16)
        cv = jax.random.normal(jax.random.key(3), ck.shape,
                               jnp.float32).astype(jnp.bfloat16)
        for pos in (S - 1, S, S + 10, S + 100):
            position = jnp.array([pos, pos + 3], jnp.int32)
            o_ref, _, _ = A.decode_self_attention(p, cfg, x, ck, cv,
                                                  position)
            o_pal, _, _ = A.decode_self_attention(p, pal_cfg, x, ck, cv,
                                                  position)
            np.testing.assert_allclose(
                np.asarray(o_ref, np.float32), np.asarray(o_pal, np.float32),
                atol=2e-2, rtol=2e-2)

    def test_window_and_softcap_fall_back_to_reference(self):
        """The kernel only implements linear buffers without softcap; the
        flag must be a no-op for ring-buffer / softcapped configs (hybrid
        smoke uses sliding windows) instead of producing wrong attention."""
        cfg = dataclasses.replace(get_smoke_config("recurrentgemma-2b"),
                                  use_pallas_decode=True)
        base = InferenceEngine(cfg_for("recurrentgemma-2b"), slots=2,
                               max_len=48)
        eng = InferenceEngine(cfg, params=base.params, slots=2, max_len=48)
        ref = InferenceEngine(base.cfg, params=base.params, slots=2,
                              max_len=48)
        prompt = np.arange(9, dtype=np.int32)
        eng.prefill_session("s", prompt)
        ref.prefill_session("s", prompt)
        assert eng.decode_round(steps=5)["s"] == ref.decode_round(steps=5)["s"]


class _TickClock:
    """now() advances a fixed amount per call — deterministic timing for
    EWMA accounting tests."""

    def __init__(self, tick_s):
        self.t = 0.0
        self.tick = tick_s

    def now(self):
        self.t += self.tick
        return self.t


class _StubEngine:
    """Captures prompts; emits fixed token blocks."""

    def __init__(self):
        self.cfg = get_config("edge-tiny")
        self.prompts = {}
        self._slot_map = {}

    def prefill_session(self, sid, prompt):
        self.prompts[sid] = np.asarray(prompt)
        self._slot_map[sid] = 0
        return {"first_token": 1, "ttfb_ms": 1.0}

    def decode_round(self, steps=None):
        k = steps or 1
        return {sid: ([2] * k if steps is not None else 2)
                for sid in self._slot_map}

    def free_slots(self):
        return 1

    def release_slot(self, sid):
        self._slot_map.pop(sid, None)


class TestBackendAccounting:
    def test_ewma_normalizes_by_tokens_not_calls(self):
        """A K-step chunk taking T ms must train the per-token EWMA toward
        T/K — NOT T/len(sessions) — so predicted_service_ms (EWMA × G) stays
        calibrated for deadline fast-fail at any chunk size."""
        eng = _StubEngine()
        eng._slot_map = {"a": 0, "b": 1, "c": 2}    # 3 sessions share rounds
        clock = _TickClock(0.008)                    # 8 ms between now() calls
        be = RealEngineBackend(eng, clock)
        be.decode_round(steps=8)
        assert be._ms_per_token == pytest.approx(1.0)    # 8ms / 8 steps
        req = Request("r", "s", "premium", 16, 100, 1e9)
        assert be.predicted_service_ms(req) == pytest.approx(100.0)

    def test_admit_prompt_seed_is_crc32_not_hash(self):
        """Synthetic prompts must derive from crc32 (stable across
        processes), never from PYTHONHASHSEED-dependent hash()."""
        import zlib
        eng = _StubEngine()
        be = RealEngineBackend(eng, _TickClock(0.001), seed=3)
        req = Request("req-1", "sess-1", "assured", 6, 4, 1e9)
        be.admit(req, 0.0)
        expected = np.random.default_rng(
            (zlib.crc32(b"sess-1") ^ zlib.crc32(b"req-1") ^ 3)
            % 2**31).integers(0, eng.cfg.vocab_size, size=6).astype(np.int32)
        np.testing.assert_array_equal(eng.prompts["sess-1"], expected)

    def test_engine_serve_seed_is_crc32(self):
        import zlib
        cfg = get_config("edge-tiny")
        eng = InferenceEngine(cfg, slots=2, max_len=64)
        out = eng.serve("det-session", prompt_tokens=6, gen_tokens=4)
        assert len(out["tokens"]) == 4
        # same crc32-derived prompt on a FRESH engine with the same weights
        eng2 = InferenceEngine(cfg, params=eng.params, slots=2, max_len=64)
        out2 = eng2.serve("det-session", prompt_tokens=6, gen_tokens=4)
        assert out["tokens"] == out2["tokens"]


class TestPlaneChunking:
    def _plane(self, chunk=None):
        from repro.core.clock import VirtualClock
        clock = VirtualClock()
        cfg = get_config("edge-tiny")
        eng = InferenceEngine(cfg, slots=4, max_len=64)
        return ServingPlane(clock, RealEngineBackend(eng, clock), slots=4,
                            site_id="t", decode_chunk=chunk)

    def test_chunk_respects_remaining_budget(self):
        """The fused chunk never overshoots any running request's token
        budget — completion accounting stays exact."""
        plane = self._plane()
        plane.submit(session_id="a", klass="best-effort", prompt_tokens=4,
                     gen_tokens=5, t_max_ms=1e9)
        plane.submit(session_id="b", klass="best-effort", prompt_tokens=4,
                     gen_tokens=20, t_max_ms=1e9)
        # a has 4 tokens left after prefill's first token
        assert plane._chunk_steps() == 4
        plane.drain()
        res = {r.session_id: r for r in plane.pop_results()}
        assert res["a"].tokens == 5 and res["b"].tokens == 20
        assert len(res["a"].token_ids) == 5
        assert len(res["b"].token_ids) == 20

    def test_backend_admit_failure_frees_scheduler_slot(self):
        """A backend that refuses admission (oversized prompt) must yield a
        failed PlaneResult and free the scheduler slot — never leave the
        request wedged in running."""
        from repro.core.failures import FailureCause
        plane = self._plane()   # engine max_len = 64
        plane.submit(session_id="big", klass="best-effort",
                     prompt_tokens=100, gen_tokens=4, t_max_ms=1e9,
                     prompt=np.arange(100, dtype=np.int32))
        assert not plane.scheduler.running
        assert plane.scheduler.queue_depth() == 0
        res = plane.pop_results()
        assert len(res) == 1
        assert res[0].failed is FailureCause.NO_FEASIBLE_BINDING
        # the plane still serves well-formed requests afterwards
        ok = plane.serve(session_id="ok", klass="best-effort",
                         prompt_tokens=8, gen_tokens=3, t_max_ms=1e9,
                         prompt=np.arange(8, dtype=np.int32))
        assert ok.completed and ok.tokens == 3

    def test_chunk_caps_at_highest_class_present(self):
        """Premium work (running OR queued) shrinks the chunk: the chunk is
        the preemption granularity premium TTFT rides on."""
        plane = self._plane(chunk={"premium": 2, "assured": 8,
                                   "best-effort": 32})
        plane.submit(session_id="be", klass="best-effort", prompt_tokens=4,
                     gen_tokens=64, t_max_ms=1e9)
        assert plane._chunk_steps() == 32
        # a queued premium request tightens the cap without being admitted
        plane.scheduler.queues["premium"].append(
            Request("rq", "p", "premium", 4, 8, 1e9))
        assert plane._chunk_steps() == 2
