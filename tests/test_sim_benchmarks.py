"""§V simulator behaviour + the paper's three figure claims + Table I."""

import numpy as np
import pytest

from repro.sim import (LatencyModel, SimConfig, simulate_endpoint,
                       simulate_neaiaas, simulate_mobility)


@pytest.fixture(scope="module")
def model():
    return LatencyModel(SimConfig(n_requests=4000))


class TestQueueModel:
    def test_lindley_wait_grows_with_load(self, model):
        rng = np.random.default_rng(0)
        svc = model.infer_times(rng, 4000)
        w_lo = model.queue_wait(np.random.default_rng(1), 4000, 0.3, svc)
        w_hi = model.queue_wait(np.random.default_rng(1), 4000, 0.95, svc)
        assert w_hi.mean() > 5 * w_lo.mean()

    def test_transport_tails(self, model):
        rng = np.random.default_rng(2)
        be = model.transport_best_effort(rng, 20000)
        qos = model.transport_qos(np.random.default_rng(2), 20000)
        assert np.quantile(be, 0.999) > 4 * np.quantile(qos, 0.999)
        assert qos.max() <= model.cfg.qos_cap_ms + 1e-9


class TestPaperClaims:
    def test_fig2_tail_collapse_delayed(self, model):
        e = simulate_endpoint(0.95, model, ell99=400, t_max=1000)
        n = simulate_neaiaas(0.95, model, ell99=400, t_max=1000)
        assert e.p99_ms > 1.5 * n.p99_ms

    def test_fig3_served_and_failed(self, model):
        e = simulate_endpoint(0.95, model, ell99=400, t_max=1000)
        n = simulate_neaiaas(0.95, model, ell99=400, t_max=1000)
        assert e.violation_prob > 0.15
        assert n.violation_prob < 0.05
        assert n.admitted_frac < 1.0      # admission actually rejected load

    def test_fig3_low_load_equivalence(self, model):
        """At low load both systems comply — the win is the tail regime."""
        e = simulate_endpoint(0.3, model, ell99=400, t_max=1000)
        n = simulate_neaiaas(0.3, model, ell99=400, t_max=1000)
        assert e.violation_prob < 0.05 and n.violation_prob < 0.05

    def test_fig4_interruption(self):
        t = simulate_mobility(90, "teardown", n_sessions=20)
        b = simulate_mobility(90, "mbb", n_sessions=20)
        assert t.interruption_prob > 0.5
        assert b.interruption_prob <= 0.1
        assert b.mean_gap_ms <= t.mean_gap_ms

    def test_fig4_static_user_no_interruption(self):
        t = simulate_mobility(0, "teardown", n_sessions=10)
        assert t.interruption_prob == 0.0


class TestFederatedScenarios:
    def test_federated_roaming_continuity(self):
        from repro.sim import simulate_federated_roaming
        r = simulate_federated_roaming(n_sessions=8)
        assert r.roamed == 8 and r.aborted == 0
        assert r.max_interruption_ms == 0.0      # make-before-break
        assert r.bytes_moved > 0                 # real state crossed
        # the visited anchor serves the new zone as well as home served
        # the old one (the rtt symmetry of the topology)
        assert r.p99_post_ms <= 2.0 * r.p99_pre_ms

    def test_home_overload_spillover_beats_single_domain(self):
        from repro.sim import simulate_home_overload_spillover
        fed = simulate_home_overload_spillover(
            n_sessions=24, home_slots=8, federated=True)
        single = simulate_home_overload_spillover(
            n_sessions=24, home_slots=8, federated=False)
        assert single.admitted_frac < 0.5        # home alone saturates
        assert fed.admitted_frac == 1.0          # spillover absorbs all
        assert fed.established_visited > 0
        assert fed.served > single.served


class TestTable1:
    def test_all_requirements_pass(self):
        from benchmarks.figures import table1_requirements
        rows, derived = table1_requirements()
        failed = [r["req"] for r in rows if not r["passes"]]
        assert not failed, f"requirements failing: {failed}"
        assert derived["holds"] and derived["passes"] == 10
