"""Federated multi-domain control plane: cross-domain establish and roaming
migration through the UNCHANGED SessionClient/NorthboundGateway contract,
with every east-west lifecycle verb crossing the typed wire.

Covers the acceptance criteria: a session established northbound can be
anchored on — and live-migrated to — a site in a different DomainController;
duplicate cross-domain COMMITs are idempotent; and every abort path leaves
both domains' leases and charging state clean.
"""

import dataclasses

import pytest

from repro.api import messages as m
from repro.api.client import ScarcityError, SessionClient
from repro.api.gateway import NorthboundGateway
from repro.core.asp import MobilityClass, QualityTier, default_asp
from repro.core.catalog import Catalog, default_catalog
from repro.core.clock import VirtualClock
from repro.core.failures import FailureCause, SessionError
from repro.core.orchestrator import Orchestrator
from repro.core.sites import ExecutionSite, SiteSpec
from repro.federation import (DomainController, EWTimeout,
                              FederationRegistry, decompose_budget)
from repro.federation import eastwest as ew


def _site(site_id: str, region: str, rtt: dict, clock, *,
          slots: int = 8) -> ExecutionSite:
    v5e_flops, v5e_bw, hbm = 197e12, 819e9, 16e9
    return ExecutionSite(SiteSpec(
        site_id, "edge", region, chips=16, hbm_bytes_total=16 * hbm,
        peak_flops=16 * v5e_flops, hbm_bw=16 * v5e_bw, decode_slots=slots,
        rtt_ms=dict(rtt), hosted_models=("edge-tiny@1.0",),
        price_per_chip_s=2.0e-4), clock)


def _catalog() -> Catalog:
    cat = Catalog()
    cat.register(default_catalog().get("edge-tiny"))
    return cat


def make_federation(*, solicit: str = "fallback", home_slots: int = 8,
                    visited_slots: int = 32, transit_ms: float = 5.0,
                    registry_max_age: float = 30.0):
    """Two peered single-site domains sharing a clock + registry: the home
    site is close to zone-a and hopeless from zone-b; the visited site the
    reverse — the zone flip is the roaming trigger."""
    clock = VirtualClock()
    registry = FederationRegistry(clock, max_age_s=registry_max_age)
    home = DomainController(
        "home", registry, solicit=solicit,
        orchestrator=Orchestrator(
            clock=clock, catalog=_catalog(),
            sites={"h-edge": _site("h-edge", "eu",
                                   {"zone-a": 2.0, "zone-b": 400.0},
                                   clock, slots=home_slots)}))
    visited = DomainController(
        "visited", registry, solicit=solicit,
        orchestrator=Orchestrator(
            clock=clock, catalog=_catalog(),
            sites={"v-edge": _site("v-edge", "eu",
                                   {"zone-a": 25.0, "zone-b": 2.0},
                                   clock, slots=visited_slots)}))
    home.connect(visited, transit_ms=transit_ms)
    return clock, home, visited


def saturate(site: ExecutionSite, model) -> None:
    free = site.spec.decode_slots - site.slots_in_use()
    if free > 0:
        lease = site.prepare(model, slots=free, cache_bytes=0.0, ttl_s=1e9)
        site.confirm(lease.lease_id, lease_s=1e9)


def _asp(**kw):
    return default_asp(tier=QualityTier.BASIC, **kw)


# ----------------------------------------------------------------------
class TestCrossDomainEstablish:
    def test_saturated_home_spills_to_visited_via_unchanged_client(self):
        clock, home, visited = make_federation()
        saturate(home.core.sites["h-edge"], home.core.catalog.get("edge-tiny"))
        gw = NorthboundGateway(home)    # DomainController accepted as-is
        with SessionClient(gw, _asp(), invoker="ue-f", zone="zone-a") as c:
            assert c.anchor == "visited/v-edge"
            # the candidate set is merged + domain-annotated
            remote = [x for x in c.candidates if x["domain"] == "visited"]
            assert remote and any(x["admissible"] for x in remote)
            local = [x for x in c.candidates if not x["domain"]]
            assert all(x["exclusion_reason"] == "home:compute-saturated"
                       for x in local)
            # serve runs on the visited plane, metered in BOTH domains
            stream = c.generate(prompt_tokens=32, gen_tokens=8)
            assert len(stream.tokens()) == 8
            assert stream.complete.completed
            sid = c.session_id
            sess = home.core.sessions[sid]
            assert sess.binding.site_id == "visited/v-edge"
            assert sess.committed() and sess.serve_allowed()
            home_rec = home.core.policy.charging(sess.charging_ref)
            assert home_rec.tokens == 8          # retail (home) metering
            guest = visited._guest_sessions[sid]
            vis_rec = visited.core.policy.charging(guest.charging_ref)
            assert vis_rec.tokens == 8           # wholesale (visited)
            # heartbeat renews the visited leases over the east-west wire
            ack = c.heartbeat()
            assert ack.committed
        # context-managed release: BOTH domains end clean
        assert visited._guest_sessions == {}
        assert visited._guest_by_ref == {}
        base = visited.core.sites["v-edge"].slots_in_use()
        assert base == 0
        assert home._remote_bindings == {}

    def test_duplicate_cross_domain_commit_is_idempotent(self):
        clock, home, visited = make_federation()
        saturate(home.core.sites["h-edge"], home.core.catalog.get("edge-tiny"))
        orch = home.core
        s = orch.begin_session(_asp(), "ue-i", "zone-a")
        chosen = orch.page_for(s, orch.discover_for(s))
        assert chosen.domain == "visited"
        prepared = orch.prepare_for(s, chosen)
        commit = ew.EWCommit(home_domain="home", session_ref=s.session_id,
                             prepared_ref=prepared.prepared_ref)
        r1 = visited.handle_eastwest_json(commit.to_json())
        r2 = visited.handle_eastwest_json(commit.to_json())
        assert r1 == r2
        assert isinstance(ew.from_json(r1), ew.EWCommitted)
        assert visited.core.sites["v-edge"].slots_in_use() == 1   # once
        assert len(visited.core.policy._charges) == 1             # once

    def test_home_commit_abort_rolls_back_visited_cleanly(self):
        """Visited PREPARE granted, then the home COMMIT fails (transport
        lease expired): the visited lease is rolled back and NO charging
        was ever opened on the visited side."""
        clock, home, visited = make_federation()
        saturate(home.core.sites["h-edge"], home.core.catalog.get("edge-tiny"))
        orch = home.core
        s = orch.begin_session(_asp(), "ue-a", "zone-a")
        chosen = orch.page_for(s, orch.discover_for(s))
        prepared = orch.prepare_for(s, chosen)
        assert visited.core.sites["v-edge"].slots_in_use() == 1
        assert visited.core.policy._charges == {}    # held, not billed
        clock.advance(orch.timers.tau_prep + orch.timers.tau_com + 1.0)
        with pytest.raises(SessionError) as ei:
            orch.commit_for(s, chosen, prepared)
        assert ei.value.cause is FailureCause.DEADLINE_EXPIRY
        assert visited.core.sites["v-edge"].slots_in_use() == 0
        assert visited.core.policy._charges == {}    # never opened
        assert visited._guest_by_ref == {}
        assert visited._guest_sessions == {}
        # home transport half is rolled back too
        assert orch.qos.in_use(("zone-a", "ew:visited"), "best-effort") == 0

    def test_lost_commit_reply_redrives_visited_to_clean_state(self):
        """The EWCommit LANDS but its reply is lost: the home gives up
        (DEADLINE_EXPIRY) and must re-drive the visited domain clean via
        EWAbort — which degenerates to release post-COMMIT, so no guest
        lease survives and nothing was ever metered."""
        clock, home, visited = make_federation()
        saturate(home.core.sites["h-edge"], home.core.catalog.get("edge-tiny"))
        real = home.peers["visited"]

        def lossy(payload: str) -> str:
            reply = real(payload)
            if '"type": "ew_commit"' in payload:
                raise EWTimeout("commit reply lost in transit")
            return reply

        home.peers["visited"] = lossy
        orch = home.core
        s = orch.begin_session(_asp(), "ue-l", "zone-a")
        chosen = orch.page_for(s, orch.discover_for(s))
        prepared = orch.prepare_for(s, chosen)
        with pytest.raises(SessionError) as ei:
            orch.commit_for(s, chosen, prepared)
        assert ei.value.cause is FailureCause.DEADLINE_EXPIRY
        assert visited.core.sites["v-edge"].slots_in_use() == 0
        assert visited._guest_by_ref == {} and visited._guest_sessions == {}
        for rec in visited.core.policy._charges.values():
            assert rec.tokens == 0 and rec.cost == 0.0   # never billed
        assert orch.qos.in_use(("zone-a", "ew:visited"), "best-effort") == 0

    def test_discover_query_carries_only_the_visited_budget_share(self):
        """The east-west wire never leaks the raw home objectives or the
        full cost envelope — a peer sees only the share it must meet."""
        clock, home, visited = make_federation(solicit="always")
        seen = []
        real = home.peers["visited"]

        def spy(payload: str) -> str:
            seen.append(ew.from_json(payload))
            return real(payload)

        home.peers["visited"] = spy
        orch = home.core
        s = orch.begin_session(_asp(), "ue-w", "zone-a")
        orch.discover_for(s)
        queries = [q for q in seen if isinstance(q, ew.DiscoverQuery)]
        assert queries
        asp = _asp()
        budget = decompose_budget(asp, home.transit_ms_for("visited"),
                                  home_cost_share=home.home_cost_share)
        wired = queries[0].asp
        assert wired["objectives"]["ttfb_ms"] == budget.ttfb_ms
        assert wired["objectives"]["p99_ms"] == budget.p99_ms
        assert wired["max_cost_per_1k_tokens"] == budget.max_cost_per_1k
        assert wired["objectives"]["ttfb_ms"] < asp.objectives.ttfb_ms

    def test_offer_timeout_anchors_home(self):
        clock, home, visited = make_federation(solicit="always")
        home.peers["visited"] = _raise_timeout
        gw = NorthboundGateway(home)
        with SessionClient(gw, _asp(), invoker="ue-t", zone="zone-a") as c:
            assert c.anchor == "h-edge"
            notes = [x for x in c.candidates
                     if x["exclusion_reason"] == "visited:offer-timeout"]
            assert notes, "timeout must be an attributable exclusion"

    def test_merged_no_feasible_binding_aggregates_domains(self):
        clock, home, visited = make_federation()
        asp = _asp()
        asp = dataclasses.replace(asp, max_cost_per_1k_tokens=1e-9)
        gw = NorthboundGateway(home)
        client = SessionClient(gw, asp, invoker="ue-n", zone="zone-a")
        with pytest.raises(ScarcityError) as ei:
            client.establish()
        assert ei.value.cause is FailureCause.NO_FEASIBLE_BINDING
        assert "home:cost-envelope" in ei.value.detail
        assert "visited:cost-envelope" in ei.value.detail

    def test_elapsed_time_alone_does_not_stale_a_live_peer(self):
        """A peer with a live advertisement provider is re-pulled when its
        digest ages out — federation must not go dark just because the
        clock moved."""
        clock, home, visited = make_federation(registry_max_age=1.0)
        saturate(home.core.sites["h-edge"], home.core.catalog.get("edge-tiny"))
        clock.advance(60.0)                  # way past max_age_s
        orch = home.core
        s = orch.begin_session(_asp(), "ue-live", "zone-a")
        chosen = orch.page_for(s, orch.discover_for(s))
        assert chosen.domain == "visited"

    def test_registry_staleness_is_attributable_and_recoverable(self):
        """Staleness means the peer stopped answering the registry (dead
        provider), is excluded attributably, and recovers when the peer
        re-advertises."""
        clock, home, visited = make_federation(registry_max_age=1.0)
        saturate(home.core.sites["h-edge"], home.core.catalog.get("edge-tiny"))
        home.registry.drop_provider("visited")   # peer goes silent
        clock.advance(5.0)                       # its digest ages out
        orch = home.core
        s = orch.begin_session(_asp(), "ue-s", "zone-a")
        with pytest.raises(SessionError) as ei:
            orch.page_for(s, orch.discover_for(s))
        assert ei.value.cause is FailureCause.NO_FEASIBLE_BINDING
        assert "visited:registry-stale" in ei.value.detail
        visited.advertise()                  # fresh digest ⇒ recoverable
        s2 = orch.begin_session(_asp(), "ue-s2", "zone-a")
        chosen = orch.page_for(s2, orch.discover_for(s2))
        assert chosen.domain == "visited"

    def test_guest_ref_collision_refused_not_clobbered(self):
        """A session_ref naming a NATIVE visited session (or another
        home's guest) is refused — ids are only unique per home domain."""
        clock, home, visited = make_federation()
        native = visited.core.establish(_asp(), "local-ue", "zone-b")
        req = ew.EWPrepare(
            home_domain="home", session_ref=native.session_id,
            model_id="edge-tiny", model_version="1.0", site_id="v-edge",
            klass="best-effort", zone="zone-a")
        reply = ew.from_json(visited.handle_eastwest_json(req.to_json()))
        assert isinstance(reply, ew.EWError)
        assert reply.code == "E_POLICY"
        assert native.committed()            # untouched

    def test_abandoned_guest_leases_are_reaped_after_ttl(self):
        """A home that prepares and vanishes leaves nothing behind once
        the provisional leases expire — the next east-west exchange
        sweeps the bookkeeping."""
        clock, home, visited = make_federation()
        saturate(home.core.sites["h-edge"], home.core.catalog.get("edge-tiny"))
        orch = home.core
        s = orch.begin_session(_asp(), "ue-gone", "zone-a")
        chosen = orch.page_for(s, orch.discover_for(s))
        orch.prepare_for(s, chosen)          # …and the home "crashes"
        assert len(visited._guest_by_ref) == 1
        clock.advance(orch.timers.tau_prep + orch.timers.tau_com + 1.0)
        # any later inbound traffic triggers the sweep
        probe = ew.DiscoverQuery(
            home_domain="home", query_id="probe", zone="zone-a",
            asp=_asp().to_wire(),
            budget=decompose_budget(_asp(), 5.0).to_wire())
        visited.handle_eastwest_json(probe.to_json())
        assert visited._guest_by_ref == {}
        assert visited.core.sites["v-edge"].slots_in_use() == 0
        assert visited.core.policy._charges == {}

    def test_budget_decomposition_infeasible_maps_to_no_feasible_binding(self):
        asp = _asp()
        with pytest.raises(SessionError) as ei:
            decompose_budget(asp, asp.objectives.ttfb_ms + 1.0)
        assert ei.value.cause is FailureCause.NO_FEASIBLE_BINDING
        b = decompose_budget(asp, 50.0, home_cost_share=0.2)
        assert b.ttfb_ms == asp.objectives.ttfb_ms - 50.0
        assert b.max_cost_per_1k == pytest.approx(
            0.8 * asp.max_cost_per_1k_tokens)
        assert b.home_cost_per_1k == pytest.approx(
            0.2 * asp.max_cost_per_1k_tokens)


def _raise_timeout(payload: str) -> str:
    raise EWTimeout("no offer within the solicitation window")


# ----------------------------------------------------------------------
class TestRoamingMigration:
    def _establish_and_roam(self, *, serve_first=True):
        clock, home, visited = make_federation()
        gw = NorthboundGateway(home)
        client = SessionClient(gw, _asp(mobility=MobilityClass.VEHICULAR),
                               invoker="car-f", zone="zone-a").establish()
        assert client.anchor == "h-edge"
        if serve_first:
            assert len(client.generate(prompt_tokens=64,
                                       gen_tokens=8).tokens()) == 8
        # mobility: the invoker crosses the domain boundary
        session = home.core.sessions[client.session_id]
        session.zone = "zone-b"
        ack = client.heartbeat(trigger_l99=0.0, trigger_ttfb=0.0)
        return clock, home, visited, gw, client, session, ack

    def test_live_migration_to_visited_domain(self):
        clock, home, visited, gw, client, session, ack = \
            self._establish_and_roam()
        mig = ack.migration
        assert mig and mig["migrated"] and not mig["aborted"]
        assert mig["from_site"] == "h-edge"
        assert mig["to_site"] == "visited/v-edge"
        assert mig["interruption_ms"] == 0.0          # make-before-break
        assert mig["transfer_bytes"] > 0              # real state moved
        assert mig["fingerprint"]                     # verified
        assert client.anchor == "visited/v-edge"
        assert session.committed()                    # never left Committed
        # the home anchor's resources were released after the break
        assert home.core.sites["h-edge"].slots_in_use() == 0
        backend = visited.core.plane_for(
            visited.core.sites["v-edge"]).backend
        assert backend.has_slot(client.session_id)    # state lives abroad
        # serving continues through the same northbound contract
        stream = client.generate(prompt_tokens=32, gen_tokens=4)
        assert len(stream.tokens()) == 4 and stream.complete.completed
        # release settles BOTH domains
        rel = client.release()
        assert rel.state == "released"
        assert visited._guest_sessions == {}
        assert visited.core.sites["v-edge"].slots_in_use() == 0
        assert not backend.has_slot(client.session_id)
        assert home._remote_bindings == {}

    def test_roaming_abort_keeps_home_anchor_and_both_domains_clean(self):
        """Visited import refusal mid-transfer: the migration aborts with
        COMPUTE_SCARCITY, the session keeps serving at home, and the
        visited provisional lease + any provisional state are rolled
        back without charging."""
        from repro.serving.state_transfer import TransferInjections
        clock, home, visited = make_federation()
        gw = NorthboundGateway(home)
        client = SessionClient(gw, _asp(mobility=MobilityClass.VEHICULAR),
                               invoker="car-x", zone="zone-a").establish()
        client.generate(prompt_tokens=64, gen_tokens=8)
        vplane = visited.core.plane_for(visited.core.sites["v-edge"])
        vplane.migration_inject = TransferInjections(deny_admission=True)
        session = home.core.sessions[client.session_id]
        session.zone = "zone-b"
        ack = client.heartbeat(trigger_l99=0.0, trigger_ttfb=0.0)
        mig = ack.migration
        assert mig and mig["aborted"]
        assert mig["cause"] == FailureCause.COMPUTE_SCARCITY.value
        assert client.anchor == "h-edge"
        assert session.committed()
        assert session.binding.site_id == "h-edge"
        # both domains clean: no guest lease, no guest charging, no slot
        assert visited._guest_by_ref == {}
        assert visited.core.policy._charges == {}
        assert visited.core.sites["v-edge"].slots_in_use() == 0
        assert not vplane.backend.has_slot(client.session_id)
        assert home.core.qos.in_use(("zone-b", "ew:visited"),
                                    "best-effort") == 0
        # and the session still serves at home
        assert len(client.generate(gen_tokens=4).tokens()) == 4

    def test_cross_domain_transfer_rides_the_peering_link(self):
        """The roaming transfer is billed to the (slower) east-west link,
        not the intra-domain DCN."""
        clock, home, visited, gw, client, session, ack = \
            self._establish_and_roam()
        mig = ack.migration
        tf = home.core.migrations.transfer_fn
        declared = visited.core.catalog.get("edge-tiny").session_state_bytes(
            max(session.context_tokens, 1))
        wire_bytes = max(mig["transfer_bytes"], declared)
        assert mig["transfer_ms"] == pytest.approx(
            wire_bytes / tf.ew_link_bw * 1e3, rel=1e-6)
        # the same payload on the intra-domain DCN would be 4× cheaper
        assert mig["transfer_ms"] > wire_bytes / tf.link_bw * 1e3


# ----------------------------------------------------------------------
class TestEastWestWire:
    def test_roundtrip_every_message_type(self):
        budget = decompose_budget(_asp(), 10.0).to_wire()
        samples = [
            ew.DiscoverQuery(home_domain="a", query_id="q1", zone="z",
                             asp=_asp().to_wire(), budget=budget),
            ew.DiscoverOffer(visited_domain="b", query_id="q1",
                             candidates=[{"model_id": "m"}],
                             digest_epoch=3, at_s=1.5),
            ew.EWPrepare(home_domain="a", session_ref="s1", model_id="m",
                         model_version="1.0", site_id="e", klass="premium",
                         zone="z", slots=1, context_tokens=4096,
                         hold_s=2.0, budget=budget),
            ew.EWPrepared(visited_domain="b", session_ref="s1",
                          prepared_ref="b/ewp-1", site_id="e", qfi=7,
                          cache_bytes=1e6, expires_at=9.0),
            ew.EWCommit(home_domain="a", session_ref="s1",
                        prepared_ref="b/ewp-1"),
            ew.EWCommitted(visited_domain="b", session_ref="s1",
                           prepared_ref="b/ewp-1", site_id="e",
                           endpoint="aiaas://b/e/m", qfi=7,
                           compute_lease_id="e/cmp-0",
                           qos_lease_id="qos-0", charging_ref="chg-1",
                           lease_s=30.0, price_per_1k=0.1, at_s=2.0),
            ew.EWAbort(home_domain="a", session_ref="s1",
                       prepared_ref="b/ewp-1", reason="deadline expiry"),
            ew.EWAbortAck(visited_domain="b", prepared_ref="b/ewp-1",
                          released=True),
            ew.EWRenew(home_domain="a", prepared_ref="b/ewp-1",
                       lease_s=30.0),
            ew.EWRenewAck(visited_domain="b", prepared_ref="b/ewp-1",
                          renewed=True),
            ew.EWRelease(home_domain="a", prepared_ref="b/ewp-1"),
            ew.EWReleaseAck(visited_domain="b", prepared_ref="b/ewp-1",
                            released=True, tokens=12, cost=0.5),
            ew.EWError(visited_domain="b", code="E_COMPUTE_SCARCITY",
                       cause="compute scarcity", detail="full"),
        ]
        assert {type(s) for s in samples} == set(
            ew.message_types().values())
        for msg in samples:
            assert ew.from_json(msg.to_json()) == msg

    def test_major_version_mismatch_refused_structurally(self):
        clock, home, visited = make_federation()
        bad = ew.EWRelease(home_domain="home", prepared_ref="x",
                           schema_version="2.0")
        reply = ew.from_json(visited.handle_eastwest_json(bad.to_json()))
        assert isinstance(reply, ew.EWError)
        assert reply.code == "E_EW_SCHEMA"

    def test_visited_session_error_crosses_as_its_eq12_cause(self):
        clock, home, visited = make_federation()
        req = ew.EWPrepare(home_domain="home", session_ref="s",
                           model_id="nope", model_version="9.9",
                           site_id="v-edge", klass="best-effort", zone="z")
        reply = ew.from_json(visited.handle_eastwest_json(req.to_json()))
        assert isinstance(reply, ew.EWError)
        assert reply.code == "E_MODEL_UNAVAILABLE"
        err = reply.to_session_error()
        assert err.cause is FailureCause.MODEL_UNAVAILABLE
        assert "[visited]" in err.detail

    def test_abort_and_release_are_idempotent(self):
        clock, home, visited = make_federation()
        for msg in (ew.EWAbort(home_domain="home", session_ref="s",
                               prepared_ref="visited/ewp-000099"),
                    ew.EWRelease(home_domain="home",
                                 prepared_ref="visited/ewp-000099")):
            reply = ew.from_json(visited.handle_eastwest_json(msg.to_json()))
            assert reply.released is False      # unknown ref = clean no-op


# ----------------------------------------------------------------------
class TestSatellites:
    def test_consent_ttl_lapses_to_consent_violation_mid_session(self):
        clock = VirtualClock()
        orch = Orchestrator(clock=clock, catalog=_catalog())
        orch.policy.consent_ttl_s = 5.0
        s = orch.establish(_asp(), "ue-ttl", "zone-a")
        assert orch.serve(s, gen_tokens=2).completed
        clock.advance(6.0)                   # lease_s=30 still live…
        assert s.committed() and not s.v_sigma()   # …but consent lapsed
        with pytest.raises(SessionError) as ei:
            orch.serve(s, gen_tokens=2)
        assert ei.value.cause is FailureCause.CONSENT_VIOLATION
        # re-authorization restores service (remediation path)
        s.authz_ref = orch.policy.grant_consent("ue-ttl",
                                                s.asp.allowed_regions)
        assert orch.serve(s, gen_tokens=2).completed

    def test_heartbeat_keeps_consent_alive_across_ttl_windows(self):
        """Consent is a sliding window: the session's own heartbeats renew
        the grant through the northbound surface, so only a session that
        STOPS heartbeating (or is revoked) lapses mid-flight."""
        clock = VirtualClock()
        orch = Orchestrator(clock=clock, catalog=_catalog())
        orch.policy.consent_ttl_s = 5.0
        s = orch.establish(_asp(), "ue-hb", "zone-a")
        for _ in range(4):                   # 12 s > TTL, but heartbeating
            clock.advance(3.0)
            orch.heartbeat(s)
        assert s.v_sigma()
        assert orch.serve(s, gen_tokens=2).completed
        clock.advance(6.0)                   # silence ⇒ the grant lapses
        with pytest.raises(SessionError) as ei:
            orch.serve(s, gen_tokens=2)
        assert ei.value.cause is FailureCause.CONSENT_VIOLATION

    def test_lapsed_consent_cannot_be_renewed(self):
        clock = VirtualClock()
        from repro.core.policy import PolicyControl
        pol = PolicyControl(clock, consent_ttl_s=2.0)
        ref = pol.grant_consent("ue", ("eu",))
        assert pol.consent_valid(ref)
        assert pol.renew_consent(ref)        # live grant extends
        clock.advance(3.0)
        assert not pol.consent_valid(ref)
        assert not pol.renew_consent(ref)    # lapsed ⇒ re-acquire

    def test_predictions_memoized_until_heartbeat_invalidates(self):
        clock = VirtualClock()
        orch = Orchestrator(clock=clock, catalog=_catalog())
        s = orch.establish(_asp(), "ue-m", "zone-a")
        pred = orch.predictors
        hits0, misses0 = pred.memo_hits, pred.memo_misses
        s2 = orch.begin_session(_asp(), "ue-m2", "zone-a")
        orch.discover_for(s2)                # identical cross product
        assert pred.memo_misses == misses0   # all served from the memo
        assert pred.memo_hits > hits0
        # new load evidence bumps the epoch ⇒ recompute
        orch.analytics.observe_site("edge-a", utilization=0.5,
                                    queue_depth=1.0, arrival_rate=2.0)
        s3 = orch.begin_session(_asp(), "ue-m3", "zone-a")
        orch.discover_for(s3)
        assert pred.memo_misses > misses0

    def test_federated_discover_shares_the_memo_across_solicitations(self):
        clock, home, visited = make_federation(solicit="always")
        orch = home.core
        s = orch.begin_session(_asp(), "ue-mm", "zone-a")
        orch.discover_for(s)
        vm0 = visited.core.predictors.memo_misses
        s2 = orch.begin_session(_asp(), "ue-mm2", "zone-a")
        orch.discover_for(s2)
        assert visited.core.predictors.memo_misses == vm0

    def test_boundary_scrub_strips_non_essential_payload(self):
        from repro.core.migration import PlaneTransferPath
        payload = {"cache": {"sim": [1.0]}, "position": 3, "last_token": 7,
                   "request_log": ["secret"], "invoker_notes": "x"}
        out = PlaneTransferPath._boundary_scrub(dict(payload))
        assert set(out) == {"cache", "position", "last_token"}
