"""Sharding planner invariants + small-mesh integration (subprocess)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.models import LM
from repro.models.config import ModelConfig
from repro.sharding import SHAPES, cell_runnable, input_specs, make_plan

ASSIGNED = [a for a in ARCH_IDS if a != "edge-tiny"]


class FakeMesh:
    """Just enough Mesh surface for the planner's pure logic."""

    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)
        n = int(np.prod(list(shape.values())))
        self.devices = np.empty((n,), object)


def mesh16():
    return FakeMesh({"data": 16, "model": 16})


class TestPlannerInvariants:
    @pytest.mark.parametrize("arch", ASSIGNED)
    def test_param_specs_divisible(self, arch):
        """Every sharded dim divides its mesh axes — jit would reject
        anything else, so this is the planner's core contract."""
        cfg = get_config(arch)
        lm = LM(cfg)
        tree = lm.param_specs()
        plan = make_plan(cfg, mesh16(), "train", batch=256, seq=4096,
                         param_tree=tree)
        sizes = {"data": 16, "model": 16}
        flat_p = jax.tree.leaves(tree)
        flat_s = jax.tree.leaves(plan.param_specs,
                                 is_leaf=lambda x: isinstance(x, P))
        assert len(flat_p) == len(flat_s)
        for leaf, spec in zip(flat_p, flat_s):
            for dim, s in zip(leaf.shape, tuple(spec)):
                if s is None:
                    continue
                axes = s if isinstance(s, tuple) else (s,)
                k = int(np.prod([sizes[a] for a in axes]))
                assert dim % k == 0, f"{arch}: {leaf.shape} vs {spec}"

    @pytest.mark.parametrize("arch", ASSIGNED)
    @pytest.mark.parametrize("shape", list(SHAPES))
    def test_cache_and_batch_specs_exist(self, arch, shape):
        cfg = get_config(arch)
        ok, _ = cell_runnable(cfg, shape)
        if not ok:
            pytest.skip("cell skipped by sub-quadratic rule")
        cell, batch, seq, specs = input_specs(cfg, shape)
        lm = LM(cfg)
        cache = (lm.init_cache(batch, seq, abstract=True)
                 if cell.kind == "decode" else None)
        plan = make_plan(cfg, mesh16(), cell.kind, batch=batch, seq=seq,
                         cache_tree=cache)
        assert set(specs) <= set(plan.batch_specs) | {"tokens"}
        if cache is not None:
            n_leaves = len(jax.tree.leaves(cache))
            n_specs = len(jax.tree.leaves(
                plan.cache_specs, is_leaf=lambda x: isinstance(x, P)))
            assert n_leaves == n_specs

    def test_microbatches_scale_with_depth(self):
        big = get_config("qwen2-vl-72b")
        small = get_config("mamba2-1.3b")
        mb_big = make_plan(big, mesh16(), "train", batch=256,
                           seq=4096).microbatches
        mb_small = make_plan(small, mesh16(), "train", batch=256,
                             seq=4096).microbatches
        assert mb_big >= mb_small >= 1

    def test_padded_vocab_shards(self):
        for arch in ("mamba2-1.3b", "seamless-m4t-medium"):
            cfg = get_config(arch)
            assert cfg.padded_vocab % 16 == 0
            assert cfg.padded_vocab >= cfg.vocab_size
            assert cfg.padded_vocab - cfg.vocab_size < 256


SMALL_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    sys.path.insert(0, "src")
    from repro.launch.mesh import make_test_mesh
    from repro.launch.dryrun import lower_cell
    mesh = make_test_mesh((2, 4), ("data", "model"))
    overrides = {"num_layers": 2, "d_model": 128, "num_heads": 8,
                 "num_kv_heads": 8, "head_dim": 16, "d_ff": 256,
                 "vocab_size": 1024, "attn_block_q": 16, "attn_block_kv": 32}
    out = {}
    for arch, shape in [("codeqwen1.5-7b", "train_4k"),
                        ("codeqwen1.5-7b", "decode_32k")]:
        rec, _ = lower_cell(arch, shape, mesh, scale=1/128,
                            overrides=overrides)
        out[f"{arch}/{shape}"] = rec["status"]
    print("RESULT::" + json.dumps(out))
""")


class TestSmallMeshIntegration:
    def test_lower_compile_on_8_devices(self):
        """End-to-end lower+compile through the real dry-run code path on a
        forced 8-device host mesh (subprocess: jax device count is locked at
        first init)."""
        r = subprocess.run([sys.executable, "-c", SMALL_MESH_SCRIPT],
                           capture_output=True, text=True, timeout=560,
                           cwd=os.path.dirname(os.path.dirname(__file__)))
        assert r.returncode == 0, r.stderr[-2000:]
        line = [l for l in r.stdout.splitlines() if l.startswith("RESULT::")]
        assert line, r.stdout[-2000:]
        out = json.loads(line[0][8:])
        assert all(v == "ok" for v in out.values()), out
