"""Paged KV cache + tiered hibernation: pool accounting, admission,
pressure-driven reclaim, slot reuse, and the transparent resume path.

The tier model under test (ISSUE 6 / the AIS lease lifecycle):

    resident (device, decoding) -> parked (device, idle, frozen in the
    fused batch) -> hibernated (host numpy, slot + pages freed)

and back, bit-exactly. Paged layout applies to full-attention stacked-KV
families (dense/moe); hybrid and SSM engines silently keep the dense slot
layout but park/hibernate identically.
"""

import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.core.clock import VirtualClock
from repro.serving import state_transfer
from repro.serving.engine import InferenceEngine, PagePoolExhausted
from repro.serving.plane import RealEngineBackend, ServingPlane

CFG = get_config("edge-tiny")


def _prompt(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, CFG.vocab_size, size=n).astype(np.int32)


def _paged(slots=3, max_len=64, page_size=16, num_pages=None, store=True,
           params=None):
    return InferenceEngine(CFG, params=params, slots=slots, max_len=max_len,
                           paged=True, page_size=page_size,
                           num_pages=num_pages, hibernation=store)


class TestPageAccounting:
    def test_pool_sizing_and_alloc(self):
        """Default pool covers every slot at max_len plus the scratch page;
        pages are allocated lazily by position, freed on release."""
        eng = _paged(slots=2, max_len=64, page_size=16)
        assert eng.total_pages() == 2 * 4          # scratch page not counted
        assert eng.free_pages() == 8 and eng.page_util() == 0.0
        eng.prefill_session("a", _prompt(5))       # 1 page (pos 5)
        assert eng.free_pages() == 7
        eng.prefill_session("b", _prompt(33))      # 3 pages (pos 33)
        assert eng.free_pages() == 4
        assert eng.page_util() == pytest.approx(0.5)
        eng.release_slot("a")
        assert eng.free_pages() == 5
        eng.release_slot("b")
        assert eng.free_pages() == 8 and eng.pool_bytes() > 0

    def test_decode_extends_pages_on_demand(self):
        eng = _paged(slots=1, max_len=64, page_size=16)
        eng.prefill_session("a", _prompt(15))
        assert eng.free_pages() == 3
        eng.decode_round(steps=4)                  # crosses the 16 boundary
        assert eng.free_pages() == 2

    def test_exhaustion_is_explicit_admission_failure(self):
        """A pool too small for the offered load raises PagePoolExhausted
        at prefill — never a silent eviction or corruption — and the
        failed admission leaves no partial slot behind."""
        eng = _paged(slots=3, max_len=64, page_size=16, num_pages=1 + 4,
                     store=False)
        eng.prefill_session("a", _prompt(40))      # 3 pages
        with pytest.raises(PagePoolExhausted):
            eng.prefill_session("b", _prompt(33))  # needs 3, only 1 left
        assert not eng.has_slot("b") and eng.free_slots() == 2
        eng.prefill_session("c", _prompt(10))      # 1 page still fits
        assert eng.free_pages() == 0

    def test_exhaustion_reclaims_parked_first(self):
        """Under pressure the engine hibernates the coldest parked session
        to free pages before refusing admission."""
        eng = _paged(slots=3, max_len=64, page_size=16, num_pages=1 + 4)
        eng.prefill_session("a", _prompt(40))
        eng.park_slot("a")
        eng.prefill_session("b", _prompt(33))      # reclaim: a -> host
        assert eng.has_hibernated("a") and not eng.has_slot("a")
        assert eng.has_slot("b") and eng.bound_sessions() == 2


class TestSlotReuse:
    @pytest.mark.parametrize("paged", [False, True])
    def test_no_bleed_through_after_release(self, paged):
        """A new session admitted into a released slot (and its reclaimed
        pages) must produce exactly the tokens a fresh engine produces —
        no stale KV/position bleed-through."""
        if paged:
            eng = _paged(slots=1, max_len=64, store=False)
        else:
            eng = InferenceEngine(CFG, slots=1, max_len=64)
        fresh = InferenceEngine(CFG, params=eng.params, slots=1, max_len=64)
        eng.prefill_session("old", _prompt(37, seed=1))
        eng.decode_round(steps=8)
        eng.release_slot("old")

        r0 = eng.prefill_session("new", _prompt(9, seed=2))
        r1 = fresh.prefill_session("new", _prompt(9, seed=2))
        assert r0["first_token"] == r1["first_token"]
        for _ in range(3):
            assert eng.decode_round(steps=4)["new"] == \
                fresh.decode_round(steps=4)["new"]

    def test_no_bleed_through_after_hibernate(self):
        """Same, when the slot was vacated by hibernation instead of
        release — and the hibernated session still resumes bit-exactly
        afterwards from a different slot's pages."""
        eng = _paged(slots=2, max_len=64)
        twin = InferenceEngine(CFG, params=eng.params, slots=2, max_len=64)
        eng.prefill_session("h", _prompt(21, seed=3))
        twin.prefill_session("h", _prompt(21, seed=3))
        for _ in range(2):
            assert eng.decode_round()["h"] == twin.decode_round()["h"]
        eng.hibernate_slot("h")

        r0 = eng.prefill_session("n", _prompt(12, seed=4))
        r1 = twin.prefill_session("n", _prompt(12, seed=4))
        assert r0["first_token"] == r1["first_token"]

        eng.resume_slot("h")                       # back, next to "n"
        for _ in range(3):
            a, b = eng.decode_round(), twin.decode_round()
            assert a["h"] == b["h"] and a["n"] == b["n"]


class TestPagedDenseIdentity:
    @pytest.mark.parametrize("arch", ["edge-tiny", "recurrentgemma-2b",
                                      "mamba2-1.3b"])
    def test_token_streams_identical(self, arch):
        """paged=True is a layout change, not a semantics change: for every
        family the token stream and the canonical export fingerprint match
        the dense engine (for hybrid/SSM, paged silently no-ops)."""
        cfg = CFG if arch == "edge-tiny" else get_smoke_config(arch)
        dense = InferenceEngine(cfg, slots=2, max_len=64)
        paged = InferenceEngine(cfg, params=dense.params, slots=2,
                                max_len=64, paged=True, page_size=16)
        assert paged.paged == (arch == "edge-tiny")
        for i, n in enumerate((5, 17)):
            sid = f"s{i}"
            p = _prompt(n, seed=i)
            assert dense.prefill_session(sid, p)["first_token"] == \
                paged.prefill_session(sid, p)["first_token"]
        for _ in range(3):
            assert dense.decode_round(steps=4) == paged.decode_round(steps=4)
        for sid in ("s0", "s1"):
            assert state_transfer.fingerprint(dense.export_slot(sid)) == \
                state_transfer.fingerprint(paged.export_slot(sid))


class TestPlaneTiering:
    def _plane(self, *, slots=2, num_pages=None, hibernate_idle_s=None,
               watermark=0.25):
        eng = _paged(slots=slots, num_pages=num_pages)
        clock = VirtualClock()
        backend = RealEngineBackend(eng, clock,
                                    free_page_watermark=watermark,
                                    hibernate_idle_s=hibernate_idle_s)
        return eng, clock, ServingPlane(clock, backend, slots=slots,
                                        site_id="t",
                                        premium_reserved_frac=0.0)

    def _serve(self, plane, sid, *, gen=4, resume=False, seed=0):
        return plane.serve(session_id=sid, klass="best-effort",
                           prompt_tokens=8, gen_tokens=gen, t_max_ms=1e12,
                           prompt=None if resume else _prompt(8, seed=seed),
                           resume=resume)

    def test_ensure_capacity_hibernates_under_page_pressure(self):
        """Satellite 1: ensure_capacity reclaims the LRU parked session
        when free pages sit below the watermark, even with a slot free."""
        eng, clock, plane = self._plane(slots=3, num_pages=1 + 6,
                                        watermark=0.5)
        for i in range(2):                          # park u0 (LRU), then u1
            r = self._serve(plane, f"u{i}", gen=12, seed=i)  # 2 pages each
            assert not r.failed
        assert eng.parked_sessions() == 2 and eng.free_slots() == 1
        assert eng.free_pages() < 0.5 * eng.total_pages()
        plane.backend.ensure_capacity(set())
        # coldest first: u0 went to host, u1 is still resident-parked
        assert eng.has_hibernated("u0") and eng.is_parked("u1")
        assert eng.free_pages() >= 0.5 * eng.total_pages()

    def test_idle_ttl_tick_hibernates_parked(self):
        """Lease-TTL expiry: load() drives the tick; sessions parked past
        hibernate_idle_s move to host, occupancy splits the tiers."""
        eng, clock, plane = self._plane(slots=2, hibernate_idle_s=5.0)
        assert not self._serve(plane, "a").failed
        load = plane.load()
        assert load.resident_sessions == 1 and load.hibernated_sessions == 0
        clock.advance(10.0)
        load = plane.load()
        assert load.resident_sessions == 0 and load.hibernated_sessions == 1
        assert load.bound_sessions == 1 and eng.has_hibernated("a")

    def test_resume_continues_hibernated_stream(self):
        """serve(resume=True) on a hibernated session re-imports and
        continues exactly where the lease left off."""
        eng, clock, plane = self._plane(slots=2, hibernate_idle_s=0.0)
        r0 = self._serve(plane, "a", gen=4)
        plane.load()                                # -> hibernated
        assert eng.has_hibernated("a")
        pos0 = eng.position_of("a")

        twin = InferenceEngine(CFG, params=eng.params, slots=1, max_len=64)
        tclock = VirtualClock()
        tp = ServingPlane(tclock,
                          RealEngineBackend(twin, tclock,
                                            retain_sessions=True),
                          slots=1, site_id="twin",
                          premium_reserved_frac=0.0)
        t0 = tp.serve(session_id="a", klass="best-effort", prompt_tokens=8,
                      gen_tokens=4, t_max_ms=1e12, prompt=_prompt(8))
        assert t0.token_ids == r0.token_ids

        r1 = self._serve(plane, "a", gen=4, resume=True)
        t1 = tp.serve(session_id="a", klass="best-effort", prompt_tokens=0,
                      gen_tokens=4, t_max_ms=1e12, resume=True)
        assert not r1.failed and r1.token_ids == t1.token_ids
        assert eng.position_of("a") == pos0 + 4
