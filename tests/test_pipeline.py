"""Pipeline parallelism: pipelined forward == sequential reference.

Runs in a subprocess with 8 forced host devices (jax locks the device count
at first init)."""

import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.sharding.pipeline import pipeline_forward, stage_params_from_stack

    devs = np.asarray(jax.devices()[:4]).reshape(4)
    mesh = Mesh(devs, ("pipe",))

    L, D, M, mb = 8, 32, 6, 4      # 8 layers over 4 stages, 6 microbatches
    key = jax.random.key(0)
    w = jax.random.normal(key, (L, D, D)) / np.sqrt(D)
    x = jax.random.normal(jax.random.key(1), (M, mb, D))

    def layer(p, h):
        return jnp.tanh(h @ p)

    def stage_fn(stage_w, h):      # stage_w: [L/S, D, D]
        def body(h, p):
            return layer(p, h), None
        h, _ = jax.lax.scan(body, h, stage_w)
        return h

    # sequential reference
    ref = x
    for i in range(L):
        ref = layer(w[i], ref)

    staged = stage_params_from_stack(w, 4)
    f = pipeline_forward(stage_fn, mesh, num_microbatches=M, axis="pipe")
    out = f(staged, x)
    err = float(jnp.max(jnp.abs(out - ref)))
    print("PIPE_ERR::%.8f" % err)
""")


def test_pipeline_matches_sequential():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=560,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-2000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("PIPE_ERR::")]
    assert line, r.stdout
    assert float(line[0].split("::")[1]) < 1e-5
