"""Northbound acceptance: the full AIS lifecycle driven PURELY through the
NorthboundGateway with JSON-serialized messages — no Orchestrator internals
imported. DISCOVER, PAGE, PREPARE/COMMIT (idempotent), chunk-by-chunk
streaming SERVE, migration SessionEvents, and RELEASE all cross the wire."""

import pytest

from repro.api import messages as m
from repro.api import (NorthboundGateway, SessionClient, ConsentRevoked,
                       DeadlineExpired, NorthboundError)
from repro.core.asp import MobilityClass, QualityTier, default_asp
from repro.core.clock import VirtualClock


def send(gw, msg):
    """One wire exchange: JSON out, JSON back, parsed."""
    out = gw.handle_json(msg.to_json())
    if isinstance(out, list):
        return [m.from_json(o) for o in out]
    return m.from_json(out)


def first(reply):
    """A refused streaming request arrives as a single error frame."""
    return reply[0] if isinstance(reply, list) else reply


@pytest.fixture
def gw():
    return NorthboundGateway(clock=VirtualClock())


class TestLifecycleOverWire:
    def test_full_lifecycle_with_migration_event(self, gw):
        gw.subscribe("car-1")
        asp = default_asp(mobility=MobilityClass.VEHICULAR)

        disc = send(gw, m.DiscoverRequest(invoker="car-1", zone="zone-a",
                                          asp=asp))
        assert isinstance(disc, m.DiscoverResponse)
        sid = disc.session_id
        assert any(c["admissible"] for c in disc.candidates)

        paged = send(gw, m.PageRequest(session_id=sid))
        assert isinstance(paged, m.PageResponse)
        src_site = paged.site_id

        prep = send(gw, m.PrepareRequest(session_id=sid,
                                         idempotency_key="p-1"))
        assert isinstance(prep, m.PrepareResponse)
        com = send(gw, m.CommitRequest(session_id=sid,
                                       prepared_ref=prep.prepared_ref,
                                       idempotency_key="c-1"))
        assert isinstance(com, m.CommitResponse)
        assert com.record["state"] == "committed"
        assert com.record["anchor"] == src_site

        # streaming serve: one chunk per generated token, then completion
        frames = send(gw, m.ServeRequest(session_id=sid, prompt_tokens=64,
                                         gen_tokens=8, stream=True))
        chunks, done = frames[:-1], frames[-1]
        assert len(chunks) == 8
        assert all(isinstance(c, m.ServeChunk) for c in chunks)
        assert [c.seq for c in chunks] == list(range(8))
        assert isinstance(done, m.ServeComplete)
        assert done.completed and done.error_code is None
        assert done.tokens == 8 and done.ttfb_ms > 0

        # heartbeat with tightened Eq. (14) thresholds fires a migration;
        # the invoker sees it in the ack AND as a SessionEvent
        hb = send(gw, m.HeartbeatReport(session_id=sid, trigger_l99=0.0,
                                        trigger_ttfb=0.0))
        assert isinstance(hb, m.HeartbeatAck)
        assert hb.migration and hb.migration["migrated"]
        assert hb.migration["from_site"] == src_site
        assert hb.migration["to_site"] != src_site
        assert hb.committed        # MBB: never left the committed domain

        events = send(gw, m.EventPoll(invoker="car-1"))
        states = [e.state for e in events if e.event == "state-transition"]
        assert states[:4] == ["discovered", "anchored", "prepared",
                              "committed"]
        migs = [e for e in events if e.event == "migration"]
        assert len(migs) == 1
        assert migs[0].detail["to_site"] == hb.migration["to_site"]
        assert migs[0].detail["interruption_ms"] == 0.0

        # serving continues at the NEW anchor after the event
        frames = send(gw, m.ServeRequest(session_id=sid, gen_tokens=4))
        assert frames[-1].completed

        rel = send(gw, m.ReleaseRequest(session_id=sid))
        assert isinstance(rel, m.ReleaseAck)
        assert rel.state == "released" and rel.tokens == 12

        err = first(send(gw, m.ServeRequest(session_id=sid, gen_tokens=1)))
        assert isinstance(err, m.ErrorResponse)
        assert err.code == "E_DEADLINE"

    def test_timer_incompatible_asp_is_bad_request(self, gw):
        """An ASP whose T_max is below this gateway's τ_mig is refused as
        an input error, never an E_INTERNAL leak."""
        import dataclasses
        asp = default_asp()
        asp = dataclasses.replace(asp, objectives=dataclasses.replace(
            asp.objectives, ttfb_ms=100.0, p95_ms=200.0, p99_ms=300.0,
            t_max_ms=1500.0))
        err = send(gw, m.DiscoverRequest(invoker="x", zone="zone-a",
                                         asp=asp))
        assert isinstance(err, m.ErrorResponse)
        assert err.code == "E_BAD_REQUEST"

    def test_unary_serves_do_not_become_phantom_completions(self, gw):
        """drain()/CompletionPoll carry ONLY async-submitted results: a
        unary serve (wire or direct orchestrator call) already returned
        its result inline."""
        with SessionClient(gw, default_asp(), invoker="ue-u") as c:
            list(c.generate(gen_tokens=2))           # wire unary
            s = gw.orch.sessions[c.session_id]
            gw.orch.serve(s, prompt_tokens=8, gen_tokens=2)  # direct unary
            rid = c.submit(prompt_tokens=8, gen_tokens=2)    # async
            done = gw.drain()
            assert [d.request_id for d in done] == [rid]

    def test_failed_establishment_maps_cause_code(self, gw):
        # BASIC tier + impossible cost envelope ⇒ every candidate excluded
        import dataclasses
        asp = dataclasses.replace(default_asp(),
                                  max_cost_per_1k_tokens=1e-9)
        disc = send(gw, m.DiscoverRequest(invoker="x", zone="zone-a",
                                          asp=asp))
        pg = send(gw, m.PageRequest(session_id=disc.session_id))
        assert isinstance(pg, m.ErrorResponse)
        assert pg.code == "E_NO_FEASIBLE_BINDING"
        assert pg.cause == "no feasible binding"

    def test_unknown_session_and_bad_json(self, gw):
        err = first(send(gw, m.ServeRequest(session_id="ais-999999")))
        assert err.code == "E_UNKNOWN_SESSION"
        raw = gw.handle_json("{\"type\": \"no-such\"}")
        assert m.from_json(raw).code == "E_BAD_REQUEST"
        for payload in ("[]", "42", "null", "\"hi\""):
            assert m.from_json(gw.handle_json(payload)).code == \
                "E_BAD_REQUEST"

    def test_stream_carries_invoker_request_id(self, gw):
        disc = send(gw, m.DiscoverRequest(invoker="a", zone="zone-a",
                                          asp=default_asp()))
        sid = disc.session_id
        send(gw, m.PageRequest(session_id=sid))
        prep = send(gw, m.PrepareRequest(session_id=sid))
        send(gw, m.CommitRequest(session_id=sid,
                                 prepared_ref=prep.prepared_ref))
        frames = send(gw, m.ServeRequest(session_id=sid, gen_tokens=3,
                                         request_id="corr-7"))
        assert all(f.request_id == "corr-7" for f in frames)

    def test_schema_version_negotiation(self, gw):
        req = m.ReleaseRequest(session_id="s", schema_version="2.0")
        err = send(gw, req)
        assert err.code == "E_SCHEMA_VERSION"
        # incompatible ASP major embedded in an otherwise-valid request
        wire = m.DiscoverRequest(invoker="x", zone="z",
                                 asp=default_asp()).to_wire()
        wire["asp"]["schema_version"] = "9.0"
        import json
        out = m.from_json(gw.handle_json(json.dumps(wire)))
        assert out.code == "E_SCHEMA_VERSION"


class TestIdempotency:
    def _prepare(self, gw, key="pk"):
        disc = send(gw, m.DiscoverRequest(invoker="a", zone="zone-a",
                                          asp=default_asp()))
        sid = disc.session_id
        send(gw, m.PageRequest(session_id=sid))
        prep = send(gw, m.PrepareRequest(session_id=sid,
                                         idempotency_key=key))
        return sid, prep

    def test_duplicate_prepare_reserves_once(self, gw):
        sid, prep = self._prepare(gw)
        site = gw.orch.sites[prep.site_id]
        before = site.slots_in_use()
        again = send(gw, m.PrepareRequest(session_id=sid,
                                          idempotency_key="pk"))
        assert again == prep                     # original outcome replayed
        assert site.slots_in_use() == before     # no second reservation

    def test_duplicate_commit_does_not_double_reserve(self, gw):
        sid, prep = self._prepare(gw)
        req = m.CommitRequest(session_id=sid, prepared_ref=prep.prepared_ref,
                              idempotency_key="ck")
        com = send(gw, req)
        assert isinstance(com, m.CommitResponse)
        site = gw.orch.sites[prep.site_id]
        slots, qos = site.slots_in_use(), com.record["qfi"]
        again = send(gw, req)
        assert again == com                      # byte-identical outcome
        assert site.slots_in_use() == slots      # provably not re-reserved
        assert again.record["qfi"] == qos
        # a RETRY WITHOUT the key is not idempotent: the state machine
        # refuses the second commit instead of silently re-reserving
        fresh = send(gw, m.CommitRequest(session_id=sid,
                                         prepared_ref=prep.prepared_ref,
                                         idempotency_key="other"))
        assert isinstance(fresh, m.ErrorResponse)
        assert site.slots_in_use() == slots

    def test_lost_response_page_and_prepare_replay(self, gw):
        """A keyless duplicate PAGE/PREPARE (response lost in transport)
        replays the original outcome; it must NOT fail the session."""
        disc = send(gw, m.DiscoverRequest(invoker="a", zone="zone-a",
                                          asp=default_asp()))
        sid = disc.session_id
        paged = send(gw, m.PageRequest(session_id=sid))
        assert send(gw, m.PageRequest(session_id=sid)) == paged
        prep = send(gw, m.PrepareRequest(session_id=sid))
        again = send(gw, m.PrepareRequest(session_id=sid))
        assert again == prep
        site = gw.orch.sites[prep.site_id]
        assert site.slots_in_use() == 1          # one reservation, not two
        com = send(gw, m.CommitRequest(session_id=sid,
                                       prepared_ref=prep.prepared_ref))
        assert isinstance(com, m.CommitResponse)
        assert com.record["state"] == "committed"

    def test_commit_retry_after_failed_commit_is_structured(self, gw):
        """A COMMIT refused by the state machine must leave the gateway in
        a state where the retry gets a structured error, not E_INTERNAL —
        and the retry re-reports the ORIGINAL failure cause (the response
        may have been lost in flight), never a bogus out-of-order code."""
        sid, prep = self._prepare(gw)
        # let the provisional leases lapse: commit now fails cleanly
        gw.orch.clock.advance(10 * gw.orch.timers.tau_com)
        req = m.CommitRequest(session_id=sid,
                              prepared_ref=prep.prepared_ref)
        first_try = send(gw, req)
        assert first_try.code == "E_DEADLINE"
        retry = send(gw, req)
        assert isinstance(retry, m.ErrorResponse)
        assert retry.code == "E_DEADLINE"        # same outcome, re-reported
        assert "re-reports the original outcome" in retry.detail

    def test_key_reuse_with_different_payload_conflicts(self, gw):
        sid, prep = self._prepare(gw)
        com = send(gw, m.CommitRequest(session_id=sid,
                                       prepared_ref=prep.prepared_ref,
                                       idempotency_key="k"))
        assert isinstance(com, m.CommitResponse)
        err = send(gw, m.CommitRequest(session_id=sid,
                                       prepared_ref="prep-bogus",
                                       idempotency_key="k"))
        assert err.code == "E_IDEMPOTENCY_CONFLICT"


class TestSessionClient:
    def test_context_managed_stream_and_release(self, gw):
        asp = default_asp(tier=QualityTier.PREMIUM)
        with SessionClient(gw, asp, invoker="ue-1") as c:
            assert c.record["state"] == "committed"
            stream = c.generate(prompt_tokens=32, gen_tokens=6)
            assert len(list(stream)) == 6
            assert stream.complete.completed
            assert [e.state for e in c.events()].count("committed") == 1
        # context exit released the session server-side
        err = first(send(gw, m.ServeRequest(session_id=c.session_id)))
        assert err.code == "E_DEADLINE"

    def test_consent_revocation_is_typed(self, gw):
        with SessionClient(gw, default_asp(), invoker="ue-2") as c:
            gw.orch.policy.revoke(gw.orch.sessions[c.session_id].authz_ref)
            with pytest.raises(ConsentRevoked) as ei:
                list(c.generate())
            assert ei.value.code == "E_CONSENT"
            assert ei.value.cause.value == "consent violation"

    def test_auto_lease_renewal(self, gw):
        clock = gw.orch.clock
        step = 0.27 * gw.orch.timers.lease_s     # 6 steps ≈ 1.6 leases
        with SessionClient(gw, default_asp(), invoker="ue-3") as c:
            for _ in range(6):
                clock.advance(step)
                list(c.generate(gen_tokens=2))   # renews past the margin
            assert gw.orch.sessions[c.session_id].committed()

        with SessionClient(gw, default_asp(), invoker="ue-4",
                           auto_renew=False) as c2:
            with pytest.raises(DeadlineExpired):
                for _ in range(6):               # dies once the lease lapses
                    clock.advance(step)
                    list(c2.generate(gen_tokens=2))

    def test_migration_updates_anchor(self, gw):
        asp = default_asp(mobility=MobilityClass.VEHICULAR)
        with SessionClient(gw, asp, invoker="car-9") as c:
            old = c.anchor
            ack = c.heartbeat(trigger_l99=0.0, trigger_ttfb=0.0)
            assert ack.migration["migrated"]
            assert c.anchor == ack.migration["to_site"] != old
            assert any(e.event == "migration" for e in c.events())


class TestServerlessParity:
    """Sessions established northbound and sessions established directly on
    the orchestrator serve through the same planes and meters."""

    def test_async_submit_completions_over_wire(self, gw):
        """stream=False serves are fully drivable northbound: SubmitAck,
        then ServeComplete frames via CompletionPoll after a drain cycle."""
        with SessionClient(gw, default_asp(), invoker="ue-async") as c:
            rids = [c.submit(prompt_tokens=16, gen_tokens=4)
                    for _ in range(3)]
            assert all(rids)
            # advance the planes without consuming the buffer (drain() is
            # the in-process consumer; the wire consumer is CompletionPoll)
            gw.pump(gw.orch.clock.now() + 60.0)
            done = c.completions()
            assert {d.request_id for d in done} == set(rids)
            assert all(isinstance(d, m.ServeComplete) and d.tokens == 4
                       for d in done)
            assert c.completions() == []     # consumed exactly once

    def test_wire_session_is_metered(self, gw):
        with SessionClient(gw, default_asp(), invoker="ue-m") as c:
            for _ in range(3):
                list(c.generate(prompt_tokens=16, gen_tokens=4))
            rep = c.compliance()
            assert rep.n == 3
            ack = c.release()
            assert ack.tokens == 12 and ack.total_cost > 0
