"""Make-before-break migration invariants (Section IV-B, Eq. 14)."""

import pytest

from repro.core import Orchestrator, default_asp
from repro.core.asp import MobilityClass
from repro.core.clock import VirtualClock
from repro.core.failures import FailureCause, SessionError
from repro.core.migration import MigrationTriggers
from repro.core.session import SessionState


@pytest.fixture()
def orch():
    return Orchestrator(clock=VirtualClock())


def vehicular_session(orch):
    asp = default_asp(mobility=MobilityClass.VEHICULAR)
    return orch.establish(asp, invoker="car", zone="zone-a")


class TestMakeBeforeBreak:
    def test_successful_migration_never_leaves_committed(self, orch):
        s = vehicular_session(orch)
        src = s.binding.site_id
        out = orch.migrations.migrate(s, "zone-a")
        assert out.migrated
        assert out.to_site != src
        assert out.interruption_ms == 0.0
        assert s.committed()
        # source leases released only after target commit
        assert s.binding.site_id == out.to_site

    def test_source_lease_released_after_swap(self, orch):
        s = vehicular_session(orch)
        src_site = orch.sites[s.binding.site_id]
        old_lease = s.binding.compute_lease_id
        orch.migrations.migrate(s, "zone-a")
        assert not src_site.lease_valid(old_lease)

    def test_transfer_failure_aborts_and_keeps_source(self, orch):
        s = vehicular_session(orch)
        src = s.binding.site_id

        def fail(session, a, b):
            raise SessionError(FailureCause.STATE_TRANSFER_FAILURE, "boom")

        orch.migrations.transfer_fn = fail
        out = orch.migrations.migrate(s, "zone-a")
        assert not out.migrated and out.aborted
        assert out.cause is FailureCause.STATE_TRANSFER_FAILURE
        assert s.binding.site_id == src
        assert s.committed()
        assert s.state is SessionState.COMMITTED

    def test_slow_transfer_exceeding_tau_mig_aborts(self, orch):
        s = vehicular_session(orch)
        orch.migrations.transfer_fn = lambda *_: orch.timers.tau_mig * 2
        out = orch.migrations.migrate(s, "zone-a")
        assert not out.migrated
        assert out.cause is FailureCause.STATE_TRANSFER_FAILURE
        assert s.committed()

    def test_target_leases_rolled_back_on_abort(self, orch):
        s = vehicular_session(orch)
        before = {sid: site.slots_in_use()
                  for sid, site in orch.sites.items()}

        def fail(session, a, b):
            raise SessionError(FailureCause.STATE_TRANSFER_FAILURE, "boom")

        orch.migrations.transfer_fn = fail
        orch.migrations.migrate(s, "zone-a")
        after = {sid: site.slots_in_use() for sid, site in orch.sites.items()}
        assert before == after, "target leases leaked on abort"

    def test_migrate_requires_committed(self, orch):
        s = vehicular_session(orch)
        orch.release(s)
        with pytest.raises(SessionError):
            orch.migrations.migrate(s, "zone-a")


class TestTriggers:
    def test_eq14_thresholds(self):
        t = MigrationTriggers(delta_l99=0.3, delta_ttfb=0.4)
        assert t.should_migrate(0.31, 0.0)
        assert t.should_migrate(0.0, 0.41)
        assert not t.should_migrate(0.29, 0.39)

    def test_heartbeat_without_risk_no_migration(self, orch):
        s = vehicular_session(orch)
        out = orch.heartbeat(s, MigrationTriggers(delta_l99=0.99,
                                                  delta_ttfb=0.99))
        assert out is None
        assert s.committed()
