"""InferenceEngine hot-path benchmark — the BENCH_engine trajectory.

Measures the four costs the fast serving path is about, on the CPU tiny
configs (the same code jit-compiles under the production mesh on a pod):

* ``decode tokens/s``   — serving throughput through the REAL hot path
                          (ServingPlane + RealEngineBackend) at batch
                          1 / 8 / 64: the seed's per-token loop (one jitted
                          step + eager argmax + host round-trip + per-token
                          plane accounting per token, reimplemented
                          faithfully below) versus fused K-step chunks.
                          The headline runs on the smoke tiny config, where
                          step compute does not mask the dispatch overhead
                          being measured — the same regime a production
                          decode cell is in (step time ~ dispatch+host
                          latency; that is why serving engines fuse
                          multi-step loops at all).
* ``prefill_compiles``  — jit variants traced over 100 mixed-length
                          prompts (bucketed: <= ceil(log2(max_len))),
* ``ttft_ms``           — median admit-to-first-token latency,
* ``export_ms`` / ``import_ms`` — slot state extraction/install (the
                          donated, index-addressed path migration rides).

    PYTHONPATH=src python -m benchmarks.engine_bench [--quick]
        [--check-baseline] [--write-baseline]

``--check-baseline`` compares fused decode tokens/s against the checked-in
``benchmarks/baselines/engine.json`` and exits non-zero on a >20% drop —
the CI regression guard for the serving hot path.
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import numpy as np  # noqa: E402

from benchmarks import _baseline  # noqa: E402
from repro.configs import get_config, get_smoke_config  # noqa: E402
from repro.core.clock import Clock  # noqa: E402
from repro.serving.engine import InferenceEngine  # noqa: E402
from repro.serving.plane import (RealEngineBackend,  # noqa: E402
                                 ServingPlane)

BASELINE_NAME = "engine"


class SeedLoopEngine:
    """Faithful reimplementation of the pre-PR engine hot loop (the "before"
    arm): one jitted ``decode_step`` per token with NO buffer donation, an
    eager ``jnp.argmax`` dispatch, and a device→host token transfer every
    step — plus the seed's per-row batched-scatter decode-cache insert
    (``decode_cache_scatter=True``), which XLA serialises on CPU."""

    def __init__(self, cfg, params, slots, max_len):
        import dataclasses
        import jax
        import jax.numpy as jnp
        from repro.models.transformer import LM
        cfg = dataclasses.replace(cfg, decode_cache_scatter=True)
        self.cfg = cfg
        self.lm = LM(cfg)
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache = self.lm.init_cache(slots, max_len)
        self._slot_map = {}
        self._slots = [None] * slots
        self._prefill = jax.jit(lambda p, b: self.lm.prefill(p, b, max_len))
        self._decode = jax.jit(self.lm.decode_step)

    def free_slots(self):
        return sum(1 for s in self._slots if s is None)

    def prefill_session(self, sid, prompt):
        import jax
        import jax.numpy as jnp
        t0 = time.perf_counter()
        batch = {"tokens": jnp.asarray(prompt[None, :], jnp.int32)}
        logits, cache1 = self._prefill(self.params, batch)
        tok = int(jnp.argmax(logits[0]))
        idx = next(i for i, s in enumerate(self._slots) if s is None)
        self._slot_map[sid] = idx

        def ins(path, full, one):
            ax = 1 if any(str(getattr(k, "key", "")) in ("k", "v")
                          for k in path) else 0
            row = jax.lax.index_in_dim(one, 0, axis=ax, keepdims=False)
            return (full.at[idx].set(row) if ax == 0
                    else full.at[:, idx].set(row))

        self.cache = jax.tree_util.tree_map_with_path(ins, self.cache,
                                                      cache1)
        self._slots[idx] = {"sid": sid, "last": tok}
        return {"first_token": tok,
                "ttfb_ms": (time.perf_counter() - t0) * 1e3}

    def decode_round(self, steps=None):
        import jax.numpy as jnp
        if not self._slot_map:
            return {}
        toks = np.zeros((self.slots, 1), np.int32)
        for i, s in enumerate(self._slots):
            if s is not None:
                toks[i, 0] = s["last"]
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks))
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        out = {}
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            s["last"] = int(nxt[i])
            out[s["sid"]] = [s["last"]] if steps is not None else s["last"]
        return out

    def release_slot(self, sid):
        idx = self._slot_map.pop(sid, None)
        if idx is not None:
            self._slots[idx] = None


def _prompt(n, vocab, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, vocab, size=n).astype(np.int32)


def _mk_plane(engine, *, batch, chunk):
    clock = Clock()
    # no premium reservation: measure clean batch-N continuous batching
    # (reserved slots would split the workload into waves)
    return ServingPlane(clock, RealEngineBackend(engine, clock),
                        slots=batch, site_id="bench", decode_chunk=chunk,
                        premium_reserved_frac=0.0)


def _drain_once(plane, *, batch, gen, vocab):
    for i in range(batch):
        plane.submit(session_id=f"s{i}", klass="best-effort",
                     prompt_tokens=12, gen_tokens=gen, t_max_ms=1e12,
                     prompt=_prompt(12, vocab, seed=i))
    t0 = time.perf_counter()
    plane.drain()
    wall = time.perf_counter() - t0
    plane.pop_results()
    return batch * gen / wall


PER_TOKEN_CHUNK = {"premium": 1, "assured": 1, "best-effort": 1}


def bench_decode(batch: int, *, gen: int = 49, max_len: int = 64,
                 reps: int = 6, cfg=None, params=None):
    """Decode tokens/s through the plane: seed per-token loop vs fused.

    The two arms run INTERLEAVED rep-by-rep and the speedup is the median
    of per-pair ratios — background load on a shared box then inflates
    both arms of a pair together instead of skewing whichever arm happened
    to run during the noisy window. Rep 0 pays jit compiles (discarded).
    """
    cfg = cfg or get_smoke_config("edge-tiny")
    fused_eng = InferenceEngine(cfg, params=params, slots=batch,
                                max_len=max_len)
    params = fused_eng.params
    seed_eng = SeedLoopEngine(cfg, params, batch, max_len)
    seed_plane = _mk_plane(seed_eng, batch=batch, chunk=PER_TOKEN_CHUNK)
    fused_plane = _mk_plane(fused_eng, batch=batch, chunk=None)
    vocab = cfg.vocab_size
    seeds, fuseds, ratios = [], [], []
    for rep in range(reps + 1):
        s = _drain_once(seed_plane, batch=batch, gen=gen, vocab=vocab)
        f = _drain_once(fused_plane, batch=batch, gen=gen, vocab=vocab)
        if rep > 0:                       # rep 0 = compile warmup
            seeds.append(s)
            fuseds.append(f)
            ratios.append(f / s)
    return {"per_token": statistics.median(seeds),
            "fused": statistics.median(fuseds),
            "speedup": statistics.median(ratios)}, params


def bench_prefill(n_prompts: int = 100, *, max_len: int = 256, params=None):
    """Compile count + TTFT over a mixed-length prompt population."""
    cfg = get_config("edge-tiny")
    eng = InferenceEngine(cfg, params=params, slots=2, max_len=max_len)
    rng = np.random.default_rng(11)
    lengths = rng.integers(1, max_len, size=n_prompts)
    # warm every bucket first so ttft measures steady-state dispatch
    for b in eng.buckets:
        eng.prefill_session("warm", _prompt(b, cfg.vocab_size))
        eng.release_slot("warm")
    warm_compiles = eng.prefill_compiles
    ttfts = []
    for i, n in enumerate(lengths):
        sid = f"p{i}"
        r = eng.prefill_session(sid, _prompt(int(n), cfg.vocab_size, seed=i))
        ttfts.append(r["ttfb_ms"])
        eng.release_slot(sid)
    return {
        "prefill_compiles": eng.prefill_compiles,
        "bucket_count": len(eng.buckets),
        "compiles_during_run": eng.prefill_compiles - warm_compiles,
        "ttft_ms_p50": round(statistics.median(ttfts), 3),
        "ttft_ms_p99": round(sorted(ttfts)[int(0.99 * (len(ttfts) - 1))], 3),
    }, eng.params


def bench_transfer(*, rounds: int = 20, max_len: int = 128, params=None):
    """export_slot / import_slot latency (the migration data-plane cost)."""
    import jax
    cfg = get_config("edge-tiny")
    src = InferenceEngine(cfg, params=params, slots=2, max_len=max_len)
    dst = InferenceEngine(cfg, params=src.params, slots=2, max_len=max_len)
    src.prefill_session("m", _prompt(24, cfg.vocab_size))
    src.decode_round(steps=4)
    exp_ms, imp_ms = [], []
    for _ in range(rounds):
        t0 = time.perf_counter()
        payload = src.export_slot("m")
        jax.block_until_ready(payload["cache"])
        exp_ms.append((time.perf_counter() - t0) * 1e3)
        t0 = time.perf_counter()
        dst.import_slot("m", payload)
        jax.block_until_ready(dst.cache)
        imp_ms.append((time.perf_counter() - t0) * 1e3)
        dst.release_slot("m")
    return {
        "export_ms_p50": round(statistics.median(exp_ms), 3),
        "import_ms_p50": round(statistics.median(imp_ms), 3),
    }, src.params


def run(*, quick: bool = False) -> dict:
    gen = 49
    n_prompts = 30 if quick else 100
    batches = (1, 8) if quick else (1, 8, 64)
    params = None
    decode = {}
    for b in batches:
        decode[b], params = bench_decode(
            b, gen=gen, reps=4 if quick else 6, params=params)
    # the demo config for reference: compute-bound regime (fusion still
    # wins, but the step time dominates the dispatch being amortised)
    demo, _ = bench_decode(8, gen=17, reps=2,
                           cfg=get_config("edge-tiny"))
    prefill, _ = bench_prefill(n_prompts)
    transfer, _ = bench_transfer(rounds=5 if quick else 20)
    return {
        "decode": {str(b): {k: round(v, 1) for k, v in d.items()}
                   for b, d in decode.items()},
        "decode_demo_cfg_batch8": {k: round(v, 1) for k, v in demo.items()},
        "prefill": prefill,
        "transfer": transfer,
        "gen": gen,
        "n_prompts": n_prompts,
    }


def figure_rows(quick: bool = False):
    """(rows, derived) in the benchmarks/figures.py convention."""
    import math
    r = run(quick=quick)
    rows = []
    for b, d in r["decode"].items():
        rows.append({"batch": int(b), "per_token_tok_s": d["per_token"],
                     "fused_tok_s": d["fused"], "speedup": d["speedup"]})
    at8 = r["decode"].get("8", next(iter(r["decode"].values())))
    max_len = 256
    derived = {
        "claim": "fused K-step decode >= 3x the seed per-token serving loop "
                 "at batch 8; prefill compiles bounded by log2 buckets",
        "speedup_at_batch8": at8["speedup"],
        "prefill_compiles": r["prefill"]["prefill_compiles"],
        "compile_ceiling": math.ceil(math.log2(max_len)),
        "ttft_ms_p50": r["prefill"]["ttft_ms_p50"],
        "export_ms_p50": r["transfer"]["export_ms_p50"],
        "import_ms_p50": r["transfer"]["import_ms_p50"],
        "holds": (at8["speedup"] >= 3.0
                  and r["prefill"]["prefill_compiles"]
                  <= math.ceil(math.log2(max_len))),
    }
    return rows, derived


def check_baseline(result: dict) -> list:
    """Regression guard, hardware-independent: the fused-vs-seed SPEEDUP
    ratio (both arms measured on the same machine in the same run) must not
    fall below the per-batch floor, and prefill compiles must stay within
    the bucket count. Absolute tok/s values in the baseline are reference
    only — they depend on the runner, the ratio does not. Returns failure
    messages."""
    base = _baseline.load_baseline(BASELINE_NAME)
    failures = []
    for b, d in base["decode"].items():
        got = result["decode"].get(b)
        floor = d.get("speedup_floor")
        if got is None or floor is None:
            continue
        if got["speedup"] < floor:
            failures.append(
                f"decode batch={b}: fused/seed speedup "
                f"{got['speedup']:.2f}x < floor {floor:.2f}x "
                f"(a fused-path regression; reversion to the per-token "
                f"loop reads ~1.0x)")
    ceiling = base["prefill"]["bucket_count"]
    if result["prefill"]["prefill_compiles"] > ceiling:
        failures.append(
            f"prefill compiles {result['prefill']['prefill_compiles']} > "
            f"bucket count {ceiling}")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--check-baseline", action="store_true",
                    help="fail on >20%% fused-decode regression vs "
                         "benchmarks/baselines/engine.json")
    ap.add_argument("--write-baseline", action="store_true",
                    help="overwrite the checked-in baseline with this run")
    args = ap.parse_args()
    r = run(quick=args.quick)
    print(json.dumps(r, indent=1))
    os.makedirs("artifacts/bench", exist_ok=True)
    with open("artifacts/bench/engine.json", "w") as f:
        json.dump(r, f, indent=1)
    if args.write_baseline:
        _baseline.write_baseline(r, BASELINE_NAME)
    if args.check_baseline:
        _baseline.enforce(check_baseline(r))


if __name__ == "__main__":
    main()
