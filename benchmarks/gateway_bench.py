"""Northbound gateway overhead benchmark.

Measures what the exposure layer costs per request on top of the direct
control-plane call, holding everything else fixed (same orchestrator
topology, same SimulatedEngine plane under VirtualClock, one committed
session, identical request mix):

* ``direct``  — ``Orchestrator.serve`` (the pre-gateway invocation path),
* ``typed``   — ``NorthboundGateway.handle`` with typed messages
                (dispatch + chunk synthesis, no serialization),
* ``json``    — ``NorthboundGateway.handle_json`` (full wire: request
                parse + per-chunk serialization), i.e. what a remote
                invoker's traffic costs the gateway process.

Reports requests/s (wall) and per-call p50/p99 µs, plus the ADDED p50/p99
versus direct — the number the API redesign is accountable for.

    PYTHONPATH=src python -m benchmarks.gateway_bench [--requests 2000]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import numpy as np  # noqa: E402

from repro.api import messages as wire  # noqa: E402
from repro.api.gateway import NorthboundGateway  # noqa: E402
from repro.core import Orchestrator, default_asp  # noqa: E402
from repro.core.clock import VirtualClock  # noqa: E402


def _fresh_session(with_gateway: bool = True):
    """The direct baseline must NOT construct a gateway: its result sink
    would stay registered on the orchestrator and tax every serve call."""
    orch = Orchestrator(clock=VirtualClock())
    gw = NorthboundGateway(orch) if with_gateway else None
    session = orch.establish(default_asp(), "bench", "zone-a")
    return orch, gw, session


def _percall(fn, n: int) -> np.ndarray:
    out = np.empty(n)
    for i in range(n):
        t0 = time.perf_counter()
        fn(i)
        out[i] = time.perf_counter() - t0
    return out * 1e6                       # µs


def bench_gateway(n_requests: int = 2000, *, gen_tokens: int = 16,
                  prompt_tokens: int = 64) -> dict:
    modes = {}

    orch, _, s = _fresh_session(with_gateway=False)
    modes["direct"] = _percall(
        lambda i: orch.serve(s, prompt_tokens=prompt_tokens,
                             gen_tokens=gen_tokens), n_requests)

    _, gw, s = _fresh_session()
    modes["typed"] = _percall(
        lambda i: gw.handle(wire.ServeRequest(
            session_id=s.session_id, prompt_tokens=prompt_tokens,
            gen_tokens=gen_tokens)), n_requests)

    _, gw, s = _fresh_session()
    payload = wire.ServeRequest(
        session_id=s.session_id, prompt_tokens=prompt_tokens,
        gen_tokens=gen_tokens).to_json()
    modes["json"] = _percall(lambda i: gw.handle_json(payload), n_requests)

    base_p50 = float(np.quantile(modes["direct"], 0.5))
    base_p99 = float(np.quantile(modes["direct"], 0.99))
    rows = []
    for mode, us in modes.items():
        p50 = float(np.quantile(us, 0.5))
        p99 = float(np.quantile(us, 0.99))
        rows.append({
            "mode": mode,
            "requests_per_s_wall": round(1e6 / max(us.mean(), 1e-9), 1),
            "p50_us": round(p50, 1),
            "p99_us": round(p99, 1),
            "added_p50_us": round(p50 - base_p50, 1),
            "added_p99_us": round(p99 - base_p99, 1),
        })
    return {
        "n_requests": n_requests,
        "gen_tokens": gen_tokens,
        "rows": rows,
    }


def figure_rows(n_requests: int = 2000):
    """(rows, derived) for benchmarks.run — the claim tracked is that the
    exposure layer stays a small constant per call: full-wire dispatch adds
    under 10 ms p50 over the direct control-plane call."""
    res = bench_gateway(n_requests)
    rows = res["rows"]
    json_row = next(r for r in rows if r["mode"] == "json")
    typed_row = next(r for r in rows if r["mode"] == "typed")
    derived = {
        "typed_added_p50_us": typed_row["added_p50_us"],
        "json_added_p50_us": json_row["added_p50_us"],
        "json_requests_per_s": json_row["requests_per_s_wall"],
        "holds": json_row["added_p50_us"] < 10_000.0,
    }
    return rows, derived


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=2000)
    ap.add_argument("--quick", action="store_true",
                    help="small sample for CI smoke")
    a = ap.parse_args()
    n = 300 if a.quick else a.requests
    rows, derived = figure_rows(n)
    for r in rows:
        print(f"{r['mode']:8s} {r['requests_per_s_wall']:10.1f} req/s "
              f"p50={r['p50_us']:8.1f}µs p99={r['p99_us']:8.1f}µs "
              f"(+{r['added_p50_us']:.1f}/+{r['added_p99_us']:.1f})")
    os.makedirs("artifacts/bench", exist_ok=True)
    with open("artifacts/bench/gateway_overhead.json", "w") as f:
        json.dump({"rows": rows, "derived": derived}, f, indent=1)
    print(f"derived: {json.dumps(derived)}")
    if not derived["holds"]:
        raise SystemExit("gateway overhead claim does NOT hold")


if __name__ == "__main__":
    main()
