"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts.

    PYTHONPATH=src python -m benchmarks.report > /tmp/roofline_tables.md
"""

from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, "src")

ART = "artifacts/dryrun"
SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def load(mesh):
    out = {}
    for f in glob.glob(os.path.join(ART, f"*__{mesh}.json")):
        r = json.load(open(f))
        out[(r["arch"], r["shape"])] = r
    return out


def fmt_bytes(b):
    return f"{b/1e9:.2f}"


def dryrun_table(mesh="pod16x16"):
    recs = load(mesh)
    lines = ["| arch | shape | kind | status | GB/device (args+temp) | fits "
             "16 GB | compile s | µbatches | collective ops (loop-aware) |",
             "|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape) in sorted(recs):
        r = recs[(arch, shape)]
        if r["status"] != "ok":
            lines.append(f"| {arch} | {shape} | - | **{r['status']}** | - | "
                         f"- | - | - | {r.get('reason', '')[:60]} |")
            continue
        m = r["memory"]
        colls = ", ".join(
            f"{k}×{int(v['count'])}" for k, v in sorted(
                r["collectives"].items()))
        lines.append(
            f"| {arch} | {shape} | {r['kind']} | ok | "
            f"{fmt_bytes(m['argument_bytes'])}+{fmt_bytes(m['temp_bytes'])} | "
            f"{'✓' if m['fits_hbm'] else '✗'} | {r['compile_s']} | "
            f"{r.get('microbatches', 1)} | {colls[:90]} |")
    return "\n".join(lines)


def roofline_table(mesh="pod16x16"):
    recs = load(mesh)
    lines = ["| arch | shape | compute ms | memory ms | collective ms | "
             "dominant | MODEL/HLO flops | roofline fraction | "
             "what would move the dominant term |",
             "|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape) in sorted(recs):
        r = recs[(arch, shape)]
        if r["status"] != "ok":
            lines.append(f"| {arch} | {shape} | - | - | - | skipped | - | - | "
                         f"sub-quadratic rule |")
            continue
        roof = r["roofline"]
        frac = roof["compute_s"] / max(roof["roofline_bound_s"], 1e-12)
        hint = {
            "compute": "reduce recompute (remat policy) / causal block skip",
            "memory": "KV/cache dtype + layout; batch to amortise weights",
            "collective": "resharde weights (cut gathers) / overlap comm",
        }[roof["dominant"]]
        lines.append(
            f"| {arch} | {shape} | {roof['compute_s']*1e3:.2f} | "
            f"{roof['memory_s']*1e3:.2f} | {roof['collective_s']*1e3:.2f} | "
            f"{roof['dominant']} | {r['useful_flops_ratio']:.2f} | "
            f"{frac:.2f} | {hint} |")
    return "\n".join(lines)


def main():
    for mesh in ("pod16x16", "pod2x16x16"):
        n = len(load(mesh))
        print(f"\n## §Dry-run — mesh {mesh} ({n} cells)\n")
        print(dryrun_table(mesh))
    print("\n## §Roofline — single-pod 16×16\n")
    print(roofline_table("pod16x16"))
    from benchmarks import roofline as R
    print("\nhillclimb picks:", json.dumps(R.pick_hillclimb_cells()))


if __name__ == "__main__":
    main()
