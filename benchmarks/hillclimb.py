"""§Perf hillclimb driver: run named variants of a dry-run cell and report
roofline-term deltas vs the baseline artifact.

    PYTHONPATH=src python -m benchmarks.hillclimb --cell mixtral-8x7b/decode_32k \
        --variant kvheads '{"kv_shard": "heads"}'

Variants are ModelConfig field overrides (the planner and model read config
fields, so sharding/impl/remat levers are all expressible). Results land in
artifacts/dryrun/<arch>__<shape>__<mesh>__<tag>.json and the comparison
prints as a §Perf table row.
"""

from __future__ import annotations

import argparse
import json
import sys

sys.path.insert(0, "src")


def compare(base: dict, var: dict) -> dict:
    rb, rv = base["roofline"], var["roofline"]
    out = {}
    for k in ("compute_s", "memory_s", "collective_s", "roofline_bound_s"):
        b, v = rb[k], rv[k]
        out[k] = {"before_ms": round(b * 1e3, 3), "after_ms": round(v * 1e3, 3),
                  "delta_pct": round(100 * (v - b) / b, 1) if b else None}
    out["dominant"] = {"before": rb["dominant"], "after": rv["dominant"]}
    out["useful_flops_ratio"] = {
        "before": round(base["useful_flops_ratio"], 3),
        "after": round(var["useful_flops_ratio"], 3)}
    out["peak_gb"] = {
        "before": round(base["memory"]["peak_bytes_per_device"] / 1e9, 2),
        "after": round(var["memory"]["peak_bytes_per_device"] / 1e9, 2)}
    return out


def run_variant(arch: str, shape: str, tag: str, overrides: dict,
                multi_pod: bool = False):
    # import inside: dryrun must own the XLA_FLAGS device count
    from repro.launch.dryrun import run_cell
    base = run_cell(arch, shape, multi_pod=multi_pod)
    var = run_cell(arch, shape, multi_pod=multi_pod, overrides=overrides,
                   tag=tag, force=True)
    if var["status"] != "ok":
        print(json.dumps({"variant": tag, "status": var["status"],
                          "error": var.get("error", "")[:300]}, indent=1))
        return None
    rep = compare(base, var)
    print(f"== {arch}/{shape} :: {tag} {json.dumps(overrides)}")
    print(json.dumps(rep, indent=1))
    return rep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch/shape")
    ap.add_argument("--tag", required=True)
    ap.add_argument("--overrides", required=True, help="JSON dict")
    ap.add_argument("--multi-pod", action="store_true")
    a = ap.parse_args()
    arch, shape = a.cell.split("/")
    run_variant(arch, shape, a.tag, json.loads(a.overrides),
                multi_pod=a.multi_pod)


if __name__ == "__main__":
    main()
