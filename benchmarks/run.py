"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus the per-figure data as
CSV blocks), and writes machine-readable copies under artifacts/bench/.

    PYTHONPATH=src python -m benchmarks.run [--fast]
"""

from __future__ import annotations

import argparse
import csv
import io
import json
import os
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks import figures  # noqa: E402


def _csv_block(rows) -> str:
    if not rows:
        return ""
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=list(rows[0].keys()))
    w.writeheader()
    w.writerows(rows)
    return buf.getvalue()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller Monte-Carlo samples (CI)")
    args = ap.parse_args()
    n_req = 4000 if args.fast else 20_000
    n_sess = 15 if args.fast else 40

    from benchmarks import (adapter_bench, engine_bench,  # noqa: E402
                            federation_bench, gateway_bench,
                            migration_bench, netfault_bench, plane_bench,
                            splitserve_bench)
    benches = [
        ("engine",
         lambda: engine_bench.figure_rows(quick=args.fast)),
        ("adapters",
         lambda: adapter_bench.figure_rows(quick=args.fast)),
        ("splitserve",
         lambda: splitserve_bench.figure_rows(quick=args.fast)),
        ("fig2_p99_vs_load",
         lambda: figures.fig2_p99_vs_load(n_requests=n_req)),
        ("fig3_violation_vs_load",
         lambda: figures.fig3_violation_vs_load(n_requests=n_req)),
        ("fig4_interruption_vs_speed",
         lambda: figures.fig4_interruption_vs_speed(n_sessions=n_sess)),
        ("table1_requirements", figures.table1_requirements),
        ("plane_throughput",
         lambda: plane_bench.figure_rows(n_requests=n_req)),
        ("gateway_overhead",
         lambda: gateway_bench.figure_rows(
             n_requests=400 if args.fast else 2000)),
        ("migration_continuity",
         lambda: migration_bench.figure_rows(
             n_sessions=3 if args.fast else 10)),
        ("federation",
         lambda: federation_bench.figure_rows(
             60 if args.fast else 200)),
        ("netfault",
         lambda: netfault_bench.figure_rows(quick=args.fast)),
    ]

    os.makedirs("artifacts/bench", exist_ok=True)
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches:
        t0 = time.perf_counter()
        rows, derived = fn()
        us = (time.perf_counter() - t0) * 1e6
        print(f"{name},{us:.0f},{json.dumps(derived)}")
        sys.stdout.write(_csv_block(rows))
        print()
        with open(f"artifacts/bench/{name}.json", "w") as f:
            json.dump({"rows": rows, "derived": derived,
                       "us_per_call": us}, f, indent=1)
        if not derived.get("holds", True):
            failures += 1
            print(f"!! {name}: paper claim does NOT hold", file=sys.stderr)

    # roofline summary from dry-run artifacts, if present
    try:
        from benchmarks import roofline
        table = roofline.summary_table()
        if table:
            print("roofline_summary (from artifacts/dryrun):")
            sys.stdout.write(_csv_block(table))
    except Exception as e:
        print(f"(roofline summary unavailable: {e})")

    if failures:
        raise SystemExit(f"{failures} paper-claim checks failed")


if __name__ == "__main__":
    main()
