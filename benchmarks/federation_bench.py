"""Federation overhead + spillover benchmark.

Two questions the east-west redesign is accountable for:

* **Establish overhead** — what a cross-domain establish costs on top of
  an intra-domain one, holding the topology fixed (same two peered
  domains; the intra arm anchors home, the east-west arm is forced abroad
  by saturating the home site). The delta is the full typed handshake:
  DISCOVER solicitation + budget decomposition + EWPrepare/EWCommit.
* **Spillover throughput** — offered establishes past the home capacity:
  admitted fraction and served requests, federated vs single-domain.

    PYTHONPATH=src python -m benchmarks.federation_bench [--quick]
        [--check-baseline] [--write-baseline]

``--check-baseline`` enforces ``benchmarks/baselines/federation.json``:
spillover must admit and serve strictly more than the saturated single
domain (a ratio, so runner speed cancels) and the east-west handshake
must stay under the control-plane budget. CI regression guard for the
federation path.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import numpy as np  # noqa: E402

from benchmarks import _baseline  # noqa: E402
from repro.api.client import SessionClient  # noqa: E402
from repro.api.gateway import NorthboundGateway  # noqa: E402
from repro.core import default_asp  # noqa: E402
from repro.core.asp import QualityTier  # noqa: E402
from repro.core.clock import VirtualClock  # noqa: E402
from repro.sim.scenarios import (_federation_pair,  # noqa: E402
                                 simulate_home_overload_spillover)


def _percall(fn, n: int) -> np.ndarray:
    out = np.empty(n)
    for i in range(n):
        t0 = time.perf_counter()
        fn(i)
        out[i] = time.perf_counter() - t0
    return out * 1e6                       # µs


def bench_establish(n: int = 200) -> dict:
    """Per-establish µs, intra-domain vs east-west (home saturated)."""
    asp = default_asp(tier=QualityTier.BASIC)
    out = {}
    for mode in ("intra", "east-west"):
        clock = VirtualClock()
        home, visited = _federation_pair(
            clock, home_slots=n + 8 if mode == "intra" else 8,
            visited_slots=n + 8)
        if mode == "east-west":
            site = home.core.sites["h-edge"]
            model = home.core.catalog.get("edge-tiny")
            lease = site.prepare(model, slots=site.spec.decode_slots,
                                 cache_bytes=0.0, ttl_s=1e9)
            site.confirm(lease.lease_id, lease_s=1e9)
        gw = NorthboundGateway(home)

        def establish(i):
            c = SessionClient(gw, asp, invoker=f"b-{mode}-{i}",
                              zone="zone-a",
                              subscribe_events=False).establish()
            expect = "visited/v-edge" if mode == "east-west" else "h-edge"
            assert c.anchor == expect, c.anchor

        us = _percall(establish, n)
        out[mode] = {"p50_us": float(np.percentile(us, 50)),
                     "p99_us": float(np.percentile(us, 99)),
                     "mean_us": float(us.mean()), "n": n}
    out["added_p50_us"] = out["east-west"]["p50_us"] - out["intra"]["p50_us"]
    out["added_p99_us"] = out["east-west"]["p99_us"] - out["intra"]["p99_us"]
    return out


def bench_spillover(n_sessions: int = 48, home_slots: int = 16) -> dict:
    fed = simulate_home_overload_spillover(
        n_sessions=n_sessions, home_slots=home_slots, federated=True)
    single = simulate_home_overload_spillover(
        n_sessions=n_sessions, home_slots=home_slots, federated=False)
    return {
        "n_offered": n_sessions, "home_slots": home_slots,
        "federated": {"admitted_frac": fed.admitted_frac,
                      "served": fed.served, "p99_ms": fed.p99_ms,
                      "established_visited": fed.established_visited},
        "single": {"admitted_frac": single.admitted_frac,
                   "served": single.served, "p99_ms": single.p99_ms,
                   "failed": single.failed},
    }


def figure_rows(n_requests: int = 200):
    est = bench_establish(n_requests)
    spill = bench_spillover()
    rows = [
        {"mode": "intra", **est["intra"]},
        {"mode": "east-west", **est["east-west"]},
    ]
    derived = {
        "added_p50_us": est["added_p50_us"],
        "added_p99_us": est["added_p99_us"],
        "spillover_admitted_frac": spill["federated"]["admitted_frac"],
        "single_admitted_frac": spill["single"]["admitted_frac"],
        "spillover_served": spill["federated"]["served"],
        "single_served": spill["single"]["served"],
        # the claims: federation admits strictly more offered load than a
        # saturated single domain, and the east-west handshake stays in
        # control-plane territory (< 50 ms per establish)
        "holds": bool(
            spill["federated"]["admitted_frac"]
            > spill["single"]["admitted_frac"]
            and spill["federated"]["served"] > spill["single"]["served"]
            and est["added_p50_us"] < 50_000.0),
    }
    return rows, derived


BASELINE_NAME = "federation"


def check_baseline(derived: dict) -> list:
    """Regression guard, hardware-independent by construction: the
    spillover claims are ratios/orderings between two arms run on the
    SAME machine (runner speed cancels), and the handshake bound is a
    generous control-plane budget, not a tuned absolute. Per-call µs
    figures are recorded in the baseline as reference only. Returns
    failure messages."""
    base = _baseline.load_baseline(BASELINE_NAME)
    inv = base["invariants"]
    failures = []
    if not (derived["spillover_admitted_frac"]
            > derived["single_admitted_frac"]):
        failures.append(
            f"spillover admitted_frac {derived['spillover_admitted_frac']} "
            f"<= single-domain {derived['single_admitted_frac']} "
            f"(federation no longer absorbs overload)")
    if not derived["spillover_served"] > derived["single_served"]:
        failures.append(
            f"spillover served {derived['spillover_served']} <= "
            f"single-domain {derived['single_served']}")
    if derived["added_p50_us"] >= inv["added_p50_us_max"]:
        failures.append(
            f"east-west establish overhead {derived['added_p50_us']:.0f}us "
            f">= budget {inv['added_p50_us_max']:.0f}us")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sample (CI smoke)")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--check-baseline", action="store_true",
                    help="enforce benchmarks/baselines/federation.json "
                         "invariants (CI guard)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="overwrite the checked-in baseline with this run")
    a = ap.parse_args()
    n = 60 if a.quick else a.requests
    rows, derived = figure_rows(n)
    for r in rows:
        print(json.dumps(r))
    print(json.dumps(derived, indent=1))
    os.makedirs("artifacts/bench", exist_ok=True)
    with open("artifacts/bench/federation.json", "w") as f:
        json.dump({"rows": rows, "derived": derived}, f, indent=1)
    if a.write_baseline:
        _baseline.write_baseline(
            {"_comment": "regression-guard invariants for the federation "
                         "path. check_baseline enforces the spillover "
                         "orderings (federated arm admits AND serves "
                         "strictly more than the saturated single domain "
                         "— both arms run on the same machine, so runner "
                         "speed cancels) and a generous 50 ms control-"
                         "plane budget on the east-west establish "
                         "overhead (typically < 1 ms; a 50x margin for "
                         "slow CI runners). Reference absolutes are NOT "
                         "enforced.",
             "invariants": {"added_p50_us_max": 50_000.0},
             "reference": {"rows": rows, "derived": derived}}, BASELINE_NAME)
    if a.check_baseline:
        _baseline.enforce(check_baseline(derived))
    if not derived["holds"]:
        raise SystemExit("federation claims do NOT hold")


if __name__ == "__main__":
    main()
