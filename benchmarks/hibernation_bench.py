"""Paged KV + tiered hibernation benchmark — the oversubscription numbers.

The claim under test: with the paged KV pool and the host hibernation
tier, the number of *bound* AI Sessions a site can hold is decoupled from
its *resident* decode slots — serve 10x+ more leases than slots at a
bounded resume cost, without giving up the fused-decode throughput the
dense layout gets. Three arms:

* ``oversubscribe`` — N sessions served through a ServingPlane backed by
  a paged engine with ``slots << N`` and an idle-TTL of zero: every
  session hibernates to host after its request completes. Reports
  bound/resident-slot ratio (the headline, must be >= 10x), page-pool
  occupancy, and host store bytes.
* ``resume`` — p50/p99 latency of hibernate→resume cycles at the engine
  level (restore + verify + re-import + page re-allocation), plus the
  end-to-end plane path: ``serve(resume=True)`` continuing a hibernated
  generation vs a fresh establish+serve on the same plane. The guard is
  the RATIO resume-p99 / fresh-p50 (same machine, same run — runner speed
  cancels), not an absolute.
* ``throughput`` — fused decode tokens/s, paged vs dense engines with
  identical params, interleaved rep-by-rep (engine_bench convention);
  paged must stay within noise of dense, and the two must emit identical
  token streams.

    PYTHONPATH=src python -m benchmarks.hibernation_bench [--quick]
        [--check-baseline] [--write-baseline]

``--check-baseline`` enforces ``benchmarks/baselines/hibernation.json``:
hardware-independent ratios only (bound-per-slot floor, paged/dense
throughput floor, resume/fresh latency ceiling, token identity). The CI
regression guard for the paged cache + hibernation tier.
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import numpy as np  # noqa: E402

from benchmarks import _baseline  # noqa: E402
from repro.configs import get_smoke_config  # noqa: E402
from repro.core.clock import Clock  # noqa: E402
from repro.serving.engine import InferenceEngine  # noqa: E402
from repro.serving.plane import (RealEngineBackend,  # noqa: E402
                                 ServingPlane)

BASELINE_NAME = "hibernation"


def _prompt(n, vocab, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, vocab, size=n).astype(np.int32)


def _paged_engine(cfg, *, slots, max_len, page_size, params=None):
    return InferenceEngine(cfg, params=params, slots=slots, max_len=max_len,
                           paged=True, page_size=page_size, hibernation=True)


def _mk_plane(engine, clock, *, slots, hibernate_idle_s=None):
    return ServingPlane(
        clock, RealEngineBackend(engine, clock,
                                 hibernate_idle_s=hibernate_idle_s),
        slots=slots, site_id="bench", premium_reserved_frac=0.0)


def bench_oversubscribe(n_sessions: int = 48, *, slots: int = 4,
                        max_len: int = 64, page_size: int = 16,
                        gen: int = 8) -> dict:
    """N sequential leases over ``slots`` resident slots; idle-TTL 0 means
    every completed request hibernates at the next heartbeat tick."""
    cfg = get_smoke_config("edge-tiny")
    eng = _paged_engine(cfg, slots=slots, max_len=max_len,
                        page_size=page_size)
    clock = Clock()
    plane = _mk_plane(eng, clock, slots=slots, hibernate_idle_s=0.0)
    serve_ms = []
    for i in range(n_sessions):
        t0 = time.perf_counter()
        r = plane.serve(session_id=f"u{i}", klass="best-effort",
                        prompt_tokens=12, gen_tokens=gen, t_max_ms=1e12,
                        prompt=_prompt(12, cfg.vocab_size, seed=i))
        serve_ms.append((time.perf_counter() - t0) * 1e3)
        assert not r.failed, r.failed
        plane.load()                       # heartbeat: parked -> hibernated
    load = plane.load()
    return {
        "n_sessions": n_sessions, "slots": slots,
        "bound_sessions": load.bound_sessions,
        "resident_sessions": load.resident_sessions,
        "hibernated_sessions": load.hibernated_sessions,
        "bound_per_slot": load.bound_sessions / slots,
        "page_util": round(load.page_util, 4),
        "store_bytes": eng.hibernation.bytes(),
        "store_puts": eng.hibernation.puts,
        "fresh_serve_ms_p50": round(statistics.median(serve_ms), 3),
        "_plane": plane, "_eng": eng, "_cfg": cfg,
    }


def bench_resume(over: dict, *, sample: int = 16, gen: int = 4) -> dict:
    """Resume cost, engine-level and end-to-end through the plane."""
    eng, plane, cfg = over["_eng"], over["_plane"], over["_cfg"]
    sids = eng.hibernation.sessions()[:sample]

    # engine level: restore + verify + import + page alloc, then hibernate
    # back so the store population is unchanged for the plane arm
    cycle_ms = []
    for sid in sids:
        t0 = time.perf_counter()
        eng.resume_slot(sid)
        cycle_ms.append((time.perf_counter() - t0) * 1e3)
        eng.hibernate_slot(sid)
    cycle_ms.sort()

    # plane level: serve(resume=True) continues the hibernated generation
    resume_ms = []
    for sid in sids:
        pos0 = eng.position_of(sid)
        t0 = time.perf_counter()
        r = plane.serve(session_id=sid, klass="best-effort",
                        prompt_tokens=0, gen_tokens=gen, t_max_ms=1e12,
                        resume=True)
        resume_ms.append((time.perf_counter() - t0) * 1e3)
        assert not r.failed and len(r.token_ids) == gen, (r.failed, sid)
        assert eng.position_of(sid) == pos0 + gen, sid
        plane.load()                       # hibernate it again
    resume_ms.sort()

    def p(xs, q):
        return round(xs[min(int(q * (len(xs) - 1) + 0.999), len(xs) - 1)], 3)

    return {
        "sample": len(sids), "gen": gen,
        "engine_resume_ms_p50": round(statistics.median(cycle_ms), 3),
        "engine_resume_ms_p99": p(cycle_ms, 0.99),
        "serve_resume_ms_p50": round(statistics.median(resume_ms), 3),
        "serve_resume_ms_p99": p(resume_ms, 0.99),
        "fresh_serve_ms_p50": over["fresh_serve_ms_p50"],
        # the hardware-independent form of "bounded resume latency"
        "resume_p99_over_fresh_p50": round(
            p(resume_ms, 0.99) / max(over["fresh_serve_ms_p50"], 1e-9), 3),
    }


def bench_throughput(*, batch: int = 8, gen: int = 33, max_len: int = 64,
                     page_size: int = 16, reps: int = 5) -> dict:
    """Fused decode tok/s, dense vs paged, interleaved; plus token identity
    on the full serve path (same prompts through both engines)."""
    cfg = get_smoke_config("edge-tiny")
    dense = InferenceEngine(cfg, slots=batch, max_len=max_len)
    paged = _paged_engine(cfg, slots=batch, max_len=max_len,
                          page_size=page_size, params=dense.params)
    clock = Clock()
    planes = {"dense": _mk_plane(dense, clock, slots=batch),
              "paged": _mk_plane(paged, clock, slots=batch)}

    def drain(plane, rep):
        for i in range(batch):
            plane.submit(session_id=f"s{rep}-{i}", klass="best-effort",
                         prompt_tokens=12, gen_tokens=gen, t_max_ms=1e12,
                         prompt=_prompt(12, cfg.vocab_size, seed=i))
        t0 = time.perf_counter()
        plane.drain()
        wall = time.perf_counter() - t0
        toks = {r.session_id.split("-", 1)[1]: r.token_ids
                for r in plane.pop_results()}
        return batch * gen / wall, toks

    denses, pageds, ratios, identical = [], [], [], True
    for rep in range(reps + 1):
        d, dt = drain(planes["dense"], rep)
        p, pt = drain(planes["paged"], rep)
        identical = identical and dt == pt
        if rep > 0:                        # rep 0 = compile warmup
            denses.append(d)
            pageds.append(p)
            ratios.append(p / d)
    return {"dense_tok_s": round(statistics.median(denses), 1),
            "paged_tok_s": round(statistics.median(pageds), 1),
            "paged_over_dense": round(statistics.median(ratios), 3),
            "tokens_identical": identical}


def run(*, quick: bool = False) -> dict:
    n = 44 if quick else 64
    over = bench_oversubscribe(n)
    resume = bench_resume(over, sample=8 if quick else 16)
    thru = bench_throughput(reps=3 if quick else 5)
    over = {k: v for k, v in over.items() if not k.startswith("_")}
    out = {"oversubscribe": over, "resume": resume, "throughput": thru}
    out["holds"] = (over["bound_per_slot"] >= 10.0
                    and thru["tokens_identical"]
                    and thru["paged_over_dense"] >= 0.6)
    return out


def check_baseline(result: dict) -> list:
    """Regression guard, hardware-independent by construction: every
    enforced metric is a ratio between two arms measured on the same
    machine in the same run (runner speed cancels) or a correctness bit.
    Absolute ms / tok-s figures in the baseline are reference only.
    Returns failure messages."""
    base = _baseline.load_baseline(BASELINE_NAME)
    inv = base["invariants"]
    over, res, thru = (result["oversubscribe"], result["resume"],
                       result["throughput"])
    failures = []
    if over["bound_per_slot"] < inv["bound_per_slot_min"]:
        failures.append(
            f"oversubscribe: bound/slot {over['bound_per_slot']:.1f}x < "
            f"{inv['bound_per_slot_min']:.1f}x (hibernation tier no longer "
            f"decouples bound sessions from resident slots)")
    if thru["paged_over_dense"] < inv["paged_over_dense_min"]:
        failures.append(
            f"throughput: paged/dense {thru['paged_over_dense']:.2f} < "
            f"floor {inv['paged_over_dense_min']:.2f}")
    if not thru["tokens_identical"]:
        failures.append("throughput: paged tokens diverge from dense")
    if res["resume_p99_over_fresh_p50"] > inv["resume_ratio_max"]:
        failures.append(
            f"resume: p99/fresh-p50 {res['resume_p99_over_fresh_p50']:.1f} "
            f"> ceiling {inv['resume_ratio_max']:.1f} (resume cost blew up "
            f"relative to a fresh establish)")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer sessions / reps")
    ap.add_argument("--check-baseline", action="store_true",
                    help="enforce benchmarks/baselines/hibernation.json "
                         "ratio invariants (CI guard)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="overwrite the checked-in baseline with this run")
    args = ap.parse_args()
    out = run(quick=args.quick)
    print(json.dumps(out, indent=1))
    os.makedirs("artifacts/bench", exist_ok=True)
    with open("artifacts/bench/hibernation.json", "w") as f:
        json.dump(out, f, indent=1)
    if args.write_baseline:
        _baseline.write_baseline(
            {"_comment": "regression-guard invariants for the paged cache "
                         "+ hibernation tier. check_baseline enforces "
                         "HARDWARE-INDEPENDENT ratios only: bound sessions "
                         "per resident slot (the 10x oversubscription "
                         "headline), paged/dense fused tok/s (both arms "
                         "interleaved on the same machine; floor 0.6 sits "
                         "well under the observed ~0.7-1.0), resume-p99 / "
                         "fresh-serve-p50 (observed ~0.3-0.5; ceiling 10x "
                         "catches a resume path that stopped being "
                         "transparent), and paged==dense token identity. "
                         "Reference absolutes are NOT enforced.",
             "invariants": {"bound_per_slot_min": 10.0,
                            "paged_over_dense_min": 0.6,
                            "resume_ratio_max": 10.0},
             "reference": out}, BASELINE_NAME)
    if args.check_baseline:
        _baseline.enforce(check_baseline(out))
    if not out["holds"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
