"""ServingPlane throughput benchmark — tracks the serving-plane trajectory.

Drives an open-loop Poisson workload through ONE plane (QoSScheduler +
SimulatedEngine under VirtualClock) and reports

* ``requests_per_s_wall``  — plane-machinery throughput: how many requests
  the scheduler/plane event loop itself can process per WALL second (the
  control-plane overhead budget per request), and
* ``p99_admission_wait_ms`` — virtual-time p99 admission wait per class at
  the offered load (the tail the QoS contract is about).

    PYTHONPATH=src python -m benchmarks.plane_bench [--requests 50000]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import numpy as np  # noqa: E402

from benchmarks import _baseline  # noqa: E402
from repro.core.clock import VirtualClock  # noqa: E402
from repro.serving.plane import ServingPlane, SimulatedEngine  # noqa: E402

BASELINE_NAME = "plane"


def bench_plane(n_requests: int = 50_000, *, slots: int = 256,
                rho: float = 0.85, service_ms: float = 40.0,
                mix=(("premium", 0.2), ("assured", 0.3),
                     ("best-effort", 0.5)),
                t_max_ms: float = 5_000.0, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    clock = VirtualClock()
    svc = service_ms * np.exp(0.35 * rng.standard_normal(n_requests))
    idx = {"i": 0}

    def sampler(req):
        i = idx["i"]
        idx["i"] += 1
        return 0.0, float(svc[i % n_requests])

    plane = ServingPlane(
        clock, SimulatedEngine(clock, service_sampler=sampler,
                               default_service_ms=service_ms),
        slots=slots, premium_reserved_frac=0.25, site_id="bench")
    names = [k for k, _ in mix]
    probs = np.array([w for _, w in mix], float)
    probs /= probs.sum()
    classes = rng.choice(len(names), size=n_requests, p=probs)
    lam_per_ms = rho * slots / float(svc.mean())
    arrivals_s = np.cumsum(
        rng.exponential(1.0 / lam_per_ms, size=n_requests)) / 1e3

    t0 = time.perf_counter()
    for i, t in enumerate(arrivals_s):
        plane.run_until(float(t))
        plane.submit(session_id=f"s{i}", klass=names[classes[i]],
                     prompt_tokens=128, gen_tokens=16, t_max_ms=t_max_ms)
    plane.drain()
    wall_s = time.perf_counter() - t0

    stats = plane.scheduler.stats
    results = plane.pop_results()
    ok = [r for r in results if r.failed is None]
    waits = np.array([r.queue_wait_ms for r in ok]) if ok else np.zeros(1)
    return {
        "n_requests": n_requests,
        "slots": slots,
        "rho": rho,
        "wall_s": round(wall_s, 3),
        "requests_per_s_wall": round(n_requests / wall_s, 1),
        "p99_admission_wait_ms": round(float(np.quantile(waits, 0.99)), 2),
        "p99_wait_by_class_ms": {
            k: round(stats.p_wait_ms(k, 0.99), 2) for k in names},
        "admitted": stats.admitted,
        "completed": stats.completed,
        "fast_failed": stats.fast_failed,
    }


def figure_rows(n_requests: int = 20_000):
    """(rows, derived) in the benchmarks/figures.py convention."""
    rows = []
    for rho in (0.5, 0.85, 0.95):
        r = bench_plane(n_requests, rho=rho)
        rows.append({"rho": rho,
                     "requests_per_s_wall": r["requests_per_s_wall"],
                     "p99_admission_wait_ms": r["p99_admission_wait_ms"],
                     **{f"p99_wait_{k}_ms": v
                        for k, v in r["p99_wait_by_class_ms"].items()}})
    hi = rows[-1]
    derived = {
        "claim": "plane machinery sustains production request rates; "
                 "premium tail wait stays bounded under load",
        "requests_per_s_wall_at_0.95": hi["requests_per_s_wall"],
        "p99_premium_wait_at_0.95": hi["p99_wait_premium_ms"],
        "holds": (hi["requests_per_s_wall"] > 1_000
                  and hi["p99_wait_premium_ms"]
                  < hi["p99_wait_best-effort_ms"] + 1e-9),
    }
    return rows, derived


def check_baseline(result: dict) -> list:
    """Regression guard: plane-machinery req/s must not drop >20% below
    the checked-in baseline. Returns failure messages."""
    base = _baseline.load_baseline(BASELINE_NAME)
    msg = _baseline.floor_failure(
        "plane throughput req/s", result["requests_per_s_wall"],
        base["requests_per_s_wall"])
    return [msg] if msg else []


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=50_000)
    ap.add_argument("--slots", type=int, default=256)
    ap.add_argument("--rho", type=float, default=0.85)
    ap.add_argument("--check-baseline", action="store_true",
                    help="fail on >20%% req/s regression vs "
                         "benchmarks/baselines/plane.json")
    ap.add_argument("--write-baseline", action="store_true")
    args = ap.parse_args()
    r = bench_plane(args.requests, slots=args.slots, rho=args.rho)
    print(json.dumps(r, indent=1))
    os.makedirs("artifacts/bench", exist_ok=True)
    with open("artifacts/bench/plane_throughput.json", "w") as f:
        json.dump(r, f, indent=1)
    if args.write_baseline:
        _baseline.write_baseline(r, BASELINE_NAME)
    if args.check_baseline:
        _baseline.enforce(check_baseline(r))


if __name__ == "__main__":
    main()
