"""Supervisor recovery benchmark — the fleet-ops numbers.

The claim under test: the site supervisor turns failure from an outage
into an attributable, bounded event. Three arms:

* ``crash`` — a site dies under 10k established AI Sessions (2k with
  ``--quick``) with live requests queued on its plane. Every in-flight
  request fails attributably (COMPUTE_SCARCITY), every orphaned session
  re-anchors via AI-PAGING onto a surviving site, and the per-session
  wall-clock recovery time is reported as p50/p99. The guard is the
  survival fraction (>= 0.99) plus zero silently-dropped in-flight work;
  the recovery percentiles are reference, not enforced (runner speed).
* ``drain`` — graceful exit under load: in-flight requests all finish
  (ZERO failures), every bound session migrates out make-before-break
  (hibernation fallback), and the drained plane refuses new admissions.
* ``store_full`` — a capacity-bounded HibernationStore fills up under an
  aggressive idle-TTL. The heartbeat tick must complete (degrade, never
  crash) and report the refusals through ``PlaneLoad.store_full`` as
  back-pressure the ξ loop can see.

    PYTHONPATH=src python -m benchmarks.recovery_bench [--quick]
        [--check-baseline] [--write-baseline]

``--check-baseline`` enforces ``benchmarks/baselines/recovery.json``:
hardware-independent invariants only (survival floor, zero failed
in-flight on drain, store-full visibility). The CI regression guard for
the supervisor layer.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks import _baseline  # noqa: E402

BASELINE_NAME = "recovery"


def bench_crash(*, n_sessions: int) -> dict:
    from repro.sim.scenarios import simulate_site_crash

    r = simulate_site_crash(n_sessions=n_sessions)
    return {
        "n_sessions": r.n_sessions, "orphaned": r.orphaned,
        "reanchored": r.reanchored, "lost": r.lost,
        "survival_frac": round(r.survival_frac, 4),
        "failed_inflight": r.failed_inflight,
        "recovery_ms_p50": round(r.recovery_ms_p50, 3),
        "recovery_ms_p99": round(r.recovery_ms_p99, 3),
        "causes": r.causes, "reanchor_sites": r.reanchor_sites,
        "serve_ok_after": r.serve_ok_after,
        "post_crash_establish_ok": r.post_crash_establish_ok,
    }


def bench_drain(*, n_sessions: int) -> dict:
    from repro.sim.scenarios import simulate_drain_under_load

    r = simulate_drain_under_load(n_sessions=n_sessions)
    return {
        "n_sessions": r.n_sessions, "on_site": r.on_site,
        "migrated": r.migrated, "hibernated": r.hibernated,
        "stranded": r.stranded, "failed_inflight": r.failed_inflight,
        "completed_during_drain": r.completed_during_drain,
        "post_serve_ok": r.post_serve_ok,
        "rejects_after_drain": r.rejects_after_drain,
        "evacuated": r.migrated + r.hibernated == r.on_site,
    }


def bench_store_full(*, n_sessions: int = 12, capacity_sessions: int = 3
                     ) -> dict:
    """Real paged engine, hibernation store bounded to ~capacity_sessions
    payloads, idle-TTL 0: every completed session tries to hibernate at
    the next tick, most are refused. The tick must survive every refusal
    and surface the count through PlaneLoad."""
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.core.clock import Clock
    from repro.serving.engine import InferenceEngine
    from repro.serving.hibernation import HibernationStore
    from repro.serving.plane import RealEngineBackend, ServingPlane

    cfg = get_smoke_config("edge-tiny")
    slots, max_len = 4, 64
    probe = InferenceEngine(cfg, slots=slots, max_len=max_len, paged=True,
                            page_size=16, hibernation=True)
    rng = np.random.default_rng(0)

    def prompt(seed):
        return rng.integers(0, cfg.vocab_size, size=12).astype(np.int32)

    # size the store from one real payload so the bound is ~N sessions
    # (served through a plane: engine.serve alone frees its slot, the
    # plane's parked/hibernate path is what exports it to the store)
    probe_clock = Clock()
    probe_plane = ServingPlane(
        probe_clock, RealEngineBackend(probe, probe_clock,
                                       hibernate_idle_s=0.0),
        slots=slots, site_id="sizer", premium_reserved_frac=0.0)
    probe_plane.serve(session_id="sizer", klass="best-effort",
                      prompt_tokens=12, gen_tokens=4, t_max_ms=1e12,
                      prompt=prompt(0))
    probe_plane.load()                    # parked -> hibernated
    payload_bytes = probe.hibernation.bytes()
    store = HibernationStore(
        capacity_bytes=int(capacity_sessions * payload_bytes * 1.5))
    eng = InferenceEngine(cfg, params=probe.params, slots=slots,
                          max_len=max_len, paged=True, page_size=16,
                          hibernation=store)
    clock = Clock()
    plane = ServingPlane(
        clock, RealEngineBackend(eng, clock, hibernate_idle_s=0.0),
        slots=slots, site_id="bench", premium_reserved_frac=0.0)
    ticks_ok = 0
    for i in range(n_sessions):
        r = plane.serve(session_id=f"u{i}", klass="best-effort",
                        prompt_tokens=12, gen_tokens=4, t_max_ms=1e12,
                        prompt=prompt(i))
        assert not r.failed, r.failed
        load = plane.load()               # the tick that must not crash
        ticks_ok += 1
    load = plane.load()
    return {
        "n_sessions": n_sessions, "capacity_bytes": store.capacity_bytes,
        "ticks_ok": ticks_ok + 1, "store_full": load.store_full,
        "hibernated_sessions": load.hibernated_sessions,
        "bound_sessions": load.bound_sessions,
        "tick_survives_full_store": ticks_ok + 1 == n_sessions + 1
        and load.store_full > 0,
    }


def run(*, quick: bool = False) -> dict:
    crash = bench_crash(n_sessions=2_000 if quick else 10_000)
    drain = bench_drain(n_sessions=60 if quick else 120)
    store = bench_store_full()
    out = {"crash": crash, "drain": drain, "store_full": store}
    out["holds"] = (crash["survival_frac"] >= 0.99
                    and drain["failed_inflight"] == 0
                    and drain["evacuated"]
                    and store["tick_survives_full_store"])
    return out


def check_baseline(result: dict) -> list:
    """Regression guard, hardware-independent by construction: survival
    and evacuation are counting invariants, store-full visibility is a
    correctness bit. Recovery-time absolutes in the baseline are
    reference only. Returns failure messages."""
    base = _baseline.load_baseline(BASELINE_NAME)
    inv = base["invariants"]
    crash, drain, store = (result["crash"], result["drain"],
                           result["store_full"])
    failures = []
    if crash["survival_frac"] < inv["survival_frac_min"]:
        failures.append(
            f"crash: survival {crash['survival_frac']:.4f} < floor "
            f"{inv['survival_frac_min']:.2f} (orphaned sessions no longer "
            f"re-anchor after a site crash)")
    if not crash["post_crash_establish_ok"]:
        failures.append("crash: establish after crash did not avoid the "
                        "dead site (DISCOVER exclusion broken)")
    if drain["failed_inflight"] > inv["drain_failed_inflight_max"]:
        failures.append(
            f"drain: {drain['failed_inflight']} in-flight requests failed "
            f"during graceful drain (must be "
            f"{inv['drain_failed_inflight_max']})")
    if not drain["evacuated"]:
        failures.append(
            f"drain: {drain['stranded']} sessions stranded "
            f"(migrated {drain['migrated']} + hibernated "
            f"{drain['hibernated']} != on-site {drain['on_site']})")
    if inv["store_full_reported"] and not store["tick_survives_full_store"]:
        failures.append(
            "store_full: heartbeat tick died or PlaneLoad.store_full "
            "stayed 0 on a capacity-bounded store")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 2k-session crash instead of 10k")
    ap.add_argument("--check-baseline", action="store_true",
                    help="enforce benchmarks/baselines/recovery.json "
                         "invariants (CI guard)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="overwrite the checked-in baseline with this run")
    args = ap.parse_args()
    out = run(quick=args.quick)
    print(json.dumps(out, indent=1))
    os.makedirs("artifacts/bench", exist_ok=True)
    with open("artifacts/bench/recovery.json", "w") as f:
        json.dump(out, f, indent=1)
    if args.write_baseline:
        _baseline.write_baseline(
            {"_comment": "regression-guard invariants for the site "
                         "supervisor (crash re-anchoring, graceful drain, "
                         "store-full degradation). check_baseline enforces "
                         "HARDWARE-INDEPENDENT counting invariants only: "
                         "crash survival fraction (orphans re-anchored / "
                         "orphaned, floor 0.99 — observed 1.0), dead-site "
                         "DISCOVER exclusion, zero failed in-flight "
                         "requests during graceful drain, full evacuation "
                         "(migrated+hibernated == on-site), and store-full "
                         "back-pressure visibility through PlaneLoad. "
                         "Recovery-time percentiles are reference only.",
             "invariants": {"survival_frac_min": 0.99,
                            "drain_failed_inflight_max": 0,
                            "store_full_reported": True},
             "reference": out}, BASELINE_NAME)
    if args.check_baseline:
        _baseline.enforce(check_baseline(out))
    if not out["holds"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
