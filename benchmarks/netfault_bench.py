"""Unreliable-control-plane benchmark — establishment under a lossy wire.

The claim under test: the control plane is safe and live under message
loss. A two-domain federation (home deliberately undersized so most
establishes spill east-west) is driven through seeded fault schedules —
drop/delay/duplicate/reorder/corrupt on BOTH the northbound and the
east-west paths — at loss rates 0/1/5/10% per fault class. For each rate
the bench reports establishment goodput, p50/p99 establish latency (the
retry/backoff cost the invoker actually pays), and the two safety
counters that must stay at ZERO regardless of the schedule:

* ``orphaned_after_sweep`` — provisional leases (home 2PC, visited guest
  reservations) still alive after every reaper has run, plus any slot
  not accounted to an established session. A lost COMMIT must never
  strand capacity.
* ``charging_open`` — failed establishments with a charging record still
  open. Fail-stop must also be fail-free.

    PYTHONPATH=src python -m benchmarks.netfault_bench [--quick]
        [--check-baseline] [--write-baseline]

``--check-baseline`` enforces ``benchmarks/baselines/netfault.json``:
hardware-independent invariants only (zero orphans/open charging at every
loss rate, full goodput on the clean wire, a goodput floor at 10% loss).
Latency absolutes are reference, not enforced — all time here is
VirtualClock time, so they are runner-independent anyway but stay
advisory to keep the guard about safety, not tuning.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks import _baseline  # noqa: E402

BASELINE_NAME = "netfault"

LOSS_RATES = (0.0, 0.01, 0.05, 0.10)


def bench_loss_sweep(*, n_sessions: int, seed: int = 0) -> list:
    from repro.sim.scenarios import simulate_lossy_control_plane

    rows = []
    for loss in LOSS_RATES:
        r = simulate_lossy_control_plane(
            n_sessions=n_sessions, loss=loss, seed=seed)
        rows.append({
            "loss": loss, "n_offered": r.n_offered,
            "established": r.established,
            "established_visited": r.established_visited,
            "failed": r.failed, "goodput": round(r.goodput, 4),
            "p50_establish_ms": round(r.p50_establish_ms, 3),
            "p99_establish_ms": round(r.p99_establish_ms, 3),
            "serve_ok": r.serve_ok, "causes": r.causes,
            "orphaned_after_sweep": r.orphaned_after_sweep,
            "charging_open": r.charging_open,
            "wire_sent": r.wire.get("sent", 0),
            "wire_delivered": r.wire.get("delivered", 0),
        })
    return rows


def figure_rows(*, quick: bool = False):
    rows = bench_loss_sweep(n_sessions=24 if quick else 64)
    by_loss = {r["loss"]: r for r in rows}
    derived = {
        "goodput_clean": by_loss[0.0]["goodput"],
        "goodput_10pct": by_loss[0.10]["goodput"],
        "p99_establish_ms_10pct": by_loss[0.10]["p99_establish_ms"],
        "orphaned_total": sum(r["orphaned_after_sweep"] for r in rows),
        "charging_open_total": sum(r["charging_open"] for r in rows),
        "retry_amplification_10pct": round(
            by_loss[0.10]["wire_sent"]
            / max(by_loss[0.0]["wire_sent"], 1), 3),
        # the claims: a clean wire loses nothing, a 10%-per-fault-class
        # wire still establishes >= 90% inside the deadline budget, and
        # NO schedule strands a lease or leaves charging open
        "holds": bool(
            by_loss[0.0]["goodput"] == 1.0
            and by_loss[0.10]["goodput"] >= 0.90
            and sum(r["orphaned_after_sweep"] for r in rows) == 0
            and sum(r["charging_open"] for r in rows) == 0),
    }
    return rows, derived


def check_baseline(rows: list, derived: dict) -> list:
    """Regression guard, hardware-independent by construction: goodput
    and the safety counters are counting invariants on VirtualClock time.
    Returns failure messages."""
    base = _baseline.load_baseline(BASELINE_NAME)
    inv = base["invariants"]
    failures = []
    if derived["goodput_clean"] < inv["goodput_clean_min"]:
        failures.append(
            f"clean wire: goodput {derived['goodput_clean']:.4f} < "
            f"{inv['goodput_clean_min']:.2f} (retry layer now fails "
            f"establishments with no faults injected)")
    if derived["goodput_10pct"] < inv["goodput_10pct_min"]:
        failures.append(
            f"10% loss: goodput {derived['goodput_10pct']:.4f} < floor "
            f"{inv['goodput_10pct_min']:.2f} (deadline-budgeted retries "
            f"no longer converge under loss)")
    for r in rows:
        if r["orphaned_after_sweep"] > inv["orphaned_max"]:
            failures.append(
                f"loss={r['loss']}: {r['orphaned_after_sweep']} orphaned "
                f"leases survived the sweeps (must be {inv['orphaned_max']})")
        if r["charging_open"] > inv["charging_open_max"]:
            failures.append(
                f"loss={r['loss']}: {r['charging_open']} failed sessions "
                f"left charging open (must be {inv['charging_open_max']})")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 24-session sweep instead of 64")
    ap.add_argument("--check-baseline", action="store_true",
                    help="enforce benchmarks/baselines/netfault.json "
                         "invariants (CI guard)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="overwrite the checked-in baseline with this run")
    args = ap.parse_args()
    rows, derived = figure_rows(quick=args.quick)
    for r in rows:
        print(json.dumps(r))
    print(json.dumps(derived, indent=1))
    os.makedirs("artifacts/bench", exist_ok=True)
    with open("artifacts/bench/netfault.json", "w") as f:
        json.dump({"rows": rows, "derived": derived}, f, indent=1)
    if args.write_baseline:
        _baseline.write_baseline(
            {"_comment": "regression-guard invariants for the unreliable "
                         "control plane. check_baseline enforces the "
                         "safety counters (zero orphaned leases and zero "
                         "open charging after the sweeps, at EVERY loss "
                         "rate) and the goodput floors (1.0 clean, 0.90 "
                         "at 10% per-fault-class loss). All time is "
                         "VirtualClock time, so the latency reference "
                         "rows are runner-independent but NOT enforced.",
             "invariants": {
                 "goodput_clean_min": 1.0,
                 "goodput_10pct_min": 0.90,
                 "orphaned_max": 0,
                 "charging_open_max": 0,
             },
             "reference": {"rows": rows, "derived": derived}},
            BASELINE_NAME)
    if args.check_baseline:
        _baseline.enforce(check_baseline(rows, derived))
    if not derived["holds"]:
        raise SystemExit("netfault: paper claim does NOT hold")


if __name__ == "__main__":
    main()
