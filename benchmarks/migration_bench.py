"""Make-before-break migration benchmark — the continuity numbers.

Three arms, all through the REAL migration data plane
(``MigrationController`` + ``PlaneTransferPath`` + ``state_transfer``):

* ``real``   — mid-stream migrations between two real edge-tiny engines
  behind ServingPlanes: a session is decoding when the swap happens and the
  stream finishes on the target. Reports ``interruption_ms`` (must be 0),
  wall transfer throughput (bytes/s through export→verify→import), and
  migrations/s of the whole control+data path.
* ``inject`` — every plane-level failure mode (export failure, wire
  corruption, import failure, target admission denial, τ_mig expiry) driven
  through the same path; reports the abort rate and verifies every abort
  left the source slot intact.
* ``sim``    — the §V VirtualClock arm: migration under load and the
  dense-vs-SSM payload asymmetry sweep (abort rate under τ_mig).

    PYTHONPATH=src python -m benchmarks.migration_bench [--quick]
        [--check-baseline] [--write-baseline]

``--check-baseline`` enforces the checked-in hardware-independent
invariants in ``benchmarks/baselines/migration.json`` (zero interruption,
every injected failure aborts with the source intact, every real
migration lands) and exits non-zero on violation — the CI regression
guard for the migration data plane.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import numpy as np  # noqa: E402

from benchmarks import _baseline  # noqa: E402
from repro.core import Orchestrator, default_asp  # noqa: E402
from repro.core.asp import MobilityClass  # noqa: E402
from repro.core.clock import VirtualClock  # noqa: E402
from repro.serving.server import AIaaSServer  # noqa: E402
from repro.serving.state_transfer import TransferInjections  # noqa: E402


def bench_real(n_sessions: int = 10, *, gen_tokens: int = 12,
               pre_rounds: int = 3) -> dict:
    orch = Orchestrator(clock=VirtualClock())
    srv = AIaaSServer(orch, "edge-tiny", slots=8, max_len=128)
    asp = default_asp(mobility=MobilityClass.VEHICULAR)

    outcomes, wall_s, bytes_moved, mid_stream = [], 0.0, 0, 0
    for i in range(n_sessions):
        s = orch.establish(asp, invoker=f"ue-{i}", zone="zone-a")
        src_plane = srv.planes[s.binding.site_id]
        prompt = np.arange(8 + (i % 5), dtype=np.int32)
        srv.submit(s, prompt=prompt, gen_tokens=gen_tokens)
        for _ in range(pre_rounds):          # stream on the source
            src_plane._round()
        t0 = time.perf_counter()
        out = orch.migrations.migrate(s, "zone-a")
        wall_s += time.perf_counter() - t0
        outcomes.append(out)
        if out.migrated:
            bytes_moved += out.transfer_bytes
            mid_stream += int(out.mid_stream)
            srv.planes[s.binding.site_id].drain()   # stream ends on target
            orch.record_results(orch.sites[s.binding.site_id])
        orch.release(s)

    ok = [o for o in outcomes if o.migrated]
    return {
        "n_sessions": n_sessions,
        "migrated": len(ok),
        "mid_stream": mid_stream,
        "max_interruption_ms": max(o.interruption_ms for o in outcomes),
        "bytes_moved": bytes_moved,
        "wall_s": round(wall_s, 4),
        "transfer_bytes_per_s": round(bytes_moved / wall_s, 1)
        if wall_s > 0 else 0.0,
        "migrations_per_s_wall": round(len(ok) / wall_s, 2)
        if wall_s > 0 else 0.0,
    }


def bench_inject(repeats: int = 2) -> dict:
    """Every failure mode must abort without touching the source."""
    def corrupt(payload):
        payload = dict(payload)
        payload["position"] = payload["position"] + 1
        return payload

    modes = {
        "export_failure": ("src", TransferInjections(
            on_export=lambda p: (_ for _ in ()).throw(
                IOError("injected export failure")))),
        "import_failure": ("dst", TransferInjections(
            on_import=lambda p: (_ for _ in ()).throw(
                IOError("injected import failure")))),
        "fingerprint_corruption": ("src", TransferInjections(
            corrupt=corrupt)),
        "admission_denial": ("dst", TransferInjections(
            deny_admission=True)),
        "tau_mig_expiry": ("src", TransferInjections(extra_wire_s=10.0)),
    }
    causes, intact, attempts, aborts = {}, 0, 0, 0
    for name, (side, inj) in modes.items():
        for r in range(repeats):
            orch = Orchestrator(clock=VirtualClock())
            srv = AIaaSServer(orch, "edge-tiny", slots=4, max_len=96)
            s = orch.establish(default_asp(mobility=MobilityClass.VEHICULAR),
                               invoker=f"ue-{name}-{r}", zone="zone-a")
            src = s.binding.site_id
            eng = srv.fleet.engine_for(src)
            eng.prefill_session(s.session_id, np.arange(9, dtype=np.int32))
            for site_id, plane in srv.planes.items():
                if (side == "src") == (site_id == src):
                    plane.migration_inject = inj
            out = orch.migrations.migrate(s, "zone-a")
            attempts += 1
            aborts += int(out.aborted)
            if out.aborted:
                causes[out.cause.value] = causes.get(out.cause.value, 0) + 1
            intact += int(eng.has_slot(s.session_id) and s.committed()
                          and s.binding.site_id == src)
    return {"attempts": attempts, "aborts": aborts,
            "abort_rate": aborts / max(attempts, 1),
            "sources_intact": intact, "causes": causes}


def bench_sim(n_sessions: int = 40) -> dict:
    from repro.sim import (simulate_migration_under_load,
                           simulate_payload_asymmetry)
    load = simulate_migration_under_load(
        n_sessions=n_sessions, rounds=3, handover_prob=0.4, seed=0)
    pressure = simulate_migration_under_load(
        n_sessions=max(n_sessions // 3, 4), rounds=2, handover_prob=0.8,
        target_pressure=1.0, seed=1)
    asym = simulate_payload_asymmetry(
        context_tokens=(4_096, 131_072),
        models=("minitron-8b", "mamba2-1.3b"))
    return {
        "under_load": {
            "attempts": load.n_attempts, "migrated": load.migrated,
            "abort_rate": load.abort_rate,
            "max_interruption_ms": load.max_interruption_ms,
            "mean_transfer_ms": round(load.mean_transfer_ms, 3),
            "bytes_moved": load.bytes_moved},
        "target_pressure": {
            "attempts": pressure.n_attempts,
            "abort_rate": pressure.abort_rate, "causes": pressure.causes},
        "payload_asymmetry": [
            {"model": r.model_id, "family": r.family,
             "context": r.context_tokens, "payload_bytes": r.payload_bytes,
             "transfer_ms": round(r.transfer_ms, 3),
             "migrated": r.migrated, "cause": r.cause} for r in asym],
    }


def figure_rows(n_sessions: int = 10):
    """(rows, derived) in the benchmarks/figures.py convention."""
    real = bench_real(n_sessions)
    inject = bench_inject()
    sim = bench_sim(max(n_sessions * 3, 12))
    rows = [{"arm": "real", **{k: v for k, v in real.items()
                               if not isinstance(v, dict)}}]
    derived = {
        "claim": "make-before-break: zero contract-gap interruption on every "
                 "successful migration; every injected failure aborts "
                 "without tearing down the source",
        "max_interruption_ms": real["max_interruption_ms"],
        "abort_rate_injected": inject["abort_rate"],
        "sources_intact": inject["sources_intact"],
        "holds": (real["max_interruption_ms"] == 0.0
                  and real["migrated"] == real["n_sessions"]
                  and inject["abort_rate"] == 1.0
                  and inject["sources_intact"] == inject["attempts"]
                  and sim["under_load"]["max_interruption_ms"] == 0.0),
    }
    return rows, derived


BASELINE_NAME = "migration"


def check_baseline(result: dict) -> list:
    """Regression guard, hardware-independent by construction: every
    enforced metric is a correctness invariant (interruption, abort
    accounting, migration success count), never a latency/throughput
    absolute — those are recorded as reference values only. Returns
    failure messages."""
    base = _baseline.load_baseline(BASELINE_NAME)
    inv = base["invariants"]
    real, inject, sim = result["real"], result["inject"], result["sim"]
    failures = []
    if real["max_interruption_ms"] > inv["max_interruption_ms"]:
        failures.append(
            f"real: max_interruption_ms {real['max_interruption_ms']} > "
            f"{inv['max_interruption_ms']} (make-before-break gap)")
    if real["migrated"] < real["n_sessions"]:
        failures.append(
            f"real: only {real['migrated']}/{real['n_sessions']} "
            f"migrations landed")
    if inject["abort_rate"] < inv["abort_rate"]:
        failures.append(
            f"inject: abort_rate {inject['abort_rate']} < "
            f"{inv['abort_rate']} (an injected failure slipped through)")
    if inject["sources_intact"] != inject["attempts"]:
        failures.append(
            f"inject: {inject['sources_intact']}/{inject['attempts']} "
            f"sources intact after abort")
    if sim["under_load"]["max_interruption_ms"] > inv["max_interruption_ms"]:
        failures.append(
            f"sim: under-load max_interruption_ms "
            f"{sim['under_load']['max_interruption_ms']} > "
            f"{inv['max_interruption_ms']}")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer sessions per arm")
    ap.add_argument("--sessions", type=int, default=None)
    ap.add_argument("--check-baseline", action="store_true",
                    help="enforce benchmarks/baselines/migration.json "
                         "invariants (CI guard)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="overwrite the checked-in baseline with this run")
    args = ap.parse_args()
    n = args.sessions or (3 if args.quick else 10)
    t0 = time.perf_counter()
    out = {
        "real": bench_real(n),
        "inject": bench_inject(1 if args.quick else 2),
        "sim": bench_sim(12 if args.quick else 40),
    }
    out["wall_s_total"] = round(time.perf_counter() - t0, 2)
    out["holds"] = (
        out["real"]["max_interruption_ms"] == 0.0
        and out["inject"]["abort_rate"] == 1.0
        and out["inject"]["sources_intact"] == out["inject"]["attempts"])
    print(json.dumps(out, indent=1))
    os.makedirs("artifacts/bench", exist_ok=True)
    with open("artifacts/bench/migration.json", "w") as f:
        json.dump(out, f, indent=1)
    if args.write_baseline:
        _baseline.write_baseline(
            {"_comment": "regression-guard invariants for the migration "
                         "data plane. check_baseline enforces only "
                         "HARDWARE-INDEPENDENT correctness invariants: "
                         "zero make-before-break interruption, every real "
                         "migration lands, every injected failure aborts "
                         "with the source intact. The reference block is a "
                         "dev-container snapshot; its latency/throughput "
                         "absolutes are NOT enforced.",
             "invariants": {"max_interruption_ms": 0.0, "abort_rate": 1.0},
             "reference": out}, BASELINE_NAME)
    if args.check_baseline:
        _baseline.enforce(check_baseline(out))
    if not out["holds"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
