"""Split-serving benchmark — speculative decode vs target-only streaming.

The claim under test: for an interactive stream whose verify-quality
anchor sits behind backhaul RTT, a split session (edge draft + one fused
verify round per γ-token window) delivers BOTH of:

* **token identity** — the committed stream is bitwise the target-only
  greedy stream (the subsystem's hard invariant, checked per arm), and
* **higher effective tok/s** — per streamed token the invoker pays the
  2 ms access RTT instead of the 55 ms backhaul RTT; the backhaul is paid
  once per ROUND and amortized over E[n+1] = (1−α^{γ+1})/(1−α) committed
  tokens.

Arms:

* ``target_only`` — the verify engine alone; every token pays one
  backhaul RTT plus measured decode compute. This also fixes the known
  greedy continuation the oracle arms sweep against.
* ``spec(α)`` for α ∈ {0.5, 0.7, 0.9, 0.95} — a real two-engine
  SpecDecoder with ORACLE proposals: the known continuation corrupted at
  per-token rate 1−α. The edge engine still runs (and rolls back) a real
  draft round per window, so the draft-side compute is honestly charged
  (conservatively ~2x, since the oracle path drafts AND re-grades).
* ``edge_only`` — degraded/airplane mode: draft-engine rounds with no
  verifier; the latency floor of the quality rung a verify-anchor loss
  falls back to (stream stays live, tokens are draft-tier).
* ``real_pair`` — engine-drafted (no oracle) rounds for the smoke
  pairing, reporting the genuine acceptance rate (reference only: smoke
  weights are random, so acceptance carries no signal worth guarding).

Latency model: measured compute wall-clock + a virtual network term
(55 ms backhaul / 2 ms access, the default_sites central-1 / edge-a
figures). The CI guard enforces the RATIO of effective tok/s at α = 0.7
(≥ 1.3× floor) plus the identity bits — both hardware-independent: the
compute terms appear in numerator and denominator, measured in the same
process on the same machine.

    PYTHONPATH=src python -m benchmarks.splitserve_bench [--quick]
        [--check-baseline] [--write-baseline]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import numpy as np  # noqa: E402

from benchmarks import _baseline  # noqa: E402
from repro.configs import get_smoke_config  # noqa: E402
from repro.serving.engine import InferenceEngine  # noqa: E402
from repro.splitserve import SpecDecoder, spec_speedup  # noqa: E402

BASELINE_NAME = "splitserve"

#: default_sites figures: central-1 backhaul vs edge-a access (zone-a)
RTT_VERIFY_MS = 55.0
RTT_EDGE_MS = 2.0
ALPHAS = (0.5, 0.7, 0.9, 0.95)
GAMMA = 4
VERIFY_ARCH = "recurrentgemma-2b"   # hybrid: exercises stacked rollback
DRAFT_ARCH = "edge-tiny"
MAX_LEN = 160


def _prompt(n=12, vocab=512, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(1, vocab, size=n).astype(np.int32)


def _mk_engine(arch, seed):
    return InferenceEngine(get_smoke_config(arch), slots=2,
                           max_len=MAX_LEN, seed=seed)


def _warm_spec(eng, prompt, *, grade_lens):
    """Compile every jit variant a measured run will hit (prefill, the
    γ-window autoregressive round, each teacher-forced grade length) on a
    scratch slot, then free it — measured walls are steady-state."""
    eng.prefill_session("warm", prompt)
    eng.spec_round("warm", GAMMA)
    eng.spec_abort("warm")
    for n in grade_lens:
        eng.spec_grade("warm", [0] * n)
        eng.spec_abort("warm")
    eng.release_slot("warm")


def bench_target_only(n_tokens: int) -> dict:
    """Verify model alone: the quality bar and the latency baseline."""
    eng = _mk_engine(VERIFY_ARCH, seed=0)
    prompt = _prompt()
    eng.prefill_session("warm", prompt)
    eng.decode_round()
    eng.release_slot("warm")

    t0 = time.perf_counter()
    pre = eng.prefill_session("s", prompt)
    prefill_ms = (time.perf_counter() - t0) * 1e3
    toks = [pre["first_token"]]
    t0 = time.perf_counter()
    while len(toks) < n_tokens:
        toks.append(eng.decode_round()["s"])
    compute_ms = (time.perf_counter() - t0) * 1e3
    total_ms = compute_ms + n_tokens * RTT_VERIFY_MS
    return {
        "tokens": toks,
        "ttft_ms": prefill_ms + RTT_VERIFY_MS,
        "compute_ms": compute_ms,
        "network_ms": n_tokens * RTT_VERIFY_MS,
        "tok_s_effective": n_tokens / total_ms * 1e3,
    }


def _spec_pair(prompt):
    dra = _mk_engine(DRAFT_ARCH, seed=7)
    ver = _mk_engine(VERIFY_ARCH, seed=0)
    _warm_spec(dra, prompt, grade_lens=range(1, GAMMA + 2))
    _warm_spec(ver, prompt, grade_lens=(GAMMA,))
    return dra, ver


def bench_spec(baseline: list, alpha: float, n_tokens: int,
               seed: int = 0) -> dict:
    """Oracle-draft arm: proposals are the known greedy continuation
    corrupted at per-token rate 1−α, so acceptance is swept exactly while
    every committed token must stay on the baseline path."""
    prompt = _prompt()
    dra, ver = _spec_pair(prompt)
    rng = np.random.default_rng(seed)
    vocab = get_smoke_config(VERIFY_ARCH).vocab_size
    proposals = [t if rng.random() < alpha else int((t + 1) % vocab)
                 for t in baseline[1:]]
    dec = SpecDecoder(dra, ver, gamma=GAMMA)
    t0 = time.perf_counter()
    first = dec.start(prompt)
    prefill_ms = (time.perf_counter() - t0) * 1e3
    assert first == baseline[0]
    dec.decode(n_tokens - 1, proposals=proposals)
    st = dec.stats
    n = len(dec.tokens)
    compute_ms = st.draft_ms + st.verify_ms
    network_ms = n * RTT_EDGE_MS + st.rounds * RTT_VERIFY_MS
    total_ms = compute_ms + network_ms
    out = {
        "alpha": alpha,
        "identical": dec.tokens[:n_tokens] == baseline[:n_tokens],
        "acceptance": st.acceptance,
        "tokens_per_round": st.tokens_per_round,
        "rounds": st.rounds,
        "ttft_ms": prefill_ms + RTT_VERIFY_MS,
        "compute_ms": compute_ms,
        "network_ms": network_ms,
        "tok_s_effective": n / total_ms * 1e3,
        "predicted_speedup": spec_speedup(
            alpha, GAMMA, rtt_verify_ms=RTT_VERIFY_MS,
            rtt_edge_ms=RTT_EDGE_MS),
    }
    dec.close()
    return out


def bench_edge_only(n_tokens: int) -> dict:
    """Degraded-mode floor: what the stream costs per token after a
    verify-anchor loss (edge rounds only, access RTT only)."""
    prompt = _prompt()
    dra, ver = _spec_pair(prompt)
    dec = SpecDecoder(dra, ver, gamma=GAMMA)
    dec.start(prompt)
    dec.degrade()
    t0 = time.perf_counter()
    dec.decode(n_tokens - 1)
    compute_ms = (time.perf_counter() - t0) * 1e3
    n = len(dec.tokens)
    total_ms = compute_ms + n * RTT_EDGE_MS
    out = {
        "degraded_rounds": dec.stats.degraded_rounds,
        "compute_ms": compute_ms,
        "network_ms": n * RTT_EDGE_MS,
        "tok_s_effective": n / total_ms * 1e3,
    }
    dec.close()
    return out


def bench_real_pair(n_tokens: int) -> dict:
    """Engine-drafted rounds (no oracle): the smoke pairing's true
    acceptance, identity still enforced."""
    prompt = _prompt()
    dra, ver = _spec_pair(prompt)
    base_eng = _mk_engine(VERIFY_ARCH, seed=0)
    pre = base_eng.prefill_session("s", prompt)
    base = [pre["first_token"]]
    while len(base) < n_tokens:
        base.append(base_eng.decode_round()["s"])
    dec = SpecDecoder(dra, ver, gamma=GAMMA)
    dec.start(prompt)
    dec.decode(n_tokens - 1)
    out = {
        "identical": dec.tokens[:n_tokens] == base[:n_tokens],
        "acceptance": dec.stats.acceptance,
        "tokens_per_round": dec.stats.tokens_per_round,
    }
    dec.close()
    return out


def run(*, quick: bool = False) -> dict:
    n = 48 if quick else 96
    # the baseline overshoots the decode target so oracle proposals never
    # run short: a shrunken final window would hit uncompiled shapes and
    # charge jit time to the measured run
    target = bench_target_only(n + GAMMA + 2)
    baseline_tokens = target.pop("tokens")
    spec = [bench_spec(baseline_tokens, a, n) for a in ALPHAS]
    for arm in spec:
        arm["speedup_vs_target"] = (arm["tok_s_effective"]
                                    / target["tok_s_effective"])
    edge = bench_edge_only(n)
    real = bench_real_pair(min(n, 32))
    at07 = next(a for a in spec if a["alpha"] == 0.7)
    out = {
        "gamma": GAMMA,
        "n_tokens": n,
        "rtt_verify_ms": RTT_VERIFY_MS,
        "rtt_edge_ms": RTT_EDGE_MS,
        "target_only": target,
        "spec": spec,
        "edge_only": edge,
        "real_pair": real,
        "speedup_at_0p7": at07["speedup_vs_target"],
    }
    # at alpha=0.7, gamma=4 the predictor gives E[n+1] ~= 2.77 committed
    # tokens/round; 2.0 is the floor below which the sweep isn't sweeping
    out["holds"] = (all(a["identical"] for a in spec)
                    and real["identical"]
                    and at07["tokens_per_round"] >= 2.0
                    and at07["speedup_vs_target"] >= 1.3)
    return out


def check_baseline(result: dict) -> list:
    """CI guard: hardware-independent ratios and correctness bits only.
    Both tok/s arms run in the same process on the same machine, so the
    runner's speed cancels in the ratio; identity is a bit."""
    base = _baseline.load_baseline(BASELINE_NAME)
    inv = base["invariants"]
    failures = []
    for arm in result["spec"]:
        if not arm["identical"]:
            failures.append(
                f"spec(alpha={arm['alpha']}): committed stream diverged "
                f"from target-only greedy — the identity invariant is "
                f"BROKEN")
    if not result["real_pair"]["identical"]:
        failures.append("real_pair: committed stream diverged from "
                        "target-only greedy")
    if result["speedup_at_0p7"] < inv["speedup_at_0p7_min"]:
        failures.append(
            f"spec(alpha=0.7): effective tok/s ratio "
            f"{result['speedup_at_0p7']:.2f} < floor "
            f"{inv['speedup_at_0p7_min']:.2f} (the split stopped paying "
            f"for its second anchor)")
    at07 = next(a for a in result["spec"] if a["alpha"] == 0.7)
    if at07["tokens_per_round"] < inv["round_tokens_at_0p7_min"]:
        failures.append(
            f"spec(alpha=0.7): {at07['tokens_per_round']:.2f} committed "
            f"tokens/round < {inv['round_tokens_at_0p7_min']:.2f} — the "
            f"oracle sweep is no longer sweeping what it claims "
            f"(predictor says ~2.77)")
    return failures


def figure_rows(*, quick: bool = False):
    """run.py adapter: per-arm rows + the derived guard bits."""
    out = run(quick=quick)
    target_tok_s = out["target_only"]["tok_s_effective"]
    rows = [{"arm": "target_only", "alpha": 1.0,
             "tok_s_effective": target_tok_s,
             "ttft_ms": out["target_only"]["ttft_ms"], "identical": True,
             "acceptance": 1.0, "speedup_vs_target": 1.0}]
    rows += [{"arm": "spec", "alpha": a["alpha"],
              "tok_s_effective": a["tok_s_effective"],
              "ttft_ms": a["ttft_ms"], "identical": a["identical"],
              "acceptance": a["acceptance"],
              "speedup_vs_target": a["speedup_vs_target"]}
             for a in out["spec"]]
    edge_tok_s = out["edge_only"]["tok_s_effective"]
    rows.append({"arm": "edge_only", "alpha": 0.0,
                 "tok_s_effective": edge_tok_s,
                 "ttft_ms": 0.0, "identical": False, "acceptance": 1.0,
                 "speedup_vs_target": edge_tok_s / target_tok_s})
    return rows, {"holds": out["holds"],
                  "speedup_at_0p7": out["speedup_at_0p7"],
                  "real_pair_acceptance":
                      out["real_pair"]["acceptance"]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer tokens")
    ap.add_argument("--check-baseline", action="store_true",
                    help="enforce benchmarks/baselines/splitserve.json "
                         "ratio invariants (CI guard)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="overwrite the checked-in baseline with this run")
    args = ap.parse_args()
    out = run(quick=args.quick)
    print(json.dumps(out, indent=1))
    os.makedirs("artifacts/bench", exist_ok=True)
    with open("artifacts/bench/splitserve.json", "w") as f:
        json.dump(out, f, indent=1)
    if args.write_baseline:
        _baseline.write_baseline(
            {"_comment": "regression-guard invariants for split serving "
                         "+ edge-draft speculative decode. check_baseline "
                         "enforces HARDWARE-INDEPENDENT metrics only: "
                         "bitwise token identity of every spec arm with "
                         "target-only greedy decode, the effective-tok/s "
                         "ratio at alpha=0.7 under the 55ms-backhaul/"
                         "2ms-access virtual network model (floor 1.3x "
                         "sits well under the observed ~2.5-3x; both arms "
                         "measured in the same process, so runner speed "
                         "cancels), and the oracle sweep's measured "
                         "acceptance staying near its target. Absolute "
                         "ms / tok-s figures are reference only.",
             "invariants": {"speedup_at_0p7_min": 1.3,
                            "round_tokens_at_0p7_min": 2.0},
             "reference": out}, BASELINE_NAME)
    if args.check_baseline:
        _baseline.enforce(check_baseline(out))
    if not out["holds"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
