"""Shared checked-in-baseline machinery for the benchmark regression
guards (engine_bench, plane_bench, and any future bench that wants one).

A baseline is a JSON snapshot under ``benchmarks/baselines/<name>.json``
holding conservative floors; ``floor_failures`` compares observed
throughput-style metrics (higher is better) against those floors with a
relative tolerance, and ``enforce`` turns failures into a non-zero exit
for CI.
"""

from __future__ import annotations

import json
import os
import sys
from typing import List

#: relative drop vs the checked-in floor that fails the guard
REGRESSION_TOLERANCE = 0.20


def baseline_path(name: str) -> str:
    return os.path.join(os.path.dirname(__file__), "baselines",
                        f"{name}.json")


def load_baseline(name: str) -> dict:
    with open(baseline_path(name)) as f:
        return json.load(f)


def write_baseline(result: dict, name: str) -> str:
    path = baseline_path(name)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    print(f"baseline written to {path}")
    return path


def floor_failure(label: str, observed: float, floor_value: float,
                  tolerance: float = REGRESSION_TOLERANCE):
    """One higher-is-better metric vs its baseline value; returns a
    failure message or None."""
    floor = floor_value * (1.0 - tolerance)
    if observed < floor:
        return (f"{label}: {observed:.0f} < {floor:.0f} "
                f"(baseline {floor_value:.0f} - {tolerance:.0%})")
    return None


def enforce(failures: List[str]) -> None:
    """Print failures to stderr and exit non-zero (CI guard semantics)."""
    for msg in failures:
        print(f"!! regression: {msg}", file=sys.stderr)
    if failures:
        raise SystemExit(1)
    print("baseline check OK")
