"""Roofline table builder: reads artifacts/dryrun/*.json into the
EXPERIMENTS.md §Roofline table and picks the three hillclimb cells."""

from __future__ import annotations

import glob
import json
import os

HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "link_bw": 50e9}


def load_records(art_dir: str = "artifacts/dryrun", mesh: str = "pod16x16"):
    recs = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        r = json.load(open(f))
        if r.get("mesh_name") == mesh and "__" in os.path.basename(f) \
                and os.path.basename(f).count("__") == 2:
            recs.append(r)
    return recs


def summary_table(art_dir: str = "artifacts/dryrun", mesh: str = "pod16x16"):
    rows = []
    for r in load_records(art_dir, mesh):
        if r["status"] == "skipped":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "status": "skipped", "dominant": "-",
                         "compute_ms": "-", "memory_ms": "-",
                         "collective_ms": "-", "useful_flops_ratio": "-",
                         "fits_hbm": "-"})
            continue
        if r["status"] != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "status": "ERROR", "dominant": "-",
                         "compute_ms": "-", "memory_ms": "-",
                         "collective_ms": "-", "useful_flops_ratio": "-",
                         "fits_hbm": "-"})
            continue
        roof = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "dominant": roof["dominant"],
            "compute_ms": round(roof["compute_s"] * 1e3, 2),
            "memory_ms": round(roof["memory_s"] * 1e3, 2),
            "collective_ms": round(roof["collective_s"] * 1e3, 2),
            "useful_flops_ratio": round(r["useful_flops_ratio"], 3),
            "fits_hbm": r["memory"]["fits_hbm"],
        })
    return rows


def pick_hillclimb_cells(art_dir: str = "artifacts/dryrun"):
    """worst roofline fraction / most collective-bound / most
    paper-representative (decode serving cell of the largest-session model)."""
    recs = [r for r in load_records(art_dir) if r["status"] == "ok"]
    if not recs:
        return {}

    def frac(r):
        roof = r["roofline"]
        bound = roof["roofline_bound_s"]
        return (roof["compute_s"] / bound) if bound else 0.0

    worst = min(recs, key=lambda r: max(frac(r), r["useful_flops_ratio"]))
    coll = max(recs, key=lambda r: r["roofline"]["collective_s"]
               / max(r["roofline"]["roofline_bound_s"], 1e-12))
    serving = [r for r in recs if r["kind"] == "decode"]
    rep = max(serving, key=lambda r: r["roofline"]["memory_s"]) \
        if serving else recs[0]
    key = lambda r: f"{r['arch']}×{r['shape']}"
    return {"worst_fraction": key(worst), "most_collective": key(coll),
            "paper_representative": key(rep)}
