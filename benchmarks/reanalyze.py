"""Re-run the loop-aware HLO analysis over stored artifacts (no recompile).

    PYTHONPATH=src python -m benchmarks.reanalyze
"""

import glob
import gzip
import json
import sys

sys.path.insert(0, "src")

from repro.launch import hlo_analysis as H
from repro.launch import hlo_loops as HL


def main():
    for jf in sorted(glob.glob("artifacts/dryrun/*.json")):
        hf = jf.replace(".json", ".hlo.txt.gz")
        rec = json.load(open(jf))
        if rec.get("status") != "ok":
            continue
        try:
            text = gzip.open(hf, "rt").read()
        except FileNotFoundError:
            continue
        n_dev = rec["mesh"]["devices"]
        la = HL.analyze(text, n_dev)
        roof = {
            "flops_per_device": la["flops_per_device"],
            "flops_global": la["flops_per_device"] * n_dev,
            "hbm_bytes_per_device": la["hbm_bytes_per_device"],
            "wire_bytes_per_device": la["wire_bytes_per_device"],
            "compute_s": la["flops_per_device"] / H.PEAK_FLOPS,
            "memory_s": la["hbm_bytes_per_device"] / H.HBM_BW,
            "collective_s": la["wire_bytes_per_device"] / H.LINK_BW,
        }
        roof["dominant"] = max(
            (("compute", roof["compute_s"]), ("memory", roof["memory_s"]),
             ("collective", roof["collective_s"])), key=lambda kv: kv[1])[0]
        roof["roofline_bound_s"] = max(roof["compute_s"], roof["memory_s"],
                                       roof["collective_s"])
        roof["compute_fraction_of_bound"] = (
            roof["compute_s"] / roof["roofline_bound_s"]
            if roof["roofline_bound_s"] else 0.0)
        rec["roofline"] = roof
        rec["collectives"] = la["collectives_per_op"]
        rec["useful_flops_ratio"] = (rec["model_flops"] / roof["flops_global"]
                                     if roof["flops_global"] else 0.0)
        json.dump(rec, open(jf, "w"), indent=1, default=float)
        print("reanalyzed", jf.split("/")[-1])


if __name__ == "__main__":
    main()
