"""Multi-tenant adapter fleet benchmark — the multiplexing numbers.

The claim under test: per-session LoRA multiplexing over a shared base
costs (nearly) nothing at decode time — a fused chunk whose 32 slots are
bound to 32 DISTINCT adapters runs at the same tok/s as one where every
slot shares a single adapter, and both emit tokens identical to applying
each adapter individually. Three arms:

* ``multiplex`` — fused-decode tok/s with 1 vs N distinct adapters
  bound across a full batch, interleaved rep-by-rep (engine_bench
  convention). The guard is the RATIO many/one (same machine, same run —
  runner speed cancels), not an absolute.
* ``lifecycle`` — p50/p99 of adapter load (weight pad + device table
  update) and unload at the engine, the control-plane cost of rotating a
  tenant fleet through a bounded table.
* ``identity`` — the correctness bit: a mixed batch {base, tenant-A,
  tenant-B} must emit, per session, exactly the tokens a solo engine
  with only that session's adapter emits; and the Pallas grouped-GEMM
  route must match the XLA gather route token-for-token.

    PYTHONPATH=src python -m benchmarks.adapter_bench [--quick]
        [--check-baseline] [--write-baseline]

``--check-baseline`` enforces ``benchmarks/baselines/adapters.json``:
hardware-independent ratios and identity bits only. The CI regression
guard for the adapter fleet.
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import numpy as np  # noqa: E402

from benchmarks import _baseline  # noqa: E402
from repro.adapters import AdapterRuntime, AdapterSpec, init_adapter_weights  # noqa: E402
from repro.configs import get_smoke_config  # noqa: E402
from repro.serving.engine import InferenceEngine  # noqa: E402

BASELINE_NAME = "adapters"


def _weights(adapter_id: str, d_model: int, rank: int = 4):
    spec = AdapterSpec(adapter_id=adapter_id, version="1.0",
                       base_model_id="edge-tiny", base_model_version="1.0",
                       rank=rank)
    return init_adapter_weights(spec, d_model)


def _engine(cfg, *, slots, max_adapters, params=None, route="gather"):
    rt = AdapterRuntime(cfg.d_model, max_adapters=max_adapters, rank=4,
                        route=route)
    return InferenceEngine(cfg, params=params, slots=slots, max_len=64,
                           adapters=rt)


def _prompt(i, vocab, n=8):
    rng = np.random.default_rng(1000 + i)
    return rng.integers(0, vocab, size=n).astype(np.int32)


def bench_multiplex(*, slots: int = 32, gen: int = 16,
                    reps: int = 5) -> dict:
    """Fused decode tok/s: every slot on ONE shared adapter vs every
    slot on its OWN adapter (the worst-case table gather / grouped
    dispatch), interleaved so machine noise cancels in the ratio."""
    cfg = get_smoke_config("edge-tiny")
    one = _engine(cfg, slots=slots, max_adapters=slots)
    many = _engine(cfg, slots=slots, max_adapters=slots, params=one.params)
    one.load_adapter("t0", *_weights("t0", cfg.d_model))
    for i in range(slots):
        many.load_adapter(f"t{i}", *_weights(f"t{i}", cfg.d_model))
    for i in range(slots):
        one.prefill_session(f"s{i}", _prompt(i, cfg.vocab_size),
                            adapter_id="t0")
        many.prefill_session(f"s{i}", _prompt(i, cfg.vocab_size),
                             adapter_id=f"t{i}")

    def chunk(eng):
        t0 = time.perf_counter()
        eng.decode_round(steps=gen)
        return slots * gen / (time.perf_counter() - t0)

    ones, manys, ratios = [], [], []
    for rep in range(reps + 1):
        o, m_ = chunk(one), chunk(many)
        if rep > 0:                        # rep 0 = compile warmup
            ones.append(o)
            manys.append(m_)
            ratios.append(m_ / o)
    return {"slots": slots, "gen": gen, "distinct_adapters": slots,
            "one_adapter_tok_s": round(statistics.median(ones), 1),
            "many_adapters_tok_s": round(statistics.median(manys), 1),
            "many_over_one": round(statistics.median(ratios), 3)}


def jax_block(x):
    x.block_until_ready()


def bench_lifecycle(*, n_adapters: int = 32, sample: int = 16) -> dict:
    """Adapter load/unload latency at the engine table."""
    cfg = get_smoke_config("edge-tiny")
    eng = _engine(cfg, slots=2, max_adapters=n_adapters)
    pre = [(f"t{i}", *_weights(f"t{i}", cfg.d_model))
           for i in range(min(sample, n_adapters))]
    load_ms, unload_ms = [], []
    for aid, a, b in pre:
        t0 = time.perf_counter()
        eng.load_adapter(aid, a, b)
        jax_block(eng.adapters.A)
        load_ms.append((time.perf_counter() - t0) * 1e3)
    for aid, _, _ in pre:
        t0 = time.perf_counter()
        eng.unload_adapter(aid)
        jax_block(eng.adapters.A)
        unload_ms.append((time.perf_counter() - t0) * 1e3)
    load_ms.sort()
    unload_ms.sort()

    def p(xs, q):
        return round(xs[min(int(q * (len(xs) - 1) + 0.999), len(xs) - 1)], 3)

    return {"sample": len(pre), "table_size": n_adapters,
            "load_ms_p50": round(statistics.median(load_ms), 3),
            "load_ms_p99": p(load_ms, 0.99),
            "unload_ms_p50": round(statistics.median(unload_ms), 3),
            "unload_ms_p99": p(unload_ms, 0.99)}


def bench_identity(*, gen: int = 8) -> dict:
    """Token identity: mixed multiplexed batch == individual
    application, and grouped route == gather route."""
    cfg = get_smoke_config("edge-tiny")
    sessions = [("s-base", ""), ("s-a", "tenant-a"), ("s-b", "tenant-b")]

    mux = _engine(cfg, slots=4, max_adapters=4)
    for _, aid in sessions:
        if aid:
            mux.load_adapter(aid, *_weights(aid, cfg.d_model))
    for i, (sid, aid) in enumerate(sessions):
        mux.prefill_session(sid, _prompt(i, cfg.vocab_size), adapter_id=aid)
    together = mux.decode_round(steps=gen)

    individual_ok = True
    for i, (sid, aid) in enumerate(sessions):
        solo = _engine(cfg, slots=2, max_adapters=4, params=mux.params)
        if aid:
            solo.load_adapter(aid, *_weights(aid, cfg.d_model))
        solo.prefill_session(sid, _prompt(i, cfg.vocab_size), adapter_id=aid)
        individual_ok = individual_ok and \
            solo.decode_round(steps=gen)[sid] == together[sid]

    grouped = _engine(cfg, slots=4, max_adapters=4, params=mux.params,
                      route="grouped")
    for _, aid in sessions:
        if aid:
            grouped.load_adapter(aid, *_weights(aid, cfg.d_model))
    for i, (sid, aid) in enumerate(sessions):
        grouped.prefill_session(sid, _prompt(i, cfg.vocab_size),
                                adapter_id=aid)
    routes_ok = grouped.decode_round(steps=gen) == together

    return {"sessions": len(sessions), "gen": gen,
            "mixed_equals_individual": individual_ok,
            "grouped_equals_gather": routes_ok,
            "tokens_identical": individual_ok and routes_ok}


def run(*, quick: bool = False) -> dict:
    slots = 8 if quick else 32
    mux = bench_multiplex(slots=slots, reps=3 if quick else 5)
    life = bench_lifecycle(n_adapters=slots, sample=8 if quick else 16)
    ident = bench_identity(gen=6 if quick else 8)
    out = {"multiplex": mux, "lifecycle": life, "identity": ident}
    out["holds"] = (ident["tokens_identical"]
                    and mux["many_over_one"] >= 0.5)
    return out


def check_baseline(result: dict) -> list:
    """Regression guard, hardware-independent by construction: the one
    enforced performance metric is the many/one tok-s ratio between two
    arms interleaved on the same machine (runner speed cancels); the
    rest are correctness bits. Absolute ms / tok-s figures in the
    baseline are reference only. Returns failure messages."""
    base = _baseline.load_baseline(BASELINE_NAME)
    inv = base["invariants"]
    mux, ident = result["multiplex"], result["identity"]
    failures = []
    if mux["many_over_one"] < inv["many_over_one_min"]:
        failures.append(
            f"multiplex: many/one tok-s ratio {mux['many_over_one']:.2f} "
            f"< floor {inv['many_over_one_min']:.2f} (distinct-adapter "
            f"batches no longer ride the shared-base hot path)")
    if not ident["mixed_equals_individual"]:
        failures.append(
            "identity: multiplexed batch tokens diverge from individual "
            "adapter application")
    if not ident["grouped_equals_gather"]:
        failures.append(
            "identity: grouped (Pallas moe_gemm) route diverges from the "
            "gather route")
    return failures


def figure_rows(*, quick: bool = False):
    """run.py convention: (csv rows, derived dict)."""
    out = run(quick=quick)
    rows = [
        {"arm": "multiplex", "metric": "one_adapter_tok_s",
         "value": out["multiplex"]["one_adapter_tok_s"]},
        {"arm": "multiplex", "metric": "many_adapters_tok_s",
         "value": out["multiplex"]["many_adapters_tok_s"]},
        {"arm": "multiplex", "metric": "many_over_one",
         "value": out["multiplex"]["many_over_one"]},
        {"arm": "lifecycle", "metric": "load_ms_p50",
         "value": out["lifecycle"]["load_ms_p50"]},
        {"arm": "lifecycle", "metric": "unload_ms_p50",
         "value": out["lifecycle"]["unload_ms_p50"]},
        {"arm": "identity", "metric": "tokens_identical",
         "value": int(out["identity"]["tokens_identical"])},
    ]
    derived = {"holds": out["holds"],
               "many_over_one": out["multiplex"]["many_over_one"],
               "tokens_identical": out["identity"]["tokens_identical"]}
    return rows, derived


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: smaller fleet / fewer reps")
    ap.add_argument("--check-baseline", action="store_true",
                    help="enforce benchmarks/baselines/adapters.json "
                         "ratio invariants (CI guard)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="overwrite the checked-in baseline with this run")
    args = ap.parse_args()
    out = run(quick=args.quick)
    print(json.dumps(out, indent=1))
    os.makedirs("artifacts/bench", exist_ok=True)
    with open("artifacts/bench/adapters.json", "w") as f:
        json.dump(out, f, indent=1)
    if args.write_baseline:
        _baseline.write_baseline(
            {"_comment": "regression-guard invariants for the multi-tenant "
                         "adapter fleet. check_baseline enforces "
                         "HARDWARE-INDEPENDENT metrics only: many/one "
                         "fused-decode tok/s ratio (32 distinct adapters "
                         "vs 1 shared, both arms interleaved on the same "
                         "machine; floor 0.5 sits well under the observed "
                         "~0.9-1.0) and the two token-identity bits "
                         "(multiplexed==individual, grouped==gather). "
                         "Reference absolutes are NOT enforced.",
             "invariants": {"many_over_one_min": 0.5},
             "reference": out}, BASELINE_NAME)
    if args.check_baseline:
        _baseline.enforce(check_baseline(out))
    if not out["holds"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
