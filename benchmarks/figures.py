"""One benchmark per paper table/figure (§V).

fig2  — p99 end-to-end latency vs offered load (endpoint vs NE-AIaaS)
fig3  — ASP violation probability vs offered load (served-and-failed)
fig4  — interruption probability vs user speed (teardown vs MBB)
table1— R1–R10 pass/fail harness driven against the implementation

Each returns (rows, derived) where rows are CSV-ready dicts and ``derived``
captures the paper's qualitative claim check (used by tests + EXPERIMENTS).
"""

from __future__ import annotations

import sys
import time

sys.path.insert(0, "src")

from repro.sim import (LatencyModel, SimConfig, simulate_endpoint,  # noqa: E402
                       simulate_neaiaas, simulate_mobility)

ELL99_MS = 400.0
T_MAX_MS = 1000.0
LOADS = (0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.85, 0.9, 0.95)
SPEEDS = (0, 15, 30, 60, 90, 120)


def fig2_p99_vs_load(n_requests: int = 20_000):
    model = LatencyModel(SimConfig(n_requests=n_requests))
    rows = []
    for rho in LOADS:
        e = simulate_endpoint(rho, model, ell99=ELL99_MS, t_max=T_MAX_MS)
        n = simulate_neaiaas(rho, model, ell99=ELL99_MS, t_max=T_MAX_MS)
        rows.append({"rho": rho, "endpoint_p99_ms": round(e.p99_ms, 1),
                     "neaiaas_p99_ms": round(n.p99_ms, 1),
                     "endpoint_wq_ms": round(e.decomposition["wq"], 1),
                     "neaiaas_wq_ms": round(n.decomposition["wq"], 1)})
    hi = rows[-1]
    derived = {
        "claim": "NE-AIaaS delays tail collapse under load",
        "endpoint_p99_at_0.95": hi["endpoint_p99_ms"],
        "neaiaas_p99_at_0.95": hi["neaiaas_p99_ms"],
        "tail_ratio": round(hi["endpoint_p99_ms"] / hi["neaiaas_p99_ms"], 2),
        "holds": hi["endpoint_p99_ms"] > 1.5 * hi["neaiaas_p99_ms"],
    }
    return rows, derived


def fig3_violation_vs_load(n_requests: int = 20_000):
    model = LatencyModel(SimConfig(n_requests=n_requests))
    rows = []
    for rho in LOADS:
        e = simulate_endpoint(rho, model, ell99=ELL99_MS, t_max=T_MAX_MS)
        n = simulate_neaiaas(rho, model, ell99=ELL99_MS, t_max=T_MAX_MS)
        rows.append({"rho": rho,
                     "endpoint_violation": round(e.violation_prob, 4),
                     "neaiaas_violation": round(n.violation_prob, 4),
                     "neaiaas_admitted_frac": round(n.admitted_frac, 3)})
    hi = rows[-1]
    derived = {
        "claim": "NE-AIaaS keeps served-and-failed violations low at load",
        "endpoint_viol_at_0.95": hi["endpoint_violation"],
        "neaiaas_viol_at_0.95": hi["neaiaas_violation"],
        "holds": (hi["endpoint_violation"] > 0.15
                  and hi["neaiaas_violation"] < 0.05),
    }
    return rows, derived


def fig4_interruption_vs_speed(n_sessions: int = 40):
    rows = []
    for v in SPEEDS:
        t = simulate_mobility(v, "teardown", n_sessions=n_sessions)
        b = simulate_mobility(v, "mbb", n_sessions=n_sessions)
        rows.append({"speed_kmh": v,
                     "teardown_interruption": round(t.interruption_prob, 3),
                     "mbb_interruption": round(b.interruption_prob, 3),
                     "handovers_per_session": round(t.handovers_per_session, 2)})
    hi = rows[-1]
    derived = {
        "claim": "make-before-break keeps interruption ≈ 0 across speeds",
        "teardown_at_120kmh": hi["teardown_interruption"],
        "mbb_at_120kmh": hi["mbb_interruption"],
        "holds": (hi["teardown_interruption"] > 0.5
                  and hi["mbb_interruption"] <= 0.05),
    }
    return rows, derived


def table1_requirements():
    """R1–R10 pass/fail, each exercised against the real implementation."""
    from repro.core import Orchestrator, default_asp, FailureCause, SessionError
    from repro.core.asp import MobilityClass
    from repro.core.clock import VirtualClock
    from repro.core.discovery import discover
    from repro.core.failures import Timers

    rows = []

    def check(req, desc, fn):
        t0 = time.perf_counter()
        try:
            ok = bool(fn())
        except Exception as e:  # a requirement harness must not crash
            ok = False
            desc += f" ({type(e).__name__}: {e})"
        rows.append({"req": req, "passes": ok, "definition": desc,
                     "us": round((time.perf_counter() - t0) * 1e6, 1)})

    clock = VirtualClock()
    orch = Orchestrator(clock=clock)
    asp = default_asp(mobility=MobilityClass.VEHICULAR)

    def r1():
        cands = discover(asp, orch.catalog, orch.sites, orch.predictors,
                         "zone-a", analytics=orch.analytics)
        ranked = [c for c in cands if c.admissible]
        annotated = all(c.prediction is not None for c in ranked)
        constrained = any(not c.admissible and c.exclusion_reason
                          for c in cands)
        return ranked and annotated and constrained
    check("R1", "discoverability: ASP -> ranked admissible (model,site) "
                "with explicit constraints", r1)

    session_box = {}

    def r2():
        s = orch.establish(asp, "ue-r2", "zone-a")
        session_box["s"] = s
        return s.committed()
    check("R2", "policy-consistent admission: joint compute+QoS feasibility",
          r2)

    def r3():
        # exhaust QoS flows and verify compute side rolls back atomically
        from repro.core.qos import QoSFlowManager, PREMIUM
        from repro.core.twophase import TwoPhaseCoordinator
        qos = QoSFlowManager(clock, premium_flows_per_path=0)
        coord = TwoPhaseCoordinator(clock, orch.sites, qos, Timers())
        site = orch.sites["edge-a"]
        before = site.slots_in_use()
        try:
            coord.prepare(orch.catalog.get("edge-tiny"), "edge-a", "zone-a",
                          PREMIUM, slots=1, cache_bytes=1e6)
            return False
        except SessionError as e:
            return (e.cause is FailureCause.QOS_SCARCITY
                    and site.slots_in_use() == before)
    check("R3", "atomic binding: commit both or rollback (no partial "
                "allocation)", r3)

    def r4():
        s = session_box["s"]
        return s.binding.qfi > 0 and s.binding.steering_handle
    check("R4", "enforceable transport granularity: objectives bound at "
                "QFI granularity", r4)

    def r5():
        s = session_box["s"]
        for _ in range(12):
            orch.serve(s, prompt_tokens=128, gen_tokens=16)
        rep = orch.compliance(s)
        return rep is not None and rep.z.n >= 12
    check("R5", "compute-aware QoS: execution-side terms measured via "
                "boundary telemetry", r5)

    def r6():
        s = session_box["s"]
        out = orch.migrations.migrate(s, "zone-a")
        return out.migrated and out.interruption_ms == 0.0 and s.committed()
    check("R6", "mobility continuity: bounded interruption via "
                "make-before-break", r6)

    def r7():
        s = orch.establish(asp, "ue-r7", "zone-a")
        orch.policy.revoke(s.authz_ref)
        try:
            orch.serve(s)
            return False
        except SessionError as e:
            return e.cause is FailureCause.CONSENT_VIOLATION
    check("R7", "consent binding: revocation => ServeDisabled (Eq. 6)", r7)

    def r8():
        s = session_box["s"]
        rec = orch.policy.charging(s.charging_ref)
        return rec.session_id == s.session_id and rec.tokens > 0
    check("R8", "session accounting: usage attributable to the AIS", r8)

    def r9():
        # the paper's 9 Eq. (12) classes, plus the transport-layer
        # extensions (TRANSPORT_FAILURE, DEADLINE_EXCEEDED) the
        # unreliable-control-plane work added — every member classified,
        # every remediation distinct
        from repro.core.failures import REMEDIATION
        paper_nine = {
            "consent violation", "policy denial", "sovereignty violation",
            "model unavailable", "no feasible binding", "compute scarcity",
            "QoS scarcity", "state transfer failure", "deadline expiry"}
        causes = {c.value for c in FailureCause}
        distinct = len({v for v in REMEDIATION.values()}) == len(REMEDIATION)
        return (paper_nine <= causes and len(REMEDIATION) == len(causes)
                and distinct)
    check("R9", "diagnosable failures: the 9 Eq. (12) cause classes (+ "
                "transport extensions) with distinct remediations", r9)

    def r10():
        # composition only: CAPIF/MEC/QoS/NWDAF roles exist as separate
        # modules with no monolithic coupling (import-level check)
        import repro.core.analytics, repro.core.qos  # noqa: F401
        import repro.core.sites, repro.core.catalog  # noqa: F401
        return True
    check("R10", "minimal new primitives: composition of exposure/edge/QoS/"
                 "analytics planes", r10)

    derived = {"claim": "all ten NE-AIaaS requirements pass",
               "passes": sum(1 for r in rows if r["passes"]),
               "holds": all(r["passes"] for r in rows)}
    return rows, derived
