"""Pure-jnp oracle for flash-decode."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k, v, lengths):
    """q: [B, Hq, D]; k/v: [B, Hkv, S, D]; lengths: [B]."""
    B, Hq, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    g = Hq // Hkv
    kk = jnp.repeat(k, g, axis=1).astype(jnp.float32)
    vv = jnp.repeat(v, g, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), kk) / (D ** 0.5)
    mask = jnp.arange(S)[None, None, :] < lengths[:, None, None]
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", w, vv).astype(q.dtype)
