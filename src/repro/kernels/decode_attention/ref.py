"""Pure-jnp oracle for flash-decode."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k, v, lengths):
    """q: [B, Hq, D]; k/v: [B, Hkv, S, D]; lengths: [B]."""
    B, Hq, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    g = Hq // Hkv
    kk = jnp.repeat(k, g, axis=1).astype(jnp.float32)
    vv = jnp.repeat(v, g, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), kk) / (D ** 0.5)
    mask = jnp.arange(S)[None, None, :] < lengths[:, None, None]
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", w, vv).astype(q.dtype)


def paged_decode_attention_ref(q, k_pages, v_pages, lengths, block_tables):
    """Oracle for the paged kernel: gather each sequence's pages into the
    linear [B, Hkv, S, D] view, then the dense reference above.

    q: [B, Hq, D]; k/v_pages: [P, page, Hkv, D]; block_tables: [B, PPS].
    """
    B = q.shape[0]
    page, Hkv, D = k_pages.shape[1], k_pages.shape[2], k_pages.shape[3]
    PPS = block_tables.shape[1]
    k = k_pages[block_tables].reshape(B, PPS * page, Hkv, D)
    v = v_pages[block_tables].reshape(B, PPS * page, Hkv, D)
    return decode_attention_ref(q, jnp.moveaxis(k, 1, 2),
                                jnp.moveaxis(v, 1, 2), lengths)
