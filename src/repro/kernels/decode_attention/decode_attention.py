"""Flash-decode GQA attention — Pallas TPU kernel for the serving hot path.

One new token per sequence against a long KV cache: the workload is
memory-bound (read the whole cache once), so the kernel's job is to stream
KV through VMEM at full HBM bandwidth. Grid = (batch, kv-head, kv-block)
with the kv-block dim innermost/sequential; the online-softmax state for all
``g`` grouped q-heads of this kv-head rides VMEM scratch. The [g, D] query
tile stays resident; each step issues a [g, D] × [D, block_kv] MXU matmul —
for GQA g = 4–8 this also amortises each KV byte over g queries (the reason
GQA exists).

Per-row ``lengths`` masks ragged sessions (continuous batching: every slot
sits at a different position).

Layouts: q [B, Hq, D]; k/v [B, Hkv, S, D]; lengths [B] -> out [B, Hq, D].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, block_kv: int):
    b = pl.program_id(0)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    kv_start = ik * block_kv

    @pl.when(kv_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # [g, d]  (padded g)
        k = k_ref[0, 0].astype(jnp.float32)           # [bk, d]
        v = v_ref[0, 0]                                # [bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        k_pos = kv_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)      # [g, bk]
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q, k, v, lengths, *, block_kv: int = 512,
                     interpret: bool = True):
    """q: [B, Hq, D]; k/v: [B, Hkv, S, D]; lengths: [B] -> [B, Hq, D]."""
    B, Hq, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    g = Hq // Hkv
    scale = 1.0 / (D ** 0.5)

    pad_k = (-S) % block_kv
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nk = k.shape[2] // block_kv
    # group q by kv head: [B, Hkv, g, D]
    qg = q.reshape(B, Hkv, g, D)
    grid = (B, Hkv, nk)

    kern = functools.partial(_kernel, scale=scale, block_kv=block_kv)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # lengths, scalar-prefetch
            pl.BlockSpec((1, 1, g, D), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, D), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, D), lambda b, h, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, D), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, D), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k, v)
    return out.reshape(B, Hq, D)


# ---------------------------------------------------------------------------
# paged flash-decode: gather K/V through a block table
# ---------------------------------------------------------------------------
#
# Same online-softmax core as the dense kernel above, but K/V live in a
# global page pool shared by every sequence ([P, page, Hkv, D]) and each
# sequence owns a block table of page ids. The table rides scalar prefetch
# (PrefetchScalarGridSpec): the kv-block index maps read ``tbl[b, ip]`` to
# pick which POOL page each grid step streams into VMEM — the gather happens
# in the DMA engine's addressing, so the [B, S] linear view the pure-XLA
# fallback materialises never exists.


def _paged_kernel(len_ref, tbl_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, scale: float, page: int):
    del tbl_ref                       # consumed by the index maps
    b = pl.program_id(0)
    ip = pl.program_id(2)
    npg = pl.num_programs(2)

    @pl.when(ip == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    kv_start = ip * page

    @pl.when(kv_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # [g, d]
        k = k_ref[0, :, 0].astype(jnp.float32)        # [page, d]
        v = v_ref[0, :, 0]                            # [page, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        k_pos = kv_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)      # [g, page]
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ip == npg - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def paged_decode_attention(q, k_pages, v_pages, lengths, block_tables, *,
                           interpret: bool = True):
    """q: [B, Hq, D]; k/v_pages: [P, page, Hkv, D]; lengths: [B];
    block_tables: [B, PPS] int32 page ids -> out [B, Hq, D].

    The kv-block grid dim is the block-table column: grid step (b, h, ip)
    streams pool page ``block_tables[b, ip]``. Pages past a sequence's
    length are still DMA'd (whatever the stale table entry points at) but
    their compute is skipped by the ``kv_start < length`` gate, so garbage
    and scratch pages never touch the softmax state.
    """
    B, Hq, D = q.shape
    page, Hkv = k_pages.shape[1], k_pages.shape[2]
    PPS = block_tables.shape[1]
    g = Hq // Hkv
    scale = 1.0 / (D ** 0.5)

    qg = q.reshape(B, Hkv, g, D)
    grid = (B, Hkv, PPS)
    kern = functools.partial(_paged_kernel, scale=scale, page=page)

    def kv_map(b, h, ip, lens, tbl):
        del lens
        return (tbl[b, ip], 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                       # lengths, block table
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, D),
                         lambda b, h, ip, lens, tbl: (b, h, 0, 0)),
            pl.BlockSpec((1, page, 1, D), kv_map),
            pl.BlockSpec((1, page, 1, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, D),
                               lambda b, h, ip, lens, tbl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g, D), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), block_tables.astype(jnp.int32),
      qg, k_pages, v_pages)
    return out.reshape(B, Hq, D)
