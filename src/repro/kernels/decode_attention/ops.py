"""Jit'd public wrapper for flash-decode."""

from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention.decode_attention import (
    decode_attention, paged_decode_attention)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_kv",))
def decode(q, k, v, lengths, *, block_kv: int = 512):
    return decode_attention(q, k, v, lengths, block_kv=block_kv,
                            interpret=not _on_tpu())


@jax.jit
def paged_decode(q, k_pages, v_pages, lengths, block_tables):
    return paged_decode_attention(q, k_pages, v_pages, lengths, block_tables,
                                  interpret=not _on_tpu())
