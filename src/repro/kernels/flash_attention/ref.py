"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax.numpy as jnp
import jax

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True):
    """q: [B, Hq, Sq, D]; k/v: [B, Hkv, Skv, D] (f32 math throughout)."""
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / (D ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Skv), bool), k=Skv - Sq)
        s = jnp.where(mask[None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", w, vv.astype(jnp.float32))
    return o.astype(q.dtype)
