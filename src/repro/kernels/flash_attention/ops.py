"""Jit'd public wrapper for the flash attention kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv"))
def attention(q, k, v, *, causal: bool = True, block_q: int = 128,
              block_kv: int = 128):
    """Flash attention: compiled kernel on TPU, interpreted elsewhere."""
    return flash_attention(q, k, v, causal=causal, block_q=block_q,
                           block_kv=block_kv, interpret=not _on_tpu())
