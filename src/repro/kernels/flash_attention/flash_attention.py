"""Causal GQA flash attention — Pallas TPU kernel.

TPU adaptation (not a CUDA port): the grid walks (batch, q-head, q-block,
kv-block) with the kv-block dimension innermost and *sequential*, so the
online-softmax state (m, l, acc) lives in VMEM scratch across kv steps and
the MXU sees [block_q, head_dim] × [head_dim, block_kv] tiles (block sizes
multiples of 128 to match the 128×128 systolic array; head_dim is the lane
dimension). Causal block skipping: tiles strictly above the diagonal are
skipped with ``pl.when`` — the FLOPs halving that the pure-jnp blocked path
(repro.models.attention) cannot express.

Layouts: q [B, Hq, Sq, D]; k/v [B, Hkv, Skv, D]; out [B, Hq, Sq, D].
GQA: q-head h reads kv-head h // (Hq // Hkv).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, block_q: int, block_kv: int,
            seq_kv: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    kv_start = ik * block_kv
    # causal skip: the whole tile is masked iff kv_start > q_end
    live = (not causal) or (kv_start <= q_start + block_q - 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)          # [bk, d]
        v = v_ref[0, 0]                               # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [bq, bk]
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = kv_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < seq_kv
        if causal:
            mask = mask & (k_pos <= q_pos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_kv: int = 128, interpret: bool = True):
    """q: [B, Hq, Sq, D]; k/v: [B, Hkv, Skv, D] -> [B, Hq, Sq, D]."""
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    scale = 1.0 / (D ** 0.5)

    pad_q = (-Sq) % block_q
    pad_k = (-Skv) % block_kv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = q.shape[2] // block_q
    nk = k.shape[2] // block_kv
    grid = (B, Hq, nq, nk)

    kern = functools.partial(_kernel, scale=scale, causal=causal,
                             block_q=block_q, block_kv=block_kv, seq_kv=Skv)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, iq, ik, g=g: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, iq, ik, g=g: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]
