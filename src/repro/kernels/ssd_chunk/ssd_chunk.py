"""Mamba-2 SSD chunked scan — Pallas TPU kernel.

Grid = (batch, head, chunk) with the chunk dim innermost/sequential; the SSM
state S ∈ [headdim, dstate] rides VMEM scratch between chunks. Each chunk
computes the intra-chunk dual (quadratic) term on the MXU — [Q, n]·[n, Q]
score tile, decay-masked, then [Q, Q]·[Q, hp] — plus the inter-chunk
contribution C·S and the state update, i.e. the standard SSD decomposition
(arXiv:2405.21060 §6) with the inter-chunk recurrence folded into the grid
instead of a host-side scan.

Layouts: x [B, H, T, P]; dt [B, H, T]; B/C [B, H, T, N] (already expanded to
heads); A [H] -> y [B, H, T, P], with chunk length Q = block size.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, s_ref, *, Q: int):
    ih = pl.program_id(1)
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0, 0].astype(jnp.float32)        # [Q, P]
    dt = dt_ref[0, 0].astype(jnp.float32)      # [Q]
    Bm = b_ref[0, 0].astype(jnp.float32)       # [Q, N]
    Cm = c_ref[0, 0].astype(jnp.float32)       # [Q, N]
    A = a_ref[ih]                               # scalar (negative)

    dA = dt * A                                 # [Q]
    cum = jnp.cumsum(dA)                        # [Q]
    xdt = x * dt[:, None]                       # [Q, P]

    # intra-chunk dual form: L[i,j] = exp(cum_i - cum_j) for i >= j
    seg = cum[:, None] - cum[None, :]
    causal = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    Ldec = jnp.where(causal, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [Q,Q]
    y = jax.lax.dot_general(scores * Ldec, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # [Q,P]

    # inter-chunk: y += (C ⊙ decay_in) @ S^T   (S: [P, N])
    decay_in = jnp.exp(cum)[:, None]            # [Q, 1]
    y = y + jax.lax.dot_general(Cm * decay_in, s_ref[...],
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # state update: S = exp(sum dA) S + (B ⊙ decay_out ⊙ dt x)^T-style outer
    decay_out = jnp.exp(cum[-1] - cum)[:, None]  # [Q, 1]
    s_new = jnp.exp(cum[-1]) * s_ref[...] + jax.lax.dot_general(
        xdt, Bm * decay_out, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)       # [P, N]
    s_ref[...] = s_new
    y_ref[0, 0] = y.astype(y_ref.dtype)


def ssd_chunk(x, dt, B, C, A, *, chunk: int = 128, interpret: bool = True):
    """x: [Bt, H, T, P]; dt: [Bt, H, T]; B/C: [Bt, H, T, N]; A: [H] -> y."""
    Bt, H, T, P = x.shape
    N = B.shape[-1]
    pad = (-T) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, pad)))
        B = jnp.pad(B, ((0, 0), (0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nc = x.shape[2] // chunk
    grid = (Bt, H, nc)

    out = pl.pallas_call(
        functools.partial(_kernel, Q=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),   # A: [H] scalars
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, B, C, A.astype(jnp.float32))
    return out[:, :, :T]
