"""Pure-jnp oracle for the SSD chunk kernel: exact sequential recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, B, C, A):
    """Sequential SSM: S_t = exp(dt_t A) S_{t-1} + dt_t B_t x_t^T;
    y_t = C_t · S_t. x: [Bt, H, T, P]; dt: [Bt, H, T]; B/C: [Bt, H, T, N]."""
    Bt, H, T, P = x.shape
    N = B.shape[-1]

    def step(S, inp):
        xt, dtt, Bt_, Ct = inp          # [b,h,P], [b,h], [b,h,N], [b,h,N]
        dA = jnp.exp(dtt * A)           # [b,h]
        S = S * dA[..., None, None] + jnp.einsum(
            "bhp,bhn->bhpn", xt * dtt[..., None], Bt_)
        y = jnp.einsum("bhpn,bhn->bhp", S, Ct)
        return S, y

    S0 = jnp.zeros((Bt, H, P, N), jnp.float32)
    xs = (jnp.moveaxis(x.astype(jnp.float32), 2, 0),
          jnp.moveaxis(dt.astype(jnp.float32), 2, 0),
          jnp.moveaxis(B.astype(jnp.float32), 2, 0),
          jnp.moveaxis(C.astype(jnp.float32), 2, 0))
    _, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 2).astype(x.dtype)   # [Bt, H, T, P]
