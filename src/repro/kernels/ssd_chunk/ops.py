"""Jit'd public wrapper for the SSD chunk kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.ssd_chunk.ssd_chunk import ssd_chunk


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd(x, dt, B, C, A, *, chunk: int = 128):
    return ssd_chunk(x, dt, B, C, A, chunk=chunk,
                     interpret=not _on_tpu())
