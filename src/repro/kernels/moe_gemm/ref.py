"""Pure-jnp oracle for the grouped expert GEMM."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def moe_gemm_ref(x, w):
    return jnp.einsum("ecd,edf->ecf", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def moe_ffn_fused_ref(x, w_gate, w_up):
    gate = jnp.einsum("ecd,edf->ecf", x, w_gate,
                      preferred_element_type=jnp.float32)
    up = jnp.einsum("ecd,edf->ecf", x, w_up,
                    preferred_element_type=jnp.float32)
    return (jax.nn.silu(gate) * up).astype(x.dtype)
