"""Grouped expert GEMM (+ fused SwiGLU) — Pallas TPU kernel.

Computes per-expert matmuls over capacity buffers:

    y[e] = silu(x[e] @ w_gate[e]) * (x[e] @ w_up[e])       (fused variant)
    y[e] = x[e] @ w[e]                                      (plain variant)

TPU adaptation of the MegaBlocks idea: instead of CUDA block-sparse tiles,
experts are a leading grid dimension and each (expert, C-tile, F-tile) cell
is a dense [block_c, d] × [d, block_f] MXU matmul — expert weights stream
through VMEM once per C-tile sweep. Capacity buffers make shapes static
(GShard-style), which is what the TPU wants; token routing stays outside
(repro.models.moe builds the buffers).

Layouts: x [E, C, D]; w_gate/w_up [E, D, F] -> y [E, C, F].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel_fused(x_ref, wg_ref, wu_ref, y_ref):
    x = x_ref[0]                      # [bc, D]
    wg = wg_ref[0]                    # [D, bf]
    wu = wu_ref[0]
    gate = jax.lax.dot_general(x, wg, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    up = jax.lax.dot_general(x, wu, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y_ref[0] = (jax.nn.silu(gate) * up).astype(y_ref.dtype)


def _kernel_plain(x_ref, w_ref, y_ref):
    x = x_ref[0]
    w = w_ref[0]
    y_ref[0] = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(y_ref.dtype)


def _pad(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def moe_gemm(x, w, *, block_c: int = 128, block_f: int = 256,
             interpret: bool = True):
    """Plain grouped GEMM: x [E, C, D] @ w [E, D, F] -> [E, C, F]."""
    E, C, D = x.shape
    F = w.shape[-1]
    x = _pad(x, 1, block_c)
    w = _pad(w, 2, block_f)
    nc = x.shape[1] // block_c
    nf = w.shape[2] // block_f
    out = pl.pallas_call(
        _kernel_plain,
        grid=(E, nc, nf),
        in_specs=[
            pl.BlockSpec((1, block_c, D), lambda e, ic, jf: (e, ic, 0)),
            pl.BlockSpec((1, D, block_f), lambda e, ic, jf: (e, 0, jf)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, ic, jf: (e, ic, jf)),
        out_shape=jax.ShapeDtypeStruct((E, x.shape[1], w.shape[2]), x.dtype),
        interpret=interpret,
    )(x, w)
    return out[:, :C, :F]


def moe_ffn_fused(x, w_gate, w_up, *, block_c: int = 128, block_f: int = 256,
                  interpret: bool = True):
    """Fused silu(x@wg) * (x@wu): x [E, C, D]; w_* [E, D, F] -> [E, C, F]."""
    E, C, D = x.shape
    F = w_gate.shape[-1]
    x = _pad(x, 1, block_c)
    w_gate = _pad(w_gate, 2, block_f)
    w_up = _pad(w_up, 2, block_f)
    nc = x.shape[1] // block_c
    nf = w_gate.shape[2] // block_f
    out = pl.pallas_call(
        _kernel_fused,
        grid=(E, nc, nf),
        in_specs=[
            pl.BlockSpec((1, block_c, D), lambda e, ic, jf: (e, ic, 0)),
            pl.BlockSpec((1, D, block_f), lambda e, ic, jf: (e, 0, jf)),
            pl.BlockSpec((1, D, block_f), lambda e, ic, jf: (e, 0, jf)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, ic, jf: (e, ic, jf)),
        out_shape=jax.ShapeDtypeStruct((E, x.shape[1], w_gate.shape[2]),
                                       x.dtype),
        interpret=interpret,
    )(x, w_gate, w_up)
    return out[:, :C, :F]
