"""Jit'd public wrappers for the grouped expert GEMM kernels."""

from __future__ import annotations

import functools

import jax

from repro.kernels.moe_gemm.moe_gemm import moe_gemm, moe_ffn_fused


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_c", "block_f"))
def grouped_gemm(x, w, *, block_c: int = 128, block_f: int = 256):
    return moe_gemm(x, w, block_c=block_c, block_f=block_f,
                    interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("block_c", "block_f"))
def grouped_swiglu(x, w_gate, w_up, *, block_c: int = 128,
                   block_f: int = 256):
    return moe_ffn_fused(x, w_gate, w_up, block_c=block_c, block_f=block_f,
                         interpret=not _on_tpu())
