"""RG-LRU linear recurrence — Pallas TPU kernel.

h_t = a_t ⊙ h_{t−1} + b_t, elementwise over ``width``. TPU adaptation: the
recurrence is bandwidth-bound (read a, b; write h — zero matmuls), so the
kernel tiles [block_t, block_w] VMEM panels with the time dim outermost-
sequential and carries h in VMEM scratch; within a tile the time loop is a
``fori_loop`` over vector rows (the VPU does the elementwise work; no MXU).
Width is the 128-lane dimension — block_w a multiple of 128.

Layout: a, b [B, T, W] -> h [B, T, W] (all f32; the model keeps LRU state
in f32 for recurrence stability).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h_ref, carry_ref, *, block_t: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    a = a_ref[0]          # [bt, bw]
    b = b_ref[0]

    def body(t, h):
        h = a[t] * h + b[t]
        h_ref[0, t, :] = h
        return h

    carry_ref[...] = jax.lax.fori_loop(0, block_t, body, carry_ref[...])


def rglru_scan(a, b, *, block_t: int = 128, block_w: int = 256,
               interpret: bool = True):
    """a, b: [B, T, W] f32 -> h: [B, T, W] f32."""
    B, T, W = a.shape
    pad_t = (-T) % block_t
    pad_w = (-W) % block_w
    if pad_t or pad_w:
        # pad a with 1 (identity for the decay) only where b is 0-padded on
        # time; width padding is sliced away afterwards
        a = jnp.pad(a, ((0, 0), (0, pad_t), (0, pad_w)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad_t), (0, pad_w)))
    nt = a.shape[1] // block_t
    nw = a.shape[2] // block_w
    grid = (B, nw, nt)       # time innermost => sequential carry

    out = pl.pallas_call(
        functools.partial(_kernel, block_t=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_w),
                         lambda ib, iw, it: (ib, it, iw)),
            pl.BlockSpec((1, block_t, block_w),
                         lambda ib, iw, it: (ib, it, iw)),
        ],
        out_specs=pl.BlockSpec((1, block_t, block_w),
                               lambda ib, iw, it: (ib, it, iw)),
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32))
    return out[:, :T, :W]
