"""Pure-jnp oracle for the RG-LRU scan kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a, b):
    """Sequential h_t = a_t h_{t-1} + b_t. a, b: [B, T, W]."""
    def step(h, ab):
        ai, bi = ab
        h = ai * h + bi
        return h, h

    h0 = jnp.zeros((a.shape[0], a.shape[2]), jnp.float32)
    _, hs = jax.lax.scan(step, h0,
                         (jnp.moveaxis(a.astype(jnp.float32), 1, 0),
                          jnp.moveaxis(b.astype(jnp.float32), 1, 0)))
    return jnp.moveaxis(hs, 0, 1)
