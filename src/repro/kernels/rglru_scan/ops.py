"""Jit'd public wrapper for the RG-LRU scan kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.rglru_scan.rglru_scan import rglru_scan


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_t", "block_w"))
def scan(a, b, *, block_t: int = 128, block_w: int = 256):
    return rglru_scan(a, b, block_t=block_t, block_w=block_w,
                      interpret=not _on_tpu())
