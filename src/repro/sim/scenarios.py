"""§V scenarios: endpoint AIaaS baseline vs NE-AIaaS (Figs. 2 and 3).

* **Endpoint baseline** — fixed cloud endpoint over best-effort transport;
  ALL requests are accepted and accumulate in the server queue; violation
  probability is computed over all requests (queueing is part of the
  user-perceived service).
* **NE-AIaaS** — session-oriented: an atomic PREPARE/COMMIT across compute
  slots and QoS flows (the REAL TwoPhaseCoordinator, not a re-implementation)
  admits sessions up to the site's slot capacity; only admitted sessions are
  served, over QoS-provisioned transport, and the violation probability is
  "served-and-failed" over admitted sessions (Eq. 16 semantics).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.sim.latency import LatencyModel, SimConfig


@dataclass
class LoadPointResult:
    rho: float
    p50_ms: float
    p95_ms: float
    p99_ms: float
    violation_prob: float
    admitted_frac: float = 1.0
    decomposition: dict = field(default_factory=dict)   # mean Wq / infer / net


def _eval(latency: np.ndarray, ell99: float, t_max: float) -> float:
    """Eq. (16): violation ⟺ (L > ℓ99) ∨ (L > T_max)."""
    return float(np.mean((latency > ell99) | (latency > t_max)))


def simulate_endpoint(rho: float, model: LatencyModel, *, ell99: float,
                      t_max: float, seed: int = 0) -> LoadPointResult:
    rng = np.random.default_rng(seed * 7919 + int(rho * 1000))
    n = model.cfg.n_requests
    infer = model.infer_times(rng, n)
    wq = model.queue_wait(rng, n, rho, infer)
    net = model.transport_best_effort(rng, n)
    lat = wq + infer + net
    return LoadPointResult(
        rho=rho,
        p50_ms=float(np.quantile(lat, 0.5)),
        p95_ms=float(np.quantile(lat, 0.95)),
        p99_ms=float(np.quantile(lat, 0.99)),
        violation_prob=_eval(lat, ell99, t_max),
        admitted_frac=1.0,
        decomposition={"wq": float(wq.mean()), "infer": float(infer.mean()),
                       "net": float(net.mean())})


def _admitted_fraction_via_2pc(rho: float, *, slots: int = 64,
                               target_util: float = 0.75,
                               seed: int = 0) -> float:
    """Run the real PREPARE/COMMIT machinery at session granularity.

    Sessions arrive at a rate proportional to ρ; each holds a decode slot
    for its lifetime. Admission succeeds while the site has free slots —
    compute and QoS leases are co-reserved atomically; the admitted
    fraction is what caps the *served* load at ~target_util.
    """
    from repro.core.catalog import default_catalog
    from repro.core.clock import VirtualClock
    from repro.core.failures import SessionError, Timers
    from repro.core.qos import QoSFlowManager, PREMIUM
    from repro.core.sites import default_sites
    from repro.core.twophase import TwoPhaseCoordinator

    clock = VirtualClock()
    catalog = default_catalog()
    model = catalog.get("edge-tiny")
    sites = default_sites(clock, tuple(catalog._entries.keys()))
    site = sites["edge-a"]
    site.spec = type(site.spec)(**{**site.spec.__dict__,
                                   "decode_slots": slots})
    qos = QoSFlowManager(clock, premium_flows_per_path=slots)
    timers = Timers(lease_s=1e9)
    coord = TwoPhaseCoordinator(clock, sites, qos, timers)

    rng = np.random.default_rng(seed + 17)
    # offered sessions per unit time scales with ρ; capacity admits up to
    # target_util × slots concurrently (service time 1.0 each)
    n_sessions = 400
    arrivals = np.cumsum(rng.exponential(
        1.0 / max(rho * slots * target_util * 1.35, 1e-6), size=n_sessions))
    hold = rng.exponential(1.0, size=n_sessions)
    active = []  # (end_time, prepared)
    admitted = 0
    for t, h in zip(arrivals, hold):
        clock.advance(max(0.0, t - clock.now()))
        for end, prep in [a for a in active if a[0] <= clock.now()]:
            coord.sites[prep.site_id].release(prep.compute_lease_id)
            coord.qos.release(prep.qos_lease_id)
            active.remove((end, prep))
        # cap utilisation headroom: admission refuses past target_util
        if site.slots_in_use() >= int(slots * target_util):
            continue
        try:
            prep = coord.prepare(model, "edge-a", "zone-a", PREMIUM,
                                 slots=1, cache_bytes=1e6)
            coord.commit(prep, model)
            admitted += 1
            active.append((clock.now() + h, prep))
        except SessionError:
            continue
    return admitted / n_sessions


def simulate_neaiaas(rho: float, model: LatencyModel, *, ell99: float,
                     t_max: float, target_util: float = 0.75,
                     seed: int = 0) -> LoadPointResult:
    rng = np.random.default_rng(seed * 104729 + int(rho * 1000))
    n = model.cfg.n_requests
    admitted_frac = min(1.0, _admitted_fraction_via_2pc(
        rho, target_util=target_util, seed=seed) if rho > target_util else 1.0)
    # served load is capped by admission: queue operates at min(ρ, ρ*)
    rho_served = min(rho, target_util)
    infer = model.infer_times(rng, n)
    wq = model.queue_wait(rng, n, rho_served, infer)
    net = model.transport_qos(rng, n)
    lat = wq + infer + net
    return LoadPointResult(
        rho=rho,
        p50_ms=float(np.quantile(lat, 0.5)),
        p95_ms=float(np.quantile(lat, 0.95)),
        p99_ms=float(np.quantile(lat, 0.99)),
        violation_prob=_eval(lat, ell99, t_max),   # served-and-failed
        admitted_frac=admitted_frac,
        decomposition={"wq": float(wq.mean()), "infer": float(infer.mean()),
                       "net": float(net.mean())})
