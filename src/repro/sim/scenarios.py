"""§V scenarios: endpoint AIaaS baseline vs NE-AIaaS (Figs. 2 and 3), plus
the serving-plane workloads the unified scheduler unlocks (multi-class
mixes, bursty arrivals, load + mobility at 10k+ concurrent sessions).

* **Endpoint baseline** — fixed cloud endpoint over best-effort transport;
  ALL requests are accepted and accumulate in the server queue (Lindley
  recursion); violation probability is computed over all requests (queueing
  is part of the user-perceived service).
* **NE-AIaaS** — session-oriented AND network-exposed: the arm establishes
  its session through the :class:`~repro.api.gateway.NorthboundGateway`
  (DISCOVER → PAGE → PREPARE/COMMIT wire messages) and submits every
  request northbound, so the queueing machinery it measures is the REAL
  :class:`~repro.serving.plane.ServingPlane` + ``QoSScheduler`` under a
  ``VirtualClock`` — slot admission with a bounded queue rejects offered
  load past the committed capacity (the 2PC admission cap at session
  granularity; a rejected ``SubmitAck`` IS the loss event), admitted
  requests occupy decode slots for a service time sampled from
  ``LatencyModel`` (its ONLY remaining role on this arm), heartbeats renew
  the leases across the run, and transport rides the QoS-provisioned
  class. Violation probability is "served-and-failed" over admitted
  requests (Eq. 16 semantics). There is no parallel closed-form queue
  model on this arm.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.clock import VirtualClock
from repro.serving.plane import ServingPlane, SimulatedEngine
from repro.sim.latency import LatencyModel, SimConfig


@dataclass
class LoadPointResult:
    rho: float
    p50_ms: float
    p95_ms: float
    p99_ms: float
    violation_prob: float
    admitted_frac: float = 1.0
    decomposition: dict = field(default_factory=dict)   # mean Wq / infer / net


def _eval(latency: np.ndarray, ell99: float, t_max: float) -> float:
    """Eq. (16): violation ⟺ (L > ℓ99) ∨ (L > T_max)."""
    return float(np.mean((latency > ell99) | (latency > t_max)))


def simulate_endpoint(rho: float, model: LatencyModel, *, ell99: float,
                      t_max: float, seed: int = 0) -> LoadPointResult:
    rng = np.random.default_rng(seed * 7919 + int(rho * 1000))
    n = model.cfg.n_requests
    infer = model.infer_times(rng, n)
    wq = model.queue_wait(rng, n, rho, infer)
    net = model.transport_best_effort(rng, n)
    lat = wq + infer + net
    return LoadPointResult(
        rho=rho,
        p50_ms=float(np.quantile(lat, 0.5)),
        p95_ms=float(np.quantile(lat, 0.95)),
        p99_ms=float(np.quantile(lat, 0.99)),
        violation_prob=_eval(lat, ell99, t_max),
        admitted_frac=1.0,
        decomposition={"wq": float(wq.mean()), "infer": float(infer.mean()),
                       "net": float(net.mean())})


# ----------------------------------------------------------------------
# gateway-driven NE-AIaaS arm
# ----------------------------------------------------------------------
def _drive_plane(plane: ServingPlane, clock: VirtualClock,
                 arrivals_s: np.ndarray, submit_kwargs) -> None:
    """Feed a Poisson-arrival open loop through the plane under virtual
    time: completions interleave with arrivals event-by-event."""
    for i, t in enumerate(arrivals_s):
        plane.run_until(float(t))
        plane.submit(**submit_kwargs(i))
    plane.drain()


def _neaiaas_gateway(clock: VirtualClock, cap: int, sampler, t_max: float):
    """One committed-capacity execution site fronted by the northbound
    gateway: the bounded-queue plane (the 2PC admission point) is attached
    to the site BEFORE establishment, so the session's serve path runs the
    exact scheduler the Monte-Carlo measures."""
    import dataclasses as _dc

    from repro.api.client import SessionClient
    from repro.api.gateway import NorthboundGateway
    from repro.core import Orchestrator, default_asp
    from repro.core.asp import QualityTier
    from repro.core.catalog import Catalog, default_catalog
    from repro.core.failures import Timers
    from repro.core.sites import ExecutionSite, SiteSpec

    cat = Catalog()
    cat.register(default_catalog().get("edge-tiny"))
    spec = SiteSpec("neaiaas", "edge", "eu", chips=16,
                    hbm_bytes_total=16 * 16e9, peak_flops=16 * 197e12,
                    hbm_bw=16 * 819e9, decode_slots=cap,
                    rtt_ms={"zone-a": 2.0},
                    hosted_models=("edge-tiny@1.0",),
                    price_per_chip_s=2.0e-4)
    sites = {"neaiaas": ExecutionSite(spec, clock)}
    t_max_s = t_max / 1e3
    orch = Orchestrator(clock=clock, catalog=cat, sites=sites,
                        timers=Timers(tau_mig=min(2.0, 0.9 * t_max_s)))
    plane = ServingPlane(
        clock, SimulatedEngine(clock, service_sampler=sampler),
        slots=cap, premium_reserved_frac=0.0, max_queue=0,
        site_id="neaiaas")
    sites["neaiaas"].attach_plane(plane)
    gw = NorthboundGateway(orch)
    # BASIC tier admits the edge-tiny entry; with zero premium reservation
    # and a single class the admission order is class-independent
    asp = default_asp(tier=QualityTier.BASIC)
    asp = _dc.replace(asp, objectives=_dc.replace(
        asp.objectives, ttfb_ms=0.3 * t_max, p95_ms=0.6 * t_max,
        p99_ms=0.9 * t_max, t_max_ms=t_max, nu_min=0.0))
    client = SessionClient(gw, asp, invoker="asp-0", zone="zone-a",
                           subscribe_events=False).establish()
    return gw, client


def simulate_neaiaas(rho: float, model: LatencyModel, *, ell99: float,
                     t_max: float, target_util: float = 0.75,
                     seed: int = 0, slots: int = 64) -> LoadPointResult:
    rng = np.random.default_rng(seed * 104729 + int(rho * 1000))
    n = model.cfg.n_requests
    clock = VirtualClock()

    # committed capacity: PREPARE/COMMIT admits sessions only up to
    # target_util × slots concurrent decode slots; the plane's scheduler IS
    # that admission point (bounded queue ⇒ loss past the committed share)
    cap = max(1, int(slots * target_util))
    infer = model.infer_times(rng, n)            # service-time sampler only
    idx = {"i": 0}

    def sampler(req):
        i = idx["i"]
        idx["i"] += 1
        return 0.0, float(infer[i % n])

    gw, client = _neaiaas_gateway(clock, cap, sampler, t_max)

    # offered load ρ is measured against the site's FULL slot capacity, the
    # same normalisation as the endpoint arm
    lam_per_ms = rho * slots / float(infer.mean())
    arrivals_s = np.cumsum(rng.exponential(1.0 / lam_per_ms, size=n)) / 1e3
    for t in arrivals_s:
        gw.pump(float(t))
        # the SDK's auto-renew keeps both leases valid across the span
        client.submit(prompt_tokens=128, gen_tokens=16)
    completions = gw.drain()

    results = [r for r in completions if r.error_code is None]
    admitted = len(results)
    if admitted == 0:
        return LoadPointResult(rho, 0.0, 0.0, 0.0, 1.0, 0.0)
    wq = np.array([r.queue_wait_ms for r in results])
    svc = np.array([r.latency_ms - r.queue_wait_ms for r in results])
    net = model.transport_qos(rng, admitted)
    lat = wq + svc + net
    return LoadPointResult(
        rho=rho,
        p50_ms=float(np.quantile(lat, 0.5)),
        p95_ms=float(np.quantile(lat, 0.95)),
        p99_ms=float(np.quantile(lat, 0.99)),
        violation_prob=_eval(lat, ell99, t_max),   # served-and-failed
        admitted_frac=admitted / n,
        decomposition={"wq": float(wq.mean()), "infer": float(svc.mean()),
                       "net": float(net.mean())})


# ----------------------------------------------------------------------
# new workloads unlocked by the unified plane
# ----------------------------------------------------------------------
@dataclass
class ClassStats:
    klass: str
    n: int
    share_offered: float
    p50_wait_ms: float
    p99_wait_ms: float
    p99_latency_ms: float
    fast_failed: int


@dataclass
class MixResult:
    rho: float
    per_class: Dict[str, ClassStats]
    total_fast_failed: int


def simulate_multiclass(rho: float, model: LatencyModel, *,
                        mix=(("premium", 0.2), ("assured", 0.3),
                             ("best-effort", 0.5)),
                        t_max: float = 1000.0, slots: int = 64,
                        n_requests: Optional[int] = None,
                        seed: int = 0) -> MixResult:
    """Mixed-class traffic through ONE plane: premium keeps its reserved
    share and strict ordering, best-effort absorbs the queueing, hopeless
    requests fast-fail instead of wasting slots."""
    rng = np.random.default_rng(seed * 7 + int(rho * 1000))
    n = n_requests or model.cfg.n_requests
    clock = VirtualClock()
    infer = model.infer_times(rng, n)
    idx = {"i": 0}

    def sampler(req):
        i = idx["i"]
        idx["i"] += 1
        return 0.0, float(infer[i % n])

    plane = ServingPlane(
        clock, SimulatedEngine(clock, service_sampler=sampler,
                               default_service_ms=float(infer.mean())),
        slots=slots, premium_reserved_frac=0.25, site_id="mix")
    names = [k for k, _ in mix]
    probs = np.array([w for _, w in mix], float)
    probs /= probs.sum()
    classes = rng.choice(len(names), size=n, p=probs)
    lam_per_ms = rho * slots / float(infer.mean())
    arrivals_s = np.cumsum(rng.exponential(1.0 / lam_per_ms, size=n)) / 1e3
    _drive_plane(plane, clock, arrivals_s,
                 lambda i: dict(session_id=f"s{i}",
                                klass=names[classes[i]],
                                prompt_tokens=128, gen_tokens=16,
                                t_max_ms=t_max))

    per_class: Dict[str, ClassStats] = {}
    results = plane.pop_results()
    for j, name in enumerate(names):
        rs = [r for r in results if r.klass == name]
        ok = [r for r in rs if r.failed is None]
        waits = np.array([r.queue_wait_ms for r in ok]) if ok else np.zeros(1)
        lats = np.array([r.latency_ms for r in ok]) if ok else np.zeros(1)
        per_class[name] = ClassStats(
            klass=name, n=len(rs), share_offered=float(probs[j]),
            p50_wait_ms=float(np.quantile(waits, 0.5)),
            p99_wait_ms=float(np.quantile(waits, 0.99)),
            p99_latency_ms=float(np.quantile(lats, 0.99)),
            fast_failed=sum(1 for r in rs if r.failed is not None))
    return MixResult(rho=rho, per_class=per_class,
                     total_fast_failed=plane.scheduler.stats.fast_failed)


@dataclass
class BurstResult:
    burst_factor: float
    p99_wait_ms: float
    p99_wait_calm_ms: float
    fast_fail_frac: float
    completed_frac: float


def simulate_bursty(model: LatencyModel, *, burst_factor: float = 5.0,
                    base_rho: float = 0.45, duty: float = 0.15,
                    period_s: float = 2.0, t_max: float = 1000.0,
                    slots: int = 64, n_requests: Optional[int] = None,
                    seed: int = 0) -> BurstResult:
    """Markov-modulated arrivals: calm at base_rho, bursts at
    burst_factor × base_rho for ``duty`` of each period. The scheduler's
    deadline fast-fail is what keeps served-and-failed low through bursts."""
    rng = np.random.default_rng(seed * 31 + int(burst_factor * 10))
    n = n_requests or model.cfg.n_requests
    clock = VirtualClock()
    infer = model.infer_times(rng, n)
    idx = {"i": 0}

    def sampler(req):
        i = idx["i"]
        idx["i"] += 1
        return 0.0, float(infer[i % n])

    plane = ServingPlane(
        clock, SimulatedEngine(clock, service_sampler=sampler,
                               default_service_ms=float(infer.mean())),
        slots=slots, premium_reserved_frac=0.0, site_id="burst")

    lam_base = base_rho * slots / float(infer.mean())          # per ms
    t_ms, arrivals_ms, in_burst_flags = 0.0, [], []
    period_ms, burst_ms = period_s * 1e3, duty * period_s * 1e3
    for _ in range(n):
        phase = t_ms % period_ms
        in_burst = phase < burst_ms
        lam = lam_base * (burst_factor if in_burst else 1.0)
        t_ms += rng.exponential(1.0 / lam)
        arrivals_ms.append(t_ms)
        in_burst_flags.append(in_burst)
    arrivals_s = np.asarray(arrivals_ms) / 1e3
    flags = {}

    def submit_kwargs(i):
        flags[f"s{i}"] = in_burst_flags[i]
        return dict(session_id=f"s{i}", klass="premium",
                    prompt_tokens=128, gen_tokens=16, t_max_ms=t_max)

    _drive_plane(plane, clock, arrivals_s, submit_kwargs)

    results = plane.pop_results()
    ok = [r for r in results if r.failed is None]
    waits = np.array([r.queue_wait_ms for r in ok]) if ok else np.zeros(1)
    calm = [r.queue_wait_ms for r in ok if not flags.get(r.session_id)]
    return BurstResult(
        burst_factor=burst_factor,
        p99_wait_ms=float(np.quantile(waits, 0.99)),
        p99_wait_calm_ms=float(np.quantile(np.asarray(calm), 0.99))
        if calm else 0.0,
        fast_fail_frac=plane.scheduler.stats.fast_failed / max(len(results), 1),
        completed_frac=sum(1 for r in ok if r.completed) / max(len(results), 1))


@dataclass
class LoadMobilityResult:
    n_sessions: int
    handovers: int
    completed_frac: float
    p99_wait_ms: float
    per_site_served: Dict[str, int]


def simulate_load_mobility(*, n_sessions: int = 10_000,
                           requests_per_session: int = 2,
                           handover_prob: float = 0.15,
                           rho: float = 0.7, t_max: float = 2000.0,
                           seed: int = 0,
                           sim: Optional[SimConfig] = None
                           ) -> LoadMobilityResult:
    """Load + mobility at 10k+ concurrent sessions across the default
    4-site topology: each session anchors on a site-local plane; between a
    session's requests a handover may re-anchor it to a neighbour site, so
    later requests land on a DIFFERENT plane's queue — the scheduling
    consequences of mobility, not just the lease mechanics."""
    cfg = sim or SimConfig()
    model = LatencyModel(cfg)
    rng = np.random.default_rng(seed)
    clock = VirtualClock()
    # slot counts mirror repro.core.sites.default_sites
    topo = {"edge-a": 64, "edge-b": 64, "regional-1": 384, "central-1": 2048}
    total_slots = sum(topo.values())
    n_req = n_sessions * requests_per_session
    infer = model.infer_times(rng, n_req)
    idx = {"i": 0}

    def sampler(req):
        i = idx["i"]
        idx["i"] += 1
        return 0.0, float(infer[i % n_req])

    planes = {
        sid: ServingPlane(clock,
                          SimulatedEngine(clock, service_sampler=sampler,
                                          default_service_ms=float(infer.mean())),
                          slots=nslots, premium_reserved_frac=0.25,
                          site_id=sid)
        for sid, nslots in topo.items()}
    site_ids = list(topo)
    weights = np.array([topo[s] for s in site_ids], float)
    anchor = rng.choice(len(site_ids), size=n_sessions,
                        p=weights / weights.sum())

    lam_per_ms = rho * total_slots / float(infer.mean())
    arrivals_s = np.cumsum(
        rng.exponential(1.0 / lam_per_ms, size=n_req)) / 1e3
    klasses = np.array(["premium", "assured", "best-effort"])
    sess_klass = klasses[rng.integers(0, 3, size=n_sessions)]
    handover_draws = rng.random(n_req)
    handovers = 0

    for i, t in enumerate(arrivals_s):
        sess = i % n_sessions
        if i >= n_sessions and handover_draws[i] < handover_prob:
            # re-anchor to a random other site before this request
            anchor[sess] = (anchor[sess] + 1 +
                            int(handover_draws[i] * 1000) % (len(site_ids) - 1)
                            ) % len(site_ids)
            handovers += 1
        sid = site_ids[anchor[sess]]
        planes[sid].run_until(float(t))
        planes[sid].submit(session_id=f"s{sess}", klass=str(sess_klass[sess]),
                           prompt_tokens=128, gen_tokens=16, t_max_ms=t_max)
    for plane in planes.values():
        plane.drain()

    all_results = [r for p in planes.values() for r in p.pop_results()]
    ok = [r for r in all_results if r.failed is None]
    waits = np.array([r.queue_wait_ms for r in ok]) if ok else np.zeros(1)
    per_site = {sid: p.scheduler.stats.completed for sid, p in planes.items()}
    return LoadMobilityResult(
        n_sessions=n_sessions, handovers=handovers,
        completed_frac=sum(1 for r in ok if r.completed)
        / max(len(all_results), 1),
        p99_wait_ms=float(np.quantile(waits, 0.99)),
        per_site_served=per_site)


# ----------------------------------------------------------------------
# migration under load: the LIVE data plane under VirtualClock
# ----------------------------------------------------------------------
@dataclass
class MigrationLoadResult:
    """Aggregate of driving real make-before-break migrations (through the
    sites' SimulatedEngine planes and ``state_transfer``) under load."""
    n_sessions: int
    n_attempts: int
    migrated: int
    aborted: int
    abort_rate: float
    causes: Dict[str, int]
    max_interruption_ms: float
    mean_transfer_ms: float
    bytes_moved: int
    outcomes: List[object] = field(default_factory=list)  # MigrationOutcome


def simulate_migration_under_load(*, n_sessions: int = 40, rounds: int = 3,
                                  handover_prob: float = 0.35,
                                  target_pressure: float = 0.0,
                                  export_fail_prob: float = 0.0,
                                  seed: int = 0) -> MigrationLoadResult:
    """Sessions are established northbound (gateway wire messages) and
    serve through the sites' planes (their SimulatedEngine state evolves
    per request) while a mobility process triggers LIVE migrations via
    heartbeats whose Eq. (14) thresholds are tightened to zero: each one
    exports the session's sim state, fingerprint-verifies it into the
    target plane's backend, and swaps the binding make-before-break — the
    §V arm exercising the exact abort paths the real engines hit, with the
    outcomes observed exactly as an invoker would (HeartbeatAck.migration).

    ``target_pressure`` pre-occupies that fraction of every site's decode
    slots with confirmed leases, so re-paging hits COMPUTE_SCARCITY
    (at full pressure, DISCOVER already sees every candidate site
    saturated; below it, the race surfaces at PREPARE — either way the
    abort is target-side admission pressure).
    ``export_fail_prob`` injects export failures at the source plane.
    """
    from repro.api import messages as wire
    from repro.api.gateway import NorthboundGateway
    from repro.core import Orchestrator, default_asp
    from repro.core.asp import MobilityClass
    from repro.serving.state_transfer import TransferInjections

    rng = np.random.default_rng(seed)
    clock = VirtualClock()
    orch = Orchestrator(clock=clock)
    gw = NorthboundGateway(orch)
    sessions = []
    for i in range(n_sessions):
        disc = gw.handle(wire.DiscoverRequest(
            invoker=f"ue-{i}", zone="zone-a",
            asp=default_asp(mobility=MobilityClass.VEHICULAR)))
        gw.handle(wire.PageRequest(session_id=disc.session_id))
        prep = gw.handle(wire.PrepareRequest(session_id=disc.session_id))
        gw.handle(wire.CommitRequest(session_id=disc.session_id,
                                     prepared_ref=prep.prepared_ref))
        sessions.append(orch.sessions[disc.session_id])

    if target_pressure > 0.0:
        model = orch.catalog.get(sessions[0].binding.model_id,
                                 sessions[0].binding.model_version)
        for site in orch.sites.values():
            free = site.spec.decode_slots - site.slots_in_use()
            take = min(int(site.spec.decode_slots * target_pressure), free)
            if take > 0:
                lease = site.prepare(model, slots=take, cache_bytes=0.0,
                                     ttl_s=1e9)
                site.confirm(lease.lease_id, lease_s=1e9)

    if export_fail_prob > 0.0:
        draws = iter(rng.random(4 * n_sessions * rounds + 64))

        def flaky_export(payload):
            if next(draws) < export_fail_prob:
                raise IOError("injected export failure")

        inj = TransferInjections(on_export=flaky_export)
        for site in orch.sites.values():
            orch.plane_for(site).migration_inject = inj

    outcomes = []
    handover_draws = rng.random(rounds * n_sessions)
    for r in range(rounds):
        for i, s in enumerate(sessions):
            if not s.committed():
                continue
            clock.advance(0.005)
            # renew leases under virtual time — northbound heartbeat
            gw.handle(wire.HeartbeatReport(session_id=s.session_id))
            frames = gw.handle(wire.ServeRequest(
                session_id=s.session_id, prompt_tokens=64, gen_tokens=16))
            if isinstance(frames, wire.ErrorResponse) or \
                    isinstance(frames[0], wire.ErrorResponse):
                continue
            if handover_draws[r * n_sessions + i] < handover_prob:
                # mobility event: tightened Eq. (14) thresholds force the
                # migration check to fire on this heartbeat
                ack = gw.handle(wire.HeartbeatReport(
                    session_id=s.session_id,
                    trigger_l99=0.0, trigger_ttfb=0.0))
                if isinstance(ack, wire.HeartbeatAck) and ack.migration:
                    outcomes.append(wire.outcome_from_wire(ack.migration))

    migrated = sum(1 for o in outcomes if o.migrated)
    aborted = sum(1 for o in outcomes if o.aborted)
    causes: Dict[str, int] = {}
    for o in outcomes:
        if o.cause is not None:
            causes[o.cause.value] = causes.get(o.cause.value, 0) + 1
    ok = [o for o in outcomes if o.migrated]
    return MigrationLoadResult(
        n_sessions=n_sessions, n_attempts=len(outcomes),
        migrated=migrated, aborted=aborted,
        abort_rate=aborted / max(len(outcomes), 1), causes=causes,
        max_interruption_ms=max((o.interruption_ms for o in outcomes),
                                default=0.0),
        mean_transfer_ms=float(np.mean([o.transfer_ms for o in ok]))
        if ok else 0.0,
        bytes_moved=sum(o.transfer_bytes for o in ok),
        outcomes=outcomes)


# ----------------------------------------------------------------------
# federation: roaming across an operator boundary + overload spillover
# ----------------------------------------------------------------------
def _fed_catalog():
    """Single-model catalog (edge-tiny) shared by the federation and chaos
    scenarios: DISCOVER stays O(sites), not O(sites × catalog)."""
    from repro.core.catalog import Catalog, default_catalog

    c = Catalog()
    c.register(default_catalog().get("edge-tiny"))
    return c


def _fed_site(clock: VirtualClock, site_id: str, rtt: dict, slots: int,
              *, kind: str = "edge"):
    from repro.core.sites import ExecutionSite, SiteSpec

    v5e_flops, v5e_bw, hbm = 197e12, 819e9, 16e9
    return ExecutionSite(SiteSpec(
        site_id, kind, "eu", chips=16, hbm_bytes_total=16 * hbm,
        peak_flops=16 * v5e_flops, hbm_bw=16 * v5e_bw,
        decode_slots=slots, rtt_ms=dict(rtt),
        hosted_models=("edge-tiny@1.0",),
        price_per_chip_s=2.0e-4), clock)


def _federation_pair(clock: VirtualClock, *, home_slots: int,
                     visited_slots: int, transit_ms: float = 5.0,
                     solicit: str = "fallback"):
    """Two peered single-site domains sharing one VirtualClock: the home
    edge is close to zone-a and hopeless from zone-b, the visited edge the
    reverse — crossing the zone boundary is crossing the domain boundary."""
    from repro.core import Orchestrator
    from repro.federation import DomainController, FederationRegistry

    registry = FederationRegistry(clock)
    home = DomainController(
        "home", registry, solicit=solicit,
        orchestrator=Orchestrator(
            clock=clock, catalog=_fed_catalog(),
            sites={"h-edge": _fed_site(clock, "h-edge",
                                       {"zone-a": 2.0, "zone-b": 400.0},
                                       home_slots)}))
    visited = DomainController(
        "visited", registry, solicit=solicit,
        orchestrator=Orchestrator(
            clock=clock, catalog=_fed_catalog(),
            sites={"v-edge": _fed_site(clock, "v-edge",
                                       {"zone-a": 25.0, "zone-b": 2.0},
                                       visited_slots)}))
    home.connect(visited, transit_ms=transit_ms)
    return home, visited


@dataclass
class FederatedRoamingResult:
    n_sessions: int
    roamed: int
    aborted: int
    causes: Dict[str, int]
    mean_transfer_ms: float
    bytes_moved: int
    max_interruption_ms: float
    p99_pre_ms: float            # serve latency while anchored home
    p99_post_ms: float           # serve latency after roaming abroad


def simulate_federated_roaming(*, n_sessions: int = 24,
                               pre_requests: int = 2,
                               post_requests: int = 2) -> FederatedRoamingResult:
    """A fleet of vehicular sessions establishes at the home operator,
    serves, then a mobility trace carries every invoker across the domain
    boundary (zone-a → zone-b): the next heartbeat's Eq. (14) check finds
    the home anchor infeasible from the new zone, solicits east-west
    offers, and live-migrates the session make-before-break into the
    visited operator through the typed handshake — tokens before and after
    the boundary come from the same session, observed through the same
    northbound contract."""
    from repro.api.client import SessionClient
    from repro.api.gateway import NorthboundGateway
    from repro.core import default_asp
    from repro.core.asp import MobilityClass, QualityTier

    clock = VirtualClock()
    home, visited = _federation_pair(
        clock, home_slots=2 * n_sessions, visited_slots=2 * n_sessions)
    gw = NorthboundGateway(home)
    asp = default_asp(tier=QualityTier.BASIC,
                      mobility=MobilityClass.VEHICULAR)
    clients = [SessionClient(gw, asp, invoker=f"car-{i}", zone="zone-a",
                             subscribe_events=False).establish()
               for i in range(n_sessions)]

    pre, post = [], []
    for c in clients:
        for _ in range(pre_requests):
            clock.advance(0.002)
            stream = c.generate(prompt_tokens=64, gen_tokens=16)
            stream.tokens()
            pre.append(stream.complete.latency_ms)

    outcomes = []
    for c in clients:
        # boundary crossing: the invoker's access zone flips domains
        home.core.sessions[c.session_id].zone = "zone-b"
        clock.advance(0.002)
        ack = c.heartbeat(trigger_l99=0.0, trigger_ttfb=0.0)
        if ack.migration is not None:
            from repro.api.messages import outcome_from_wire
            outcomes.append(outcome_from_wire(ack.migration))

    for c in clients:
        for _ in range(post_requests):
            clock.advance(0.002)
            stream = c.generate(prompt_tokens=64, gen_tokens=16)
            stream.tokens()
            post.append(stream.complete.latency_ms)
    for c in clients:
        c.release()

    ok = [o for o in outcomes if o.migrated]
    causes: Dict[str, int] = {}
    for o in outcomes:
        if o.cause is not None:
            causes[o.cause.value] = causes.get(o.cause.value, 0) + 1
    return FederatedRoamingResult(
        n_sessions=n_sessions, roamed=len(ok),
        aborted=sum(1 for o in outcomes if o.aborted), causes=causes,
        mean_transfer_ms=float(np.mean([o.transfer_ms for o in ok]))
        if ok else 0.0,
        bytes_moved=sum(o.transfer_bytes for o in ok),
        max_interruption_ms=max((o.interruption_ms for o in outcomes),
                                default=0.0),
        p99_pre_ms=float(np.quantile(np.asarray(pre), 0.99)) if pre else 0.0,
        p99_post_ms=float(np.quantile(np.asarray(post), 0.99))
        if post else 0.0)


@dataclass
class SpilloverResult:
    federated: bool
    n_offered: int
    established_home: int
    established_visited: int
    failed: int
    served: int
    p99_ms: float
    admitted_frac: float


def simulate_home_overload_spillover(*, n_sessions: int = 48,
                                     home_slots: int = 16,
                                     visited_slots: int = 256,
                                     requests_per_session: int = 2,
                                     federated: bool = True) -> SpilloverResult:
    """Offered establishes exceed the home operator's committed capacity.
    Single-domain, the overflow fails with COMPUTE_SCARCITY at DISCOVER
    (every home site saturated); federated, the home-first gateway solicits
    east-west offers and the overflow anchors in the visited domain — same
    client contract, measured against the same p99."""
    from repro.api.client import NorthboundError, SessionClient
    from repro.api.gateway import NorthboundGateway
    from repro.core import default_asp
    from repro.core.asp import QualityTier

    clock = VirtualClock()
    home, visited = _federation_pair(
        clock, home_slots=home_slots, visited_slots=visited_slots)
    if not federated:
        home.peers.clear()           # sever the east-west peering
    gw = NorthboundGateway(home)
    asp = default_asp(tier=QualityTier.BASIC)

    clients, at_home, abroad, failed = [], 0, 0, 0
    for i in range(n_sessions):
        clock.advance(0.001)
        c = SessionClient(gw, asp, invoker=f"asp-{i}", zone="zone-a",
                          subscribe_events=False)
        try:
            c.establish()
        except NorthboundError:
            failed += 1
            continue
        clients.append(c)
        if c.anchor.startswith("visited/"):
            abroad += 1
        else:
            at_home += 1

    lats = []
    for _ in range(requests_per_session):
        for c in clients:
            clock.advance(0.001)
            stream = c.generate(prompt_tokens=64, gen_tokens=16)
            stream.tokens()
            if stream.complete.completed:
                lats.append(stream.complete.latency_ms)
    for c in clients:
        c.release()
    return SpilloverResult(
        federated=federated, n_offered=n_sessions,
        established_home=at_home, established_visited=abroad,
        failed=failed, served=len(lats),
        p99_ms=float(np.quantile(np.asarray(lats), 0.99)) if lats else 0.0,
        admitted_frac=(at_home + abroad) / max(n_sessions, 1))


# ----------------------------------------------------------------------
# payload asymmetry: dense KV vs O(1) SSM state under τ_mig
# ----------------------------------------------------------------------
@dataclass
class PayloadAsymmetryRow:
    model_id: str
    family: str
    context_tokens: int
    payload_bytes: int
    transfer_ms: float
    migrated: bool
    cause: Optional[str]


def simulate_payload_asymmetry(*, context_tokens: Tuple[int, ...] =
                               (4_096, 32_768, 131_072),
                               models: Tuple[str, ...] =
                               ("minitron-8b", "recurrentgemma-2b",
                                "mamba2-1.3b"),
                               seed: int = 0) -> List[PayloadAsymmetryRow]:
    """Migrate long-lived sessions of each payload family at growing context
    lengths: dense KV grows linearly and blows τ_mig on the inter-site link,
    hybrid RG-LRU sits in between, SSM state is O(1) in context and always
    fits — the continuity argument for state-space anchors (§IV-B)."""
    from repro.core import Orchestrator, default_asp
    from repro.core.asp import MobilityClass, QualityTier
    from repro.core.catalog import Catalog, default_catalog

    full = default_catalog()
    rows: List[PayloadAsymmetryRow] = []
    for model_id in models:
        entry = full.get(model_id)
        for ctx in context_tokens:
            cat = Catalog()
            cat.register(entry)
            orch = Orchestrator(clock=VirtualClock(), catalog=cat)
            asp = default_asp(mobility=MobilityClass.VEHICULAR,
                              tier=QualityTier.BASIC)
            s = orch.establish(asp, invoker=f"ue-{model_id}", zone="zone-a")
            orch.serve(s, prompt_tokens=64, gen_tokens=16)  # live state
            s.context_tokens = ctx        # long-lived session fast-forward
            out = orch.migrations.migrate(s, "zone-a")
            rows.append(PayloadAsymmetryRow(
                model_id=model_id, family=entry.cfg.family,
                context_tokens=ctx,
                payload_bytes=entry.session_state_bytes(ctx),
                transfer_ms=out.transfer_ms, migrated=out.migrated,
                cause=out.cause.value if out.cause else None))
    return rows


# ----------------------------------------------------------------------
# chaos: site crash, graceful drain, domain partition, registry storms
# ----------------------------------------------------------------------
def _chaos_sites(clock: VirtualClock, n_sessions: int):
    """Federation-scale 3-site topology sized so a crashed edge's orphans
    always FIT elsewhere: each edge holds half the fleet, the regional tier
    holds all of it — survival shortfalls are supervisor bugs, not
    capacity artifacts. RTTs mirror ``default_sites``."""
    edge_slots = max(64, (2 * n_sessions) // 4)
    regional_slots = max(256, n_sessions)
    return {
        "edge-a": _fed_site(clock, "edge-a",
                            {"zone-a": 2.0, "zone-b": 9.0, "zone-c": 18.0},
                            edge_slots),
        "edge-b": _fed_site(clock, "edge-b",
                            {"zone-a": 9.0, "zone-b": 2.0, "zone-c": 10.0},
                            edge_slots),
        "regional-1": _fed_site(clock, "regional-1",
                                {"zone-a": 12.0, "zone-b": 12.0,
                                 "zone-c": 12.0},
                                regional_slots, kind="regional"),
    }


@dataclass
class SiteCrashResult:
    n_sessions: int
    orphaned: int                  # anchored on the crash site at T0
    reanchored: int
    lost: int
    survival_frac: float
    failed_inflight: int           # in-flight+queued attributed COMPUTE_SCARCITY
    recovery_ms_p50: float         # wall-clock per-session re-anchor time
    recovery_ms_p99: float
    causes: Dict[str, int]         # Eq. 12 causes of the lost sessions
    reanchor_sites: Dict[str, int]  # where the orphans landed
    serve_ok_after: int            # sampled re-anchored sessions that serve
    post_crash_establish_ok: bool  # new establishes avoid the dead site


def simulate_site_crash(*, n_sessions: int = 10_000,
                        crash_site: str = "edge-a",
                        inflight: int = 256,
                        serve_sample: int = 64,
                        seed: int = 0) -> SiteCrashResult:
    """Site crash mid-stream at federation scale: ``n_sessions`` AIS
    establish across a 3-site topology, ``inflight`` requests are queued on
    the doomed site's plane, then the supervisor declares it dead. Every
    in-flight request must fail attributably (COMPUTE_SCARCITY — the
    anchor's compute vanished mid-contract) and every orphaned session
    re-anchors via AI-PAGING onto a surviving site, with per-session
    wall-clock recovery time measured — the acceptance bar is ≥99%
    survival, which the recovery bench guards in CI."""
    from repro.core import Orchestrator, default_asp
    from repro.core.asp import QualityTier
    from repro.serving.supervisor import FleetSupervisor

    rng = np.random.default_rng(seed)
    clock = VirtualClock()
    orch = Orchestrator(clock=clock, catalog=_fed_catalog(),
                        sites=_chaos_sites(clock, n_sessions))
    asp = default_asp(tier=QualityTier.BASIC)
    zones = ("zone-a", "zone-b", "zone-c")
    sessions = []
    for i in range(n_sessions):
        sessions.append(orch.establish(asp, invoker=f"ue-{i}",
                                       zone=zones[i % 3]))
    on_site = [s for s in sessions
               if s.binding is not None and s.binding.site_id == crash_site]
    # queue live work on the doomed plane — these are the requests the
    # crash must attribute, not silently drop
    targets = [on_site[int(j)] for j in
               rng.integers(0, len(on_site), size=min(inflight,
                                                      len(on_site)))]
    for s in targets:
        orch.submit(s, prompt_tokens=64, gen_tokens=16)

    sup = FleetSupervisor(orch)
    report = sup.crash(crash_site, detail="chaos: simulated site crash")

    landed: Dict[str, int] = {}
    for s in on_site:
        if s.committed() and s.binding is not None:
            landed[s.binding.site_id] = landed.get(s.binding.site_id, 0) + 1
    # continuity: a sample of the re-anchored fleet keeps serving
    survivors = [s for s in on_site if s.committed()]
    serve_ok = 0
    for s in survivors[:serve_sample]:
        clock.advance(0.001)
        res = orch.serve(s, prompt_tokens=64, gen_tokens=16)
        serve_ok += int(res.completed)
    # the dead site is DISCOVER-excluded: a fresh establish still lands
    try:
        fresh = orch.establish(asp, invoker="ue-post", zone="zone-a")
        post_ok = fresh.binding is not None \
            and fresh.binding.site_id != crash_site
    except Exception:               # noqa: BLE001
        post_ok = False

    ms = sorted(report.recovery_ms)
    return SiteCrashResult(
        n_sessions=n_sessions, orphaned=report.orphaned,
        reanchored=report.reanchored, lost=report.lost,
        survival_frac=report.survival_frac,
        failed_inflight=report.failed_inflight,
        recovery_ms_p50=float(np.quantile(np.asarray(ms), 0.50))
        if ms else 0.0,
        recovery_ms_p99=float(np.quantile(np.asarray(ms), 0.99))
        if ms else 0.0,
        causes=dict(report.causes), reanchor_sites=landed,
        serve_ok_after=serve_ok, post_crash_establish_ok=post_ok)


@dataclass
class DrainUnderLoadResult:
    n_sessions: int
    on_site: int                   # sessions anchored at the drain site
    migrated: int
    hibernated: int
    stranded: int
    failed_inflight: int           # MUST be zero: drain is graceful
    completed_during_drain: int
    post_serve_ok: int             # migrated sessions serving elsewhere
    rejects_after_drain: bool      # drained plane refuses new admissions


def simulate_drain_under_load(*, n_sessions: int = 120,
                              drain_site: str = "edge-a",
                              inflight: int = 32,
                              seed: int = 0) -> DrainUnderLoadResult:
    """Graceful drain with live traffic: sessions serve (so their engine
    state exists to export), more requests sit queued on the draining
    site, then the supervisor drains it. Every in-flight request finishes
    — zero failures — and every bound session leaves make-before-break
    (hibernation is the fallback for state that cannot move)."""
    from repro.core import Orchestrator, default_asp
    from repro.core.asp import QualityTier
    from repro.serving.supervisor import FleetSupervisor

    rng = np.random.default_rng(seed)
    clock = VirtualClock()
    orch = Orchestrator(clock=clock)
    asp = default_asp(tier=QualityTier.BASIC)
    sessions = []
    for i in range(n_sessions):
        s = orch.establish(asp, invoker=f"ue-{i}", zone="zone-a")
        clock.advance(0.001)
        orch.serve(s, prompt_tokens=64, gen_tokens=16)   # live engine state
        sessions.append(s)
    on_site = [s for s in sessions
               if s.binding is not None and s.binding.site_id == drain_site]
    targets = [on_site[int(j)] for j in
               rng.integers(0, len(on_site), size=min(inflight,
                                                      len(on_site)))]
    for s in targets:
        orch.submit(s, prompt_tokens=64, gen_tokens=16)

    sup = FleetSupervisor(orch)
    report = sup.drain(drain_site)

    # continuity on the new anchors — and the drained plane stays closed
    post_ok = 0
    for s in on_site:
        if not s.committed():
            continue
        clock.advance(0.001)
        res = orch.serve(s, prompt_tokens=64, gen_tokens=16)
        post_ok += int(res.completed)
    plane = orch.sites[drain_site].plane
    rejected = plane is None or plane.submit(
        session_id="drain-probe", klass="best-effort", prompt_tokens=8,
        gen_tokens=8, t_max_ms=2000.0) is None
    return DrainUnderLoadResult(
        n_sessions=n_sessions, on_site=len(on_site),
        migrated=report.migrated, hibernated=report.hibernated,
        stranded=report.stranded, failed_inflight=report.failed_inflight,
        completed_during_drain=report.completed,
        post_serve_ok=post_ok, rejects_after_drain=rejected)


@dataclass
class PartitionResult:
    established_home: int
    established_visited: int
    partition_failures: int        # zone-b establishes during the partition
    partition_causes: Dict[str, int]
    timeout_notes: int             # solicit notes while the link black-holes
    dead_notes: int                # solicit notes after domain marked dead
    home_serve_ok_during: int      # home-anchored continuity under partition
    healed_established: int        # zone-b establishes after the heal


def simulate_domain_partition(*, n_sessions: int = 24,
                              heal_establishes: int = 4) -> PartitionResult:
    """East-west partition between two peered domains: zone-b traffic that
    spilled to the visited operator loses its path home. During the
    partition new zone-b establishes fail attributably (the peer reads as
    offer-timeout until the supervisor marks the domain dead, then as
    domain-dead without burning the timeout), home-anchored sessions are
    untouched, and healing the link restores spillover."""
    from repro.core import default_asp
    from repro.core.asp import QualityTier
    from repro.core.session import SessionError

    clock = VirtualClock()
    home, visited = _federation_pair(
        clock, home_slots=n_sessions, visited_slots=2 * n_sessions)
    asp = default_asp(tier=QualityTier.BASIC)
    at_home, abroad = [], []
    for i in range(n_sessions):
        clock.advance(0.001)
        zone = "zone-a" if i % 2 == 0 else "zone-b"
        s = home.core.establish(asp, invoker=f"ue-{i}", zone=zone)
        (abroad if s.binding.site_id.startswith("visited/")
         else at_home).append(s)

    # partition: the east-west link black-holes (any send raises)
    endpoint = home.peers["visited"]

    def _severed(_msg: str) -> str:
        raise ConnectionError("east-west link partitioned")

    home.peers["visited"] = _severed
    _, notes = home.solicit_offers(asp, "zone-b")
    timeout_notes = sum(1 for _, why in notes if why == "offer-timeout")

    failures, causes = 0, {}
    for i in range(n_sessions // 2):
        clock.advance(0.001)
        try:
            home.core.establish(asp, invoker=f"part-{i}", zone="zone-b")
        except SessionError as e:
            failures += 1
            causes[e.cause.value] = causes.get(e.cause.value, 0) + 1

    # supervisor verdict: stop probing the corpse — fast-fail via the
    # dead-domain list instead of eating a timeout per solicit
    home.mark_domain_dead("visited")
    _, notes = home.solicit_offers(asp, "zone-b")
    dead_notes = sum(1 for _, why in notes if why == "domain-dead")

    serve_ok = 0
    for s in at_home:
        clock.advance(0.001)
        res = home.core.serve(s, prompt_tokens=64, gen_tokens=16)
        serve_ok += int(res.completed)

    # heal: link back, domain alive, re-peer (re-registers the provider
    # that mark_domain_dead dropped) — spillover resumes
    home.peers["visited"] = endpoint
    home.mark_domain_alive("visited")
    home.connect(visited)
    healed = 0
    for i in range(heal_establishes):
        clock.advance(0.001)
        s = home.core.establish(asp, invoker=f"heal-{i}", zone="zone-b")
        healed += int(s.binding.site_id.startswith("visited/"))
    return PartitionResult(
        established_home=len(at_home), established_visited=len(abroad),
        partition_failures=failures, partition_causes=causes,
        timeout_notes=timeout_notes, dead_notes=dead_notes,
        home_serve_ok_during=serve_ok, healed_established=healed)


def _federation_star(clock: VirtualClock, *, n_domains: int,
                     home_slots: int, peer_slots: int):
    """One home domain peered with ``n_domains`` visited domains on a
    SHARED registry: the home edge is only good from zone-a, every peer is
    only good from zone-b — zone-b traffic exists solely as east-west
    spillover, so registry health IS admission health for that zone."""
    from repro.core import Orchestrator
    from repro.federation import DomainController, FederationRegistry

    registry = FederationRegistry(clock)
    home = DomainController(
        "home", registry, solicit="fallback",
        orchestrator=Orchestrator(
            clock=clock, catalog=_fed_catalog(),
            sites={"h-edge": _fed_site(clock, "h-edge",
                                       {"zone-a": 2.0, "zone-b": 400.0},
                                       home_slots)}))
    peers = []
    for k in range(n_domains):
        dom = DomainController(
            f"op-{k}", registry, solicit="fallback",
            orchestrator=Orchestrator(
                clock=clock, catalog=_fed_catalog(),
                sites={f"edge-{k}": _fed_site(
                    clock, f"edge-{k}",
                    {"zone-a": 25.0, "zone-b": 2.0 + 0.1 * k},
                    peer_slots)}))
        home.connect(dom)
        peers.append(dom)
    return home, peers


@dataclass
class StalenessStormResult:
    n_domains: int
    established_pre: int           # zone-b spillover before the storm
    stale_notes: int               # per-domain registry-stale exclusions
    storm_failures: int            # zone-b establishes during the storm
    storm_causes: Dict[str, int]
    established_post_recovery: int  # after ONE provider re-registers


def simulate_registry_staleness_storm(*, n_domains: int = 6,
                                      n_sessions: int = 60,
                                      seed: int = 0) -> StalenessStormResult:
    """Registry-staleness storm: every peer's capability digest ages past
    ``max_age_s`` with its re-pull provider gone (the failure mode of a
    crashed federation registry sync). All zone-b admission collapses with
    per-domain ``registry-stale`` notes — attributable, not mysterious —
    and recovering a single provider restores spillover through that
    domain alone."""
    from repro.core import default_asp
    from repro.core.asp import QualityTier
    from repro.core.session import SessionError

    clock = VirtualClock()
    home, peers = _federation_star(
        clock, n_domains=n_domains, home_slots=4,
        peer_slots=max(4, (2 * n_sessions) // n_domains))
    asp = default_asp(tier=QualityTier.BASIC)

    pre = 0
    for i in range(n_sessions):
        clock.advance(0.001)
        s = home.core.establish(asp, invoker=f"ue-{i}", zone="zone-b")
        pre += int(s.binding.site_id.startswith("op-"))

    # the storm: providers vanish, then every digest ages out at once
    for dom in peers:
        home.registry.drop_provider(dom.domain_id)
    clock.advance(home.registry.max_age_s + 1.0)
    _, notes = home.solicit_offers(asp, "zone-b")
    stale_notes = sum(1 for _, why in notes if why == "registry-stale")

    failures, causes = 0, {}
    for i in range(n_domains):
        clock.advance(0.001)
        try:
            home.core.establish(asp, invoker=f"storm-{i}", zone="zone-b")
        except SessionError as e:
            failures += 1
            causes[e.cause.value] = causes.get(e.cause.value, 0) + 1

    # recovery: ONE domain's provider re-registers → its digest re-pulls
    # fresh on the next solicit and spillover resumes through it
    survivor = peers[0]
    home.registry.register_provider(survivor.domain_id, survivor.digest)
    post = 0
    for i in range(4):
        clock.advance(0.001)
        try:
            s = home.core.establish(asp, invoker=f"rec-{i}", zone="zone-b")
            post += int(s.binding.site_id.startswith(
                f"{survivor.domain_id}/"))
        except SessionError:
            pass
    return StalenessStormResult(
        n_domains=n_domains, established_pre=pre, stale_notes=stale_notes,
        storm_failures=failures, storm_causes=causes,
        established_post_recovery=post)


# ----------------------------------------------------------------------
# split serving: verify-anchor crash degrades to edge-only, then recovers
# ----------------------------------------------------------------------
def _split_topology(clock: VirtualClock, n_sessions: int):
    """Two edge sites hosting the draft model plus TWO verify-capable
    regional sites (so recovery after a verify crash has somewhere to
    land). regional-2 is RTT-worse than regional-1, making the initial
    verify paging deterministic."""
    from repro.core.catalog import Catalog, default_catalog

    full = default_catalog()
    cat = Catalog()
    cat.register(full.get("recurrentgemma-2b"))   # edge draft (vocab 256k)
    cat.register(full.get("minitron-8b"))         # verify (vocab 256k)

    from repro.core.sites import ExecutionSite, SiteSpec
    v5e_flops, v5e_bw, hbm = 197e12, 819e9, 16e9

    def mk(sid, kind, rtt, slots, hosted):
        return ExecutionSite(SiteSpec(
            sid, kind, "eu", chips=16, hbm_bytes_total=16 * hbm,
            peak_flops=16 * v5e_flops, hbm_bw=16 * v5e_bw,
            decode_slots=slots, rtt_ms=dict(rtt), hosted_models=hosted,
            price_per_chip_s=2.0e-4), clock)

    edge_slots = max(64, n_sessions)
    verify_slots = max(128, n_sessions)
    draft_host = ("recurrentgemma-2b@1.0",)
    verify_host = ("minitron-8b@1.0",)
    return cat, {
        "edge-a": mk("edge-a", "edge",
                     {"zone-a": 2.0, "zone-b": 9.0}, edge_slots, draft_host),
        "edge-b": mk("edge-b", "edge",
                     {"zone-a": 9.0, "zone-b": 2.0}, edge_slots, draft_host),
        "regional-1": mk("regional-1", "regional",
                         {"zone-a": 12.0, "zone-b": 12.0}, verify_slots,
                         verify_host),
        "regional-2": mk("regional-2", "regional",
                         {"zone-a": 30.0, "zone-b": 30.0}, verify_slots,
                         verify_host),
    }


@dataclass
class VerifyCrashResult:
    n_sessions: int
    split_established: int         # sessions that committed as splits
    verify_site: str               # where the verify anchors landed
    failed_inflight: int           # MUST be 0: in-flight rides the edge
    orphaned: int                  # MUST be 0: edge bindings survive
    degraded: int                  # splits degraded to edge-only
    still_committed: int           # sessions still COMMITTED post-crash
    serve_ok_degraded: int         # sampled serves while degraded
    recovered: int                 # verify anchors re-attached
    recovered_sites: Dict[str, int]  # where recovery landed
    serve_ok_after: int            # sampled serves at full quality
    events: Dict[str, int]         # tier-change event histogram


def simulate_verify_crash_degrade(*, n_sessions: int = 48,
                                  inflight: int = 64,
                                  serve_sample: int = 16,
                                  seed: int = 0) -> VerifyCrashResult:
    """Chaos for split serving: every AIS establishes as a TWO-anchor
    split (edge draft + regional verify, ``split_policy="require"``), live
    work is queued on the EDGE data plane, then the verify site crashes.
    The acceptance bar is the airplane-mode contract: ZERO failed
    in-flight requests and ZERO orphans (the interactive path never
    touched the dead site), every split emits an explicit quality-tier
    degrade event, and after re-attachment every session is back at full
    quality on a surviving verify site."""
    from dataclasses import replace as _dc_replace

    from repro.core import Orchestrator, default_asp
    from repro.core.asp import QualityTier
    from repro.serving.supervisor import FleetSupervisor
    from repro.splitserve import SplitManager

    rng = np.random.default_rng(seed)
    clock = VirtualClock()
    cat, sites = _split_topology(clock, n_sessions)
    orch = Orchestrator(clock=clock, catalog=cat, sites=sites)
    mgr = SplitManager(orch)
    events: Dict[str, int] = {}
    orch.split_event_sinks.append(
        lambda sid, ev, d: events.update({ev: events.get(ev, 0) + 1}))

    # the split's cost envelope covers BOTH anchors (each leg gets a
    # share), so the profile pays for two reservations explicitly
    asp = _dc_replace(default_asp(tier=QualityTier.STANDARD),
                      split_policy="require", max_cost_per_1k_tokens=4.0)
    zones = ("zone-a", "zone-b")
    sessions = []
    for i in range(n_sessions):
        sessions.append(orch.establish(asp, invoker=f"ue-{i}",
                                       zone=zones[i % 2]))
    split_states = [mgr.states[s.session_id] for s in sessions]
    verify_site = split_states[0].verify_binding.site_id
    established = sum(1 for st in split_states
                      if st.verify_binding is not None)

    # live work rides the EDGE data plane — the crash must not touch it
    targets = [sessions[int(j)] for j in
               rng.integers(0, n_sessions, size=inflight)]
    for s in targets:
        orch.submit(s, prompt_tokens=64, gen_tokens=16)

    sup = FleetSupervisor(orch)
    report = sup.crash(verify_site, detail="chaos: verify anchor crash")

    degraded = sum(1 for st in split_states if st.degraded)
    still = sum(1 for s in sessions if s.committed())
    # degraded sessions keep serving (edge-only quality rung)
    serve_deg = 0
    for s in sessions[:serve_sample]:
        clock.advance(0.001)
        serve_deg += int(orch.serve(s, prompt_tokens=64,
                                    gen_tokens=16).completed)

    # recovery: re-attach a verify anchor on a surviving regional site
    recovered, landed = 0, {}
    for s in sessions:
        clock.advance(0.001)
        mgr.recover(s)
        st = mgr.states[s.session_id]
        if st.verify_binding is not None and not st.degraded:
            recovered += 1
            landed[st.verify_binding.site_id] = \
                landed.get(st.verify_binding.site_id, 0) + 1
    serve_ok = 0
    for s in sessions[:serve_sample]:
        clock.advance(0.001)
        serve_ok += int(orch.serve(s, prompt_tokens=64,
                                   gen_tokens=16).completed)
    return VerifyCrashResult(
        n_sessions=n_sessions, split_established=established,
        verify_site=verify_site, failed_inflight=report.failed_inflight,
        orphaned=report.orphaned, degraded=degraded,
        still_committed=still, serve_ok_degraded=serve_deg,
        recovered=recovered, recovered_sites=landed,
        serve_ok_after=serve_ok, events=dict(events))


# ----------------------------------------------------------------------
# unreliable control plane: lossy wire + retries + reaping, end to end
# ----------------------------------------------------------------------
@dataclass
class LossyControlPlaneResult:
    loss: float                     # per-fault rate on every control link
    n_offered: int
    established: int
    established_visited: int        # spilled east-west under loss
    failed: int
    causes: Dict[str, int]          # error code → count, for the failures
    goodput: float                  # established / offered
    p50_establish_ms: float         # virtual wall time, retries included
    p99_establish_ms: float
    serve_ok: int                   # sampled post-establish serves
    orphaned_after_sweep: int       # MUST be 0 (lease invariant)
    charging_open: int              # MUST be 0 (no billing without commit)
    wire: Dict[str, int]            # aggregated channel fault counters


def simulate_lossy_control_plane(*, n_sessions: int = 64,
                                 loss: float = 0.05,
                                 spill: bool = True,
                                 deadline_ms: float = 30_000.0,
                                 serve_sample: int = 16,
                                 seed: int = 0) -> LossyControlPlaneResult:
    """Full AIS establishment over an unreliable control plane, on BOTH
    paths: every northbound client rides its own seeded
    :class:`~repro.netfault.wire.LossyChannel` around the gateway, and the
    east-west peering between the two domains is lossy too. ``spill``
    undersizes the home edge so a share of the fleet must establish
    cross-domain (lossy EWPrepare/EWCommit with at-least-once re-sends).

    The run measures what the retry stack delivers (goodput, p50/p99
    establish latency including retries and backoff) and then asserts the
    paper's safety invariant the hard way: after the orphan sweeps, every
    lease belongs to an established session (no stranded provisional
    state) and no failed establishment left a charging record open."""
    from repro.api.client import NorthboundError, SessionClient
    from repro.api.gateway import NorthboundGateway
    from repro.core import default_asp
    from repro.core.asp import QualityTier
    from repro.netfault import (FaultPlan, LossyChannel, RetryPolicy,
                                TransportError)

    clock = VirtualClock()
    home_slots = max(n_sessions // 4, 1) if spill else 2 * n_sessions
    home, visited = _federation_pair(clock, home_slots=home_slots,
                                     visited_slots=2 * n_sessions)
    # the east-west peering is just another unreliable wire
    home.peers[visited.domain_id] = LossyChannel(
        visited.handle_eastwest_json, clock,
        FaultPlan.uniform(loss, seed=seed * 7919 + 1), name="ew:h->v")
    visited.peers[home.domain_id] = LossyChannel(
        home.handle_eastwest_json, clock,
        FaultPlan.uniform(loss, seed=seed * 7919 + 2), name="ew:v->h")
    gw = NorthboundGateway(home)
    asp = default_asp(tier=QualityTier.BASIC)

    channels: List[LossyChannel] = []
    clients, causes = [], {}
    establish_ms: List[float] = []
    established = failed = 0
    for i in range(n_sessions):
        chan = LossyChannel(
            gw.handle_json, clock,
            FaultPlan.uniform(loss, seed=seed * 100_003 + i),
            name=f"nb:{i}")
        channels.append(chan)
        client = SessionClient(
            gw, asp, invoker=f"ue-{i}", zone="zone-a",
            subscribe_events=False, transport=chan, clock=clock,
            retry=RetryPolicy(seed=seed * 31 + i),
            deadline_ms=deadline_ms)
        t0 = clock.now()
        try:
            client.establish()
            established += 1
            clients.append(client)
        except (NorthboundError, TransportError) as e:
            failed += 1
            code = getattr(e, "code", None) or "E_TRANSPORT"
            causes[code] = causes.get(code, 0) + 1
        establish_ms.append((clock.now() - t0) * 1e3)
        # the heartbeat cadence runs between arrivals: planes advance,
        # sweeps fire (gateway + home coordinator + visited guest GC)
        gw.pump(clock.now())
        visited.tick()

    serve_ok = 0
    for c in clients[:serve_sample]:
        clock.advance(0.001)
        stream = c.generate(prompt_tokens=64, gen_tokens=16)
        stream.tokens()
        serve_ok += int(stream.complete.completed)

    # let every decision window lapse, then run the sweeps one final time:
    # whatever provisional state a lost COMMIT stranded must now be reaped
    timers = home.core.timers
    clock.advance(timers.tau_prep + timers.tau_com + 1.0)
    gw.reap_orphans()
    home.core.coordinator.reap()
    visited.core.coordinator.reap()
    visited.tick()

    established_visited = sum(
        1 for c in clients
        if c.record.get("anchor", "").startswith(f"{visited.domain_id}/"))
    slots_in_use = sum(
        s.slots_in_use() for s in
        list(home.core.sites.values()) + list(visited.core.sites.values())
        if not getattr(s, "is_guest_view", False))
    guest_provisional = sum(1 for g in visited._guest_by_ref.values()
                            if not g.committed)
    orphaned = (len(home.core.coordinator.outstanding)
                + len(visited.core.coordinator.outstanding)
                + guest_provisional
                + max(slots_in_use - established, 0))
    charging_open = sum(
        1 for s in home.core.sessions.values()
        if getattr(s, "failure", None) is not None
        and getattr(s, "charging_ref", None) is not None)

    wire: Dict[str, int] = {}
    for chan in channels + [home.peers[visited.domain_id],
                            visited.peers[home.domain_id]]:
        for k, v in chan.stats.items():
            wire[k] = wire.get(k, 0) + v
    ms = np.asarray(sorted(establish_ms)) if establish_ms else np.zeros(1)
    return LossyControlPlaneResult(
        loss=loss, n_offered=n_sessions, established=established,
        established_visited=established_visited, failed=failed,
        causes=causes, goodput=established / max(n_sessions, 1),
        p50_establish_ms=float(np.quantile(ms, 0.50)),
        p99_establish_ms=float(np.quantile(ms, 0.99)),
        serve_ok=serve_ok, orphaned_after_sweep=orphaned,
        charging_open=charging_open, wire=wire)
