"""Monte-Carlo latency model — paper §V-A.

End-to-end latency decomposes per Eq. (15):

    L = W_q + L_infer + L_net

* ``W_q``      — server-side queueing from offered load ρ, simulated with the
                 exact Lindley recursion W_{n+1} = max(0, W_n + S_n − A_n)
                 (Poisson arrivals at λ = ρ/E[S]), not an M/M/1 formula — the
                 tail blow-up near saturation is the phenomenon under test.
* ``L_infer``  — stochastic inference runtime (lognormal around the service
                 median; heavy-ish tail, σ configurable).
* ``L_net``    — transport: best-effort = base + lognormal jitter + rare
                 congestion spikes (Pareto mixture); QoS-provisioned = base +
                 small truncated jitter (the enforced p99.9 delay budget).

All times in milliseconds. Everything is vectorised numpy with a fixed seed.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class SimConfig:
    n_requests: int = 20_000
    infer_median_ms: float = 40.0
    infer_sigma: float = 0.35
    # best-effort transport
    be_base_ms: float = 12.0
    be_sigma: float = 0.8
    be_spike_prob: float = 0.02
    be_spike_scale_ms: float = 80.0
    be_spike_alpha: float = 1.5       # Pareto tail index (heavy)
    # QoS-provisioned transport
    qos_base_ms: float = 8.0
    qos_sigma: float = 0.15
    qos_cap_ms: float = 25.0          # enforced delay budget
    seed: int = 0


class LatencyModel:
    def __init__(self, cfg: SimConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------
    def infer_times(self, rng, n: int) -> np.ndarray:
        c = self.cfg
        return c.infer_median_ms * np.exp(c.infer_sigma * rng.standard_normal(n))

    def queue_wait(self, rng, n: int, rho: float,
                   service_ms: np.ndarray) -> np.ndarray:
        """Lindley recursion at offered load ρ against the given services."""
        rho = min(max(rho, 1e-3), 0.999)
        lam = rho / float(np.mean(service_ms))          # arrivals per ms
        inter = rng.exponential(1.0 / lam, size=n)
        w = np.empty(n)
        acc = 0.0
        for i in range(n):
            w[i] = acc
            acc = max(0.0, acc + service_ms[i] - inter[i])
        return w

    def transport_best_effort(self, rng, n: int) -> np.ndarray:
        c = self.cfg
        base = c.be_base_ms * np.exp(c.be_sigma * rng.standard_normal(n))
        spikes = (rng.random(n) < c.be_spike_prob) * \
            c.be_spike_scale_ms * (rng.pareto(c.be_spike_alpha, n) + 1.0)
        return base + spikes

    def transport_qos(self, rng, n: int) -> np.ndarray:
        c = self.cfg
        jit = c.qos_base_ms * np.exp(c.qos_sigma * rng.standard_normal(n))
        return np.minimum(jit, c.qos_cap_ms)
