"""§V mobility study (Fig. 4): interruption probability vs user speed.

Drives the REAL control-plane objects — AISession + MigrationController with
a VirtualClock — over a mobility trace, under two handover mechanisms:

* ``teardown``   — baseline: each handover tears the session down and
  re-establishes (DISCOVER→PAGE→PREPARE→COMMIT from scratch); the session is
  interrupted whenever the re-setup gap exceeds the tolerable gap.
* ``mbb``        — NE-AIaaS make-before-break migration: the target anchor is
  prepared and committed while the source keeps serving; interruption only
  if migration fails (state-transfer failure / deadline expiry) AND the
  source lease meanwhile lapses. Transfer is the closed-form wire model
  with injectable failures.
* ``mbb-plane``  — the same control plane, but every handover moves REAL
  session state through the sites' ServingPlane backends
  (export → fingerprint verify → import via ``state_transfer``), with
  export failures injected at the plane's injection points — the live
  data plane under ``VirtualClock``.

Handover events arrive as a Poisson process with rate v / cell_diameter.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.asp import MobilityClass, default_asp
from repro.core.clock import VirtualClock
from repro.core.failures import SessionError
from repro.core.orchestrator import Orchestrator


@dataclass
class MobilityResult:
    speed_kmh: float
    mechanism: str
    interruption_prob: float
    mean_gap_ms: float
    handovers_per_session: float


def simulate_mobility(speed_kmh: float, mechanism: str, *,
                      n_sessions: int = 60, window_s: float = 120.0,
                      cell_diameter_km: float = 0.8,
                      resetup_ms: float = 450.0,
                      tolerable_gap_ms: float = 150.0,
                      transfer_fail_prob: float = 0.02,
                      seed: int = 0) -> MobilityResult:
    rng = np.random.default_rng(seed + int(speed_kmh * 10))
    rate_per_s = (speed_kmh / 3600.0) / cell_diameter_km  # handovers / s
    interrupted = 0
    gaps = []
    total_handover = 0

    for s_idx in range(n_sessions):
        clock = VirtualClock()
        orch = Orchestrator(clock=clock)
        # make migration failures injectable & deterministic per session
        fail_draws = iter(rng.random(1024))

        asp = default_asp(mobility=MobilityClass.VEHICULAR)
        session = orch.establish(asp, invoker=f"ue-{s_idx}", zone="zone-a")

        if mechanism == "mbb-plane":
            # live data plane: serve once so the session has real state in
            # its plane backend, then inject export failures at the plane
            from repro.serving.state_transfer import TransferInjections
            orch.serve(session, prompt_tokens=96, gen_tokens=16)

            def flaky_export(payload, _draws=fail_draws):
                if next(_draws) < transfer_fail_prob:
                    raise IOError("injected export failure")

            inj = TransferInjections(on_export=flaky_export)
            for site in orch.sites.values():
                orch.plane_for(site).migration_inject = inj
        else:
            def flaky_transfer(session, src, dst, _draws=fail_draws):
                if next(_draws) < transfer_fail_prob:
                    from repro.core.failures import FailureCause
                    raise SessionError(FailureCause.STATE_TRANSFER_FAILURE,
                                       "injected transfer failure")
                return 0.040  # 40 ms of state movement

            orch.migrations.transfer_fn = flaky_transfer

        n_ho = rng.poisson(rate_per_s * window_s)
        total_handover += n_ho
        session_interrupted = False
        for _ in range(n_ho):
            if mechanism == "teardown":
                # teardown/re-establish: service gap = full re-setup time
                orch.release(session)
                clock.advance(resetup_ms / 1e3)
                gaps.append(resetup_ms)
                try:
                    session = orch.establish(asp, invoker=f"ue-{s_idx}",
                                             zone="zone-a")
                except SessionError:
                    session_interrupted = True
                    break
                if resetup_ms > tolerable_gap_ms:
                    session_interrupted = True
            else:  # make-before-break (closed-form or live plane transfer)
                out = orch.migrations.migrate(session, "zone-a")
                gaps.append(out.interruption_ms)
                if out.migrated:
                    # contract never left Committed(t): gap is 0
                    if out.interruption_ms > tolerable_gap_ms:
                        session_interrupted = True
                else:
                    # abort path keeps the source binding; interruption only
                    # if the source lease lapsed mid-migration
                    if not session.committed():
                        session_interrupted = True
        if session_interrupted:
            interrupted += 1

    return MobilityResult(
        speed_kmh=speed_kmh, mechanism=mechanism,
        interruption_prob=interrupted / n_sessions,
        mean_gap_ms=float(np.mean(gaps)) if gaps else 0.0,
        handovers_per_session=total_handover / n_sessions)
