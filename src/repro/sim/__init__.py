from repro.sim.latency import LatencyModel, SimConfig  # noqa: F401
from repro.sim.scenarios import (simulate_endpoint, simulate_neaiaas,  # noqa: F401
                                 simulate_multiclass, simulate_bursty,
                                 simulate_load_mobility,
                                 simulate_migration_under_load,
                                 simulate_payload_asymmetry,
                                 simulate_federated_roaming,
                                 simulate_home_overload_spillover)
from repro.sim.mobility import simulate_mobility  # noqa: F401
