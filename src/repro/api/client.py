"""SessionClient — the invoker-side SDK over the northbound wire.

The client NEVER touches orchestrator objects: every interaction is a JSON
message through :meth:`NorthboundGateway.handle_json`, exactly what a remote
ASP would put on the wire. It provides

* context-managed establish → serve → release
  (``with SessionClient(gw, asp=...) as c: ...``),
* a streaming token iterator over ``ServeChunk`` frames,
* automatic lease renewal — a heartbeat fires whenever the server clock
  (read from response timestamps) passes the renewal margin, early by a
  configurable skew allowance so client/server clock drift cannot let a
  lease lapse between "should have renewed" and "renewed",
* at-least-once delivery over an unreliable wire: ``transport=`` accepts
  any ``json-str → json-str`` callable (e.g. a ``netfault.LossyChannel``
  around ``gateway.handle_json``); lost or garbled messages are retried
  with capped exponential backoff + full jitter under the optional
  end-to-end ``deadline_ms`` establishment budget,
* typed exceptions, one per error-code family, so callers can branch on
  remediation (Eq. 12) without string matching.
"""

from __future__ import annotations

import itertools
import uuid
from typing import Callable, Iterator, List, Optional

from repro.api import messages as m
from repro.core.asp import ASP
from repro.core.clock import Clock
from repro.core.failures import FailureCause
from repro.netfault.retry import RetryPolicy
from repro.netfault.wire import TransportError


# ----------------------------------------------------------------------
# typed exceptions
# ----------------------------------------------------------------------
class NorthboundError(Exception):
    """Base: any ErrorResponse surfaced by the gateway."""

    def __init__(self, err: m.ErrorResponse):
        super().__init__(f"{err.code}: {err.detail}")
        self.code = err.code
        self.cause: Optional[FailureCause] = m.cause_for_code(err.code)
        self.detail = err.detail
        self.session_id = err.session_id


class SchemaMismatch(NorthboundError):
    """Protocol or ASP schema version refused (E_SCHEMA_VERSION)."""


class ConsentRevoked(NorthboundError):
    """Eq. (6): serve disabled by consent revocation (E_CONSENT)."""


class PolicyDenied(NorthboundError):
    """Policy / sovereignty / idempotency refusals."""


class ScarcityError(NorthboundError):
    """Compute or QoS scarcity, no feasible binding, model unavailable."""


class DeadlineExpired(NorthboundError):
    """Eq. (11) phase deadline or state-transfer failure."""


class TransportFailure(NorthboundError):
    """Control message lost/garbled in flight (E_TRANSPORT) — retryable."""


class DeadlineExceeded(NorthboundError):
    """End-to-end budget exhausted (E_DEADLINE_EXCEEDED): stop retrying,
    re-issue with a larger ``deadline_ms``."""


class LeaseLapsed(NorthboundError):
    """Auto-renewal ultimately failed (after retries): the session's leases
    may have expired server-side; re-establish rather than keep serving."""


_ERROR_FAMILY = {
    "E_SCHEMA_VERSION": SchemaMismatch,
    "E_CONSENT": ConsentRevoked,
    "E_POLICY": PolicyDenied,
    "E_SOVEREIGNTY": PolicyDenied,
    "E_IDEMPOTENCY_CONFLICT": PolicyDenied,
    "E_MODEL_UNAVAILABLE": ScarcityError,
    "E_NO_FEASIBLE_BINDING": ScarcityError,
    "E_COMPUTE_SCARCITY": ScarcityError,
    "E_QOS_SCARCITY": ScarcityError,
    "E_STATE_TRANSFER": DeadlineExpired,
    "E_DEADLINE": DeadlineExpired,
    "E_TRANSPORT": TransportFailure,
    "E_DEADLINE_EXCEEDED": DeadlineExceeded,
    "E_IDEMPOTENCY_EVICTED": PolicyDenied,
}


def raise_for(err: m.ErrorResponse) -> None:
    raise _ERROR_FAMILY.get(err.code, NorthboundError)(err)


# ----------------------------------------------------------------------
# streaming handle
# ----------------------------------------------------------------------
class TokenStream:
    """Iterator over one streamed generation; ``complete`` holds the final
    ServeComplete after exhaustion (timings, queue wait, error code)."""

    def __init__(self, frames: List[m.Message]):
        self._frames = frames
        self.complete: Optional[m.ServeComplete] = None

    def __iter__(self) -> Iterator[m.ServeChunk]:
        for frame in self._frames:
            if isinstance(frame, m.ErrorResponse):
                raise_for(frame)
            if isinstance(frame, m.ServeComplete):
                self.complete = frame
                if frame.error_code is not None:
                    raise_for(m.ErrorResponse(
                        code=frame.error_code,
                        detail="request served-and-failed",
                        session_id=frame.session_id))
                return
            yield frame

    def tokens(self) -> List[Optional[int]]:
        """Drain the stream, returning the token ids (None when the backend
        is simulated and produces counts, not ids)."""
        return [c.token_id for c in self]


# ----------------------------------------------------------------------
# the SDK handle
# ----------------------------------------------------------------------
class SessionClient:
    """One AI Session as the invoker sees it, over the JSON wire."""

    def __init__(self, gateway, asp: ASP, *, invoker: str = "ue-0",
                 zone: str = "zone-a", subscribe_events: bool = True,
                 auto_renew: bool = True, renew_margin: float = 0.5,
                 transport: Optional[Callable[[str], object]] = None,
                 clock: Optional[Clock] = None,
                 retry: Optional[RetryPolicy] = None,
                 deadline_ms: Optional[float] = None,
                 renew_skew_s: float = 0.5):
        self._gw = gateway
        #: the wire: any json-str → json-str(s) callable. Defaults to the
        #: gateway's own handler; tests/simulations wrap it in a
        #: ``netfault.LossyChannel`` to inject drops/delays/duplicates.
        self._transport = transport if transport is not None \
            else gateway.handle_json
        self._clock = clock if clock is not None else \
            getattr(getattr(gateway, "orch", None), "clock", None) or Clock()
        self._retry = retry if retry is not None else RetryPolicy()
        self.deadline_ms = deadline_ms
        self._deadline_at: Optional[float] = None  # live establish budget
        self.asp = asp
        self.invoker = invoker
        self.zone = zone
        self.auto_renew = auto_renew
        self.renew_margin = renew_margin
        #: renew this many seconds EARLY: tolerated client/server clock skew
        #: plus one retry storm must fit before the lease actually expires
        self.renew_skew_s = renew_skew_s
        self.session_id: Optional[str] = None
        self.record: dict = {}
        self.candidates: List[dict] = []
        self.anchor: Optional[str] = None
        self._lease_s = 0.0
        self._renewed_at = 0.0       # server clock of last confirm/renew
        self._now = 0.0              # latest server clock seen in responses
        self._reqs = itertools.count(1)
        if subscribe_events:
            gateway.subscribe(invoker)

    # -- wire plumbing ---------------------------------------------------
    def _remaining_s(self) -> Optional[float]:
        if self._deadline_at is None:
            return None
        return max(self._deadline_at - self._clock.now(), 0.0)

    def _rpc(self, msg: m.Message) -> m.Message:
        """At-least-once send: transport losses are retried with jittered
        backoff; each (re)send re-stamps the shrinking ``deadline_ms`` so
        every hop downstream sees the budget that is actually left.
        Idempotency keys on the message make the retries safe."""
        attempt = 0
        while True:
            attempt += 1
            remaining = self._remaining_s()
            if remaining is not None:
                if remaining <= 0.0:
                    raise DeadlineExceeded(m.ErrorResponse(
                        code="E_DEADLINE_EXCEEDED",
                        detail=f"[client] {msg.TYPE}: establishment budget "
                               f"exhausted before send",
                        session_id=self.session_id))
                if hasattr(msg, "deadline_ms"):
                    msg.deadline_ms = remaining * 1e3
            try:
                out = self._transport(msg.to_json())
            except TransportError as err:
                if not self._retry.should_retry(err, attempt,
                                                remaining_s=remaining):
                    raise
                self._clock.sleep(self._retry.backoff_s(attempt, key=msg.TYPE))
                continue
            reply = m.from_json(out) if isinstance(out, str) \
                else [m.from_json(o) for o in out]
            if isinstance(reply, m.ErrorResponse):
                raise_for(reply)
            self._observe_time(reply)
            return reply

    def _observe_time(self, reply) -> None:
        frames = reply if isinstance(reply, list) else [reply]
        for f in frames:
            at = getattr(f, "at_s", 0.0)
            if at:
                self._now = max(self._now, at)

    # -- establishment ---------------------------------------------------
    def _establish_once(self) -> "SessionClient":
        """DISCOVER → PAGE → PREPARE → COMMIT, each its own wire message;
        PREPARE/COMMIT carry idempotency keys so retries are safe."""
        disc = self._rpc(m.DiscoverRequest(
            invoker=self.invoker, zone=self.zone, asp=self.asp))
        self.session_id = disc.session_id
        self.candidates = disc.candidates
        paged = self._rpc(m.PageRequest(session_id=self.session_id))
        self.anchor = paged.site_id
        key = uuid.uuid4().hex
        prep = self._rpc(m.PrepareRequest(
            session_id=self.session_id, idempotency_key=f"prep-{key}"))
        com = self._rpc(m.CommitRequest(
            session_id=self.session_id, prepared_ref=prep.prepared_ref,
            idempotency_key=f"commit-{key}"))
        self.record = com.record
        self._lease_s = com.lease_s
        self._renewed_at = com.at_s
        return self

    def establish(self) -> "SessionClient":
        """Establish under the (optional) end-to-end ``deadline_ms`` budget.

        Transport losses retry in ``_rpc`` (same message, same idempotency
        key); *session-level* retryable failures — scarcity, a tripped
        phase timer — re-run the whole establishment from a fresh DISCOVER,
        because the failed session object is terminal server-side. Each
        retry backs off with full jitter and fits inside whatever budget
        remains; a non-retryable cause (or an exhausted budget) surfaces
        as the typed family exception."""
        if self.deadline_ms is not None:
            self._deadline_at = self._clock.now() + self.deadline_ms / 1e3
        try:
            attempt = 0
            while True:
                attempt += 1
                try:
                    return self._establish_once()
                except NorthboundError as err:
                    if err.cause is None or not self._retry.should_retry(
                            err.cause, attempt,
                            remaining_s=self._remaining_s()):
                        raise
                    self._clock.sleep(
                        self._retry.backoff_s(attempt, key="establish"))
        finally:
            # the budget bounds establishment only — serving and renewal
            # run on the lease clock, not the establish deadline
            self._deadline_at = None

    def __enter__(self) -> "SessionClient":
        return self.establish()

    def __exit__(self, exc_type, exc, tb) -> None:
        try:
            self.release()
        except NorthboundError:
            pass                     # already failed/released server-side

    # -- serving ---------------------------------------------------------
    def _maybe_renew(self) -> None:
        if not self.auto_renew or not self._lease_s:
            return
        # renew early by renew_skew_s: the client only sees the server clock
        # through response timestamps, so its view lags by up to one RTT plus
        # any drift — the skew allowance keeps "late renewal because our
        # clock ran slow" from becoming a lapsed lease
        due = max(self.renew_margin * self._lease_s - self.renew_skew_s, 0.0)
        if self._now - self._renewed_at >= due:
            try:
                self.heartbeat()
            except (TransportError, DeadlineExpired) as err:
                # _rpc already retried with jittered backoff; an ultimate
                # loss here means the lease may expire before the next
                # serve — surface that as its own typed condition instead
                # of a generic transport error mid-generate()
                raise LeaseLapsed(m.ErrorResponse(
                    code="E_DEADLINE",
                    detail=f"[client] lease renewal failed after retries "
                           f"({err}); session may have lapsed server-side",
                    session_id=self.session_id)) from err

    def generate(self, *, prompt_tokens: int = 512, gen_tokens: int = 64,
                 prompt: Optional[List[int]] = None) -> TokenStream:
        """Streaming serve: iterate the returned TokenStream chunk by
        chunk; ``.complete`` carries the boundary timings afterwards."""
        self._maybe_renew()
        frames = self._rpc(m.ServeRequest(
            session_id=self.session_id, prompt_tokens=prompt_tokens,
            gen_tokens=gen_tokens, prompt=prompt, stream=True))
        return TokenStream(frames if isinstance(frames, list) else [frames])

    def submit(self, *, prompt_tokens: int = 512, gen_tokens: int = 64,
               prompt: Optional[List[int]] = None) -> Optional[str]:
        """Async serve: returns the request id, or None when admission
        control rejected the request (bounded-queue planes)."""
        self._maybe_renew()
        ack = self._rpc(m.ServeRequest(
            session_id=self.session_id, prompt_tokens=prompt_tokens,
            gen_tokens=gen_tokens, prompt=prompt, stream=False,
            request_id=f"{self.session_id}/c{next(self._reqs)}"))
        return ack.request_id if ack.accepted else None

    def completions(self) -> List[m.ServeComplete]:
        """Retrieve (and consume) the async completions of this invoker's
        sessions — pairs with ``submit()``."""
        out = self._rpc(m.CompletionPoll(invoker=self.invoker))
        return out if isinstance(out, list) else [out]

    # -- continuity ------------------------------------------------------
    def heartbeat(self, *, trigger_l99: Optional[float] = None,
                  trigger_ttfb: Optional[float] = None) -> m.HeartbeatAck:
        ack = self._rpc(m.HeartbeatReport(
            session_id=self.session_id, trigger_l99=trigger_l99,
            trigger_ttfb=trigger_ttfb))
        if ack.committed:
            self._lease_s = ack.lease_s
            self._renewed_at = ack.at_s
        if ack.migration and ack.migration.get("migrated"):
            self.anchor = ack.migration["to_site"]
        return ack

    def events(self) -> List[m.SessionEvent]:
        """Drain this invoker's event subscription (state transitions,
        migration notifications)."""
        out = self._rpc(m.EventPoll(invoker=self.invoker))
        return out if isinstance(out, list) else [out]

    def compliance(self) -> m.ComplianceReport:
        return self._rpc(m.ComplianceRequest(session_id=self.session_id))

    # -- teardown --------------------------------------------------------
    def release(self) -> m.ReleaseAck:
        ack = self._rpc(m.ReleaseRequest(session_id=self.session_id))
        self._lease_s = 0.0
        return ack
