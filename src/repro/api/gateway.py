"""NorthboundGateway — the single CAPIF-style entry point to the AIS
lifecycle.

Everything an invoker can do goes through :meth:`handle` (typed messages)
or :meth:`handle_json` (the actual wire): DISCOVER / AI-PAGING / PREPARE /
COMMIT stepwise, streaming or async SERVE, HEARTBEAT (with Eq. 14 trigger
overrides), COMPLIANCE, RELEASE, and per-invoker event subscriptions that
surface state transitions and migration outcomes as
:class:`~repro.api.messages.SessionEvent` notifications.

Gateway guarantees on top of the orchestrator:

* **schema-version negotiation** — messages (and the embedded ASP record)
  whose major version disagrees with the gateway's are refused with
  ``E_SCHEMA_VERSION`` before touching any lifecycle state;
* **idempotent PREPARE/COMMIT** — a retried request with the same
  ``idempotency_key`` returns the original outcome (success *or* error)
  instead of reserving twice; the same key with a different payload is an
  ``E_IDEMPOTENCY_CONFLICT``;
* **structured failure semantics** — every ``SessionError`` maps onto its
  distinct Eq. (12) error code (:data:`~repro.api.messages.ERROR_CODE_TABLE`);
  gateway-layer refusals use disjoint codes;
* **deadline budgets** — a request carrying ``deadline_ms`` (the shrinking
  remaining budget, relative so clock skew cannot corrupt it) is refused
  with ``E_DEADLINE_EXCEEDED`` when the budget cannot cover the phase's
  Eq. (11) floor — the gateway never queues doomed work. The refusal does
  NOT fail the session: the invoker may re-issue with a larger budget;
* **orphan reaping** — ``reap_orphans()`` (run on every pump/drain cycle)
  aborts prepared-but-never-committed establishments once
  τ_prep + τ_com + hold has passed, so a COMMIT lost in flight can never
  strand provisional leases;
* **idempotency-window eviction** is attributable: a retry whose key aged
  out of the bounded window gets ``E_IDEMPOTENCY_EVICTED`` (we can no
  longer prove what the original outcome was) instead of silently
  re-reserving or tripping the state machine.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import json
from typing import Callable, Deque, Dict, Iterator, List, Optional, Tuple, Union

from repro.api import messages as m
from repro.core.asp import SchemaVersionError
from repro.core.failures import FailureCause, SessionError
from repro.core.migration import MigrationTriggers
from repro.core.orchestrator import Orchestrator
from repro.core.session import AISession, SessionState

Reply = Union[m.Message, List[m.Message]]


@dataclasses.dataclass
class _Pending:
    """Establishment state between stepwise procedures. The stored
    responses make keyless PAGE/PREPARE retries replay-safe: a duplicate
    (response lost in transport) returns the original outcome instead of
    tripping the state machine into FAILED."""
    session: AISession
    candidates: Optional[list] = None
    chosen: object = None
    prepared: object = None
    page_response: Optional[m.PageResponse] = None
    prepare_response: Optional[m.PrepareResponse] = None
    #: gateway-clock timestamp of the successful PREPARE — the orphan
    #: reaper's horizon base (works for local and federated prepares alike)
    prepared_at: Optional[float] = None


class NorthboundGateway:
    def __init__(self, orch: Optional[Orchestrator] = None, *, clock=None,
                 event_queue_len: int = 1024,
                 completion_buffer_len: int = 1 << 20,
                 idempotency_window: int = 4096,
                 establishment_window: int = 4096):
        # a federation DomainController is accepted in place of its core:
        # the gateway contract is unchanged, establishment just becomes
        # home-routed (home first, then east-west offers)
        if orch is not None and hasattr(orch, "core") and \
                isinstance(orch.core, Orchestrator):
            orch = orch.core
        self.orch = orch if orch is not None else Orchestrator(clock=clock)
        self.orch.result_sinks.append(self._on_result)
        self.orch.split_event_sinks.append(self._on_split_event)
        self._pending: Dict[str, _Pending] = {}
        self._prepared_refs: Dict[str, str] = {}     # ref -> session_id
        #: bounded retry window: oldest keys age out so a long-lived
        #: gateway does not grow with total session count
        self._idem: "collections.OrderedDict[str, Tuple[str, Reply]]" = \
            collections.OrderedDict()
        self._idempotency_window = idempotency_window
        #: keys aged out of the window — a retry under one of these gets a
        #: clean E_IDEMPOTENCY_EVICTED (the original outcome is gone, so
        #: replay safety can no longer be proven). Bounded like the window.
        self._idem_evicted: "collections.OrderedDict[str, bool]" = \
            collections.OrderedDict()
        #: abandoned-handshake bound: oldest in-flight establishments are
        #: evicted past the window (their provisional 2PC leases expire by
        #: TTL on the resource planes regardless)
        self._establishment_window = establishment_window
        self._subs: Dict[str, Deque[m.SessionEvent]] = {}
        #: async completions are buffered ONLY for requests that entered
        #: through submit() — unary serves (gateway or direct orchestrator
        #: callers) return their result inline and must not reappear here
        self._async_pending: set = set()
        self._completions: Deque[m.ServeComplete] = collections.deque(
            maxlen=completion_buffer_len)
        self._refs = itertools.count(1)
        self._event_queue_len = event_queue_len

    # ------------------------------------------------------------------
    # wire entry points
    # ------------------------------------------------------------------
    def handle_json(self, payload: str) -> Union[str, List[str]]:
        """The actual northbound wire: JSON in, JSON out (a streaming
        request returns a list of JSON frames, chunks then completion)."""
        try:
            msg = m.from_json(payload)
        except SchemaVersionError as e:
            return m.ErrorResponse("E_SCHEMA_VERSION",
                                   detail=str(e)).to_json()
        except ValueError as e:
            return m.ErrorResponse("E_BAD_REQUEST",
                                   detail=str(e)).to_json()
        except (TypeError, KeyError) as e:
            return m.ErrorResponse("E_BAD_REQUEST",
                                   detail=repr(e)).to_json()
        out = self.handle(msg)
        if isinstance(out, list):
            return [o.to_json() for o in out]
        return out.to_json()

    def handle(self, msg: m.Message) -> Reply:
        """Typed dispatch (the JSON path normalizes into here)."""
        ver = getattr(msg, "schema_version", m.SCHEMA_VERSION)
        if str(ver).split(".")[0] != m.SCHEMA_VERSION.split(".")[0]:
            return m.ErrorResponse(
                "E_SCHEMA_VERSION",
                detail=f"protocol {ver!r} incompatible with gateway "
                       f"{m.SCHEMA_VERSION!r}")
        handler = self._DISPATCH.get(type(msg))
        if handler is None:
            return m.ErrorResponse(
                "E_BAD_REQUEST",
                detail=f"{msg.TYPE!r} is not an invoker-initiated message")
        try:
            return handler(self, msg)
        except _Unknown as e:
            return m.ErrorResponse("E_UNKNOWN_SESSION", detail=str(e),
                                   session_id=e.session_id)
        except SessionError as e:
            return m.ErrorResponse.from_session_error(
                e, session_id=getattr(msg, "session_id", None))
        except Exception as e:                       # noqa: BLE001
            return m.ErrorResponse(
                "E_INTERNAL", detail=f"{type(e).__name__}: {e}",
                session_id=getattr(msg, "session_id", None))

    # ------------------------------------------------------------------
    # helpers
    # ------------------------------------------------------------------
    def _session(self, session_id: str) -> AISession:
        s = self.orch.sessions.get(session_id)
        if s is None:
            raise _Unknown(session_id)
        return s

    def _emit(self, session: AISession, event: str, *,
              state: Optional[str] = None, detail: Optional[dict] = None
              ) -> None:
        q = self._subs.get(session.invoker)
        if q is None:
            return
        q.append(m.SessionEvent(
            session_id=session.session_id, event=event,
            state=state if state is not None else session.state.value,
            detail=detail or {}, at_s=self.orch.clock.now()))

    def _on_split_event(self, session_id: str, event: str,
                        detail: dict) -> None:
        """SplitManager sink: split quality-tier transitions (degrade to
        edge-only, verify recovery, collapse, verify migration) surface to
        the invoker as explicit tier-change SessionEvents — an airplane
        -mode session is DEGRADED, never silently worse and never failed."""
        session = self.orch.sessions.get(session_id)
        if session is None:
            return
        self._emit(session, "tier-change",
                   detail={"event": event, **(detail or {})})

    def subscribe(self, invoker: str) -> None:
        """Open (or reset) the invoker's event subscription."""
        self._subs[invoker] = collections.deque(
            maxlen=self._event_queue_len)

    def poll_events(self, invoker: str) -> List[m.SessionEvent]:
        q = self._subs.get(invoker)
        if q is None:
            return []
        out = list(q)
        q.clear()
        return out

    @staticmethod
    def _fingerprint(req: m.Message) -> str:
        """Payload identity for idempotency conflict detection. The
        shrinking ``deadline_ms`` budget is excluded: an at-least-once
        re-send legitimately carries less remaining budget than the
        original, and that must read as the SAME request."""
        wire = req.to_wire()
        wire.pop("deadline_ms", None)
        return json.dumps(wire, sort_keys=True)

    def _idempotent(self, key: Optional[str], req: m.Message,
                    fn: Callable[[], Reply]) -> Reply:
        if key is not None and key in self._idem:
            fingerprint, reply = self._idem[key]
            if fingerprint != self._fingerprint(req):
                return m.ErrorResponse(
                    "E_IDEMPOTENCY_CONFLICT",
                    detail=f"key {key!r} was used for a different request",
                    session_id=getattr(req, "session_id", None))
            return reply
        if key is not None and key in self._idem_evicted:
            # the original outcome aged out of the bounded window: running
            # fn() again could double-reserve, so refuse attributably —
            # the invoker must start a fresh procedure (fresh key)
            return m.ErrorResponse(
                "E_IDEMPOTENCY_EVICTED",
                detail=f"[gateway] key {key!r} aged out of the idempotency "
                       f"window ({self._idempotency_window}); the original "
                       f"outcome is no longer known",
                session_id=getattr(req, "session_id", None))
        reply = fn()
        if key is not None:
            self._idem[key] = (self._fingerprint(req), reply)
            while len(self._idem) > self._idempotency_window:
                evicted_key, _ = self._idem.popitem(last=False)
                self._idem_evicted[evicted_key] = True
                while len(self._idem_evicted) > self._idempotency_window:
                    self._idem_evicted.popitem(last=False)
        return reply

    def _check_deadline(self, deadline_ms: Optional[float], floor_s: float,
                        phase: str,
                        session: Optional[AISession] = None) -> None:
        """Refuse work the remaining budget cannot cover (Eq. 11 floor for
        the phase). Attribution is per hop — this one is ``[gateway]``; a
        visited domain rejecting the forwarded remainder says
        ``[visited:<domain>]``. The budget is relative ms on the wire
        (gRPC-style), so client/server clock skew cannot corrupt it."""
        if deadline_ms is None:
            return
        floor_ms = max(floor_s, 0.0) * 1e3
        if deadline_ms <= floor_ms:
            raise SessionError(
                FailureCause.DEADLINE_EXCEEDED,
                f"[gateway] {phase}: {deadline_ms:.1f}ms remaining cannot "
                f"cover the {floor_ms:.0f}ms phase floor")
        if session is not None:
            session.deadline_at = self.orch.clock.now() + deadline_ms / 1e3

    def _drop_establishment_state(self, session_id: str) -> None:
        self._pending.pop(session_id, None)
        for ref in [r for r, sid in self._prepared_refs.items()
                    if sid == session_id]:
            del self._prepared_refs[ref]

    def _refailed(self, session: AISession) -> Optional[Reply]:
        """A lost-response retry against an already-failed session must
        re-report the ORIGINAL failure cause, not a bogus out-of-order
        ``E_BAD_REQUEST`` — the pending establishment state was dropped
        when the session failed, but the cause (and its retryability
        class) survives on the session itself."""
        if session.failure is None:
            return None
        return m.ErrorResponse.from_session_error(
            SessionError(session.failure,
                         f"establishment already failed "
                         f"({session.failure.value}); this retry re-reports "
                         f"the original outcome"),
            session_id=session.session_id)

    def _establishment_step(self, session: AISession,
                            fn: Callable[[], Reply]) -> Reply:
        """Run one establishment procedure; a SessionError fails the session
        (mirror of Orchestrator.establish) and maps to its error code."""
        try:
            return fn()
        except SessionError as e:
            session.fail(e.cause, str(e))
            self._drop_establishment_state(session.session_id)
            self._emit(session, "state-transition", state="failed",
                       detail={"cause": e.cause.value})
            return m.ErrorResponse.from_session_error(
                e, session_id=session.session_id)

    # ------------------------------------------------------------------
    # lifecycle procedures
    # ------------------------------------------------------------------
    def discover(self, msg: m.DiscoverRequest) -> Reply:
        self._check_deadline(msg.deadline_ms, self.orch.timers.tau_disc,
                             "DISCOVER")
        try:
            session = self.orch.begin_session(msg.asp, msg.invoker,
                                              msg.zone)
        except ValueError as e:
            # contract refused before any lifecycle state exists (invalid
            # ASP, or objectives incompatible with this gateway's Eq. 11
            # timer configuration) — an input refusal, not an internal error
            return m.ErrorResponse("E_BAD_REQUEST", detail=str(e))
        while len(self._pending) >= self._establishment_window:
            oldest = next(iter(self._pending))
            self._drop_establishment_state(oldest)
        self._pending[session.session_id] = _Pending(session)

        def run():
            cands = self.orch.discover_for(session)
            self._pending[session.session_id].candidates = cands
            self._emit(session, "state-transition")
            wire = [c.to_wire() for c in cands]
            return m.DiscoverResponse(session_id=session.session_id,
                                      candidates=wire)
        return self._establishment_step(session, run)

    def page(self, msg: m.PageRequest) -> Reply:
        session = self._session(msg.session_id)
        self._check_deadline(msg.deadline_ms, self.orch.timers.tau_page,
                             "AI-PAGING", session)
        pending = self._pending.get(msg.session_id)
        if pending is None or pending.candidates is None:
            return self._refailed(session) or m.ErrorResponse(
                "E_BAD_REQUEST", detail="PAGE before DISCOVER",
                session_id=msg.session_id)
        if pending.page_response is not None:
            return pending.page_response         # lost-response retry

        def run():
            chosen = self.orch.page_for(session, pending.candidates,
                                        tuple(msg.exclude_sites))
            pending.chosen = chosen
            self._emit(session, "state-transition")
            pending.page_response = m.PageResponse(
                session_id=session.session_id,
                model_id=chosen.model.model_id,
                model_version=chosen.model.version,
                site_id=chosen.site_id, klass=chosen.klass.name,
                predicted_cost_per_1k=chosen.prediction.cost_per_1k,
                domain=chosen.domain)
            return pending.page_response
        return self._establishment_step(session, run)

    def prepare(self, msg: m.PrepareRequest) -> Reply:
        session = self._session(msg.session_id)
        self._check_deadline(msg.deadline_ms, self.orch.timers.tau_prep,
                             "PREPARE", session)
        pending = self._pending.get(msg.session_id)
        if pending is None or pending.chosen is None:
            return self._refailed(session) or m.ErrorResponse(
                "E_BAD_REQUEST", detail="PREPARE before PAGE",
                session_id=msg.session_id)
        if pending.prepare_response is not None:
            return pending.prepare_response      # lost-response retry

        def run():
            def do():
                prepared = self.orch.prepare_for(session, pending.chosen)
                pending.prepared = prepared
                pending.prepared_at = self.orch.clock.now()
                ref = f"prep-{next(self._refs):06d}"
                self._prepared_refs[ref] = session.session_id
                self._emit(session, "state-transition")
                pending.prepare_response = m.PrepareResponse(
                    session_id=session.session_id, prepared_ref=ref,
                    site_id=prepared.site_id, qfi=prepared.qfi)
                return pending.prepare_response
            return self._establishment_step(session, do)
        return self._idempotent(msg.idempotency_key, msg, run)

    def commit(self, msg: m.CommitRequest) -> Reply:
        session = self._session(msg.session_id)
        self._check_deadline(msg.deadline_ms, self.orch.timers.tau_com,
                             "COMMIT", session)

        def run():
            pending = self._pending.get(msg.session_id)
            if self._prepared_refs.get(msg.prepared_ref) != msg.session_id \
                    or pending is None or pending.prepared is None:
                return self._refailed(session) or m.ErrorResponse(
                    "E_BAD_REQUEST",
                    detail=f"no commitable PREPARE under ref "
                           f"{msg.prepared_ref!r}",
                    session_id=msg.session_id)

            def do():
                self.orch.commit_for(session, pending.chosen,
                                     pending.prepared)
                self._pending.pop(msg.session_id, None)
                self._prepared_refs.pop(msg.prepared_ref, None)
                self._emit(session, "state-transition")
                return m.CommitResponse(
                    session_id=session.session_id, record=session.record(),
                    lease_s=self.orch.timers.lease_s,
                    at_s=self.orch.clock.now())
            return self._establishment_step(session, do)
        return self._idempotent(msg.idempotency_key, msg, run)

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def _handle_serve(self, msg: m.ServeRequest) -> Reply:
        self._check_deadline(msg.deadline_ms, 0.0, "SERVE")
        if msg.stream:
            return list(self.serve_stream(msg))
        return self.submit(msg)

    def serve_stream(self, msg: m.ServeRequest) -> Iterator[m.Message]:
        """Unary-streaming serve: one ServeChunk per generated token, then
        a ServeComplete with the boundary-observable timings."""
        try:
            session = self._session(msg.session_id)
            prompt = None
            if msg.prompt is not None:
                import numpy as np
                prompt = np.asarray(msg.prompt, np.int32)
            res = self.orch.serve(
                session, prompt_tokens=msg.prompt_tokens,
                gen_tokens=msg.gen_tokens, prompt=prompt,
                request_id=msg.request_id, deadline_ms=msg.deadline_ms)
        except SessionError as e:
            yield m.ErrorResponse.from_session_error(
                e, session_id=msg.session_id)
            return
        for i in range(res.text_tokens):
            yield m.ServeChunk(
                session_id=msg.session_id, request_id=res.request_id, seq=i,
                token_id=res.token_ids[i] if res.token_ids else None)
        yield m.ServeComplete(
            session_id=msg.session_id, request_id=res.request_id,
            klass=res.klass, tokens=res.text_tokens,
            prompt_tokens=msg.prompt_tokens,
            ttfb_ms=res.ttfb_ms, latency_ms=res.latency_ms,
            queue_wait_ms=res.queue_wait_ms, completed=res.completed,
            error_code=m.code_for_cause(res.failed) if res.failed else None,
            token_ids=res.token_ids, at_s=self.orch.clock.now())

    def submit(self, msg: m.ServeRequest) -> Reply:
        """Async serve: enqueue on the anchor plane, acknowledge admission;
        the completion arrives through ``drain()`` / ``pump()``."""
        session = self._session(msg.session_id)
        prompt = None
        if msg.prompt is not None:
            import numpy as np
            prompt = np.asarray(msg.prompt, np.int32)
        req = self.orch.submit(
            session, prompt_tokens=msg.prompt_tokens,
            gen_tokens=msg.gen_tokens, prompt=prompt,
            request_id=msg.request_id, deadline_ms=msg.deadline_ms)
        if req is not None:
            self._async_pending.add(req.request_id)
        return m.SubmitAck(
            session_id=msg.session_id,
            request_id=req.request_id if req is not None else msg.request_id,
            accepted=req is not None, at_s=self.orch.clock.now())

    def _on_result(self, site, res) -> None:
        """Orchestrator result sink: every async-submitted request's
        PlaneResult becomes a buffered ServeComplete, whichever path
        (heartbeat/pump/drain) popped it; unary serves already returned
        their result inline and are not re-announced."""
        if res.request_id not in self._async_pending:
            return
        self._async_pending.discard(res.request_id)
        self._completions.append(m.ServeComplete(
            session_id=res.session_id, request_id=res.request_id,
            klass=res.klass, tokens=res.tokens,
            prompt_tokens=res.prompt_tokens, ttfb_ms=res.ttfb_ms,
            latency_ms=res.latency_ms, queue_wait_ms=res.queue_wait_ms,
            completed=res.completed,
            error_code=m.code_for_cause(res.failed) if res.failed else None,
            token_ids=res.token_ids, at_s=self.orch.clock.now()))

    def reap_orphans(self, now: Optional[float] = None) -> int:
        """Abort every prepared-but-never-committed establishment whose
        decision window (τ_prep + τ_com + hold) has passed — the COMMIT
        (or the client) was lost in flight, and nothing will re-drive it.

        Rollback is idempotent with the coordinator's own
        :meth:`~repro.core.twophase.TwoPhaseCoordinator.reap` (whichever
        sweep runs first wins; the other is a no-op); federated prepares
        abort east-west, where EWAbort degenerates to release if the
        visited COMMIT had actually landed. Runs on every pump/drain
        cycle, i.e. the plane-heartbeat cadence."""
        orch = self.orch
        now = orch.clock.now() if now is None else now
        horizon = orch.timers.tau_prep + orch.timers.tau_com
        reaped = 0
        for sid in list(self._pending):
            p = self._pending.get(sid)
            if p is None or p.prepared is None or p.prepared_at is None:
                continue
            hold = getattr(p.prepared, "hold_s", 0.0)
            if now - p.prepared_at <= horizon + hold:
                continue
            try:
                if getattr(p.prepared, "is_federated", False):
                    orch.federation.abort_remote(p.prepared,
                                                 reason="orphan-reap")
                else:
                    orch.coordinator.abort(p.prepared)
            except Exception:                        # noqa: BLE001
                pass         # provisional leases expire by TTL regardless
            session = p.session
            self._drop_establishment_state(sid)
            if session.state is SessionState.PREPARED:
                session.fail(FailureCause.DEADLINE_EXPIRY,
                             "orphaned PREPARE reaped "
                             "(COMMIT lost in flight)")
                self._emit(session, "state-transition", state="failed",
                           detail={"cause":
                                   FailureCause.DEADLINE_EXPIRY.value,
                                   "detail": "orphan-reap"})
            reaped += 1
        return reaped

    def pump(self, until_s: float) -> None:
        """Advance every site plane to absolute time ``until_s`` (virtual
        clocks) and record the completions that fell due."""
        for site in self.orch.sites.values():
            if site.plane is not None:
                site.plane.run_until(until_s)
                self.orch.record_results(site)
        self.reap_orphans()

    def drain(self) -> List[m.ServeComplete]:
        """Run every plane to completion and return ALL completions
        recorded since the last drain (async submits + heartbeat pickups)."""
        for site in self.orch.sites.values():
            if site.plane is not None:
                site.plane.drain()
                self.orch.record_results(site)
        self.reap_orphans()
        out = list(self._completions)
        self._completions.clear()
        return out

    def poll_completions(self, invoker: str) -> List[m.ServeComplete]:
        """Wire counterpart of ``drain()`` for ONE invoker: hand over (and
        remove) the buffered async completions of that invoker's sessions.
        Does not force the planes forward — completions appear as serves,
        heartbeats, and pump/drain cycles record them."""
        mine, keep = [], []
        for c in self._completions:
            s = self.orch.sessions.get(c.session_id)
            if s is not None and s.invoker == invoker:
                mine.append(c)
            else:
                keep.append(c)
        self._completions = collections.deque(
            keep, maxlen=self._completions.maxlen)
        return mine

    def _handle_completion_poll(self, msg: m.CompletionPoll) -> Reply:
        return list(self.poll_completions(msg.invoker))

    # ------------------------------------------------------------------
    # tenant adapter lifecycle
    # ------------------------------------------------------------------
    def register_adapter(self, msg: m.RegisterAdapterRequest) -> Reply:
        """Publish a versioned adapter into the domain catalog (weights
        materialised deterministically from the seed — the stand-in for
        a tenant upload). Duplicate keys and unknown base models are
        input refusals, not lifecycle failures."""
        from repro.adapters.catalog import AdapterSpec
        spec = AdapterSpec(
            adapter_id=msg.adapter_id, version=msg.version,
            base_model_id=msg.base_model_id,
            base_model_version=msg.base_model_version,
            rank=int(msg.rank), regions=tuple(msg.regions),
            scale=float(msg.scale), seed=int(msg.seed))
        try:
            stored = self.orch.catalog.register_adapter(spec)
        except ValueError as e:
            return m.ErrorResponse("E_BAD_REQUEST", detail=str(e))
        return m.RegisterAdapterResponse(
            adapter_id=stored.adapter_id, version=stored.version,
            base_model_id=stored.base_model_id,
            weight_fingerprint=stored.weight_fingerprint,
            at_s=self.orch.clock.now())

    def _adapter_site(self, site_id: str):
        site = self.orch.sites.get(site_id)
        if site is None:
            return None, m.ErrorResponse(
                "E_BAD_REQUEST", detail=f"unknown site {site_id!r}")
        return site, None

    def load_adapter(self, msg: m.LoadAdapterRequest) -> Reply:
        site, err = self._adapter_site(msg.site_id)
        if err is not None:
            return err
        adapters = self.orch.catalog.adapters
        try:
            spec = adapters.get(msg.adapter_id, msg.version or None)
        except KeyError:
            raise SessionError(
                FailureCause.MODEL_UNAVAILABLE,
                f"adapter {msg.adapter_id!r} is not registered") from None
        if site.spec.region not in spec.regions:
            raise SessionError(
                FailureCause.SOVEREIGNTY_VIOLATION,
                f"adapter {spec.key} not licensed for region "
                f"{site.spec.region!r}")
        engine_loaded = False
        backend = self.orch.plane_for(site).backend
        eng = getattr(backend, "engine", None)
        if eng is not None and getattr(eng, "adapters", None) is not None:
            a, b = adapters.weights(spec.adapter_id, spec.version)
            eng.load_adapter(spec.adapter_id, a, b)
            engine_loaded = True
        adapters.mark_loaded(spec.adapter_id, msg.site_id)
        return m.LoadAdapterResponse(
            adapter_id=spec.adapter_id, site_id=msg.site_id, loaded=True,
            engine_loaded=engine_loaded, at_s=self.orch.clock.now())

    def unload_adapter(self, msg: m.UnloadAdapterRequest) -> Reply:
        site, err = self._adapter_site(msg.site_id)
        if err is not None:
            return err
        adapters = self.orch.catalog.adapters
        try:
            spec = adapters.get(msg.adapter_id)
        except KeyError:
            raise SessionError(
                FailureCause.MODEL_UNAVAILABLE,
                f"adapter {msg.adapter_id!r} is not registered") from None
        live = (SessionState.PREPARED, SessionState.COMMITTED,
                SessionState.MIGRATING)
        bound = [s.session_id for s in self.orch.sessions.values()
                 if s.state in live and s.binding is not None
                 and s.binding.site_id == msg.site_id
                 and s.asp.adapter_id == spec.adapter_id]
        if bound:
            return m.ErrorResponse(
                "E_BAD_REQUEST", session_id=None,
                detail=f"adapter {spec.adapter_id!r} still bound at "
                       f"{msg.site_id} by live sessions {bound[:3]}")
        backend = self.orch.plane_for(site).backend
        eng = getattr(backend, "engine", None)
        if eng is not None and getattr(eng, "adapters", None) is not None \
                and eng.adapters.is_loaded(spec.adapter_id):
            try:
                eng.unload_adapter(spec.adapter_id)
            except RuntimeError as e:     # engine slots still bound
                return m.ErrorResponse("E_BAD_REQUEST", detail=str(e),
                                       session_id=None)
        adapters.mark_unloaded(spec.adapter_id, msg.site_id)
        return m.UnloadAdapterResponse(
            adapter_id=spec.adapter_id, site_id=msg.site_id, unloaded=True,
            at_s=self.orch.clock.now())

    # ------------------------------------------------------------------
    # continuity + teardown
    # ------------------------------------------------------------------
    def heartbeat(self, msg: m.HeartbeatReport) -> Reply:
        session = self._session(msg.session_id)
        self._check_deadline(msg.deadline_ms, 0.0, "HEARTBEAT", session)
        trig = None
        if msg.trigger_l99 is not None or msg.trigger_ttfb is not None:
            base = MigrationTriggers()
            trig = MigrationTriggers(
                delta_l99=msg.trigger_l99 if msg.trigger_l99 is not None
                else base.delta_l99,
                delta_ttfb=msg.trigger_ttfb if msg.trigger_ttfb is not None
                else base.delta_ttfb)
        outcome = self.orch.heartbeat(session, trig)
        wire = None
        if outcome is not None:
            wire = m.outcome_to_wire(outcome)
            self._emit(session, "migration", detail=wire)
        return m.HeartbeatAck(
            session_id=msg.session_id, committed=session.committed(),
            lease_s=self.orch.timers.lease_s, migration=wire,
            at_s=self.orch.clock.now())

    def compliance(self, msg: m.ComplianceRequest) -> Reply:
        session = self._session(msg.session_id)
        rep = self.orch.compliance(session)
        tele = self.orch.telemetry.get(msg.session_id)
        if rep is None:
            return m.ComplianceReport(session_id=msg.session_id)
        return m.ComplianceReport(
            session_id=msg.session_id, in_compliance=rep.in_compliance,
            z=dataclasses.asdict(rep.z), n=len(tele) if tele else 0)

    def release(self, msg: m.ReleaseRequest) -> Reply:
        session = self._session(msg.session_id)
        tokens, cost = 0, 0.0
        if session.charging_ref is not None:
            rec = self.orch.policy.charging(session.charging_ref)
            tokens, cost = rec.tokens, rec.cost
        self.orch.release(session)
        self._drop_establishment_state(msg.session_id)
        self._emit(session, "state-transition")
        return m.ReleaseAck(session_id=msg.session_id,
                            state=session.state.value,
                            tokens=tokens, total_cost=cost)

    def _handle_event_poll(self, msg: m.EventPoll) -> Reply:
        return list(self.poll_events(msg.invoker))

    # ------------------------------------------------------------------
    _DISPATCH: Dict[type, Callable] = {
        m.DiscoverRequest: discover,
        m.PageRequest: page,
        m.PrepareRequest: prepare,
        m.CommitRequest: commit,
        m.ServeRequest: _handle_serve,
        m.HeartbeatReport: heartbeat,
        m.ComplianceRequest: compliance,
        m.ReleaseRequest: release,
        m.EventPoll: _handle_event_poll,
        m.CompletionPoll: _handle_completion_poll,
        m.RegisterAdapterRequest: register_adapter,
        m.LoadAdapterRequest: load_adapter,
        m.UnloadAdapterRequest: unload_adapter,
    }


class _Unknown(Exception):
    """Unknown session id — a gateway-layer refusal (``E_UNKNOWN_SESSION``),
    deliberately NOT a SessionError: no Eq. (12) cause applies because the
    request never reached the lifecycle machinery."""

    def __init__(self, session_id: str):
        super().__init__(f"unknown session {session_id!r}")
        self.session_id = session_id
