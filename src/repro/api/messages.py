"""Northbound wire protocol: versioned, JSON-round-trippable messages.

Every type here is a flat dataclass whose fields are JSON-native (str, int,
float, bool, None, list, dict) except the embedded :class:`~repro.core.asp.ASP`
intent contract, which carries its own versioned wire codec. The invariant
the property tests pin down is

    m == from_json(m.to_json())        for every message type m

so a message can cross any transport (HTTP body, SBI service operation,
Kafka record) without the two sides disagreeing about its meaning.

Error semantics: :class:`ErrorResponse` carries a structured ``code`` whose
mapping onto the paper's Eq. (12) nine-cause partition is exhaustive and
bijective (``code_for_cause`` / ``cause_for_code``); gateway-level codes
(schema mismatch, unknown session, idempotency conflict, malformed request)
are disjoint from the cause codes by construction.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import ClassVar, Dict, List, Optional

from repro.core.asp import ASP
from repro.core.failures import FailureCause, SessionError

#: wire-schema version of the northbound protocol; majors must match
#: between invoker and gateway (minor additions are backward-compatible).
#: 1.1: federation — candidate entries and PageResponse carry the owning
#: ``domain`` (and candidate ``region``); "" means the home domain.
#: 1.2: tenant adapters — RegisterAdapter/LoadAdapter/UnloadAdapter
#: lifecycle messages; ``ASP.adapter_id`` rides the existing ASP codec.
#: 1.3: unreliable control plane — optional ``deadline_ms`` budget on
#: lifecycle/serve/heartbeat requests (relative milliseconds remaining,
#: gRPC-style, shrinking per hop); new causes TRANSPORT_FAILURE /
#: DEADLINE_EXCEEDED (E_TRANSPORT / E_DEADLINE_EXCEEDED) and gateway code
#: E_IDEMPOTENCY_EVICTED for retries arriving after window eviction.
SCHEMA_VERSION = "1.3"

_REGISTRY: Dict[str, type] = {}


def _registered(cls):
    _REGISTRY[cls.TYPE] = cls
    return cls


@dataclass
class Message:
    """Base: a typed wire message with a version envelope."""

    TYPE: ClassVar[str] = ""

    def to_wire(self) -> dict:
        out = {"type": self.TYPE}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, ASP):
                v = v.to_wire()
            out[f.name] = v
        return out

    def to_json(self) -> str:
        return json.dumps(self.to_wire(), sort_keys=True)

    @classmethod
    def _decode(cls, kw: dict) -> "Message":
        # minor-version forward compatibility: fields added by a newer 1.x
        # peer are ignored, exactly like ASP.from_wire (majors are checked
        # by the gateway envelope negotiation)
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in kw.items() if k in names})


def from_wire(d: dict) -> Message:
    if not isinstance(d, dict):
        raise ValueError(
            f"northbound frame must be a JSON object, got {type(d).__name__}")
    kind = d.get("type")
    cls = _REGISTRY.get(kind)
    if cls is None:
        raise ValueError(f"unknown northbound message type {kind!r}")
    return cls._decode({k: v for k, v in d.items() if k != "type"})


def from_json(s: str) -> Message:
    return from_wire(json.loads(s))


def message_types() -> Dict[str, type]:
    """The full registry (used by the exhaustiveness tests and README)."""
    return dict(_REGISTRY)


# ----------------------------------------------------------------------
# lifecycle: DISCOVER → PAGE → PREPARE → COMMIT
# ----------------------------------------------------------------------
@_registered
@dataclass
class DiscoverRequest(Message):
    TYPE: ClassVar[str] = "discover_request"
    invoker: str
    zone: str
    asp: ASP
    #: remaining deadline budget in ms (relative, gRPC-style — skew-safe);
    #: None = no enforcement (pre-1.3 peers)
    deadline_ms: Optional[float] = None
    schema_version: str = SCHEMA_VERSION

    @classmethod
    def _decode(cls, kw: dict) -> "DiscoverRequest":
        kw = dict(kw)
        if isinstance(kw.get("asp"), dict):
            kw["asp"] = ASP.from_wire(kw["asp"])
        return super()._decode(kw)


@_registered
@dataclass
class DiscoverResponse(Message):
    TYPE: ClassVar[str] = "discover_response"
    session_id: str
    #: annotated candidate set 𝒦 — each entry {model_id, model_version,
    #: site_id, klass, admissible, slack, exclusion_reason, domain,
    #: region}; federated candidates carry domain-qualified site ids and
    #: exclusion reasons prefixed with the owning domain
    candidates: List[dict] = field(default_factory=list)
    schema_version: str = SCHEMA_VERSION


@_registered
@dataclass
class PageRequest(Message):
    TYPE: ClassVar[str] = "page_request"
    session_id: str
    exclude_sites: List[str] = field(default_factory=list)
    deadline_ms: Optional[float] = None
    schema_version: str = SCHEMA_VERSION


@_registered
@dataclass
class PageResponse(Message):
    TYPE: ClassVar[str] = "page_response"
    session_id: str
    model_id: str
    model_version: str
    site_id: str
    klass: str
    predicted_cost_per_1k: float = 0.0
    #: administrative domain of the anchor ("" = the home domain) — the
    #: client contract is otherwise unchanged by federation
    domain: str = ""
    schema_version: str = SCHEMA_VERSION


@_registered
@dataclass
class PrepareRequest(Message):
    TYPE: ClassVar[str] = "prepare_request"
    session_id: str
    #: retry-safety: a repeated PREPARE with the same key returns the
    #: original outcome instead of reserving twice
    idempotency_key: Optional[str] = None
    deadline_ms: Optional[float] = None
    schema_version: str = SCHEMA_VERSION


@_registered
@dataclass
class PrepareResponse(Message):
    TYPE: ClassVar[str] = "prepare_response"
    session_id: str
    prepared_ref: str
    site_id: str
    qfi: int
    schema_version: str = SCHEMA_VERSION


@_registered
@dataclass
class CommitRequest(Message):
    TYPE: ClassVar[str] = "commit_request"
    session_id: str
    prepared_ref: str
    idempotency_key: Optional[str] = None
    deadline_ms: Optional[float] = None
    schema_version: str = SCHEMA_VERSION


@_registered
@dataclass
class CommitResponse(Message):
    TYPE: ClassVar[str] = "commit_response"
    session_id: str
    #: the auditable AIS binding record (Section III-B)
    record: dict = field(default_factory=dict)
    lease_s: float = 0.0
    at_s: float = 0.0            # server clock — drives client auto-renew
    schema_version: str = SCHEMA_VERSION


# ----------------------------------------------------------------------
# serving: unary-streaming and async submit
# ----------------------------------------------------------------------
@_registered
@dataclass
class ServeRequest(Message):
    TYPE: ClassVar[str] = "serve_request"
    session_id: str
    prompt_tokens: int = 512
    gen_tokens: int = 64
    #: explicit prompt token ids (real-engine backends); None = synthetic
    prompt: Optional[List[int]] = None
    #: stream=True → ServeChunk per token then ServeComplete;
    #: stream=False → async enqueue acknowledged by SubmitAck
    stream: bool = True
    request_id: Optional[str] = None
    deadline_ms: Optional[float] = None
    schema_version: str = SCHEMA_VERSION


@_registered
@dataclass
class SubmitAck(Message):
    TYPE: ClassVar[str] = "submit_ack"
    session_id: str
    request_id: Optional[str]
    accepted: bool
    at_s: float = 0.0
    schema_version: str = SCHEMA_VERSION


@_registered
@dataclass
class ServeChunk(Message):
    TYPE: ClassVar[str] = "serve_chunk"
    session_id: str
    request_id: str
    seq: int
    token_id: Optional[int] = None
    schema_version: str = SCHEMA_VERSION


@_registered
@dataclass
class ServeComplete(Message):
    TYPE: ClassVar[str] = "serve_complete"
    session_id: str
    request_id: str
    klass: str = ""
    tokens: int = 0
    prompt_tokens: int = 0
    ttfb_ms: float = 0.0
    latency_ms: float = 0.0
    queue_wait_ms: float = 0.0
    completed: bool = False
    #: Eq. (12) error code when the request was served-and-failed
    error_code: Optional[str] = None
    token_ids: Optional[List[int]] = None
    at_s: float = 0.0
    schema_version: str = SCHEMA_VERSION


# ----------------------------------------------------------------------
# continuity: heartbeat, events, release, compliance
# ----------------------------------------------------------------------
@_registered
@dataclass
class HeartbeatReport(Message):
    TYPE: ClassVar[str] = "heartbeat_report"
    session_id: str
    #: optional Eq. (14) threshold overrides (δ, δ') for this evaluation —
    #: tightening to 0.0 forces a migration check to fire (ops/testing)
    trigger_l99: Optional[float] = None
    trigger_ttfb: Optional[float] = None
    deadline_ms: Optional[float] = None
    schema_version: str = SCHEMA_VERSION


@_registered
@dataclass
class HeartbeatAck(Message):
    TYPE: ClassVar[str] = "heartbeat_ack"
    session_id: str
    committed: bool
    lease_s: float = 0.0
    #: wire form of a MigrationOutcome when the heartbeat triggered one
    migration: Optional[dict] = None
    at_s: float = 0.0
    schema_version: str = SCHEMA_VERSION


@_registered
@dataclass
class SessionEvent(Message):
    """Notification pushed to the invoker's subscription: state transitions
    and migration outcomes (the CAPIF event-exposure direction)."""
    TYPE: ClassVar[str] = "session_event"
    session_id: str
    event: str                   # state-transition | migration
    state: Optional[str] = None
    detail: dict = field(default_factory=dict)
    at_s: float = 0.0
    schema_version: str = SCHEMA_VERSION


@_registered
@dataclass
class EventPoll(Message):
    TYPE: ClassVar[str] = "event_poll"
    invoker: str
    schema_version: str = SCHEMA_VERSION


@_registered
@dataclass
class CompletionPoll(Message):
    """Retrieve the async (``stream=False``) completions for this invoker's
    sessions — the wire counterpart of the in-process ``gateway.drain()``."""
    TYPE: ClassVar[str] = "completion_poll"
    invoker: str
    schema_version: str = SCHEMA_VERSION


@_registered
@dataclass
class ReleaseRequest(Message):
    TYPE: ClassVar[str] = "release_request"
    session_id: str
    schema_version: str = SCHEMA_VERSION


@_registered
@dataclass
class ReleaseAck(Message):
    TYPE: ClassVar[str] = "release_ack"
    session_id: str
    state: str = "released"
    tokens: int = 0
    total_cost: float = 0.0
    schema_version: str = SCHEMA_VERSION


@_registered
@dataclass
class ComplianceRequest(Message):
    TYPE: ClassVar[str] = "compliance_request"
    session_id: str
    schema_version: str = SCHEMA_VERSION


@_registered
@dataclass
class ComplianceReport(Message):
    TYPE: ClassVar[str] = "compliance_report"
    session_id: str
    in_compliance: Optional[bool] = None
    #: boundary snapshot Z(t) (Eq. 5/13) as a flat dict
    z: dict = field(default_factory=dict)
    n: int = 0
    schema_version: str = SCHEMA_VERSION


# ----------------------------------------------------------------------
# tenant adapter lifecycle: register (catalog) / load / unload (engine)
# ----------------------------------------------------------------------
@_registered
@dataclass
class RegisterAdapterRequest(Message):
    """Publish a versioned tenant adapter into the domain catalog. The
    gateway materialises deterministic weights from ``seed`` against the
    base model's d_model (the stand-in for a tenant weight upload) and
    answers with the resulting weight fingerprint — the value migration
    and federation advertisement key on."""
    TYPE: ClassVar[str] = "register_adapter_request"
    adapter_id: str
    base_model_id: str
    version: str = "1.0"
    base_model_version: str = "1.0"
    rank: int = 8
    #: sovereignty tags of the adapter weights themselves
    regions: List[str] = field(default_factory=lambda: ["eu", "us", "apac"])
    scale: float = 1.0
    seed: int = 0
    schema_version: str = SCHEMA_VERSION


@_registered
@dataclass
class RegisterAdapterResponse(Message):
    TYPE: ClassVar[str] = "register_adapter_response"
    adapter_id: str
    version: str
    base_model_id: str = ""
    weight_fingerprint: str = ""
    at_s: float = 0.0
    schema_version: str = SCHEMA_VERSION


@_registered
@dataclass
class LoadAdapterRequest(Message):
    """Make a registered adapter hot at one site. On a real engine this
    installs A/B rows into the device tables; on simulated backends only
    the control-plane residency record advances (discovery admissibility
    is control-plane either way)."""
    TYPE: ClassVar[str] = "load_adapter_request"
    adapter_id: str
    site_id: str
    version: str = ""            # "" = highest registered version
    schema_version: str = SCHEMA_VERSION


@_registered
@dataclass
class LoadAdapterResponse(Message):
    TYPE: ClassVar[str] = "load_adapter_response"
    adapter_id: str
    site_id: str
    loaded: bool = False
    #: True when weights landed in a real engine's device tables (False:
    #: simulated backend — control-plane record only)
    engine_loaded: bool = False
    at_s: float = 0.0
    schema_version: str = SCHEMA_VERSION


@_registered
@dataclass
class UnloadAdapterRequest(Message):
    TYPE: ClassVar[str] = "unload_adapter_request"
    adapter_id: str
    site_id: str
    schema_version: str = SCHEMA_VERSION


@_registered
@dataclass
class UnloadAdapterResponse(Message):
    TYPE: ClassVar[str] = "unload_adapter_response"
    adapter_id: str
    site_id: str
    unloaded: bool = False
    at_s: float = 0.0
    schema_version: str = SCHEMA_VERSION


# ----------------------------------------------------------------------
# structured errors: exhaustive Eq. (12) cause ↔ code mapping
# ----------------------------------------------------------------------
#: the cause partition (paper's nine + the unreliable-transport pair), each
#: with a distinct documented code — remediation per cause lives in
#: repro.core.failures.REMEDIATION, retryability in failures.RETRYABLE
ERROR_CODE_TABLE: Dict[FailureCause, str] = {
    FailureCause.CONSENT_VIOLATION: "E_CONSENT",
    FailureCause.POLICY_DENIAL: "E_POLICY",
    FailureCause.SOVEREIGNTY_VIOLATION: "E_SOVEREIGNTY",
    FailureCause.MODEL_UNAVAILABLE: "E_MODEL_UNAVAILABLE",
    FailureCause.NO_FEASIBLE_BINDING: "E_NO_FEASIBLE_BINDING",
    FailureCause.COMPUTE_SCARCITY: "E_COMPUTE_SCARCITY",
    FailureCause.QOS_SCARCITY: "E_QOS_SCARCITY",
    FailureCause.STATE_TRANSFER_FAILURE: "E_STATE_TRANSFER",
    FailureCause.DEADLINE_EXPIRY: "E_DEADLINE",
    FailureCause.TRANSPORT_FAILURE: "E_TRANSPORT",
    FailureCause.DEADLINE_EXCEEDED: "E_DEADLINE_EXCEEDED",
}

#: gateway-layer failures with no Eq. (12) counterpart (the request never
#: reached the lifecycle machinery)
GATEWAY_CODES = ("E_SCHEMA_VERSION", "E_BAD_REQUEST", "E_UNKNOWN_SESSION",
                 "E_IDEMPOTENCY_CONFLICT", "E_IDEMPOTENCY_EVICTED",
                 "E_INTERNAL")

_CODE_TO_CAUSE = {v: k for k, v in ERROR_CODE_TABLE.items()}


def code_for_cause(cause: FailureCause) -> str:
    return ERROR_CODE_TABLE[cause]


def cause_for_code(code: str) -> Optional[FailureCause]:
    """Inverse mapping; None for gateway-layer codes."""
    return _CODE_TO_CAUSE.get(code)


@_registered
@dataclass
class ErrorResponse(Message):
    TYPE: ClassVar[str] = "error"
    code: str
    cause: Optional[str] = None      # FailureCause.value, when applicable
    detail: str = ""
    session_id: Optional[str] = None
    schema_version: str = SCHEMA_VERSION

    @classmethod
    def from_session_error(cls, e: SessionError,
                           session_id: Optional[str] = None
                           ) -> "ErrorResponse":
        return cls(code=code_for_cause(e.cause), cause=e.cause.value,
                   detail=e.detail or str(e), session_id=session_id)


# ----------------------------------------------------------------------
# MigrationOutcome wire helpers (HeartbeatAck.migration / SessionEvent.detail)
# ----------------------------------------------------------------------
def outcome_to_wire(o) -> dict:
    return {
        "migrated": o.migrated, "aborted": o.aborted,
        "cause": o.cause.value if o.cause else None,
        "from_site": o.from_site, "to_site": o.to_site,
        "interruption_ms": o.interruption_ms,
        "transfer_ms": o.transfer_ms, "transfer_bytes": o.transfer_bytes,
        "fingerprint": o.fingerprint, "mid_stream": o.mid_stream,
    }


def outcome_from_wire(d: dict):
    from repro.core.migration import MigrationOutcome
    return MigrationOutcome(
        migrated=d["migrated"], aborted=d["aborted"],
        cause=FailureCause(d["cause"]) if d["cause"] else None,
        from_site=d["from_site"], to_site=d["to_site"],
        interruption_ms=d["interruption_ms"],
        transfer_ms=d.get("transfer_ms", 0.0),
        transfer_bytes=d.get("transfer_bytes", 0),
        fingerprint=d.get("fingerprint"),
        mid_stream=d.get("mid_stream", False))
