"""Northbound session API (CAPIF-style exposure, Section VI).

The paper's contract is *network-exposed*: DISCOVER / AI-PAGING /
PREPARE-COMMIT / SERVE / MIGRATION are protocol-grade procedures an
application-service-provider invokes over a versioned wire protocol, not
Python calls on internal objects. This package is that exposure surface:

* :mod:`repro.api.messages` — versioned, JSON-round-trippable message types
  for the full lifecycle plus the structured error partition (every
  ``FailureCause`` has a distinct documented error code);
* :mod:`repro.api.gateway` — :class:`NorthboundGateway`, the single entry
  point wrapping the Orchestrator: schema-version negotiation, idempotent
  PREPARE/COMMIT, per-invoker event subscriptions, streaming serve;
* :mod:`repro.api.client` — :class:`SessionClient`, the invoker-side SDK
  (context-managed establish→serve→release, token streaming, automatic
  lease renewal, typed exceptions).
"""

from repro.api.messages import (  # noqa: F401
    SCHEMA_VERSION, Message, from_json, from_wire,
    DiscoverRequest, DiscoverResponse, PageRequest, PageResponse,
    PrepareRequest, PrepareResponse, CommitRequest, CommitResponse,
    ServeRequest, SubmitAck, ServeChunk, ServeComplete,
    HeartbeatReport, HeartbeatAck, SessionEvent,
    ReleaseRequest, ReleaseAck, ComplianceRequest, ComplianceReport,
    EventPoll, CompletionPoll, ErrorResponse, code_for_cause, cause_for_code,
    ERROR_CODE_TABLE, GATEWAY_CODES)
from repro.api.gateway import NorthboundGateway  # noqa: F401
from repro.api.client import (  # noqa: F401
    SessionClient, TokenStream, NorthboundError, SchemaMismatch,
    ConsentRevoked, ScarcityError, DeadlineExpired, PolicyDenied)
