"""Architecture registry: arch-id -> ModelConfig."""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCH_IDS = (
    "phi3-medium-14b",
    "command-r-35b",
    "codeqwen1.5-7b",
    "minitron-8b",
    "qwen2-vl-72b",
    "qwen3-moe-30b-a3b",
    "mixtral-8x7b",
    "recurrentgemma-2b",
    "mamba2-1.3b",
    "seamless-m4t-medium",
    # the paper's own demo model (used by examples/serving tests)
    "edge-tiny",
)


def _module(arch_id: str):
    mod = arch_id.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).smoke_config()
