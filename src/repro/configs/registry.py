"""Architecture registry: arch-id -> ModelConfig."""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCH_IDS = (
    "phi3-medium-14b",
    "command-r-35b",
    "codeqwen1.5-7b",
    "minitron-8b",
    "qwen2-vl-72b",
    "qwen3-moe-30b-a3b",
    "mixtral-8x7b",
    "recurrentgemma-2b",
    "mamba2-1.3b",
    "seamless-m4t-medium",
    # the paper's own demo model (used by examples/serving tests)
    "edge-tiny",
)


def _module(arch_id: str):
    mod = arch_id.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).smoke_config()


# ----------------------------------------------------------------------
# Split-serving metadata: which archs can draft for which targets, and
# which tier of the device–RAN–cloud ladder each arch naturally lives on.
# ----------------------------------------------------------------------

#: draft arch -> target archs it may draft for. A pairing is only usable
#: when ``draft_compatible`` also holds for the concrete configs (greedy
#: spec-decode needs an identical token space; enforced at PREPARE so a
#: mismatch is a placement-time NO_FEASIBLE_BINDING, never a mid-stream
#: decode fault). Smoke configs all share vocab 512, so every pairing is
#: exercisable in tests; the full-size lists pair within the
#: vocab-256000 tokenizer family.
DRAFT_PAIRINGS = {
    "recurrentgemma-2b": ("command-r-35b", "minitron-8b"),
    "mamba2-1.3b": (),        # vocab 50280 matches no full-size target
    "edge-tiny": (),          # full edge-tiny vocab (2048) pairs with no
                              # full-size target; smoke-form pairs freely
}

#: arch -> placement tier it is sized for ("edge" drafts on-device /
#: on-RAN; "region"/"central" verify). Discovery uses this to partition
#: split candidates by role.
ARCH_TIERS = {
    "edge-tiny": "edge",
    "recurrentgemma-2b": "edge",
    "mamba2-1.3b": "edge",
    "minitron-8b": "region",
    "phi3-medium-14b": "region",
    "codeqwen1.5-7b": "region",
    "seamless-m4t-medium": "region",
    "command-r-35b": "central",
    "qwen2-vl-72b": "central",
    "qwen3-moe-30b-a3b": "central",
    "mixtral-8x7b": "central",
}


def draft_targets(draft_arch: str) -> tuple:
    """Declared full-size targets for ``draft_arch`` (may be empty)."""
    return tuple(DRAFT_PAIRINGS.get(draft_arch, ()))


def arch_tier(arch_id: str) -> str:
    """The device–RAN–cloud tier this arch is sized for."""
    return ARCH_TIERS.get(arch_id, "central")


def draft_compatible(draft_cfg: ModelConfig, target_cfg: ModelConfig) -> bool:
    """True iff greedy spec-decode between the two configs is well-typed:
    the draft's proposals index the target's token space bijectively
    (same vocab size — the argmax comparison is over token ids, so any
    mismatch is structurally wrong, not just low-acceptance)."""
    return int(draft_cfg.vocab_size) == int(target_cfg.vocab_size)
