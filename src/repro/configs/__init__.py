"""Assigned-architecture configs (``--arch <id>``) + the paper's demo model.

Every module exposes ``CONFIG`` (full production config, exercised only via
the dry-run) and ``smoke_config()`` (reduced same-family config for CPU
tests). ``registry.get_config(arch_id)`` resolves dashed arch ids.
"""

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config  # noqa: F401
