"""edge-tiny — the paper's demo model: a small dense LM that executes for
real on CPU in the examples and serving tests (the AIS contract machinery is
model-agnostic; this keeps the end-to-end demos fast)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="edge-tiny",
    family="dense",
    num_layers=4,
    d_model=256,
    num_heads=8,
    num_kv_heads=4,
    head_dim=32,
    d_ff=1024,
    vocab_size=2048,
    remat="none",
    attn_block_q=64,
    attn_block_kv=128,
)


def smoke_config() -> ModelConfig:
    return CONFIG.smoke()
