"""seamless-m4t-medium — encoder-decoder multimodal backbone
[arXiv:2308.11596; hf].

12L enc + 12L dec, d_model=1024 16H (MHA kv=16) d_ff=4096 vocab=256206.
The audio frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings [b, src, d_model] fed through the encoder
adapter. source_len=1536 frames (~30 s of speech after downsampling).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=12,
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    frontend="audio",
    source_len=1536,
)


def smoke_config() -> ModelConfig:
    return CONFIG.smoke()
