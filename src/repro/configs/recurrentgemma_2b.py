"""recurrentgemma-2b — RG-LRU + local attention, pattern (rec, rec, attn)
[arXiv:2402.19427; hf].

26L d_model=2560 10H (MQA kv=1, head_dim 256) d_ff=7680 vocab=256000,
local window 2048, logits softcap 30. Decode state = RG-LRU states +
2048-token rings: bounded, so long_500k is admissible.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    rope_theta=10_000.0,
    sliding_window=2048,
    block_pattern=("rec", "rec", "attn"),
    lru_width=2560,
    conv_width=4,
    logits_softcap=30.0,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.smoke()
