"""mamba2-1.3b — attention-free SSD (state-space duality) [arXiv:2405.21060].

48L d_model=2048, ssm_state=128, headdim 64, expand 2, vocab 50280.
Decode state is O(1) in sequence length — the best case for AIS migration
and the canonical long_500k architecture.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_chunk=128,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.smoke()
