"""qwen3-moe-30b-a3b — 128-expert top-8 MoE [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H (GQA kv=4) moe_d_ff=768 vocab=151936, 128e top-8,
qk-norm (qwen3 family).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    rope_theta=1_000_000.0,
    use_qk_norm=True,
    num_experts=128,
    num_experts_per_tok=8,
    moe_d_ff=768,
)


def smoke_config() -> ModelConfig:
    return CONFIG.smoke()
