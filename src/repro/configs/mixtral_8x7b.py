"""mixtral-8x7b — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, SWA window 4096.
The sliding window bounds the decode cache, so long_500k is admissible.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1_000_000.0,
    sliding_window=4096,
    num_experts=8,
    num_experts_per_tok=2,
    moe_d_ff=14336,
)


def smoke_config() -> ModelConfig:
    return CONFIG.smoke()
