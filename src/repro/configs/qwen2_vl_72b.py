"""qwen2-vl-72b — VLM backbone with M-RoPE [arXiv:2409.12191; hf].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064. The vision frontend
is a STUB per the assignment: input_specs() provides precomputed patch
embeddings [b, n_img, d_model] spliced over the first n_img token slots and
passed through a learned adapter. M-RoPE uses (t, h, w) position streams
with half-dim sections (16, 24, 24).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    frontend="vision",
    num_frontend_tokens=256,
)


def smoke_config() -> ModelConfig:
    return CONFIG.smoke()
