"""Federation registry: where administrative domains advertise what they
are *willing to host for roamers* — and nothing more.

The unit of advertisement is the :class:`CapabilityDigest`, a coarse,
versioned summary deliberately weaker than the domain's real state:

* hosted **model keys** and modalities/tiers — yes;
* sovereignty **regions** — yes;
* a **load bucket** (low/medium/high) and a **price floor** — yes;
* lease tables, per-site queue depths, per-session occupancy — **never**.

This is the inter-operator trust boundary: a peer can pre-screen "is it
even worth soliciting domain X for this ASP" from the digest, but every
binding quantity (predicted TTFB/p99/cost of a concrete candidate) only
exists in a :class:`~repro.federation.eastwest.DiscoverOffer`, produced by
the visited domain against a decomposed budget at solicitation time.

Digests carry an epoch and an advertisement timestamp. A digest older than
``max_age_s`` is *stale*: the home domain skips the peer and records a
``registry-stale`` exclusion, which aggregates into ``NO_FEASIBLE_BINDING``
(Eq. 12) when nothing else admits — staleness is diagnosable, not silent.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.core.clock import Clock


@dataclass(frozen=True)
class CapabilityDigest:
    """One domain's coarse east-west advertisement."""
    domain_id: str
    epoch: int
    advertised_at: float         # registry clock
    model_keys: Tuple[str, ...]  # "model_id@version" hosted for roamers
    modalities: Tuple[str, ...]
    regions: Tuple[str, ...]
    load_bucket: str             # low | medium | high (coarse, not raw util)
    min_price_per_1k: float = 0.0
    #: tenant adapters ("adapter_id@version") this domain hosts for
    #: roamers — a peer can pre-screen "does domain X even carry my
    #: adapter" before soliciting, same coarseness rules as model_keys
    adapter_keys: Tuple[str, ...] = ()

    def to_wire(self) -> dict:
        return {
            "domain_id": self.domain_id, "epoch": self.epoch,
            "advertised_at": self.advertised_at,
            "model_keys": list(self.model_keys),
            "modalities": list(self.modalities),
            "regions": list(self.regions),
            "load_bucket": self.load_bucket,
            "min_price_per_1k": self.min_price_per_1k,
            "adapter_keys": list(self.adapter_keys),
        }

    @classmethod
    def from_wire(cls, d: dict) -> "CapabilityDigest":
        return cls(domain_id=d["domain_id"], epoch=int(d["epoch"]),
                   advertised_at=float(d["advertised_at"]),
                   model_keys=tuple(d["model_keys"]),
                   modalities=tuple(d["modalities"]),
                   regions=tuple(d["regions"]),
                   load_bucket=d["load_bucket"],
                   min_price_per_1k=float(d.get("min_price_per_1k", 0.0)),
                   adapter_keys=tuple(d.get("adapter_keys", ())))


def load_bucket(mean_utilization: float) -> str:
    """Coarse load signal: bucketed so the digest leaks ordering, not the
    actual occupancy."""
    if mean_utilization < 0.3:
        return "low"
    if mean_utilization < 0.7:
        return "medium"
    return "high"


def digest_of(domain_id: str, catalog, sites, clock: Clock,
              epoch: int) -> CapabilityDigest:
    """Build a digest from one domain's catalog + sites (what the
    DomainController advertises)."""
    entries = catalog.entries()
    modalities = sorted({m.value for e in entries for m in e.modalities})
    regions = sorted({s.spec.region for s in sites.values()})
    utils = [s.utilization() for s in sites.values()]
    mean_util = sum(utils) / max(len(utils), 1)
    adapters = getattr(catalog, "adapters", None)
    return CapabilityDigest(
        domain_id=domain_id, epoch=epoch, advertised_at=clock.now(),
        model_keys=tuple(sorted(catalog.keys())),
        modalities=tuple(modalities), regions=tuple(regions),
        load_bucket=load_bucket(mean_util),
        min_price_per_1k=min((e.price_per_1k_tokens for e in entries),
                             default=0.0),
        adapter_keys=tuple(adapters.keys()) if adapters is not None else ())


class FederationRegistry:
    """Shared (or replicated) digest directory of a federation.

    In this repro the registry is an in-process object the peered domains
    share; in a deployment it is the CAPIF interconnection / GSMA roaming
    hub equivalent. Either way the *content* is only digests.
    """

    def __init__(self, clock: Clock, *, max_age_s: float = 30.0):
        self.clock = clock
        self.max_age_s = max_age_s
        self._digests: Dict[str, CapabilityDigest] = {}
        #: live re-advertisement hooks (the CAPIF heartbeat direction): a
        #: domain that registers a provider gets its digest re-pulled when
        #: it ages out; staleness then MEANS the provider is gone/broken,
        #: not merely that time passed
        self._providers: Dict[str, object] = {}

    # -- advertisement ---------------------------------------------------
    def advertise(self, digest: CapabilityDigest) -> None:
        """Upsert one domain's digest (newest epoch wins)."""
        cur = self._digests.get(digest.domain_id)
        if cur is None or digest.epoch >= cur.epoch:
            self._digests[digest.domain_id] = digest

    def register_provider(self, domain_id: str, fn) -> None:
        """``fn() -> CapabilityDigest`` used to refresh a stale digest."""
        self._providers[domain_id] = fn

    def drop_provider(self, domain_id: str) -> None:
        self._providers.pop(domain_id, None)

    # -- lookup ----------------------------------------------------------
    def get(self, domain_id: str) -> Optional[CapabilityDigest]:
        return self._digests.get(domain_id)

    def fresh(self, domain_id: str) -> bool:
        d = self._digests.get(domain_id)
        return bool(d and self.clock.now() - d.advertised_at
                    <= self.max_age_s)

    def ensure_fresh(self, domain_id: str) -> bool:
        """Freshness with one re-pull attempt: a stale digest whose domain
        registered a provider is refreshed in place; False (⇒ the caller's
        ``registry-stale`` exclusion) only when no live provider answers."""
        if self.fresh(domain_id):
            return True
        fn = self._providers.get(domain_id)
        if fn is None:
            return False
        try:
            self.advertise(fn())
        except Exception:
            return False
        return self.fresh(domain_id)

    def domains(self, *, exclude: Tuple[str, ...] = ()) -> Tuple[str, ...]:
        """Advertised domain ids (stale ones included — the *caller* must
        classify staleness so the exclusion is attributable)."""
        return tuple(d for d in sorted(self._digests) if d not in exclude)
