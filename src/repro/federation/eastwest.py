"""East-west (inter-domain) wire protocol — the federation counterpart of
:mod:`repro.api.messages`.

Where the northbound protocol exposes the AIS lifecycle to *invokers*, this
protocol exposes it between *administrative domains* (operators): DISCOVER
solicitation with per-domain SLA budgets, the visited half of a cross-domain
PREPARE/COMMIT/ABORT, lease renewal, and release. Every type is a flat
dataclass with JSON-native fields and the same round-trip invariant as the
northbound wire::

    m == from_json(m.to_json())        for every east-west message m

**SLA budget decomposition.** A home domain never forwards the raw ASP
objectives: it splits each latency bound between the *home transport share*
(the access + inter-domain transit leg it keeps) and the *visited execution
share* (what the visited domain must meet end-to-end on its own leg), and
splits the cost envelope between the home (transit/retail) share and the
visited (execution/wholesale) share::

    ℓ_visited = ℓ − t_home          for ℓ ∈ {ℓ_TTFB, ℓ_0.95, ℓ_0.99, T_max}
    γ_visited = γ · (1 − c_home)

A decomposition with any non-positive visited share is *infeasible before
solicitation* and maps to ``NO_FEASIBLE_BINDING`` (Eq. 12) — the visited
domain is never asked to promise what the transit budget already consumed.

**Error semantics.** Visited-side ``SessionError``s cross the boundary as
:class:`EWError` carrying the Eq. (12) cause code from the northbound
``ERROR_CODE_TABLE`` — the home domain re-raises them as the *same* cause,
so an inter-domain failure is diagnosable with the single-domain taxonomy.
Protocol-layer refusals (schema mismatch, unknown ref, internal) use
disjoint ``E_EW_*`` codes, mirroring the northbound gateway codes.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import ClassVar, Dict, List, Optional

from repro.api.messages import cause_for_code, code_for_cause
from repro.core.failures import FailureCause, SessionError

#: wire-schema version of the east-west protocol; majors must match between
#: peered domains (minor additions are backward-compatible)
#: 1.1: + deadline_ms budgets (DiscoverQuery/EWPrepare/EWCommit) and
#:      EWPrepare.prepare_key at-least-once idempotency
EW_SCHEMA_VERSION = "1.1"

#: protocol-layer codes with no Eq. (12) counterpart (the request never
#: reached the visited domain's lifecycle machinery)
EW_PROTOCOL_CODES = ("E_EW_SCHEMA", "E_EW_BAD_REQUEST", "E_EW_UNKNOWN_REF",
                     "E_EW_INTERNAL")

_REGISTRY: Dict[str, type] = {}


class EWTimeout(Exception):
    """An east-west exchange did not complete within the solicitation
    window. Raised by transports; the home domain maps it to an
    ``offer-timeout`` exclusion (DISCOVER) or ``DEADLINE_EXPIRY``
    (PREPARE/COMMIT, where provisional state must be rolled back)."""


def _registered(cls):
    _REGISTRY[cls.TYPE] = cls
    return cls


@dataclass
class EWMessage:
    """Base: a typed east-west message with a version envelope."""

    TYPE: ClassVar[str] = ""

    def to_wire(self) -> dict:
        out = {"type": self.TYPE}
        for f in dataclasses.fields(self):
            out[f.name] = getattr(self, f.name)
        return out

    def to_json(self) -> str:
        return json.dumps(self.to_wire(), sort_keys=True)

    @classmethod
    def _decode(cls, kw: dict) -> "EWMessage":
        # minor-version forward compatibility, same as the northbound wire
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in kw.items() if k in names})


def from_wire(d: dict) -> EWMessage:
    if not isinstance(d, dict):
        raise ValueError(
            f"east-west frame must be a JSON object, got {type(d).__name__}")
    kind = d.get("type")
    cls = _REGISTRY.get(kind)
    if cls is None:
        raise ValueError(f"unknown east-west message type {kind!r}")
    return cls._decode({k: v for k, v in d.items() if k != "type"})


def from_json(s: str) -> EWMessage:
    return from_wire(json.loads(s))


def message_types() -> Dict[str, type]:
    """The full east-west registry (exhaustiveness tests + README table)."""
    return dict(_REGISTRY)


# ----------------------------------------------------------------------
# SLA budget decomposition — shared with split placement; the canonical
# implementation lives in repro.core.budget and is re-exported here so
# the east-west wire surface is unchanged.
# ----------------------------------------------------------------------
from repro.core.budget import (SLABudget, apply_budget,  # noqa: E402,F401
                               decompose_budget, decompose_tiers)


# ----------------------------------------------------------------------
# DISCOVER solicitation
# ----------------------------------------------------------------------
@_registered
@dataclass
class DiscoverQuery(EWMessage):
    """Home → visited: solicit offers for one ASP under a decomposed
    budget. The visited domain answers with its OWN annotated candidate
    set evaluated against the visited execution share."""
    TYPE: ClassVar[str] = "ew_discover_query"
    home_domain: str
    query_id: str
    zone: str
    asp: dict                    # ASP.to_wire()
    budget: dict                 # SLABudget.to_wire()
    #: remaining end-to-end establishment budget at the visited ingress
    #: (the home already subtracted its transit estimate); None = unbounded
    deadline_ms: Optional[float] = None
    schema_version: str = EW_SCHEMA_VERSION


@_registered
@dataclass
class DiscoverOffer(EWMessage):
    """Visited → home: annotated candidates under the visited budget.

    Each entry is {model_id, model_version, site_id, region, klass,
    admissible, slack, exclusion_reason, prediction} — *predicted boundary
    quantities* of a concrete offer, never raw site state (lease tables,
    queue contents, per-session occupancy stay behind the boundary)."""
    TYPE: ClassVar[str] = "ew_discover_offer"
    visited_domain: str
    query_id: str
    candidates: List[dict] = field(default_factory=list)
    digest_epoch: int = 0
    at_s: float = 0.0
    schema_version: str = EW_SCHEMA_VERSION


# ----------------------------------------------------------------------
# cross-domain 2PC: the visited half of PREPARE/COMMIT/ABORT
# ----------------------------------------------------------------------
@_registered
@dataclass
class EWPrepare(EWMessage):
    """Home → visited: provisional co-reservation on the visited planes.
    ``hold_s`` keeps the provisional leases committable past τ_com — the
    home COMMIT (or a roaming migration's τ_mig window) arrives later."""
    TYPE: ClassVar[str] = "ew_prepare"
    home_domain: str
    session_ref: str             # home session id — the roaming anchor key
    model_id: str
    model_version: str
    site_id: str                 # visited-local site id (unqualified)
    klass: str
    zone: str
    slots: int = 1
    context_tokens: int = 2048   # sizes the visited cache reservation
    hold_s: float = 0.0
    budget: dict = field(default_factory=dict)
    #: at-least-once idempotency: a re-sent PREPARE with the same key
    #: returns the original EWPrepared instead of double-reserving
    prepare_key: Optional[str] = None
    deadline_ms: Optional[float] = None
    schema_version: str = EW_SCHEMA_VERSION


@_registered
@dataclass
class EWPrepared(EWMessage):
    TYPE: ClassVar[str] = "ew_prepared"
    visited_domain: str
    session_ref: str
    prepared_ref: str            # the handle every later 2PC verb names
    site_id: str
    qfi: int
    cache_bytes: float = 0.0     # visited-computed reservation size
    expires_at: float = 0.0      # provisional-lease horizon (visited clock)
    schema_version: str = EW_SCHEMA_VERSION


@_registered
@dataclass
class EWCommit(EWMessage):
    """Home → visited: confirm the provisional leases. Idempotent — a
    duplicate COMMIT for the same ``prepared_ref`` returns the original
    response and reserves nothing twice."""
    TYPE: ClassVar[str] = "ew_commit"
    home_domain: str
    session_ref: str
    prepared_ref: str
    deadline_ms: Optional[float] = None
    schema_version: str = EW_SCHEMA_VERSION


@_registered
@dataclass
class EWCommitted(EWMessage):
    TYPE: ClassVar[str] = "ew_committed"
    visited_domain: str
    session_ref: str
    prepared_ref: str
    site_id: str
    endpoint: str
    qfi: int
    compute_lease_id: str
    qos_lease_id: str
    charging_ref: str            # visited wholesale charging (opened HERE,
    lease_s: float = 0.0         # never at PREPARE)
    #: visited retail price; None (unstated) is distinct from a free tier's
    #: legitimate 0.0 — the home falls back to the offer price only for None
    price_per_1k: Optional[float] = None
    at_s: float = 0.0
    schema_version: str = EW_SCHEMA_VERSION


@_registered
@dataclass
class EWAbort(EWMessage):
    """Home → visited: roll back a provisional PREPARE. Idempotent; an
    abort after COMMIT degenerates to release (leases freed, charging
    closed), so a crashed home coordinator can always re-drive the visited
    domain to a clean state."""
    TYPE: ClassVar[str] = "ew_abort"
    home_domain: str
    session_ref: str
    prepared_ref: str
    reason: str = ""
    schema_version: str = EW_SCHEMA_VERSION


@_registered
@dataclass
class EWAbortAck(EWMessage):
    TYPE: ClassVar[str] = "ew_abort_ack"
    visited_domain: str
    prepared_ref: str
    released: bool = False       # False ⇒ the ref was already clean
    schema_version: str = EW_SCHEMA_VERSION


# ----------------------------------------------------------------------
# continuity + teardown for committed roaming sessions
# ----------------------------------------------------------------------
@_registered
@dataclass
class EWRenew(EWMessage):
    """Home heartbeat fan-out: renew BOTH visited leases (compute + QoS)
    atomically, mirroring the single-domain ``AISession.renew``."""
    TYPE: ClassVar[str] = "ew_renew"
    home_domain: str
    prepared_ref: str
    lease_s: float
    schema_version: str = EW_SCHEMA_VERSION


@_registered
@dataclass
class EWRenewAck(EWMessage):
    TYPE: ClassVar[str] = "ew_renew_ack"
    visited_domain: str
    prepared_ref: str
    renewed: bool = False
    schema_version: str = EW_SCHEMA_VERSION


@_registered
@dataclass
class EWRelease(EWMessage):
    TYPE: ClassVar[str] = "ew_release"
    home_domain: str
    prepared_ref: str
    schema_version: str = EW_SCHEMA_VERSION


@_registered
@dataclass
class EWReleaseAck(EWMessage):
    """Final visited-side accounting for the settled roaming leg."""
    TYPE: ClassVar[str] = "ew_release_ack"
    visited_domain: str
    prepared_ref: str
    released: bool = False
    tokens: int = 0
    cost: float = 0.0
    schema_version: str = EW_SCHEMA_VERSION


# ----------------------------------------------------------------------
# structured errors
# ----------------------------------------------------------------------
@_registered
@dataclass
class EWError(EWMessage):
    TYPE: ClassVar[str] = "ew_error"
    visited_domain: str
    code: str
    cause: Optional[str] = None      # FailureCause.value, when applicable
    detail: str = ""
    schema_version: str = EW_SCHEMA_VERSION

    @classmethod
    def from_session_error(cls, domain: str, e: SessionError) -> "EWError":
        return cls(visited_domain=domain, code=code_for_cause(e.cause),
                   cause=e.cause.value, detail=e.detail or str(e))

    def to_session_error(self, *, fallback: FailureCause =
                         FailureCause.POLICY_DENIAL) -> SessionError:
        """Re-raise an inter-domain failure under the Eq. (12) taxonomy:
        lifecycle causes round-trip exactly; protocol-layer refusals map to
        the fallback cause (the visited domain refused to participate)."""
        cause = cause_for_code(self.code) or fallback
        return SessionError(cause, f"[{self.visited_domain}] {self.detail}")
