"""Federated multi-domain control plane: east-west inter-domain API.

One :class:`~repro.federation.domain.DomainController` per administrative
domain (operator); domains advertise coarse
:class:`~repro.federation.registry.CapabilityDigest` records into a
:class:`~repro.federation.registry.FederationRegistry` and speak the typed
:mod:`~repro.federation.eastwest` protocol for DISCOVER solicitation,
cross-domain PREPARE/COMMIT/ABORT with SLA-budget decomposition, and
roaming make-before-break migration.
"""

from repro.federation.domain import (DomainController, FederatedPrepared,
                                     GuestSiteView, RemoteModelRef)
from repro.federation.eastwest import (EW_SCHEMA_VERSION, EWTimeout,
                                       SLABudget, apply_budget,
                                       decompose_budget)
from repro.federation.registry import (CapabilityDigest, FederationRegistry,
                                       digest_of)

__all__ = [
    "DomainController", "FederatedPrepared", "GuestSiteView",
    "RemoteModelRef", "EW_SCHEMA_VERSION", "EWTimeout", "SLABudget",
    "apply_budget", "decompose_budget", "CapabilityDigest",
    "FederationRegistry", "digest_of",
]
