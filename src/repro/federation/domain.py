"""DomainController — one administrative domain of a federated NE-AIaaS
deployment.

The previous single-domain :class:`~repro.core.orchestrator.Orchestrator`
becomes the **per-domain core**: it still owns that domain's catalog,
sites, policy, analytics and 2PC coordinator, and the controller adds the
*federation* role on top:

* **visited side** — a typed east-west endpoint
  (:meth:`handle_eastwest_json`) serving DISCOVER solicitations under a
  decomposed SLA budget, the visited half of cross-domain PREPARE (held
  provisionally until the home COMMIT arrives), idempotent COMMIT, and
  ABORT/RENEW/RELEASE with explicit rollback semantics. Charging for a
  roaming guest is opened at COMMIT, never at PREPARE — an aborted
  handshake leaves no billable trace.
* **home side** — solicitation of offers from peered domains
  (merged into the annotated candidate set with exclusion reasons prefixed
  by the owning domain), the home half of the cross-domain 2PC (a
  transport-share QoS lease via
  :meth:`~repro.core.twophase.TwoPhaseCoordinator.prepare_transport`), and
  the roaming state of sessions anchored abroad.

Control plane vs user plane: every *lifecycle* verb crosses the boundary
as a versioned JSON message (:mod:`repro.federation.eastwest`); the *user
plane* — serving through the visited site's ServingPlane and the
make-before-break state transfer — rides direct object references via
:class:`GuestSiteView`, exactly as a home-routed N9 tunnel carries traffic
the control plane only set up.
"""

from __future__ import annotations

import itertools
from collections import OrderedDict
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.clock import Clock
from repro.core.discovery import Candidate, discover
from repro.core.failures import FailureCause, SessionError
from repro.core.orchestrator import Orchestrator
from repro.core.predictors import Prediction
from repro.core.qos import ASSURED, BEST_EFFORT, PREMIUM
from repro.core.session import Binding
from repro.federation import eastwest as ew
from repro.federation.registry import (CapabilityDigest, FederationRegistry,
                                       digest_of)
from repro.netfault.breaker import BreakerBoard
from repro.netfault.retry import RetryPolicy
from repro.netfault.wire import TransportError

_KLASS = {c.name: c for c in (PREMIUM, ASSURED, BEST_EFFORT)}

#: east-west verbs that are safe to re-send verbatim: COMMIT/ABORT/RENEW/
#: RELEASE are idempotent by protocol contract, PREPARE only when it
#: carries its ``prepare_key`` (checked at send time)
_IDEMPOTENT_EW = (ew.EWPrepare, ew.EWCommit, ew.EWAbort, ew.EWRenew,
                  ew.EWRelease)


# ----------------------------------------------------------------------
# home-side records
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class RemoteModelRef:
    """Identity of a model offered by another domain — the home domain
    ranks and binds it WITHOUT holding the peer's ModelEntry (weights,
    footprint and price stay behind the east-west boundary)."""
    model_id: str
    version: str


@dataclass
class FederatedPrepared:
    """Home-side handle of one cross-domain PREPARE: the home transport
    lease plus the visited domain's ``prepared_ref``. Quacks enough like
    :class:`~repro.core.twophase.Prepared` for the callers that branch on
    ``is_federated``."""
    domain: str
    session_ref: str
    prepared_ref: str
    site_id: str                 # domain-qualified ("<domain>/<site>")
    qfi: int
    home_qos_lease_id: str
    prepared_at: float
    hold_s: float = 0.0
    cache_bytes: float = 0.0
    price_per_1k: float = 0.0

    is_federated = True


@dataclass
class _RemoteRef:
    """Roaming bookkeeping keyed by the visited compute-lease id the home
    Binding carries."""
    domain: str
    prepared_ref: str
    session_ref: str
    visited_charging_ref: str
    price_per_1k: float


# ----------------------------------------------------------------------
# visited-side records
# ----------------------------------------------------------------------
@dataclass
class _GuestLease:
    """One provisional-or-committed reservation held for a roaming home
    session (lease-scoped: a roaming re-anchor creates a new record)."""
    session_ref: str
    home_domain: str
    model: object                # local ModelEntry
    prepared: object             # twophase.Prepared
    site_id: str
    committed: bool = False
    charging_ref: Optional[str] = None
    response: Optional[ew.EWCommitted] = None


class _GuestSessionAdapter:
    """Registered in the visited core's session table so the single
    recorder meters a guest's served requests against the visited
    (wholesale) charging ref — same path as a native session."""

    def __init__(self, session_ref: str, binding: Binding,
                 charging_ref: str):
        self.session_id = session_ref
        self.binding = binding
        self.charging_ref = charging_ref
        self.context_tokens = 0

    def note_context(self, tokens: int) -> None:
        self.context_tokens += max(int(tokens), 0)


# ----------------------------------------------------------------------
# the home-domain façade of a visited site
# ----------------------------------------------------------------------
class GuestSiteView:
    """A visited-domain ExecutionSite as the home domain sees it.

    Registered in the home site table under the qualified id
    ``<domain>/<site>`` so the whole single-domain machinery (bind-time
    lease validation, serve routing, heartbeat congestion sensing, the
    PlaneTransferPath) works unchanged on roaming sessions. Reads
    (lease validity, utilization, the serving plane) are direct
    user-plane references; writes with contract meaning (renew, release)
    fan out as typed east-west messages through the home controller.
    """

    is_guest_view = True

    def __init__(self, domain_id: str, site, owner_core: Orchestrator,
                 federation: "DomainController"):
        self.domain_id = domain_id
        self._site = site
        self._core = owner_core          # the VISITED domain's orchestrator
        self._fed = federation           # the HOME domain's controller
        self.spec = replace(site.spec,
                            site_id=f"{domain_id}/{site.spec.site_id}")

    # -- user plane (direct) --------------------------------------------
    @property
    def plane(self):
        return self._core.plane_for(self._site)

    def record_results(self) -> list:
        """The OWNING domain's recorder drains this plane (wholesale
        metering); guest results are forwarded home by its result sink."""
        return self._core.record_results(self._site)

    def lease_valid(self, lease_id: str) -> bool:
        return self._site.lease_valid(lease_id)

    def utilization(self) -> float:
        return self._site.utilization()

    def slots_in_use(self) -> int:
        return self._site.slots_in_use()

    def hosts(self, model_key: str) -> bool:
        return self._site.hosts(model_key)

    def attach_plane(self, plane) -> None:
        self._site.attach_plane(plane)

    # -- control plane (east-west) --------------------------------------
    def renew(self, lease_id: str, lease_s: float) -> bool:
        return self._fed._renew_remote(self.domain_id, lease_id, lease_s)

    def release(self, lease_id: str) -> None:
        self._fed._release_remote_lease(self.domain_id, lease_id)


# ----------------------------------------------------------------------
class DomainController:
    def __init__(self, domain_id: str,
                 registry: Optional[FederationRegistry] = None, *,
                 clock: Optional[Clock] = None,
                 orchestrator: Optional[Orchestrator] = None,
                 catalog=None, sites=None, timers=None,
                 solicit: str = "fallback",
                 default_transit_ms: float = 20.0,
                 home_cost_share: float = 0.15):
        """``solicit`` policy: ``"fallback"`` solicits east-west offers
        only when the home annotated set has no admissible candidate left
        (home-first routing); ``"always"`` merges offers into every
        DISCOVER; ``"never"`` disables federation for this domain."""
        if solicit not in ("fallback", "always", "never"):
            raise ValueError(f"unknown solicit policy {solicit!r}")
        self.domain_id = domain_id
        self.core = orchestrator or Orchestrator(
            clock=clock, catalog=catalog, sites=sites, timers=timers)
        self.registry = registry or FederationRegistry(self.core.clock)
        self.solicit = solicit
        self.default_transit_ms = default_transit_ms
        self.home_cost_share = home_cost_share
        self.transit_ms: Dict[str, float] = {}     # per-peer override
        #: east-west control-plane endpoints: domain -> JSON callable
        self.peers: Dict[str, Callable[[str], str]] = {}
        #: user-plane references (GuestSiteView construction, result
        #: forwarding) — in-process federation only
        self._peer_objects: Dict[str, "DomainController"] = {}
        #: per-peer circuit breakers over the east-west control path:
        #: repeated solicitation timeouts open the circuit and DISCOVER
        #: skips the peer with the attributable note ``circuit-open``
        #: until the half-open probe succeeds
        self.peer_breakers = BreakerBoard(self.core.clock)
        #: at-least-once retry policy for the idempotent east-west verbs
        self.retry = RetryPolicy()
        # home side
        self._views: Dict[str, GuestSiteView] = {}
        self._remote_bindings: Dict[str, _RemoteRef] = {}
        # visited side
        self._guest_by_ref: Dict[str, _GuestLease] = {}
        self._guest_sessions: Dict[str, _GuestLease] = {}
        #: EWPrepare replay cache (prepare_key → original EWPrepared):
        #: a re-sent PREPARE whose reply was lost must not double-reserve
        self._prepare_replays: "OrderedDict[str, ew.EWPrepared]" = \
            OrderedDict()
        self._prepare_replay_window = 256
        #: supervisor/chaos verdict: domains declared dead are skipped in
        #: solicitation (note ``domain-dead``) and their providers dropped —
        #: a partitioned peer must not stall every DISCOVER on timeouts
        self._dead_domains: set = set()
        self._refs = itertools.count(1)
        self._epochs = itertools.count(1)
        # wire the core into the federation
        self.core.federation = self
        self.core.migrations.federation = self
        self.core.result_sinks.append(self._forward_guest_result)
        self.registry.advertise(self.digest())
        self.registry.register_provider(self.domain_id, self.digest)

    # ------------------------------------------------------------------
    # peering + advertisement
    # ------------------------------------------------------------------
    def digest(self) -> CapabilityDigest:
        local = {sid: s for sid, s in self.core.sites.items()
                 if not getattr(s, "is_guest_view", False)}
        return digest_of(self.domain_id, self.core.catalog, local,
                         self.core.clock, next(self._epochs))

    def advertise(self) -> None:
        """Refresh this domain's capability digest (epoch bump)."""
        self.registry.advertise(self.digest())

    def connect(self, other: "DomainController", *,
                transit_ms: Optional[float] = None) -> None:
        """Peer two domains bidirectionally: exchange east-west endpoints,
        user-plane references, and fresh digests."""
        self.peers[other.domain_id] = other.handle_eastwest_json
        other.peers[self.domain_id] = self.handle_eastwest_json
        self._peer_objects[other.domain_id] = other
        other._peer_objects[self.domain_id] = self
        if transit_ms is not None:
            self.transit_ms[other.domain_id] = transit_ms
            other.transit_ms[self.domain_id] = transit_ms
        regs = [self.registry]
        if other.registry is not self.registry:
            regs.append(other.registry)
        for reg in regs:
            reg.advertise(self.digest())
            reg.advertise(other.digest())
            reg.register_provider(self.domain_id, self.digest)
            reg.register_provider(other.domain_id, other.digest)

    def transit_ms_for(self, domain: str) -> float:
        return self.transit_ms.get(domain, self.default_transit_ms)

    def mark_domain_dead(self, domain: str) -> None:
        """Fleet-ops verdict on a peer (partition, mass site failure): stop
        soliciting it and stop re-pulling its digest. Existing roamed
        sessions are not torn down here — their guest leases TTL-expire on
        the visited side and re-anchoring is the home core's job."""
        self._dead_domains.add(domain)
        self.registry.drop_provider(domain)

    def mark_domain_alive(self, domain: str) -> None:
        """Partition healed: solicit again; the peer re-registers its
        provider on the next ``connect``/``advertise``. The heal verdict
        also closes the peer's circuit breaker — waiting out the cooldown
        would leave the first post-heal establishes excluded as
        ``circuit-open`` despite an explicit operator decision."""
        self._dead_domains.discard(domain)
        self.peer_breakers.reset(domain)

    # ==================================================================
    # HOME SIDE
    # ==================================================================
    def is_remote(self, candidate) -> bool:
        return bool(getattr(candidate, "domain", ""))

    def _send(self, domain: str, msg: ew.EWMessage) -> ew.EWMessage:
        """One east-west exchange, with at-least-once re-send of the
        idempotent verbs under jittered backoff. The ultimate loss still
        maps to DEADLINE_EXPIRY — the exchange window expired and the
        provisional state (if any) is the reaper's/TTL's to clean up."""
        endpoint = self.peers.get(domain)
        if endpoint is None:
            raise SessionError(FailureCause.NO_FEASIBLE_BINDING,
                               f"no east-west peering with {domain!r}")
        attempts = 1
        if isinstance(msg, _IDEMPOTENT_EW) and not (
                isinstance(msg, ew.EWPrepare) and not msg.prepare_key):
            attempts = self.retry.max_attempts
        for attempt in range(1, attempts + 1):
            try:
                reply = ew.from_json(endpoint(msg.to_json()))
            except (ew.EWTimeout, TransportError) as e:
                if attempt < attempts:
                    self.core.clock.sleep(self.retry.backoff_s(
                        attempt, key=f"{domain}:{msg.TYPE}"))
                    continue
                self.peer_breakers.record(domain, False)
                raise SessionError(
                    FailureCause.DEADLINE_EXPIRY,
                    f"east-west {msg.TYPE} to {domain} timed out: {e}")
            self.peer_breakers.record(domain, True)
            return reply

    # -- DISCOVER solicitation ------------------------------------------
    def augment(self, session, cands: List[Candidate], *,
                exclude_sites: Tuple[str, ...] = ()) -> List[Candidate]:
        """Home-routed DISCOVER: merge east-west offers into the local
        annotated set. Under the ``fallback`` policy the federation is
        consulted only when no local candidate remains admissible (the
        home-first rule); exclusion reasons in the merged set are prefixed
        with the owning domain so a NO_FEASIBLE_BINDING is attributable
        per domain (Eq. 12)."""
        if self.solicit == "never" or not self.peers:
            return cands
        local_ok = any(c.admissible and c.site_id not in exclude_sites
                       for c in cands)
        if self.solicit == "fallback" and local_ok:
            return cands
        merged = [replace(c, exclusion_reason=
                          f"{self.domain_id}:{c.exclusion_reason}")
                  if c.exclusion_reason else c for c in cands]
        offers, notes = self.solicit_offers(
            session.asp, session.zone,
            deadline_at=getattr(session, "deadline_at", None))
        merged.extend(offers)
        for dom, why in notes:
            merged.append(Candidate(
                model=RemoteModelRef("*", "*"), site_id=f"{dom}/*",
                prediction=None, slack=float("-inf"), klass=BEST_EFFORT,
                admissible=False, exclusion_reason=f"{dom}:{why}",
                domain=dom))
        merged.sort(key=lambda c: c.slack, reverse=True)
        return merged

    def merged_discover(self, session, zone: str, *,
                        exclude_sites: Tuple[str, ...] = ()
                        ) -> List[Candidate]:
        """Full federated candidate set (used by roaming migration)."""
        cands = discover(session.asp, self.core.catalog, self.core.sites,
                         self.core.predictors, zone,
                         analytics=self.core.analytics)
        return self.augment(session, cands, exclude_sites=exclude_sites)

    def solicit_offers(self, asp, zone: str, *,
                       exclude: Tuple[str, ...] = (),
                       deadline_at: Optional[float] = None
                       ) -> Tuple[List[Candidate], List[Tuple[str, str]]]:
        """Query every fresh, digest-compatible peer; returns the offered
        candidates plus per-domain exclusion notes for peers that could
        not offer (stale digest, infeasible budget, timeout, circuit open,
        exhausted deadline, refusal)."""
        offers: List[Candidate] = []
        notes: List[Tuple[str, str]] = []
        for dom in self.registry.domains(
                exclude=(self.domain_id,) + tuple(exclude)):
            endpoint = self.peers.get(dom)
            if endpoint is None:
                continue
            if dom in self._dead_domains:
                notes.append((dom, "domain-dead"))
                continue
            if not self.peer_breakers.allow(dom):
                # consecutive exchange failures opened this peer's circuit:
                # skip it attributably instead of stalling every DISCOVER
                # on its timeout window until the half-open probe re-admits
                notes.append((dom, "circuit-open"))
                continue
            if not self.registry.ensure_fresh(dom):
                notes.append((dom, "registry-stale"))
                continue
            deadline_ms = None
            if deadline_at is not None:
                deadline_ms = (deadline_at - self.core.clock.now()) * 1e3 \
                    - self.transit_ms_for(dom)
                if deadline_ms <= 0.0:
                    # the remaining budget cannot even cover the transit
                    # leg — don't ask the peer to promise the impossible
                    notes.append((dom, "deadline-exceeded"))
                    continue
            digest = self.registry.get(dom)
            if asp.modality.value not in digest.modalities:
                notes.append((dom, "modality-not-advertised"))
                continue
            if set(digest.regions).isdisjoint(asp.allowed_regions):
                notes.append((dom, "sovereignty"))
                continue
            try:
                budget = ew.decompose_budget(
                    asp, self.transit_ms_for(dom),
                    home_cost_share=self.home_cost_share)
            except SessionError:
                notes.append((dom, "budget-infeasible"))
                continue
            # the wire carries the budget-applied contract, never the raw
            # home objectives/cost envelope — a peer sees only the share
            # it is being asked to meet (the SLABudget trust boundary)
            query = ew.DiscoverQuery(
                home_domain=self.domain_id,
                query_id=f"{self.domain_id}/q-{next(self._refs):06d}",
                zone=zone, asp=ew.apply_budget(asp, budget).to_wire(),
                budget=budget.to_wire(), deadline_ms=deadline_ms)
            try:
                reply = ew.from_json(endpoint(query.to_json()))
            except ew.EWTimeout:
                self.peer_breakers.record(dom, False)
                notes.append((dom, "offer-timeout"))
                continue
            except Exception:
                # an unreachable peer is indistinguishable from a timeout
                self.peer_breakers.record(dom, False)
                notes.append((dom, "offer-timeout"))
                continue
            self.peer_breakers.record(dom, True)
            if isinstance(reply, ew.EWError):
                notes.append((dom, reply.cause or reply.code))
                continue
            offers.extend(self._offer_candidate(dom, e, budget)
                          for e in reply.candidates)
        return offers, notes

    def _offer_candidate(self, dom: str, e: dict,
                         budget: ew.SLABudget) -> Candidate:
        """One offer entry → a home-rankable Candidate: the home transport
        share is re-added to the offered latencies and the home cost share
        to the offered price, so the merged ranking compares end-to-end
        boundary quantities."""
        pred = None
        if e.get("prediction"):
            pred = Prediction(**e["prediction"])
            pred = replace(
                pred,
                t_ff_ms=pred.t_ff_ms + budget.home_transport_ms,
                l95_ms=pred.l95_ms + budget.home_transport_ms,
                l99_ms=pred.l99_ms + budget.home_transport_ms,
                cost_per_1k=pred.cost_per_1k + budget.home_cost_per_1k)
        reason = e.get("exclusion_reason", "")
        return Candidate(
            model=RemoteModelRef(e["model_id"], e["model_version"]),
            site_id=f"{dom}/{e['site_id']}", prediction=pred,
            slack=float("-inf") if e.get("slack") is None else e["slack"],
            klass=_KLASS.get(e.get("klass", ""), BEST_EFFORT),
            admissible=bool(e["admissible"]),
            exclusion_reason=f"{dom}:{reason}" if reason else "",
            domain=dom, region=e.get("region", ""))

    def _remaining_ms(self, session, dom: str) -> Optional[float]:
        """Shrinking end-to-end budget as seen at the visited ingress:
        what is left of the session's establishment deadline minus the
        inter-domain transit this exchange will spend."""
        deadline_at = getattr(session, "deadline_at", None)
        if deadline_at is None:
            return None
        return (deadline_at - self.core.clock.now()) * 1e3 \
            - self.transit_ms_for(dom)

    # -- cross-domain 2PC (home half) -----------------------------------
    def prepare_remote(self, session, chosen, *, hold_s: float = 0.0,
                       context_tokens: int = 2048) -> FederatedPrepared:
        """Stage 1 across the boundary: the home transport-share QoS lease
        plus the visited domain's provisional co-reservation — both or
        neither, exactly like the single-domain PREPARE."""
        dom = chosen.domain
        budget = ew.decompose_budget(session.asp, self.transit_ms_for(dom),
                                     home_cost_share=self.home_cost_share)
        timers = self.core.timers
        deadline_ms = self._remaining_ms(session, dom)
        if deadline_ms is not None and deadline_ms <= timers.tau_prep * 1e3:
            # reject BEFORE reserving anything: the budget cannot cover
            # transit + the visited PREPARE floor, and this hop says so
            raise SessionError(
                FailureCause.DEADLINE_EXCEEDED,
                f"[home:{self.domain_id}] cross-domain PREPARE to {dom}: "
                f"{deadline_ms:.1f}ms remaining cannot cover the "
                f"{timers.tau_prep * 1e3:.0f}ms phase floor")
        ttl_s = timers.tau_prep + timers.tau_com + hold_s
        qos_lease = self.core.coordinator.prepare_transport(
            (session.zone, f"ew:{dom}"), chosen.klass, ttl_s=ttl_s)
        site_local = chosen.site_id.split("/", 1)[1]
        req = ew.EWPrepare(
            home_domain=self.domain_id, session_ref=session.session_id,
            model_id=chosen.model.model_id,
            model_version=chosen.model.version,
            site_id=site_local, klass=chosen.klass.name, zone=session.zone,
            slots=1, context_tokens=int(context_tokens), hold_s=hold_s,
            budget=budget.to_wire(), deadline_ms=deadline_ms,
            prepare_key=f"{self.domain_id}/{session.session_id}"
                        f"/pk-{next(self._refs):06d}")
        try:
            reply = self._send(dom, req)
        except BaseException:
            self.core.qos.release(qos_lease.lease_id)
            raise
        if isinstance(reply, ew.EWError):
            self.core.qos.release(qos_lease.lease_id)
            raise reply.to_session_error()
        self.ensure_view(dom, site_local)
        return FederatedPrepared(
            domain=dom, session_ref=session.session_id,
            prepared_ref=reply.prepared_ref, site_id=chosen.site_id,
            qfi=reply.qfi, home_qos_lease_id=qos_lease.lease_id,
            prepared_at=self.core.clock.now(), hold_s=hold_s,
            cache_bytes=reply.cache_bytes,
            price_per_1k=chosen.prediction.cost_per_1k
            if chosen.prediction else 0.0)

    def commit_remote(self, session, chosen,
                      prepared: FederatedPrepared) -> Binding:
        """Stage 2: confirm the home transport lease, then the visited
        half. A failure on either side rolls BOTH back — the visited
        PREPARE was held provisionally exactly for this window."""
        try:
            self.core.qos.confirm(prepared.home_qos_lease_id,
                                  lease_s=self.core.timers.lease_s)
        except BaseException:
            self.abort_remote(prepared, reason="home transport confirm")
            raise
        try:
            reply = self._send(prepared.domain, ew.EWCommit(
                home_domain=self.domain_id,
                session_ref=prepared.session_ref,
                prepared_ref=prepared.prepared_ref,
                deadline_ms=self._remaining_ms(session, prepared.domain)))
        except BaseException:
            # the COMMIT may have landed with the reply lost — EWAbort
            # degenerates to release on the visited side, re-driving it to
            # a clean (unbilled) state either way
            self.abort_remote(prepared, reason="home commit exchange lost")
            raise
        if isinstance(reply, ew.EWError):
            self.abort_remote(prepared, reason=reply.code)
            raise reply.to_session_error()
        self.ensure_view(prepared.domain, reply.site_id)
        binding = Binding(
            model_id=chosen.model.model_id,
            model_version=chosen.model.version,
            site_id=prepared.site_id, endpoint=reply.endpoint,
            qfi=reply.qfi,
            steering_handle=f"steer/ew/{prepared.domain}/qfi{reply.qfi}",
            compute_lease_id=reply.compute_lease_id,
            qos_lease_id=prepared.home_qos_lease_id)
        self._remote_bindings[reply.compute_lease_id] = _RemoteRef(
            domain=prepared.domain, prepared_ref=prepared.prepared_ref,
            session_ref=prepared.session_ref,
            visited_charging_ref=reply.charging_ref,
            price_per_1k=reply.price_per_1k
            if reply.price_per_1k is not None else prepared.price_per_1k)
        return binding

    def abort_remote(self, prepared: FederatedPrepared, *,
                     reason: str = "") -> None:
        """Idempotent rollback of both halves. The east-west ABORT is
        best-effort: the visited provisional leases expire by TTL even if
        the peer is unreachable."""
        self.core.qos.release(prepared.home_qos_lease_id)
        try:
            self._send(prepared.domain, ew.EWAbort(
                home_domain=self.domain_id,
                session_ref=prepared.session_ref,
                prepared_ref=prepared.prepared_ref, reason=reason))
        except Exception:
            pass

    # -- roaming session plumbing ---------------------------------------
    def ensure_view(self, domain: str, site_local: str) -> GuestSiteView:
        key = f"{domain}/{site_local}"
        view = self._views.get(key)
        if view is None:
            peer = self._peer_objects.get(domain)
            if peer is None:
                raise SessionError(
                    FailureCause.NO_FEASIBLE_BINDING,
                    f"no user-plane reference for domain {domain!r}")
            view = GuestSiteView(domain, peer.core.sites[site_local],
                                 peer.core, self)
            self._views[key] = view
            self.core.sites[key] = view
        return view

    def _renew_remote(self, domain: str, compute_lease_id: str,
                      lease_s: float) -> bool:
        ref = self._remote_bindings.get(compute_lease_id)
        if ref is None:
            return False
        try:
            reply = self._send(domain, ew.EWRenew(
                home_domain=self.domain_id, prepared_ref=ref.prepared_ref,
                lease_s=lease_s))
        except SessionError:
            return False
        return isinstance(reply, ew.EWRenewAck) and reply.renewed

    def _release_remote_lease(self, domain: str,
                              compute_lease_id: str) -> None:
        ref = self._remote_bindings.pop(compute_lease_id, None)
        if ref is None:
            return
        try:
            self._send(domain, ew.EWRelease(
                home_domain=self.domain_id,
                prepared_ref=ref.prepared_ref))
        except Exception:
            pass    # visited leases expire by TTL regardless

    def _on_guest_result(self, domain: str, site_id: str, res) -> None:
        """A roaming session's completion, forwarded by the visited
        domain: record home-side telemetry, context, and retail charging,
        and fan out to the home result sinks (async completions)."""
        view = self._views.get(f"{domain}/{site_id}")
        if view is None:
            return
        session = self.core.sessions.get(res.session_id)
        if session is None:
            return
        price = None
        if session.binding is not None:
            ref = self._remote_bindings.get(session.binding.compute_lease_id)
            if ref is not None:
                price = ref.price_per_1k
        self.core._record_one(view, res, price_override=price)

    # ==================================================================
    # VISITED SIDE — the typed east-west endpoint
    # ==================================================================
    def handle_eastwest_json(self, payload: str) -> str:
        return self.handle_eastwest_msg(payload).to_json()

    def handle_eastwest_msg(self, payload: str) -> ew.EWMessage:
        try:
            msg = ew.from_json(payload)
        except (ValueError, TypeError, KeyError) as e:
            return ew.EWError(visited_domain=self.domain_id,
                              code="E_EW_BAD_REQUEST", detail=repr(e))
        ver = str(getattr(msg, "schema_version", ew.EW_SCHEMA_VERSION))
        if ver.split(".")[0] != ew.EW_SCHEMA_VERSION.split(".")[0]:
            return ew.EWError(
                visited_domain=self.domain_id, code="E_EW_SCHEMA",
                detail=f"east-west {ver!r} incompatible with "
                       f"{ew.EW_SCHEMA_VERSION!r}")
        handler = self._EW_DISPATCH.get(type(msg))
        if handler is None:
            return ew.EWError(
                visited_domain=self.domain_id, code="E_EW_BAD_REQUEST",
                detail=f"{msg.TYPE!r} is not a visited-side message")
        try:
            return handler(self, msg)
        except SessionError as e:
            return ew.EWError.from_session_error(self.domain_id, e)
        except Exception as e:                        # noqa: BLE001
            return ew.EWError(visited_domain=self.domain_id,
                              code="E_EW_INTERNAL",
                              detail=f"{type(e).__name__}: {e}")

    def _ew_discover(self, q: ew.DiscoverQuery) -> ew.EWMessage:
        from repro.core.asp import ASP
        self._gc_guests()
        budget = ew.SLABudget.from_wire(q.budget)
        # the HOME owns the budget application (the wire never carries the
        # raw objectives); the visited side only verifies the contract it
        # received stays inside the declared visited share
        vasp = ASP.from_wire(q.asp)
        o = vasp.objectives
        if o.ttfb_ms > budget.ttfb_ms or o.p99_ms > budget.p99_ms or \
                o.t_max_ms > budget.t_max_ms or \
                vasp.max_cost_per_1k_tokens > budget.max_cost_per_1k:
            return ew.EWError(
                visited_domain=self.domain_id, code="E_EW_BAD_REQUEST",
                detail="solicited contract exceeds its declared "
                       "visited budget share")
        if q.deadline_ms is not None and \
                q.deadline_ms <= self.core.timers.tau_disc * 1e3:
            raise SessionError(
                FailureCause.DEADLINE_EXCEEDED,
                f"[visited:{self.domain_id}] DISCOVER: {q.deadline_ms:.1f}ms "
                f"remaining cannot cover the "
                f"{self.core.timers.tau_disc * 1e3:.0f}ms phase floor")
        cands = discover(vasp, self.core.catalog, self.core.sites,
                         self.core.predictors, q.zone,
                         analytics=self.core.analytics)
        entries = [c.to_wire(include_prediction=True) for c in cands]
        digest = self.registry.get(self.domain_id)
        return ew.DiscoverOffer(
            visited_domain=self.domain_id, query_id=q.query_id,
            candidates=entries,
            digest_epoch=digest.epoch if digest else 0,
            at_s=self.core.clock.now())

    def _ew_prepare(self, req: ew.EWPrepare) -> ew.EWMessage:
        self._gc_guests()
        if req.prepare_key and req.prepare_key in self._prepare_replays:
            # at-least-once delivery: the home re-sent a PREPARE whose
            # reply was lost — return the original instead of reserving a
            # second set of provisional leases for the same establishment
            return self._prepare_replays[req.prepare_key]
        if req.deadline_ms is not None and \
                req.deadline_ms <= self.core.timers.tau_prep * 1e3:
            raise SessionError(
                FailureCause.DEADLINE_EXCEEDED,
                f"[visited:{self.domain_id}] PREPARE: "
                f"{req.deadline_ms:.1f}ms remaining cannot cover the "
                f"{self.core.timers.tau_prep * 1e3:.0f}ms phase floor")
        # session_ref namespace guard: ids are only unique per home
        # domain, so a ref that names a NATIVE session here — or another
        # home's guest — must be refused, never clobbered
        existing = self.core.sessions.get(req.session_ref)
        guest = self._guest_sessions.get(req.session_ref)
        if existing is not None and guest is None:
            raise SessionError(
                FailureCause.POLICY_DENIAL,
                f"session ref {req.session_ref!r} collides with a native "
                f"session of domain {self.domain_id!r}")
        if guest is not None and guest.home_domain != req.home_domain:
            raise SessionError(
                FailureCause.POLICY_DENIAL,
                f"session ref {req.session_ref!r} already roams here from "
                f"{guest.home_domain!r}")
        try:
            model = self.core.catalog.get(req.model_id, req.model_version)
        except KeyError:
            raise SessionError(
                FailureCause.MODEL_UNAVAILABLE,
                f"{req.model_id}@{req.model_version} not in catalog")
        klass = _KLASS.get(req.klass)
        if klass is None:
            return ew.EWError(visited_domain=self.domain_id,
                              code="E_EW_BAD_REQUEST",
                              detail=f"unknown QoS class {req.klass!r}")
        # ONE sizing for both the local reservation and the wire reply —
        # the home uses cache_bytes as the roaming-migration payload size,
        # so it must equal what the coordinator actually holds
        cache_bytes = float(model.session_state_bytes(
            max(int(req.context_tokens), 1)))
        prepared = self.core.coordinator.prepare(
            model, req.site_id, req.zone, klass, slots=req.slots,
            cache_bytes=cache_bytes, hold_s=req.hold_s)
        ref = f"{self.domain_id}/ewp-{next(self._refs):06d}"
        self._guest_by_ref[ref] = _GuestLease(
            session_ref=req.session_ref, home_domain=req.home_domain,
            model=model, prepared=prepared, site_id=req.site_id)
        timers = self.core.timers
        reply = ew.EWPrepared(
            visited_domain=self.domain_id, session_ref=req.session_ref,
            prepared_ref=ref, site_id=req.site_id, qfi=prepared.qfi,
            cache_bytes=cache_bytes,
            expires_at=prepared.prepared_at + timers.tau_prep
            + timers.tau_com + req.hold_s)
        if req.prepare_key:
            self._prepare_replays[req.prepare_key] = reply
            while len(self._prepare_replays) > self._prepare_replay_window:
                self._prepare_replays.popitem(last=False)
        return reply

    def _ew_commit(self, req: ew.EWCommit) -> ew.EWMessage:
        g = self._guest_by_ref.get(req.prepared_ref)
        if g is None:
            return ew.EWError(visited_domain=self.domain_id,
                              code="E_EW_UNKNOWN_REF",
                              detail=f"no PREPARE under "
                                     f"{req.prepared_ref!r}")
        if g.committed:
            return g.response            # duplicate COMMIT: idempotent
        if req.deadline_ms is not None and \
                req.deadline_ms <= self.core.timers.tau_com * 1e3:
            # refuse (rather than half-run) a COMMIT the budget cannot
            # cover; the home rolls the provisional PREPARE back on this
            # error, and the reaper/TTL covers a home that vanished
            raise SessionError(
                FailureCause.DEADLINE_EXCEEDED,
                f"[visited:{self.domain_id}] COMMIT: "
                f"{req.deadline_ms:.1f}ms remaining cannot cover the "
                f"{self.core.timers.tau_com * 1e3:.0f}ms phase floor")
        try:
            binding = self.core.coordinator.commit(g.prepared, g.model)
        except SessionError:
            # coordinator.commit already rolled both leases back
            self._guest_by_ref.pop(req.prepared_ref, None)
            raise
        g.charging_ref = self.core.policy.open_charging(req.session_ref)
        g.committed = True
        self._guest_sessions[req.session_ref] = g
        self.core.sessions[req.session_ref] = _GuestSessionAdapter(
            req.session_ref, binding, g.charging_ref)
        g.response = ew.EWCommitted(
            visited_domain=self.domain_id, session_ref=req.session_ref,
            prepared_ref=req.prepared_ref, site_id=g.site_id,
            endpoint=f"aiaas://{self.domain_id}/{g.site_id}"
                     f"/{g.model.model_id}",
            qfi=binding.qfi, compute_lease_id=binding.compute_lease_id,
            qos_lease_id=binding.qos_lease_id,
            charging_ref=g.charging_ref,
            lease_s=self.core.timers.lease_s,
            price_per_1k=g.model.price_per_1k_tokens,
            at_s=self.core.clock.now())
        return g.response

    def _ew_abort(self, req: ew.EWAbort) -> ew.EWMessage:
        g = self._guest_by_ref.pop(req.prepared_ref, None)
        if g is None:
            return ew.EWAbortAck(visited_domain=self.domain_id,
                                 prepared_ref=req.prepared_ref,
                                 released=False)
        if g.committed:
            self._teardown_guest(g)      # late abort degenerates to release
        else:
            self.core.coordinator.abort(g.prepared)
        return ew.EWAbortAck(visited_domain=self.domain_id,
                             prepared_ref=req.prepared_ref, released=True)

    def _ew_renew(self, req: ew.EWRenew) -> ew.EWMessage:
        g = self._guest_by_ref.get(req.prepared_ref)
        renewed = False
        if g is not None:
            site = self.core.sites[g.site_id]
            ok1 = site.renew(g.prepared.compute_lease_id, req.lease_s)
            ok2 = self.core.qos.renew(g.prepared.qos_lease_id, req.lease_s)
            renewed = ok1 and ok2
        return ew.EWRenewAck(visited_domain=self.domain_id,
                             prepared_ref=req.prepared_ref,
                             renewed=renewed)

    def _ew_release(self, req: ew.EWRelease) -> ew.EWMessage:
        g = self._guest_by_ref.pop(req.prepared_ref, None)
        if g is None:
            return ew.EWReleaseAck(visited_domain=self.domain_id,
                                   prepared_ref=req.prepared_ref,
                                   released=False)
        tokens, cost = self._teardown_guest(g)
        return ew.EWReleaseAck(visited_domain=self.domain_id,
                               prepared_ref=req.prepared_ref,
                               released=True, tokens=tokens, cost=cost)

    def tick(self) -> int:
        """Visited-side orphan sweep, on the plane-heartbeat cadence: reap
        outstanding coordinator PREPAREs past their decision window, then
        collect guest leases whose underlying leases both TTL-expired (a
        lost COMMIT, a vanished home). Returns guest records reaped."""
        before = len(self._guest_by_ref)
        self.core.coordinator.reap()
        self._gc_guests()
        return before - len(self._guest_by_ref)

    def _gc_guests(self) -> None:
        """Reap guest leases whose home domain vanished: once BOTH
        underlying leases expired by TTL (never renewed, never committed
        or released), the bookkeeping — and for committed guests the
        session adapter and backend slot — must not outlive them."""
        dead = []
        for ref, g in self._guest_by_ref.items():
            site = self.core.sites.get(g.site_id)
            cmp_live = site is not None and \
                site.lease_valid(g.prepared.compute_lease_id)
            qos_live = self.core.qos.lease_valid(g.prepared.qos_lease_id)
            if not cmp_live and not qos_live:
                dead.append(ref)
        for ref in dead:
            self._teardown_guest(self._guest_by_ref.pop(ref))

    def _teardown_guest(self, g: _GuestLease) -> Tuple[int, float]:
        """Release this guest lease's compute + QoS (idempotent), free the
        backend slot when it was the session's current anchor here, and
        settle the wholesale charge."""
        site = self.core.sites.get(g.site_id)
        current = self._guest_sessions.get(g.session_ref) is g
        if site is not None:
            site.release(g.prepared.compute_lease_id)
            plane = site.plane
            if current and plane is not None and \
                    hasattr(plane.backend, "release_slot"):
                plane.backend.release_slot(g.session_ref)
        self.core.qos.release(g.prepared.qos_lease_id)
        if current:
            del self._guest_sessions[g.session_ref]
            if isinstance(self.core.sessions.get(g.session_ref),
                          _GuestSessionAdapter):
                self.core.sessions.pop(g.session_ref, None)
        tokens, cost = 0, 0.0
        if g.charging_ref is not None:
            rec = self.core.policy.charging(g.charging_ref)
            tokens, cost = rec.tokens, rec.cost
        return tokens, cost

    def _forward_guest_result(self, site, res) -> None:
        """Visited result sink: a drained completion that belongs to a
        roaming home session is forwarded to its home controller."""
        g = self._guest_sessions.get(res.session_id)
        if g is None:
            return
        home = self._peer_objects.get(g.home_domain)
        if home is not None:
            home._on_guest_result(self.domain_id, site.spec.site_id, res)

    # ------------------------------------------------------------------
    _EW_DISPATCH: Dict[type, Callable] = {
        ew.DiscoverQuery: _ew_discover,
        ew.EWPrepare: _ew_prepare,
        ew.EWCommit: _ew_commit,
        ew.EWAbort: _ew_abort,
        ew.EWRenew: _ew_renew,
        ew.EWRelease: _ew_release,
    }
