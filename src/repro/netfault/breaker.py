"""Per-target circuit breakers: closed → open → half-open → closed.

A breaker trips after ``failure_threshold`` *consecutive* transport
failures against one target (site or peer domain). While open, the target
is excluded from DISCOVER/PAGING/solicitation with the attributable
exclusion reason ``"circuit-open"`` — no request is wasted on a flapping
link. After ``cooldown_s`` the breaker lets exactly one probe through
(half-open); the probe's outcome closes or re-opens the circuit.

The board is consulted *before* sending (``allow``) and fed *after*
(``record``), so call sites stay one-liners and every transition is
observable via ``snapshot()`` for the analytics/event surfaces.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from repro.core.clock import Clock

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half-open"


class CircuitBreaker:
    """One target's breaker state machine (driven by an external clock)."""

    def __init__(self, clock: Clock, failure_threshold: int = 3,
                 cooldown_s: float = 5.0):
        if failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        self.clock = clock
        self.failure_threshold = failure_threshold
        self.cooldown_s = cooldown_s
        self._state = CLOSED
        self._consecutive = 0
        self._opened_at = 0.0
        self._probe_out = False
        self.transitions: List[Tuple[float, str]] = []

    @property
    def state(self) -> str:
        return self._state

    def _to(self, state: str) -> None:
        if state != self._state:
            self._state = state
            self.transitions.append((self.clock.now(), state))

    def allow(self) -> bool:
        """May we send to this target now? Open circuits admit exactly one
        probe per cooldown window (half-open)."""
        if self._state == CLOSED:
            return True
        if self._state == OPEN:
            if self.clock.now() - self._opened_at >= self.cooldown_s:
                self._to(HALF_OPEN)
                self._probe_out = True
                return True
            return False
        # half-open: only the in-flight probe may talk
        if not self._probe_out:
            self._probe_out = True
            return True
        return False

    def reset(self) -> None:
        """Administrative close (a fleet-ops heal verdict): forget the
        failure history and admit traffic immediately — an explicit
        operator decision outranks the cooldown timer."""
        self._consecutive = 0
        self._probe_out = False
        self._to(CLOSED)

    def record(self, ok: bool) -> None:
        if ok:
            self._consecutive = 0
            self._probe_out = False
            self._to(CLOSED)
            return
        self._probe_out = False
        if self._state == HALF_OPEN:
            # failed probe: straight back to open, fresh cooldown
            self._opened_at = self.clock.now()
            self._to(OPEN)
            return
        self._consecutive += 1
        if self._consecutive >= self.failure_threshold:
            self._opened_at = self.clock.now()
            self._to(OPEN)


class BreakerBoard:
    """Registry of per-target breakers with one shared configuration."""

    def __init__(self, clock: Clock, failure_threshold: int = 3,
                 cooldown_s: float = 5.0):
        self.clock = clock
        self.failure_threshold = failure_threshold
        self.cooldown_s = cooldown_s
        self._breakers: Dict[str, CircuitBreaker] = {}

    def _get(self, target: str) -> CircuitBreaker:
        b = self._breakers.get(target)
        if b is None:
            b = self._breakers[target] = CircuitBreaker(
                self.clock, self.failure_threshold, self.cooldown_s)
        return b

    def allow(self, target: str) -> bool:
        return self._get(target).allow()

    def record(self, target: str, ok: bool) -> None:
        self._get(target).record(ok)

    def reset(self, target: str) -> None:
        b = self._breakers.get(target)
        if b is not None:
            b.reset()

    def state(self, target: str) -> str:
        b = self._breakers.get(target)
        return b.state if b is not None else CLOSED

    def snapshot(self) -> Dict[str, str]:
        return {t: b.state for t, b in self._breakers.items()}
