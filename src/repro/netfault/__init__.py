"""netfault: deterministic unreliable-transport layer + the machinery that
makes the control plane correct under at-least-once delivery.

* :mod:`repro.netfault.wire` — seeded per-link fault injection
  (:class:`FaultPlan` / :class:`LossyChannel`) over the VirtualClock.
* :mod:`repro.netfault.retry` — budget-aware capped-backoff
  :class:`RetryPolicy` keyed off the FailureCause remediation classes.
* :mod:`repro.netfault.breaker` — per-site/per-domain
  :class:`CircuitBreaker` / :class:`BreakerBoard` (closed → open →
  half-open) consulted by DISCOVER/PAGING/solicitation.
* :mod:`repro.netfault.reaper` — :class:`OrphanReaper`, the heartbeat-
  cadence sweep that enforces τ_prep/τ_com/hold on provisional leases.
"""

from repro.netfault.breaker import (CLOSED, HALF_OPEN, OPEN, BreakerBoard,
                                    CircuitBreaker)
from repro.netfault.reaper import OrphanReaper, attach
from repro.netfault.retry import RetryPolicy
from repro.netfault.wire import (BOTH, REQUEST, RESPONSE, FaultPlan,
                                 LossyChannel, TransportError,
                                 TransportTimeout)

__all__ = [
    "FaultPlan", "LossyChannel", "TransportError", "TransportTimeout",
    "REQUEST", "RESPONSE", "BOTH",
    "RetryPolicy",
    "CircuitBreaker", "BreakerBoard", "CLOSED", "OPEN", "HALF_OPEN",
    "OrphanReaper", "attach",
]
