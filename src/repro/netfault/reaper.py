"""Orphan-lease reaper: one periodic sweep over every lease-holding plane.

A COMMIT lost in flight leaves provisional compute/QoS leases (and, cross
domain, guest reservations) that no caller will ever confirm or abort.
Each plane owns its own sweep — ``TwoPhaseCoordinator.reap`` (home
provisional leases past τ_prep + τ_com + hold), ``NorthboundGateway.
reap_orphans`` (prepared-but-never-committed gateway sessions) and
``DomainController.tick`` (visited-side guest reservations) — and the
reaper is the thin aggregator that runs them on the plane-heartbeat cadence
so τ-timers are enforced, not advisory.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Tuple


class OrphanReaper:
    """Aggregate per-plane sweeps; each returns how many orphans it reaped."""

    def __init__(self):
        self._sweeps: List[Tuple[str, Callable[[], int]]] = []
        self.total_reaped = 0

    def register(self, name: str, sweep: Callable[[], int]) -> None:
        self._sweeps.append((name, sweep))

    def sweep(self) -> Dict[str, int]:
        """Run every registered sweep once; returns per-plane reap counts."""
        out: Dict[str, int] = {}
        for name, fn in self._sweeps:
            reaped = fn()
            try:
                n = len(reaped)        # sweeps may return the reaped items
            except TypeError:
                n = int(reaped or 0)
            out[name] = out.get(name, 0) + n
            self.total_reaped += n
        return out


def attach(gateway=None, coordinator=None, domains=()) -> OrphanReaper:
    """Wire the standard sweeps for a deployment in one call."""
    r = OrphanReaper()
    if coordinator is not None:
        r.register("coordinator", coordinator.reap)
    if gateway is not None:
        r.register("gateway", gateway.reap_orphans)
    for d in domains:
        r.register(f"domain:{d.domain_id}", d.tick)
    return r
