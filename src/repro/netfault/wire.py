"""Deterministic unreliable-transport layer for the control plane.

A :class:`LossyChannel` wraps any request/response endpoint — the northbound
``NorthboundGateway.handle_json`` (str → str) or an east-west
``DomainController`` peer endpoint (message → message) — and injects a
seeded per-link fault schedule driven by the shared
:class:`~repro.core.clock.VirtualClock`:

* **drop (request)** — the request never reaches the server; the caller
  burns ``timeout_s`` of (virtual) time and sees :class:`TransportTimeout`.
* **drop (response)** — the server *does* process the request (its state
  mutates!) but the reply is lost: the classic lost-COMMIT. The caller
  times out and must retry idempotently.
* **delay** — the round trip takes extra time off the caller's deadline
  budget without failing.
* **duplicate** — the request is delivered twice back-to-back
  (at-least-once delivery); the server must be idempotent.
* **reorder** — a stale copy of the *previous* request arrives immediately
  before the current one (late retransmission overtaking the window).
* **corrupt** — the frame is mangled in flight and discarded by the link
  layer (CRC failure): surfaces as a retryable :class:`TransportError`,
  never as a malformed frame handed to the server.
* **partition** — one-way windows ``(start_s, end_s, direction)`` during
  which every message in that direction is dropped.

Determinism: all draws come from ``random.Random(plan.seed)`` in a fixed
per-message order, so a fault schedule replays bit-identically from its
seed — the property tests and the netfault bench rely on this.
"""

from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

from repro.core.clock import Clock


class TransportError(Exception):
    """A retryable link-layer delivery failure (lost/corrupted frame)."""


class TransportTimeout(TransportError):
    """No reply within ``timeout_s`` — the caller cannot tell whether the
    server processed the request (the defining 2PC ambiguity)."""


#: partition directions
REQUEST = "request"
RESPONSE = "response"
BOTH = "both"


@dataclass(frozen=True)
class FaultPlan:
    """A seeded per-link fault schedule. All probabilities are per-message
    and independent; ``uniform(rate)`` gives the bench's single-knob form.
    """
    seed: int = 0
    p_drop_request: float = 0.0
    p_drop_response: float = 0.0
    p_duplicate: float = 0.0
    p_reorder: float = 0.0
    p_corrupt: float = 0.0
    p_delay: float = 0.0
    delay_ms: Tuple[float, float] = (1.0, 20.0)
    #: how long a caller waits before concluding the message died
    timeout_s: float = 0.05
    #: one-way partition windows (start_s, end_s, direction) on the
    #: VirtualClock timeline
    partitions: Tuple[Tuple[float, float, str], ...] = ()

    @classmethod
    def uniform(cls, rate: float, seed: int = 0, **kw) -> "FaultPlan":
        """Equal per-fault rate — the bench's loss-rate knob."""
        return cls(seed=seed, p_drop_request=rate, p_drop_response=rate,
                   p_duplicate=rate, p_reorder=rate, p_corrupt=rate,
                   p_delay=rate, **kw)

    def validate(self) -> None:
        for name in ("p_drop_request", "p_drop_response", "p_duplicate",
                     "p_reorder", "p_corrupt", "p_delay"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name}={p} outside [0, 1]")
        for start, end, direction in self.partitions:
            if end < start:
                raise ValueError(f"partition window ({start}, {end}) inverted")
            if direction not in (REQUEST, RESPONSE, BOTH):
                raise ValueError(f"unknown partition direction {direction!r}")


class LossyChannel:
    """Wrap ``endpoint`` (request → response) with a seeded fault schedule.

    The channel is itself callable with the same signature, so it drops in
    wherever the reliable endpoint was wired: ``SessionClient(transport=...)``
    or ``DomainController.connect(..., endpoint=LossyChannel(...))``.
    """

    def __init__(self, endpoint: Callable[[Any], Any], clock: Clock,
                 plan: FaultPlan, name: str = "link"):
        plan.validate()
        self.endpoint = endpoint
        self.clock = clock
        self.plan = plan
        self.name = name
        self._rng = random.Random(plan.seed)
        self._held: Optional[Any] = None     # previous payload for reorder
        self.stats: Dict[str, int] = {
            "sent": 0, "delivered": 0, "drop_request": 0,
            "drop_response": 0, "duplicate": 0, "reorder": 0,
            "corrupt": 0, "delay": 0, "partition": 0,
        }

    # -- internals ------------------------------------------------------
    def _partitioned(self, direction: str) -> bool:
        now = self.clock.now()
        for start, end, d in self.plan.partitions:
            if start <= now < end and (d == BOTH or d == direction):
                return True
        return False

    def _timeout(self, kind: str) -> "TransportTimeout":
        # waiting for a reply that never comes consumes real budget
        advance = getattr(self.clock, "advance", None)
        if advance is not None:
            advance(self.plan.timeout_s)
        self.stats[kind] += 1
        return TransportTimeout(
            f"[{self.name}] {kind} (timeout {self.plan.timeout_s * 1e3:.0f}ms)")

    # -- the wire -------------------------------------------------------
    def __call__(self, payload: Any) -> Any:
        plan, rng = self.plan, self._rng
        self.stats["sent"] += 1
        # fixed draw order per message → deterministic replay from the seed
        r_corrupt = rng.random()
        r_drop_req = rng.random()
        r_delay = rng.random()
        delay_s = rng.uniform(*plan.delay_ms) / 1e3
        r_reorder = rng.random()
        r_dup = rng.random()
        r_drop_resp = rng.random()

        if self._partitioned(REQUEST):
            raise self._timeout("partition")
        if r_corrupt < plan.p_corrupt:
            # mangled in flight; the link layer discards the frame, so the
            # server never sees malformed bytes — the caller just times out
            raise self._timeout("corrupt")
        if r_drop_req < plan.p_drop_request:
            raise self._timeout("drop_request")
        if r_delay < plan.p_delay:
            self.stats["delay"] += 1
            advance = getattr(self.clock, "advance", None)
            if advance is not None:
                advance(delay_s)
        if r_reorder < plan.p_reorder and self._held is not None:
            # a stale retransmission of the previous request overtakes the
            # window and lands first; its response is lost to history
            self.stats["reorder"] += 1
            try:
                self.endpoint(self._held)
            except Exception:
                pass                     # stale delivery outcome is moot
        if r_dup < plan.p_duplicate:
            # at-least-once: deliver twice, the caller sees the second reply
            self.stats["duplicate"] += 1
            try:
                self.endpoint(payload)
            except Exception:
                pass                     # first copy's fate is invisible
        response = self.endpoint(payload)
        self._held = payload
        if self._partitioned(RESPONSE):
            raise self._timeout("partition")
        if r_drop_resp < plan.p_drop_response:
            # the server processed the request; only the reply died
            raise self._timeout("drop_response")
        self.stats["delivered"] += 1
        return response
