"""Budget-aware retry engine: capped exponential backoff + full jitter.

Retryability keys off the :data:`repro.core.failures.RETRYABLE` remediation
classes — a :class:`~repro.netfault.wire.TransportError` is always
retryable (the request may never have arrived), a ``SessionError`` only
when its cause is in the retryable partition, and every retry first checks
the remaining deadline budget so a caller never sleeps past its own
deadline (retry amplification is bounded by the budget, not just the
attempt cap).

Backoff draws are deterministic per ``(seed, key, attempt)`` so a fault
schedule replays bit-identically; "full jitter" (uniform in ``[0, cap]``)
is the AWS-style scheme that decorrelates synchronized retry storms.
"""

from __future__ import annotations

import random
import zlib
from dataclasses import dataclass
from typing import Optional, Union

from repro.core.failures import RETRYABLE, FailureCause, SessionError
from repro.netfault.wire import TransportError


@dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff with full jitter and a deadline budget."""
    max_attempts: int = 5
    base_s: float = 0.01
    cap_s: float = 0.5
    seed: int = 0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.base_s <= 0 or self.cap_s < self.base_s:
            raise ValueError("need 0 < base_s <= cap_s")

    def retryable(self, err: Union[BaseException, FailureCause]) -> bool:
        """Is this failure class worth another attempt at all?"""
        if isinstance(err, FailureCause):
            return err in RETRYABLE
        if isinstance(err, TransportError):
            return True
        if isinstance(err, SessionError):
            return err.cause in RETRYABLE
        return False

    def backoff_s(self, attempt: int, key: str = "") -> float:
        """Jittered sleep before retry ``attempt`` (1-based). Deterministic
        per (seed, key, attempt); crc32 keeps it stable across processes
        (str hash() is salted)."""
        cap = min(self.cap_s, self.base_s * (2 ** max(0, attempt - 1)))
        mix = zlib.crc32(f"{self.seed}:{key}:{attempt}".encode())
        return random.Random(mix).uniform(0.0, cap)

    def should_retry(self, err: Union[BaseException, FailureCause],
                     attempt: int,
                     remaining_s: Optional[float] = None) -> bool:
        """True when attempt ``attempt`` (1-based, just failed) should be
        followed by another; budget-aware — the next backoff must fit in
        the remaining deadline."""
        if not self.retryable(err):
            return False
        if attempt >= self.max_attempts:
            return False
        if remaining_s is not None:
            if remaining_s <= 0:
                return False
            if self.backoff_s(attempt) >= remaining_s:
                return False
        return True
