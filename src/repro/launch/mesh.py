"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state. The dry-run launcher sets
``--xla_force_host_platform_device_count=512`` before any jax import; smoke
tests and benches see the real single CPU device.

Production target: TPU v5e pods. Single pod = 16×16 = 256 chips
(axes data×model); multi-pod = 2×16×16 = 512 chips (pod×data×model, the
"pod" axis rides the DCN/inter-pod links).
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False, devices=None) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = list(devices if devices is not None else jax.devices())
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for {'multi-pod' if multi_pod else 'single-pod'} "
            f"mesh, have {len(devs)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 (dry-run) "
            f"or on the real pod")
    if len(devs) == n:
        try:
            return jax.make_mesh(shape, axes, devices=devs)
        except TypeError:  # older jax without devices kwarg
            pass
    return Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model"), devices=None) -> Mesh:
    """Small mesh for integration tests (8 forced host devices)."""
    n = int(np.prod(shape))
    devs = list(devices if devices is not None else jax.devices())[:n]
    return Mesh(np.asarray(devs).reshape(shape), axes)
