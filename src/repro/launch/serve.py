"""NE-AIaaS serving launcher: control plane + real engines + QoS scheduler.

    PYTHONPATH=src python -m repro.launch.serve --model edge-tiny \
        --sessions 4 --requests 12

Production path: on a pod, the engine's prefill/decode jit under
``make_production_mesh()`` with the decode plan's shardings (the dry-run
proves every assigned arch compiles there); on this container it runs the
small configs for real. Either way the AIS lifecycle, QoS scheduling,
telemetry, and charging are identical — that is the paper's point.
"""

from __future__ import annotations

import argparse
import sys

import numpy as np

from repro.configs import ARCH_IDS
from repro.core import Orchestrator, default_asp
from repro.core.asp import QualityTier
from repro.core.clock import Clock
from repro.serving.scheduler import QoSScheduler, Request
from repro.serving.server import AIaaSServer


def serve(model: str = "edge-tiny", *, sessions: int = 4, requests: int = 12,
          slots: int = 8, max_len: int = 192, gen_tokens: int = 8,
          t_max_ms: float = 300_000.0, seed: int = 0, quiet: bool = False):
    import dataclasses
    clock = Clock()
    orch = Orchestrator(clock=clock)
    server = AIaaSServer(orch, model, slots=slots, max_len=max_len)
    sched = QoSScheduler(clock, slots=slots)
    rng = np.random.default_rng(seed)

    live = {}
    for i in range(sessions):
        tier = QualityTier.PREMIUM if i % 2 == 0 else QualityTier.BASIC
        asp = default_asp(tier=tier)
        asp = dataclasses.replace(
            asp, objectives=dataclasses.replace(
                asp.objectives, ttfb_ms=t_max_ms / 10, p95_ms=t_max_ms / 3,
                p99_ms=t_max_ms / 2, t_max_ms=t_max_ms, nu_min=0.0))
        s = orch.establish(asp, invoker=f"ue-{i}", zone="zone-a")
        live[s.session_id] = s
        if not quiet:
            print(f"AIS {s.session_id} tier={tier.name} "
                  f"anchor={s.binding.site_id} qfi={s.binding.qfi}")

    sids = list(live)
    for r in range(requests):
        sid = sids[r % len(sids)]
        sched.submit(Request(
            f"req-{r}", sid,
            "premium" if live[sid].asp.tier >= 2 else "best-effort",
            int(rng.integers(8, 32)), gen_tokens, t_max_ms))

    served = 0
    while served < requests and (sched.queue_depth() or sched.running):
        for req in sched.next_batch(predicted_service_ms=100.0):
            prompt = rng.integers(0, 2048, size=req.prompt_tokens
                                  ).astype(np.int32)
            server.request(live[req.session_id], prompt,
                           gen_tokens=req.gen_tokens)
            sched.complete(req.request_id)
            served += 1
        if not sched.running and not sched.queue_depth():
            break

    reports = {}
    for sid, s in live.items():
        rep = orch.compliance(s)
        reports[sid] = rep
        if not quiet and rep:
            print(f"{sid} q99={rep.z.q99_ms:9.1f}ms ρ̂={rep.z.rho:.2f} "
                  f"ν̂={rep.z.nu_tokens_per_s:7.1f} tok/s "
                  f"compliant={rep.in_compliance} "
                  f"cost={orch.policy.charging(s.charging_ref).cost:.4f}")
        orch.release(s)
    if not quiet:
        print(f"served {served}/{requests} "
              f"(fast-failed {sched.stats.fast_failed} on deadline)")
    return served, reports


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="edge-tiny", choices=ARCH_IDS)
    ap.add_argument("--sessions", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--gen-tokens", type=int, default=8)
    a = ap.parse_args()
    serve(a.model, sessions=a.sessions, requests=a.requests, slots=a.slots,
          gen_tokens=a.gen_tokens)


if __name__ == "__main__":
    main()
