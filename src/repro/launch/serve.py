"""NE-AIaaS serving launcher: real engines behind QoS-scheduled serving
planes, driven END-TO-END through the northbound session API.

    PYTHONPATH=src python -m repro.launch.serve --model edge-tiny \
        --sessions 4 --requests 12

Every session here is established, served, and released by a
:class:`~repro.api.client.SessionClient` speaking JSON to the
:class:`~repro.api.gateway.NorthboundGateway` — the exact wire surface a
remote application-service-provider would use. Production path: on a pod,
the engine's prefill/decode jit under ``make_production_mesh()`` with the
decode plan's shardings; on this container it runs the small configs for
real. Either way the AIS lifecycle, QoS-scheduled admission (class order +
premium reservation + deadline fast-fail), telemetry, and charging are
identical — that is the paper's point.
"""

from __future__ import annotations

import argparse

from repro.api.client import SessionClient
from repro.configs import ARCH_IDS
from repro.core import Orchestrator, default_asp
from repro.core.asp import QualityTier
from repro.core.clock import Clock
from repro.serving.server import AIaaSServer


def serve(model: str = "edge-tiny", *, sessions: int = 4, requests: int = 12,
          slots: int = 8, max_len: int = 192, gen_tokens: int = 8,
          t_max_ms: float = 300_000.0, seed: int = 0, quiet: bool = False,
          decode_chunk: int = 0, pallas_decode: bool = False):
    import dataclasses

    import numpy as np
    clock = Clock()
    orch = Orchestrator(clock=clock)
    # decode_chunk > 0 overrides the per-class fused-chunk caps uniformly
    # (benchmarks / A-B runs); 0 keeps the QoS-adaptive defaults
    chunks = ({k: decode_chunk for k in ("premium", "assured", "best-effort")}
              if decode_chunk > 0 else None)
    server = AIaaSServer(orch, model, slots=slots, max_len=max_len,
                         decode_chunk=chunks, pallas_decode=pallas_decode)
    rng = np.random.default_rng(seed)

    clients = []
    for i in range(sessions):
        tier = QualityTier.PREMIUM if i % 2 == 0 else QualityTier.BASIC
        asp = default_asp(tier=tier)
        asp = dataclasses.replace(
            asp, objectives=dataclasses.replace(
                asp.objectives, ttfb_ms=t_max_ms / 10, p95_ms=t_max_ms / 3,
                p99_ms=t_max_ms / 2, t_max_ms=t_max_ms, nu_min=0.0))
        c = SessionClient(server.gateway, asp, invoker=f"ue-{i}",
                          zone="zone-a").establish()
        clients.append(c)
        if not quiet:
            print(f"AIS {c.session_id} tier={tier.name} "
                  f"anchor={c.record['anchor']} qfi={c.record['qfi']}")

    # submit everything through the northbound API — admission order
    # (premium first, reserved share, fast-fail) is the site planes' job
    for r in range(requests):
        c = clients[r % len(clients)]
        c.submit(prompt_tokens=int(rng.integers(8, 32)),
                 gen_tokens=gen_tokens)
    results = server.drain()
    served = sum(1 for res in results.values()
                 if res.failed is None)
    fast_failed = sum(p.scheduler.stats.fast_failed
                      for p in server.planes.values())

    reports = {}
    for c in clients:
        rep = c.compliance()
        reports[c.session_id] = rep
        ack = c.release()
        if not quiet and rep.n:
            z = rep.z
            print(f"{c.session_id} q99={z['q99_ms']:9.1f}ms ρ̂={z['rho']:.2f} "
                  f"ν̂={z['nu_tokens_per_s']:7.1f} tok/s "
                  f"compliant={rep.in_compliance} cost={ack.total_cost:.4f}")
    if not quiet:
        print(f"served {served}/{requests} "
              f"(fast-failed {fast_failed} on deadline)")
    return served, reports


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="edge-tiny", choices=ARCH_IDS)
    ap.add_argument("--sessions", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--gen-tokens", type=int, default=8)
    ap.add_argument("--decode-chunk", type=int, default=0,
                    help="uniform fused-decode chunk size "
                         "(0 = QoS-adaptive per-class defaults)")
    ap.add_argument("--pallas-decode", action="store_true",
                    help="route decode attention through the Pallas "
                         "flash-decode kernel (interpret mode off-TPU)")
    a = ap.parse_args()
    serve(a.model, sessions=a.sessions, requests=a.requests, slots=a.slots,
          gen_tokens=a.gen_tokens, decode_chunk=a.decode_chunk,
          pallas_decode=a.pallas_decode)


if __name__ == "__main__":
    main()
