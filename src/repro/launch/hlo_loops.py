"""Loop-aware HLO analysis.

XLA's ``cost_analysis()`` counts a while-loop body ONCE (verified: an 8-step
scan of matmuls reports 1/8 of the unrolled FLOPs), and collectives inside
loop bodies appear once in ``as_text()``. Every interesting program here is
scan-shaped (layers × microbatches × attention/MoE chunk loops), so naive
numbers are off by 1–3 orders of magnitude.

This module parses the optimized HLO text into computations, propagates
``known_trip_count`` multipliers through the while-call graph, and produces:

* ``flops``        — 2·prod(result)·prod(contracting) per dot × multiplier
                     (matmul-dominated programs; elementwise FLOPs ignored)
* ``hbm_bytes``    — per-op operand+result bytes × multiplier, counted in
                     non-fused computations only (a fusion op's boundary is
                     the real HBM traffic; its body ops are register-resident)
* ``collectives``  — wire bytes per device × multiplier, same cost model as
                     repro.launch.hlo_analysis.
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

import numpy as np

from repro.launch.hlo_analysis import (_DTYPE_BYTES, _GROUPS_IOTA_RE,
                                       _GROUPS_LIST_RE, _WIRE_FACTOR,
                                       shape_bytes)

# computation headers have nested parens in the param list:
#   %region_0.2 (arg: (s32[], f32[16,256]{1,0})) -> (s32[], ...) {
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
# shape group is lazy up to the op name: big tuple shapes contain
# '/*index=5*/' comments (with '='), so a character-class parse breaks
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"(.+?)\s([\w\-]+)\((.*)$")
_TRIP = re.compile(r'known_trip_count[":{ ]+n["\s:]+\"?(\d+)')
_BODY = re.compile(r"body=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_OPERAND = re.compile(r"%([\w.\-]+)")
_DOT_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_SHAPE1 = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_NO_TRAFFIC = {"get-tuple-element", "tuple", "parameter", "bitcast",
               "constant", "while", "conditional", "after-all", "token",
               "opt-barrier"}
_COLLECTIVES = set(_WIRE_FACTOR)


def _dims(shape_str):
    m = _SHAPE1.search(shape_str)
    if not m or m.group(1) not in _DTYPE_BYTES:
        return None, None
    d = [int(x) for x in m.group(2).split(",")] if m.group(2) else []
    return d, _DTYPE_BYTES[m.group(1)]


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)   # (name, shape, op, rest)
    shapes: dict = field(default_factory=dict)   # symbol -> shape string


def parse_computations(text: str):
    comps = {}
    cur = None
    entry = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(1))
                if line.lstrip().startswith("ENTRY"):
                    entry = cur.name
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            name, shape, op, rest = m.groups()
            cur.instrs.append((name, shape, op, rest))
            cur.shapes[name] = shape
    return comps, entry


def multipliers(comps, entry):
    """Propagate trip-count products through the while/fusion call graph.
    Returns (mult, fused): per-computation execution multiplier and whether
    the computation body is fused (excluded from HBM byte accounting)."""
    mult = defaultdict(float)
    fused = {}
    mult[entry] = 1.0
    fused[entry] = False
    # build edges
    edges = defaultdict(list)    # parent -> [(child, factor, is_fused_body)]
    for cname, comp in comps.items():
        for (_, _, op, rest) in comp.instrs:
            if op == "while":
                n = 1
                tm = _TRIP.search(rest)
                if tm:
                    n = int(tm.group(1))
                bm = _BODY.search(rest)
                cm = _COND.search(rest)
                if bm:
                    edges[cname].append((bm.group(1), float(n), False))
                if cm:
                    edges[cname].append((cm.group(1), float(n + 1), True))
            else:
                for callee in _CALLS.findall(rest):
                    edges[cname].append((callee, 1.0, True))
    # BFS from entry
    seen = [entry]
    i = 0
    while i < len(seen):
        parent = seen[i]
        i += 1
        for child, factor, is_fused in edges.get(parent, ()):
            if child not in comps:
                continue
            m = mult[parent] * factor
            if m > mult[child]:
                mult[child] = m
            f = fused[parent] or is_fused
            fused[child] = min(fused.get(child, True), f) if child in fused \
                else f
            if seen.count(child) < 3:    # allow re-visits for max propagation
                seen.append(child)
    return mult, fused


def _fusion_root_op(comps, rest: str) -> str:
    """Op kind of the fused computation's ROOT (in-place dus fusions alias
    their big operand — counting it as traffic inflates decode 100×)."""
    m = _CALLS.search(rest)
    if not m or m.group(1) not in comps:
        return ""
    callee = comps[m.group(1)]
    if not callee.instrs:
        return ""
    return callee.instrs[-1][2]   # last instruction == ROOT in HLO text


def _fusion_ops(comps, rest: str) -> set:
    """All op kinds inside the fused computation (dus/ds may be fused mid-
    body with converts, not at the root)."""
    m = _CALLS.search(rest)
    if not m or m.group(1) not in comps:
        return set()
    return {i[2] for i in comps[m.group(1)].instrs}


def analyze(text: str, n_devices: int) -> dict:
    comps, entry = parse_computations(text)
    if entry is None:
        return {}
    mult, fused = multipliers(comps, entry)

    flops = 0.0
    hbm = 0.0
    coll = defaultdict(lambda: {"count": 0.0, "result_bytes": 0.0,
                                "wire_bytes": 0.0})
    wire_total = 0.0

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        body_fused = fused.get(cname, True)
        for (iname, shape, op, rest) in comp.instrs:
            # ---- FLOPs: dots anywhere (incl. fusion bodies) --------------
            if op == "dot":
                rd, _ = _dims(shape)
                cm = _DOT_CONTRACT.search(rest)
                k = 1
                if cm and cm.group(1):
                    lhs_ref = _OPERAND.search(rest)
                    if lhs_ref and lhs_ref.group(1) in comp.shapes:
                        ld, _ = _dims(comp.shapes[lhs_ref.group(1)])
                        if ld:
                            for ci in cm.group(1).split(","):
                                ci = int(ci)
                                if ci < len(ld):
                                    k *= ld[ci]
                if rd is not None:
                    flops += 2.0 * float(np.prod(rd or [1])) * k * m
            # ---- collectives (non-fused computations carry real comm) ----
            if op in _COLLECTIVES and not body_fused:
                b = shape_bytes(shape)
                gm = _GROUPS_IOTA_RE.search(rest)
                if gm:
                    n = int(gm.group(2))
                else:
                    gl = _GROUPS_LIST_RE.search(rest)
                    n = (len(gl.group(1).split(","))
                         if gl and gl.group(1).strip() else n_devices)
                if n > 1:
                    wire = _WIRE_FACTOR[op](n) * b * m
                    coll[op]["count"] += m
                    coll[op]["result_bytes"] += b * m
                    coll[op]["wire_bytes"] += wire
                    wire_total += wire
            # ---- HBM traffic: op boundaries in non-fused computations ----
            if not body_fused and op not in _NO_TRAFFIC:
                b = shape_bytes(shape)
                opb = [shape_bytes(comp.shapes[o])
                       for o in _OPERAND.findall(rest)[:8]
                       if o in comp.shapes]
                if op in ("dynamic-slice", "gather"):
                    # reads only the sliced/gathered elements (≈ result)
                    traffic = 2.0 * b
                elif op in ("dynamic-update-slice", "scatter"):
                    # in-place on the aliased big operand: traffic ≈ update
                    traffic = 2.0 * (sum(opb) - max(opb)) if opb else b
                elif op == "fusion":
                    fops = _fusion_ops(comps, rest)
                    mx = max(opb) if opb else 0
                    if ({"dynamic-update-slice", "scatter"} & fops
                            and opb and b >= 0.5 * mx):
                        # in-place update fused with elementwise ops: the
                        # result aliases the big operand (stacked cache);
                        # real traffic is the updated slice + small operands
                        traffic = 2.0 * (sum(opb) - mx)
                    elif ({"dynamic-slice", "gather"} & fops
                            and opb and mx > 2 * b):
                        # slice-read fused with converts: only the slice and
                        # the result move, not the whole sliced-from buffer
                        traffic = 2.0 * b + (sum(opb) - mx)
                    else:
                        traffic = b + sum(opb)
                else:
                    traffic = b + sum(opb)
                hbm += traffic * m

    return {
        "flops_per_device": flops,
        "hbm_bytes_per_device": hbm,
        "wire_bytes_per_device": wire_total,
        "collectives_per_op": {k: dict(v) for k, v in coll.items()},
        "n_computations": len(comps),
    }
