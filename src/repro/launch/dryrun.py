import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# (Override for small integration tests via REPRO_DRYRUN_DEVICES.)
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input shape) cell, on the single-pod 16×16 mesh
and the 2×16×16 multi-pod mesh:

    with mesh:
        lowered = jax.jit(step, in_shardings=…, donate…).lower(*input_specs)
        compiled = lowered.compile()
        print(compiled.memory_analysis())   # proves it fits
        print(compiled.cost_analysis())     # FLOPs/bytes for §Roofline

plus HLO collective parsing → artifacts/dryrun/<arch>__<shape>__<mesh>.json
consumed by benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-medium-14b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all            # single-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
"""

import argparse
import gzip
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.models.transformer import LM
from repro.sharding import SHAPES, cell_runnable, input_specs, make_plan
from repro.sharding.planner import data_axes
from repro.launch.mesh import make_production_mesh
from repro.launch import hlo_analysis as H
from repro.launch import hlo_loops as HL
from repro.training.train_step import (abstract_train_state, make_train_step,
                                       train_state_specs)

ASSIGNED = tuple(a for a in ARCH_IDS if a != "edge-tiny")


def _shard(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _batch_shardings(mesh, plan, batch_specs):
    return {k: NamedSharding(mesh, plan.batch_specs.get(k, P()))
            for k in batch_specs}


def lower_cell(arch: str, shape_name: str, mesh, *, scale: float = 1.0,
               overrides=None, hlo_out: str | None = None):
    """Build + lower + compile one cell. Returns (record, compiled)."""
    cfg = get_config(arch)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    ok, reason = cell_runnable(cfg, shape_name)
    if not ok:
        return {"status": "skipped", "reason": reason}, None

    cell, batch, seq, specs = input_specs(cfg, shape_name, scale=scale)
    lm = LM(cfg)
    n_dev = mesh.devices.size
    t0 = time.time()

    if cell.kind == "train":
        state_abs = abstract_train_state(lm)
        plan = make_plan(cfg, mesh, "train", batch=batch, seq=seq,
                         param_tree=state_abs.params)
        step = make_train_step(lm, microbatches=plan.microbatches)
        state_specs = train_state_specs(plan, state_abs)
        in_sh = (jax.tree.map(lambda s: NamedSharding(mesh, s), state_specs,
                              is_leaf=lambda x: isinstance(x, P)),
                 _batch_shardings(mesh, plan, specs))
        with mesh:
            lowered = jax.jit(step, in_shardings=in_sh,
                              donate_argnums=(0,)).lower(state_abs, specs)
    elif cell.kind == "prefill":
        params_abs = lm.param_specs()
        max_len = seq
        cache_abs = lm.init_cache(batch, max_len, abstract=True)
        plan = make_plan(cfg, mesh, "prefill", batch=batch, seq=seq,
                         param_tree=params_abs, cache_tree=cache_abs)

        def prefill_step(params, b):
            return lm.prefill(params, b, max_len)

        in_sh = (_shard(mesh, plan.param_specs),
                 _batch_shardings(mesh, plan, specs))
        # the output cache is the session state: shard it like the decode
        # cache, else XLA leaves it batch-sharded only (13 GB/device observed)
        out_sh = (NamedSharding(mesh, P()), _shard(mesh, plan.cache_specs))
        with mesh:
            lowered = jax.jit(prefill_step, in_shardings=in_sh,
                              out_shardings=out_sh).lower(params_abs, specs)
    else:  # decode / serve_step
        params_abs = lm.param_specs()
        if cfg.serve_weight_dtype == "int8":
            from repro.models.quant import abstract_quantize_tree
            params_abs = abstract_quantize_tree(params_abs)
        cache_abs = lm.init_cache(batch, seq, abstract=True)
        plan = make_plan(cfg, mesh, "decode", batch=batch, seq=seq,
                         param_tree=params_abs, cache_tree=cache_abs)

        def serve_step(params, cache, tokens):
            return lm.decode_step(params, cache, tokens)

        in_sh = (_shard(mesh, plan.param_specs),
                 _shard(mesh, plan.cache_specs),
                 NamedSharding(mesh, plan.batch_specs["tokens"]))
        with mesh:
            lowered = jax.jit(serve_step, in_shardings=in_sh,
                              donate_argnums=(1,)).lower(
                                  params_abs, cache_abs, specs["tokens"])

    lower_s = time.time() - t0
    t1 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t1

    ca = H.normalize_cost_analysis(compiled.cost_analysis())
    ma = compiled.memory_analysis()
    print(ma)
    print({k: ca.get(k) for k in ("flops", "bytes accessed")})
    hlo_text = compiled.as_text()
    if hlo_out:
        with gzip.open(hlo_out, "wt", compresslevel=5) as f:
            f.write(hlo_text)
    colls = H.collect_collectives(hlo_text, n_dev)
    roof_naive = H.roofline_terms(ca, colls, n_dev)
    # loop-aware analysis: XLA's cost_analysis counts while bodies once —
    # scan-shaped programs need trip-count multipliers (repro.launch.hlo_loops)
    la = HL.analyze(hlo_text, n_dev)
    roof = {
        "flops_per_device": la["flops_per_device"],
        "flops_global": la["flops_per_device"] * n_dev,
        "hbm_bytes_per_device": la["hbm_bytes_per_device"],
        "wire_bytes_per_device": la["wire_bytes_per_device"],
        "compute_s": la["flops_per_device"] / H.PEAK_FLOPS,
        "memory_s": la["hbm_bytes_per_device"] / H.HBM_BW,
        "collective_s": la["wire_bytes_per_device"] / H.LINK_BW,
    }
    roof["dominant"] = max(
        (("compute", roof["compute_s"]), ("memory", roof["memory_s"]),
         ("collective", roof["collective_s"])), key=lambda kv: kv[1])[0]
    roof["roofline_bound_s"] = max(roof["compute_s"], roof["memory_s"],
                                   roof["collective_s"])
    roof["compute_fraction_of_bound"] = (
        roof["compute_s"] / roof["roofline_bound_s"]
        if roof["roofline_bound_s"] else 0.0)
    mf = H.model_flops(cfg, cell.kind, batch, seq)
    record = {
        "status": "ok",
        "arch": arch,
        "shape": shape_name,
        "kind": cell.kind,
        "mesh": {"shape": list(mesh.devices.shape),
                 "axes": list(mesh.axis_names), "devices": int(n_dev)},
        "batch": batch,
        "seq": seq,
        "scale": scale,
        "microbatches": getattr(plan, "microbatches", 1),
        "plan_notes": plan.notes,
        "lower_s": round(lower_s, 2),
        "compile_s": round(compile_s, 2),
        "memory": H.memory_report(ma),
        "cost_analysis": {k: float(v) for k, v in ca.items()
                          if isinstance(v, (int, float))},
        "collectives": la["collectives_per_op"],
        "roofline": roof,
        "roofline_naive_bodyonce": roof_naive,
        "model_flops": mf,
        "useful_flops_ratio": (mf / roof["flops_global"]
                               if roof["flops_global"] else 0.0),
    }
    return record, compiled


def run_cell(arch, shape_name, *, multi_pod=False, scale=1.0, out_dir=None,
             force=False, overrides=None, tag=""):
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    out_dir = out_dir or "artifacts/dryrun"
    os.makedirs(out_dir, exist_ok=True)
    stem = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    path = os.path.join(out_dir, stem + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        record, _ = lower_cell(arch, shape_name, mesh, scale=scale,
                               overrides=overrides,
                               hlo_out=path.replace(".json", ".hlo.txt.gz"))
    except Exception as e:  # a failure here is a bug in the system
        record = {"status": "error", "arch": arch, "shape": shape_name,
                  "mesh": mesh_name, "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-4000:]}
    record.setdefault("arch", arch)
    record.setdefault("shape", shape_name)
    record["mesh_name"] = mesh_name
    with open(path, "w") as f:
        json.dump(record, f, indent=1, default=float)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    cells = []
    archs = ASSIGNED if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    for a in archs:
        for s in shapes:
            cells.append((a, s))
    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = 0
    for mp in meshes:
        for a, s in cells:
            t0 = time.time()
            rec = run_cell(a, s, multi_pod=mp, scale=args.scale,
                           out_dir=args.out, force=args.force)
            status = rec["status"]
            extra = ""
            if status == "ok":
                r = rec["roofline"]
                extra = (f"dom={r['dominant']:<10} "
                         f"bound={r['roofline_bound_s']*1e3:8.2f}ms "
                         f"fit={rec['memory']['fits_hbm']}")
            elif status == "error":
                failures += 1
                extra = rec["error"][:120]
            print(f"[{'2x16x16' if mp else '16x16'}] {a:22s} {s:12s} "
                  f"{status:8s} {time.time()-t0:6.1f}s {extra}", flush=True)
    if failures:
        raise SystemExit(f"{failures} dry-run cells FAILED")


if __name__ == "__main__":
    main()
