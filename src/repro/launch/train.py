"""Training driver with fault-tolerant operation.

    PYTHONPATH=src python -m repro.launch.train --arch edge-tiny --steps 200

Wires together: config → sharding plan → microbatched remat train step →
synthetic data stream → periodic sharded checkpoints → deterministic restart
(--resume picks up the latest step AND the data cursor) → straggler policy
telemetry. On the CPU container this trains the small configs for real; on a
pod the same driver runs under ``make_production_mesh()`` (--production).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.transformer import LM
from repro.training.data import DataConfig, SyntheticLMStream
from repro.training.optimizer import AdamWHyper
from repro.training.train_step import (TrainState, init_train_state,
                                       make_train_step, train_state_specs)
from repro.training import checkpoint as ckpt
from repro.training.fault_tolerance import StragglerPolicy


def train(arch: str = "edge-tiny", *, steps: int = 100, batch: int = 8,
          seq: int = 128, smoke: bool = False, ckpt_dir: str | None = None,
          ckpt_every: int = 50, resume: bool = False, compress: bool = False,
          microbatches: int = 1, production_mesh: bool = False,
          log_every: int = 10, seed: int = 0):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    lm = LM(cfg)
    hyper = AdamWHyper(total_steps=steps)
    step_fn = make_train_step(lm, hyper=hyper, microbatches=microbatches,
                              compress=compress)

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                          global_batch=batch, seed=seed)
    start_step = 0
    state = None
    if resume and ckpt_dir:
        last = ckpt.latest_step(ckpt_dir)
        if last is not None:
            like = jax.eval_shape(
                lambda k: init_train_state(lm, k, compress=compress),
                jax.random.key(seed))
            state, extra = ckpt.restore(ckpt_dir, last, like)
            start_step = extra.get("data_step", last)
            print(f"resumed from step {last} (data cursor {start_step})")
    if state is None:
        state = init_train_state(lm, jax.random.key(seed), compress=compress)

    stream = SyntheticLMStream(data_cfg, start_step=start_step)
    jit_step = jax.jit(step_fn, donate_argnums=(0,))
    straggler = StragglerPolicy()

    if production_mesh:
        from repro.launch.mesh import make_production_mesh
        from repro.sharding import make_plan
        mesh = make_production_mesh()
        plan = make_plan(cfg, mesh, "train", batch=batch, seq=seq,
                         param_tree=state.params)
        specs = train_state_specs(plan, state)
        shard = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(mesh, s), specs,
            is_leaf=lambda x: isinstance(
                x, jax.sharding.PartitionSpec))
        state = jax.device_put(state, shard)

    losses = []
    for i in range(start_step, start_step + steps):
        batch_np = stream.next_batch()
        batch_dev = {k: jnp.asarray(v) for k, v in batch_np.items()}
        t0 = time.perf_counter()
        state, metrics = jit_step(state, batch_dev)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        verdict = straggler.observe("worker-0", dt)
        losses.append(loss)
        if i % log_every == 0 or i == start_step + steps - 1:
            print(f"step {i:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} "
                  f"{dt*1e3:7.1f} ms {verdict}", flush=True)
        if ckpt_dir and ((i + 1) % ckpt_every == 0 or
                         i == start_step + steps - 1):
            ckpt.save(ckpt_dir, i + 1, state,
                      extra={"data_step": stream.step, "loss": loss})
    return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="edge-tiny", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress", action="store_true",
                    help="int8 gradient compression with error feedback")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--production", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    _, losses = train(a.arch, steps=a.steps, batch=a.batch, seq=a.seq,
                      smoke=a.smoke, ckpt_dir=a.ckpt_dir,
                      ckpt_every=a.ckpt_every, resume=a.resume,
                      compress=a.compress, microbatches=a.microbatches,
                      production_mesh=a.production, seed=a.seed)
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")


if __name__ == "__main__":
    main()
