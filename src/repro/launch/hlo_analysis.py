"""Roofline-term extraction from compiled SPMD artifacts.

Empirical semantics on this JAX/XLA (verified by probe):
* ``compiled.cost_analysis()`` FLOPs / bytes are **per-device** for an
  SPMD-partitioned module (global = per-device × n_devices).
* ``compiled.memory_analysis()`` argument/output/temp sizes are per-device.
* Collective ops appear in ``compiled.as_text()`` with per-shard operand
  shapes and replica_groups.

Wire-cost model per collective (ring algorithms, B = result bytes/device,
n = participants in the replica group):
    all-reduce          2·(n−1)/n · B
    all-gather          (n−1)/n · B          (B = gathered result)
    reduce-scatter      (n−1) · B            (B = scattered result)
    all-to-all          (n−1)/n · B
    collective-permute  B
    collective-broadcast(n−1)/n · B

Hardware constants (TPU v5e-class, from the assignment):
    197 TFLOP/s bf16 / chip; 819 GB/s HBM / chip; ~50 GB/s/link ICI.
The collective term conservatively assumes one active link per chip.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
HBM_PER_CHIP = 16e9  # v5e

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<shape>\([^=]*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute|collective-broadcast)(?:-start)?\(",
)
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def shape_bytes(shape_str: str) -> int:
    """Total bytes of one 'bf16[2,3]{...}' (or tuple of) shape string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        if dims == "":
            n = 1
        else:
            n = int(np.prod([int(d) for d in dims.split(",")]))
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        body = m.group(1).strip()
        return len(body.split(",")) if body else 1
    return total_devices


_WIRE_FACTOR = {
    "all-reduce": lambda n: 2.0 * (n - 1) / n,
    "all-gather": lambda n: (n - 1) / n,
    "reduce-scatter": lambda n: float(n - 1),
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
    "collective-broadcast": lambda n: (n - 1) / n,
}


@dataclass
class CollectiveStats:
    per_op: dict = field(default_factory=dict)   # op -> {count, result_bytes, wire_bytes}
    wire_bytes_per_device: float = 0.0

    def as_dict(self):
        return {"per_op": self.per_op,
                "wire_bytes_per_device": self.wire_bytes_per_device}


def collect_collectives(hlo_text: str, total_devices: int) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        if f"{op}-done" in line:
            continue  # -start carries the shape; -done would double count
        b = shape_bytes(m.group("shape"))
        n = _group_size(line, total_devices)
        if n <= 1:
            continue
        wire = _WIRE_FACTOR[op](n) * b
        rec = stats.per_op.setdefault(
            op, {"count": 0, "result_bytes": 0, "wire_bytes": 0.0})
        rec["count"] += 1
        rec["result_bytes"] += b
        rec["wire_bytes"] += wire
        stats.wire_bytes_per_device += wire
    return stats


def normalize_cost_analysis(ca) -> dict:
    """``compiled.cost_analysis()`` returns a dict on older JAX and a list
    of per-computation dicts on newer releases; fold either into one flat
    {metric: value} dict (numeric values summed across computations)."""
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        merged: dict = {}
        for entry in ca:
            for k, v in (entry or {}).items():
                if isinstance(v, (int, float)):
                    merged[k] = merged.get(k, 0.0) + float(v)
        return merged
    return dict(ca)


def roofline_terms(cost_analysis, collectives: CollectiveStats,
                   n_devices: int) -> dict:
    """The three roofline terms, in seconds (per step, per device)."""
    cost_analysis = normalize_cost_analysis(cost_analysis)
    flops_dev = float(cost_analysis.get("flops", 0.0))
    bytes_dev = float(cost_analysis.get("bytes accessed", 0.0))
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = collectives.wire_bytes_per_device / LINK_BW
    dominant = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)), key=lambda kv: kv[1])[0]
    bound = max(compute_s, memory_s, collective_s)
    return {
        "flops_per_device": flops_dev,
        "flops_global": flops_dev * n_devices,
        "hbm_bytes_per_device": bytes_dev,
        "wire_bytes_per_device": collectives.wire_bytes_per_device,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "roofline_bound_s": bound,
        # fraction of the bound explained by compute — the "roofline fraction"
        # a perf pass tries to drive toward 1.0 for compute-bound cells
        "compute_fraction_of_bound": (compute_s / bound) if bound > 0 else 0.0,
    }


def model_flops(cfg, kind: str, batch: int, seq: int) -> float:
    """MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (inference)."""
    n = cfg.active_param_count()
    if kind == "train":
        return 6.0 * n * batch * seq
    if kind == "prefill":
        return 2.0 * n * batch * seq
    return 2.0 * n * batch  # decode: one token per sequence


def memory_report(mem_analysis) -> dict:
    g = lambda a: float(getattr(mem_analysis, a, 0) or 0)
    args = g("argument_size_in_bytes")
    temp = g("temp_size_in_bytes")
    out = g("output_size_in_bytes")
    alias = g("alias_size_in_bytes")
    peak = args + temp + out - alias
    return {
        "argument_bytes": args,
        "output_bytes": out,
        "temp_bytes": temp,
        "alias_bytes": alias,
        "peak_bytes_per_device": peak,
        "fits_hbm": bool(peak <= HBM_PER_CHIP),
        "hbm_per_chip": HBM_PER_CHIP,
    }
