"""Modality frontend STUBS (per assignment: ``[audio]``/``[vlm]`` entries
specify the transformer backbone only; ``input_specs()`` provides precomputed
frame/patch embeddings).

These helpers synthesise deterministic fake embeddings for smoke tests and
examples; the dry-run uses ShapeDtypeStructs from ``repro.sharding.specs``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def fake_vision_embeds(cfg: ModelConfig, key, batch: int):
    n = cfg.num_frontend_tokens or 256
    return jax.random.normal(key, (batch, n, cfg.d_model), jnp.float32) * 0.02


def fake_audio_frames(cfg: ModelConfig, key, batch: int, src_len: int | None = None):
    src = src_len or cfg.source_len
    return jax.random.normal(key, (batch, src, cfg.d_model), jnp.float32) * 0.02


def make_batch(cfg: ModelConfig, key, batch: int, seq: int):
    """Synthetic full batch for the given config (tokens + frontend extras)."""
    k1, k2, k3 = jax.random.split(key, 3)
    tokens = jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size, jnp.int32)
    out = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.frontend == "vision":
        out["vision_embeds"] = fake_vision_embeds(cfg, k2, batch)
        # don't train on the vision positions
        nv = out["vision_embeds"].shape[1]
        lbl = out["labels"]
        out["labels"] = lbl.at[:, :nv].set(-1) if nv <= seq else lbl
    if cfg.frontend == "audio":
        out["frames"] = fake_audio_frames(cfg, k3, batch)
    return out
