"""Mamba-2 SSD layer (state-space duality, arXiv:2405.21060).

Chunked SSD forward: within a chunk the quadratic (dual/attention) form is
used; across chunks a linear recurrence carries the SSM state
``S ∈ [b, heads, headdim, dstate]``. The whole computation runs under one
``lax.scan`` over chunks so peak memory stays
O(b · heads · chunk² + b · heads · headdim · dstate).

Decode is the exact single-step recurrence — session state is O(1) in the
sequence length, which is why the long_500k shape is admissible for this
family and why AIS migration is cheapest here (DESIGN.md §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models import layers as L
from repro.models.quant import as_weight


def ssd_init(key, cfg: ModelConfig):
    dt = L.dtype_of(cfg)
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    g, nh = cfg.ssm_ngroups, cfg.ssm_nheads
    K = cfg.conv_width
    conv_dim = di + 2 * g * n
    k1, k2, k3, k4 = jax.random.split(key, 4)
    proj_out = 2 * di + 2 * g * n + nh
    # dt bias init so softplus(dt_bias) spans [1e-3, 1e-1] (mamba default)
    u = jax.random.uniform(k4, (nh,), jnp.float32, np.log(1e-3), np.log(1e-1))
    dt_bias = jnp.exp(u)
    dt_bias = dt_bias + jnp.log(-jnp.expm1(-dt_bias))  # inv softplus
    return {
        "in_proj": L.dense_init(k1, d, proj_out, dt),
        "conv": (jax.random.normal(k2, (K, conv_dim), jnp.float32)
                 / np.sqrt(K)).astype(dt),
        "out_proj": L.dense_init(k3, di, d, dt),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": dt_bias,
        "norm": L.rmsnorm_init(di),
    }


def _split_proj(cfg: ModelConfig, zxbcdt):
    di, n, g, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups, cfg.ssm_nheads
    z = zxbcdt[..., :di]
    x = zxbcdt[..., di: 2 * di]
    B = zxbcdt[..., 2 * di: 2 * di + g * n]
    C = zxbcdt[..., 2 * di + g * n: 2 * di + 2 * g * n]
    dt = zxbcdt[..., 2 * di + 2 * g * n:]
    return z, x, B, C, dt


def _conv(p, xbc, state=None, length=None):
    """Causal depthwise conv over [b, l, conv_dim].

    ``length`` (traced scalar): true sequence length when the input is
    right-padded to a compile bucket — the carried conv state must be the
    last K-1 *real* inputs, i.e. padded rows ``xp[:, length:length+K-1]``
    (the K-1 zeros of the causal left-pad shift the index by exactly K-1).
    """
    K = p["conv"].shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], K - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    y = sum(xp[:, i: i + xbc.shape[1]] * p["conv"][i] for i in range(K))
    if length is None:
        new_state = xp[:, -(K - 1):]
    else:
        new_state = jax.lax.dynamic_slice_in_dim(xp, length, K - 1, axis=1)
    return jax.nn.silu(y.astype(jnp.float32)).astype(xbc.dtype), new_state


def _ssd_chunked(cfg: ModelConfig, x, dt, A, B, C, S0):
    """Chunked SSD scan.

    x: [b, l, nh, hp]; dt: [b, l, nh] (post-softplus); A: [nh] (negative);
    B, C: [b, l, g, n]; S0: [b, nh, hp, n] initial state.
    Returns (y [b, l, nh, hp], S_final).
    """
    b, l, nh, hp = x.shape
    g, n = B.shape[2], B.shape[3]
    Q = min(cfg.ssm_chunk, l)
    pad = (-l) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = x.shape[1] // Q
    heads_per_group = nh // g

    def chunkify(t):
        return jnp.moveaxis(t.reshape((b, nc) + (Q,) + t.shape[2:]), 1, 0)

    xc, dtc, Bc, Cc = map(chunkify, (x, dt, B, C))

    def step(S, xs):
        xq, dtq, Bq, Cq = xs            # [b,Q,nh,hp], [b,Q,nh], [b,Q,g,n]
        dA = dtq * A                     # [b,Q,nh]
        cum = jnp.cumsum(dA, axis=1)     # within-chunk cumulative
        # expand B,C to heads
        Bh = jnp.repeat(Bq, heads_per_group, axis=2)   # [b,Q,nh,n]
        Ch = jnp.repeat(Cq, heads_per_group, axis=2)
        xdt = xq.astype(jnp.float32) * dtq[..., None]  # [b,Q,nh,hp]
        # ---- intra-chunk (dual / quadratic) term -------------------------
        seg = cum[:, :, None, :] - cum[:, None, :, :]  # [b,Q,Q,nh] (i,j)
        causal = jnp.tril(jnp.ones((Q, Q), bool))
        Ldec = jnp.where(causal[None, :, :, None], jnp.exp(seg), 0.0)
        scores = jnp.einsum("bihn,bjhn->bijh", Ch.astype(jnp.float32),
                            Bh.astype(jnp.float32))     # [b,Q,Q,nh]
        y_diag = jnp.einsum("bijh,bijh,bjhp->bihp", scores, Ldec, xdt)
        # ---- inter-chunk: contribution of carried state ------------------
        decay_in = jnp.exp(cum)                          # [b,Q,nh]
        y_off = jnp.einsum("bihn,bhpn->bihp",
                           Ch.astype(jnp.float32) * decay_in[..., None], S)
        # ---- state update -------------------------------------------------
        decay_out = jnp.exp(cum[:, -1:, :] - cum)        # [b,Q,nh]
        S_new = (jnp.exp(cum[:, -1, :])[..., None, None] * S
                 + jnp.einsum("bjhn,bjhp->bhpn",
                              Bh.astype(jnp.float32) * decay_out[..., None],
                              xdt))
        return S_new, (y_diag + y_off)

    body = jax.checkpoint(step) if cfg.remat != "none" else step
    S_f, ys = jax.lax.scan(body, S0.astype(jnp.float32), (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nc * Q, nh, hp)
    return y[:, :l], S_f


def ssd_apply(p, cfg: ModelConfig, x, conv_state=None, ssm_state=None,
              length=None):
    """Sequence path. x: [b, l, d] -> (y [b, l, d], (conv_state, ssm_state)).

    ``length`` (traced scalar) marks the true prompt length of a
    right-padded bucket: padded steps get dt = 0, which makes the SSD
    recurrence an exact identity there (decay exp(0·A) = 1, input dt·B·x
    = 0), so the carried state equals the state at ``length`` bit-for-bit.
    """
    b, l, d = x.shape
    di, nh, hp = cfg.d_inner, cfg.ssm_nheads, cfg.ssm_headdim
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    zxbcdt = jnp.einsum("bld,dp->blp", x, as_weight(p["in_proj"]),
                        preferred_element_type=jnp.float32).astype(x.dtype)
    z, xs, B, C, dt = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([xs, B, C], axis=-1)
    xbc, conv_state = _conv(p, xbc, conv_state, length=length)
    xs = xbc[..., :di].reshape(b, l, nh, hp)
    B = xbc[..., di: di + g * n].reshape(b, l, g, n)
    C = xbc[..., di + g * n:].reshape(b, l, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    if length is not None:
        valid = jnp.arange(l, dtype=jnp.int32) < length
        dt = jnp.where(valid[None, :, None], dt, 0.0)
    A = -jnp.exp(p["A_log"])
    if ssm_state is None:
        ssm_state = jnp.zeros((b, nh, hp, n), jnp.float32)
    y, S = _ssd_chunked(cfg, xs, dt, A, B, C, ssm_state)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, l, di).astype(x.dtype)
    y = L.rmsnorm_apply(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                        cfg.norm_eps)
    out = jnp.einsum("blp,pd->bld", y, as_weight(p["out_proj"]),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, (conv_state, S)


def ssd_decode(p, cfg: ModelConfig, x, conv_state, ssm_state):
    """Single-token recurrence. x: [b, 1, d]."""
    b = x.shape[0]
    di, nh, hp = cfg.d_inner, cfg.ssm_nheads, cfg.ssm_headdim
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    zxbcdt = jnp.einsum("bld,dp->blp", x, as_weight(p["in_proj"]),
                        preferred_element_type=jnp.float32).astype(x.dtype)
    z, xs, B, C, dt = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([xs, B, C], axis=-1)
    xbc, conv_state = _conv(p, xbc, conv_state)
    xs = xbc[:, 0, :di].reshape(b, nh, hp)
    B = xbc[:, 0, di: di + g * n].reshape(b, g, n)
    C = xbc[:, 0, di + g * n:].reshape(b, g, n)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [b,nh]
    A = -jnp.exp(p["A_log"])
    hpg = nh // g
    Bh = jnp.repeat(B, hpg, axis=1)  # [b,nh,n]
    Ch = jnp.repeat(C, hpg, axis=1)
    dA = jnp.exp(dt * A)             # [b,nh]
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt, xs.astype(jnp.float32), Bh.astype(jnp.float32))
    S = ssm_state * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", S, Ch.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = L.rmsnorm_apply(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                        cfg.norm_eps)
    out = jnp.einsum("blp,pd->bld", y, as_weight(p["out_proj"]),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, (conv_state, S)


def ssd_state_shapes(cfg: ModelConfig, batch: int):
    conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return {
        "conv": (batch, cfg.conv_width - 1, conv_dim),
        "ssm": (batch, cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state),
    }
