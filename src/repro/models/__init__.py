"""Model zoo: the execution substrate that AI Sessions bind to."""

from repro.models.config import ModelConfig  # noqa: F401
from repro.models.transformer import LM  # noqa: F401
