"""Model configuration for the NE-AIaaS execution substrate.

One ``ModelConfig`` describes any of the assigned architecture families:

* ``dense``  — decoder-only transformer with GQA (phi3, command-r, codeqwen,
               minitron, qwen2-vl backbone).
* ``moe``    — decoder-only with mixture-of-experts FFN (qwen3-moe, mixtral).
* ``hybrid`` — RG-LRU recurrent blocks interleaved with local attention
               (recurrentgemma / Griffin pattern).
* ``ssm``    — attention-free Mamba-2 (SSD) stack.
* ``encdec`` — encoder-decoder (seamless-m4t backbone; audio frontend stubbed).

The config is a frozen dataclass so it can be hashed into jit static args and
carried inside AIS catalog entries.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class ModelConfig:
    # -- identity ---------------------------------------------------------
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec
    # -- trunk ------------------------------------------------------------
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # -- attention --------------------------------------------------------
    sliding_window: int = 0          # 0 => full causal attention
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (t,h,w) half-dims
    use_qk_norm: bool = False
    attn_logits_softcap: float = 0.0
    # -- MoE ----------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0
    moe_capacity_factor: float = 1.25
    moe_impl: str = "einsum"        # einsum | scatter | dense
    moe_chunk: int = 2048            # tokens per dispatch chunk (einsum impl)
    # -- hybrid (RG-LRU) ----------------------------------------------------
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    lru_width: int = 0
    conv_width: int = 4
    # -- SSM (Mamba-2 / SSD) -------------------------------------------------
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_ngroups: int = 1
    # -- encoder-decoder ------------------------------------------------------
    encoder_layers: int = 0
    source_len: int = 1536           # stubbed frontend frames/patches
    # -- frontend stubs -------------------------------------------------------
    frontend: str = ""               # "" | "vision" | "audio"
    num_frontend_tokens: int = 0     # vision tokens prepended to the stream
    # -- numerics / structure ---------------------------------------------
    norm_eps: float = 1e-6
    use_bias: bool = False
    tie_embeddings: bool = False
    logits_softcap: float = 0.0
    dtype: str = "bfloat16"
    remat: str = "full"              # none | dots | full
    scan_layers: bool = True
    attn_block_q: int = 256
    attn_block_kv: int = 1024
    # -- distribution levers (read by repro.sharding.planner; exposed as
    #    dry-run overrides for the §Perf hillclimb) -------------------------
    kv_shard: str = "auto"           # auto | heads | seq — decode cache axis
    #: route single-token decode attention through the Pallas flash-decode
    #: kernel (interpret mode off-TPU); falls back to the reference path for
    #: sliding-window rings and softcapped logits, which the kernel doesn't
    #: implement
    use_pallas_decode: bool = False
    #: legacy per-row batched-scatter decode-cache insert (XLA lowers it to
    #: a serial loop on CPU); kept as an A/B lever for engine_bench — the
    #: default is the fused select write
    decode_cache_scatter: bool = False
    serve_embed_replicated: bool = False
    serve_fsdp_mode: str = "auto"    # auto | on | off — weight-gathered serve
    serve_weight_dtype: str = "bfloat16"  # bfloat16 | int8 (weight-only quant)
    train_microbatches: int = 0      # 0 = auto (planner memory budget)

    # ------------------------------------------------------------------
    # derived quantities
    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Embedding/unembedding table rows padded so the vocab dim shards
        over any reasonable model axis (non-divisible vocabs like 50280 /
        256206 otherwise force replicated lm_heads and unsharded logits —
        26 GB/device of f32 loss buffers observed). The tail logits are
        masked to -inf; tokens never map there."""
        m = 256
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def decode_state_kind(self) -> str:
        """What session-state migration must transfer (see DESIGN.md §4)."""
        if self.family == "ssm":
            return "recurrent"
        if self.family == "hybrid":
            return "recurrent+window"
        if self.sliding_window > 0:
            return "window"
        return "kv_full"

    @property
    def sub_quadratic(self) -> bool:
        """True when long_500k decode is admissible (bounded decode state)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    @property
    def has_decode(self) -> bool:
        """Encoder-only archs would return False; all assigned archs decode."""
        return True

    def param_count(self) -> int:
        """Analytic parameter count (embedding + trunk), used by predictors
        and the roofline MODEL_FLOPS term."""
        d, L = self.d_model, self.num_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            di, ns = self.d_inner, self.ssm_state
            nh = self.ssm_nheads
            conv_dim = di + 2 * self.ssm_ngroups * ns
            per = (
                d * (2 * di + 2 * self.ssm_ngroups * ns + nh)   # in_proj
                + conv_dim * self.conv_width                      # conv1d
                + di * d                                          # out_proj
                + 2 * nh + di                                     # A, D, norm
                + d
            )
            return emb + L * per
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.is_moe:
            ffn = self.num_experts * 3 * d * self.moe_d_ff + d * self.num_experts
        else:
            ffn = 3 * d * self.d_ff
        norms = 2 * d
        per = attn + ffn + norms
        if self.family == "hybrid":
            n_attn = sum(1 for k in self._pattern() if k == "attn")
            n_rec = L - n_attn
            w = self.lru_width or d
            rec = 2 * d * w + w * self.conv_width + w * d + 2 * w * w // 8 + 4 * w
            # rec block: in/gate proj, conv, out proj, (block-diag a/i gates), lru params
            per_attn = attn + 3 * d * self.d_ff + 2 * d
            per_rec = rec + 3 * d * self.d_ff + 2 * d
            return emb + n_attn * per_attn + n_rec * per_rec
        if self.family == "encdec":
            enc = self.encoder_layers * (attn + 3 * d * self.d_ff + 2 * d)
            dec = L * (attn + attn + 3 * d * self.d_ff + 3 * d)  # + cross attn
            return emb + enc + dec
        return emb + L * per

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        total = self.param_count()
        all_experts = L * self.num_experts * 3 * d * self.moe_d_ff
        active = L * self.num_experts_per_tok * 3 * d * self.moe_d_ff
        return total - all_experts + active

    def _pattern(self) -> Tuple[str, ...]:
        """Expanded per-layer block pattern for hybrid models."""
        if self.family != "hybrid":
            return tuple("attn" for _ in range(self.num_layers))
        pat = self.block_pattern or ("rec", "rec", "attn")
        out = []
        while len(out) < self.num_layers:
            out.extend(pat)
        return tuple(out[: self.num_layers])

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 4 if self.family == "hybrid" else 2),
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=16,
            d_ff=128,
            vocab_size=512,
            source_len=24,
            moe_chunk=32,
            attn_block_q=16,
            attn_block_kv=32,
            remat="none",
        )
        if self.family == "hybrid":
            kw["num_layers"] = 4  # rec, rec, attn, rec
            kw["lru_width"] = 64
            kw["sliding_window"] = 16
        if self.sliding_window:
            kw["sliding_window"] = 16
        if self.is_moe:
            kw["num_experts"] = 4
            kw["num_experts_per_tok"] = min(self.num_experts_per_tok, 2)
            kw["moe_d_ff"] = 64
            # drop-free capacity so prefill/decode exactly match forward
            kw["moe_capacity_factor"] = 4.0
        if self.family == "ssm":
            kw["ssm_state"] = 16
            kw["ssm_headdim"] = 16
            kw["ssm_chunk"] = 16
            kw["num_heads"] = 0
            kw["num_kv_heads"] = 0
            kw["head_dim"] = 0
            kw["d_ff"] = 0
        if self.family == "encdec":
            kw["encoder_layers"] = 2
        if self.mrope_sections:
            kw["mrope_sections"] = (4, 2, 2)
        if self.num_frontend_tokens:
            kw["num_frontend_tokens"] = 8
        return dataclasses.replace(self, **kw)


def validate(cfg: ModelConfig) -> None:
    if cfg.family not in ("dense", "moe", "hybrid", "ssm", "encdec"):
        raise ValueError(f"unknown family {cfg.family}")
    if cfg.family != "ssm":
        if cfg.num_heads % max(cfg.num_kv_heads, 1):
            raise ValueError("num_heads must be a multiple of num_kv_heads")
    if cfg.is_moe and cfg.num_experts_per_tok > cfg.num_experts:
        raise ValueError("top-k exceeds expert count")
    if cfg.mrope_sections and sum(cfg.mrope_sections) != cfg.head_dim // 2:
        raise ValueError("mrope sections must sum to head_dim//2")
    if cfg.family == "ssm" and cfg.d_inner % cfg.ssm_headdim:
        raise ValueError("d_inner must divide into ssm heads")
