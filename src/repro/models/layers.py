"""Shared neural-net layers (pure-functional, pytree params).

Conventions
-----------
* Params are nested dicts of jnp arrays; weights stored in ``cfg.dtype``
  (bf16 by default), norm scales in f32.
* Every ``*_init`` returns params; every ``*_apply`` is a pure function.
* Matmul-heavy ops run in bf16 with f32 accumulation via
  ``preferred_element_type``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.sharding.ctx import constrain
from repro.models.quant import as_weight


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype):
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int):
    return {"scale": jnp.ones((dim,), jnp.float32)}


def rmsnorm_apply(p, x, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(x.dtype)


def layernorm_init(dim: int):
    return {"scale": jnp.ones((dim,), jnp.float32), "bias": jnp.zeros((dim,), jnp.float32)}


def layernorm_apply(p, x, eps: float):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings (RoPE + M-RoPE)
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # [head_dim//2]


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotate pairs. x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
                sections: tuple) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.

    positions: [3, ..., seq] — temporal / height / width position streams.
    ``sections`` are half-dim section sizes that sum to head_dim//2; section i
    takes its rotation angle from position stream i.
    """
    freqs = rope_frequencies(x.shape[-1], theta)  # [half]
    # pick, per frequency index, which position stream feeds it
    sec_ids = np.repeat(np.arange(len(sections)), sections)  # [half]
    # gather the right stream per section: positions[sec_ids[j], ..., seq]
    pos_sel = positions.astype(jnp.float32)[sec_ids]          # [half, ..., seq]
    pos_sel = jnp.moveaxis(pos_sel, 0, -1)                    # [..., seq, half]
    angles = pos_sel * freqs                                   # [..., seq, half]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def rope_for(cfg: ModelConfig, x, positions):
    """Dispatch RoPE vs M-RoPE. positions: [b, s] or [3, b, s] for mrope."""
    if cfg.mrope_sections:
        if positions.ndim == 2:  # text-only: duplicate stream
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    if positions.ndim == 3:
        positions = positions[0]
    return apply_rope(x, positions, cfg.rope_theta)


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None):
    d_ff = d_ff or cfg.d_ff
    dt = dtype_of(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, cfg.d_model, d_ff, dt),
        "w_up": dense_init(k2, cfg.d_model, d_ff, dt),
        "w_down": dense_init(k3, d_ff, cfg.d_model, dt),
    }


def mlp_apply(p, x):
    gate = jnp.einsum("...d,df->...f", x, as_weight(p["w_gate"]),
                      preferred_element_type=jnp.float32)
    up = jnp.einsum("...d,df->...f", x, as_weight(p["w_up"]),
                    preferred_element_type=jnp.float32)
    h = (jax.nn.silu(gate) * up).astype(x.dtype)
    h = constrain(h, *(["dp"] + [None] * (h.ndim - 2) + ["model"]))
    return jnp.einsum("...f,fd->...d", h, as_weight(p["w_down"]),
                      preferred_element_type=jnp.float32).astype(x.dtype)


# ---------------------------------------------------------------------------
# softcap
# ---------------------------------------------------------------------------

def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap
