"""Attention layers: GQA, sliding-window, cross-attention, cached decode.

Memory discipline: full-sequence attention never materialises the
``[b, h, s, s]`` score tensor. Training/prefill paths run a blocked
online-softmax (flash-style) implemented with ``lax.scan`` so compiled
peak memory stays ``O(b · h · block_q · block_kv)`` per step. Sliding-window
prefill slices a static-width band with ``lax.dynamic_slice`` so FLOPs are
``O(s · (window + block_q))`` rather than ``O(s²)``.

These are the pure-jnp reference paths used by the dry-run lowering; the
Pallas kernels in ``repro.kernels`` implement the same math for TPU with
explicit VMEM BlockSpecs and causal block skipping.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models import layers as L
from repro.sharding.ctx import constrain
from repro.models.quant import as_weight

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def attention_init(key, cfg: ModelConfig, *, cross: bool = False):
    dt = L.dtype_of(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "w_q": L.dense_init(k1, cfg.d_model, cfg.q_dim, dt),
        "w_k": L.dense_init(k2, cfg.d_model, cfg.kv_dim, dt),
        "w_v": L.dense_init(k3, cfg.d_model, cfg.kv_dim, dt),
        "w_o": L.dense_init(k4, cfg.q_dim, cfg.d_model, dt),
    }
    if cfg.use_qk_norm:
        p["q_norm"] = L.rmsnorm_init(cfg.head_dim)
        p["k_norm"] = L.rmsnorm_init(cfg.head_dim)
    return p


def _project_q(p, cfg: ModelConfig, x, positions):
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dq->bsq", x, as_weight(p["w_q"]),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    q = q.reshape(b, s, cfg.num_heads, cfg.head_dim)
    q = constrain(q, "dp", None, "model", None)
    if cfg.use_qk_norm:
        q = L.rmsnorm_apply(p["q_norm"], q, cfg.norm_eps)
    if positions is not None:
        q = L.rope_for(cfg, q, positions)
    return q


def _project_kv(p, cfg: ModelConfig, x, positions):
    b, s, _ = x.shape
    k = jnp.einsum("bsd,dq->bsq", x, as_weight(p["w_k"]),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsd,dq->bsq", x, as_weight(p["w_v"]),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = k.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    k = constrain(k, "dp", None, "model", None)
    v = constrain(v, "dp", None, "model", None)
    if cfg.use_qk_norm:
        k = L.rmsnorm_apply(p["k_norm"], k, cfg.norm_eps)
    if positions is not None:
        k = L.rope_for(cfg, k, positions)
    return k, v


# ---------------------------------------------------------------------------
# blocked online-softmax core
# ---------------------------------------------------------------------------

def _block_attend(q, k, v, q_pos, k_pos, *, causal, window, scale, softcap):
    """One (q-block × kv-block) tile. q: [b, bq, kh, g, d]; k/v: [b, bk, kh, d].

    Returns per-tile scores statistics for the online-softmax combine.
    """
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = L.softcap(s, softcap)
    valid = (k_pos[None, :] >= 0)
    if causal:
        valid = valid & (k_pos[None, :] <= q_pos[:, None])
    if window:
        valid = valid & (k_pos[None, :] > q_pos[:, None] - window)
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    return s


def _online_softmax_scan(q, kv_blocks_iter, q_pos, *, causal, window, scale,
                         softcap, out_dtype, remat=False):
    """Scan over kv blocks maintaining (m, l, o) running statistics.

    q: [b, bq, kh, g, d]. kv_blocks_iter yields (k_blk, v_blk, k_pos_blk).

    ``remat=True`` checkpoints the per-tile body so the backward pass
    recomputes the P tile instead of saving it — the flash-attention
    memory discipline (saving P tiles for every (q, kv) block pair costs
    O(b·h·s²) f32/device: 17–84 GB observed on the train_4k cells).
    """
    b, bq, kh, g, d = q.shape

    def step(carry, blk):
        m, l, o = carry
        k_blk, v_blk, kpos = blk
        s = _block_attend(q, k_blk, v_blk, q_pos, kpos, causal=causal,
                          window=window, scale=scale, softcap=softcap)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype), v_blk,
                        preferred_element_type=jnp.float32)
        o = o * alpha[..., None] + pv
        return (m_new, l, o), None

    m0 = jnp.full((b, kh, g, bq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kh, g, bq), jnp.float32)
    o0 = jnp.zeros((b, kh, g, bq, d), jnp.float32)
    body = jax.checkpoint(step) if remat else step
    (m, l, o), _ = jax.lax.scan(body, (m0, l0, o0), kv_blocks_iter)
    o = o / jnp.maximum(l[..., None], 1e-37)
    # [b, kh, g, bq, d] -> [b, bq, kh*g, d]
    o = jnp.moveaxis(o, 3, 1).reshape(b, bq, kh * g, d)
    return o.astype(out_dtype)


def _pad_to(x, axis, multiple):
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def blocked_attention(q, k, v, q_positions, k_positions, *, causal: bool,
                      window: int, block_q: int, block_kv: int,
                      softcap: float = 0.0, remat: bool = False):
    """Flash-style attention. q: [b, sq, hq, d]; k/v: [b, skv, kh, d].

    ``q_positions``/``k_positions``: [sq] / [skv] absolute positions (shared
    across batch; ragged batches are handled by -1 sentinels in k_positions).
    """
    b, sq, hq, d = q.shape
    kh = k.shape[2]
    g = hq // kh
    scale = 1.0 / np.sqrt(d)

    q, sq0 = _pad_to(q, 1, block_q)
    qp, _ = _pad_to(q_positions, 0, block_q)
    k, _ = _pad_to(k, 1, block_kv)
    v, _ = _pad_to(v, 1, block_kv)
    kp = jnp.pad(k_positions, (0, k.shape[1] - k_positions.shape[0]),
                 constant_values=-1)

    nq = q.shape[1] // block_q
    nk = k.shape[1] // block_kv
    qb = q.reshape(b, nq, block_q, kh, g, d)
    qpb = qp.reshape(nq, block_q)
    kb = jnp.moveaxis(k.reshape(b, nk, block_kv, kh, d), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nk, block_kv, kh, d), 1, 0)
    kpb = kp.reshape(nk, block_kv)

    def per_q_block(carry, xs):
        qblk, qpos = xs
        o = _online_softmax_scan(qblk, (kb, vb, kpb), qpos, causal=causal,
                                 window=window, scale=scale, softcap=softcap,
                                 out_dtype=q.dtype, remat=remat)
        return carry, o

    body = jax.checkpoint(per_q_block) if remat else per_q_block
    _, outs = jax.lax.scan(body, (), (jnp.moveaxis(qb, 1, 0), qpb))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, -1, hq, d)
    return out[:, :sq0]


def banded_attention(q, k, v, q_positions, k_positions, *, window: int,
                     block_q: int, softcap: float = 0.0,
                     remat: bool = False):
    """Sliding-window causal attention with O(s·window) FLOPs.

    For q block starting at position p, only the KV band
    ``[p + block_q - band, p + block_q)`` can be visible, with
    ``band = window + block_q`` (static size) sliced via dynamic_slice.
    """
    b, sq, hq, d = q.shape
    kh = k.shape[2]
    g = hq // kh
    scale = 1.0 / np.sqrt(d)
    band = window + block_q

    q, sq0 = _pad_to(q, 1, block_q)
    qp, _ = _pad_to(q_positions, 0, block_q)
    nq = q.shape[1] // block_q
    skv = k.shape[1]
    # left-pad KV by band so every dynamic_slice stays in range
    k = jnp.pad(k, ((0, 0), (band, 0), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (band, 0), (0, 0), (0, 0)))
    kp = jnp.pad(k_positions, (band, 0), constant_values=-1)
    k, _ = _pad_to(k, 1, block_q)
    v, _ = _pad_to(v, 1, block_q)
    kp, _ = _pad_to(kp, 0, block_q)
    # both pads (left band, right round-up) must read as invalid positions
    ar = jnp.arange(kp.shape[0])
    kp = jnp.where((ar < band) | (ar >= band + skv), -1, kp)

    qb = jnp.moveaxis(q.reshape(b, nq, block_q, kh, g, d), 1, 0)
    qpb = qp.reshape(nq, block_q)

    def per_q_block(carry, xs):
        i, qblk, qpos = xs
        start = i * block_q  # band end aligns with q block end (+band offset)
        k_band = jax.lax.dynamic_slice_in_dim(k, start, band + block_q, axis=1)
        v_band = jax.lax.dynamic_slice_in_dim(v, start, band + block_q, axis=1)
        kp_band = jax.lax.dynamic_slice_in_dim(kp, start, band + block_q, axis=0)
        s = _block_attend(qblk, k_band, v_band, qpos, kp_band, causal=True,
                          window=window, scale=scale, softcap=softcap)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bhgqk,bkhd->bhgqd", (p / jnp.maximum(l, 1e-37)).astype(v_band.dtype),
                       v_band, preferred_element_type=jnp.float32)
        o = jnp.moveaxis(o, 3, 1).reshape(qblk.shape[0], block_q, kh * g, d)
        return carry, o.astype(qblk.dtype)

    idx = jnp.arange(nq)
    body = jax.checkpoint(per_q_block) if remat else per_q_block
    _, outs = jax.lax.scan(body, (), (idx, qb, qpb))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, -1, hq, d)
    return out[:, :sq0]


def qwhole_attention(q, k, v, q_positions, k_positions, *, causal: bool,
                     window: int, block_kv: int, softcap: float = 0.0,
                     remat: bool = False):
    """Sequence-parallel flash attention: q kept whole (its seq dim carries
    the model-axis sharding), single online-softmax scan over KV blocks.

    Used when the head counts don't divide the model axis (e.g. phi3 40H/10KV
    on a 16-way axis): head-sharded tiles would be batch/head-replicated and
    the nested-scan residuals blow past HBM (33 GB/device observed). Here the
    per-step score tile is [b, kh, g, s_local, block_kv].
    """
    b, sq, hq, d = q.shape
    kh = k.shape[2]
    g = hq // kh
    scale = 1.0 / np.sqrt(d)
    q5 = q.reshape(b, sq, kh, g, d)
    k, _ = _pad_to(k, 1, block_kv)
    v, _ = _pad_to(v, 1, block_kv)
    kp = jnp.pad(k_positions, (0, k.shape[1] - k_positions.shape[0]),
                 constant_values=-1)
    nk = k.shape[1] // block_kv
    kb = jnp.moveaxis(k.reshape(b, nk, block_kv, kh, d), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nk, block_kv, kh, d), 1, 0)
    kpb = kp.reshape(nk, block_kv)
    return _online_softmax_scan(q5, (kb, vb, kpb), q_positions, causal=causal,
                                window=window, scale=scale, softcap=softcap,
                                out_dtype=q.dtype, remat=remat)


def _heads_shardable(cfg: ModelConfig) -> bool:
    from repro.sharding.ctx import current_mesh
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return True
    m = mesh.shape["model"]
    return cfg.num_heads % m == 0


def full_attention(q, k, v, qpos, kpos, cfg: ModelConfig, *, causal=True):
    """Dispatch between the blocked / banded / sequence-parallel paths."""
    remat = cfg.remat != "none"
    if cfg.sliding_window and causal:
        return banded_attention(q, k, v, qpos, kpos,
                                window=cfg.sliding_window,
                                block_q=cfg.attn_block_q,
                                softcap=cfg.attn_logits_softcap, remat=remat)
    if not _heads_shardable(cfg):
        q = constrain(q, "dp", "model", None, None)
        return qwhole_attention(q, k, v, qpos, kpos, causal=causal,
                                window=cfg.sliding_window,
                                block_kv=cfg.attn_block_kv,
                                softcap=cfg.attn_logits_softcap, remat=remat)
    return blocked_attention(q, k, v, qpos, kpos, causal=causal,
                             window=cfg.sliding_window,
                             block_q=cfg.attn_block_q,
                             block_kv=cfg.attn_block_kv,
                             softcap=cfg.attn_logits_softcap, remat=remat)


# ---------------------------------------------------------------------------
# public layer entry points
# ---------------------------------------------------------------------------

def self_attention(p, cfg: ModelConfig, x, positions, *, causal=True):
    """Full-sequence self attention (training / encoder)."""
    pos1d = positions[0] if positions.ndim == 3 else positions
    q = _project_q(p, cfg, x, positions)
    k, v = _project_kv(p, cfg, x, positions)
    qpos = pos1d[0] if pos1d.ndim == 2 else pos1d
    kpos = qpos
    o = full_attention(q, k, v, qpos, kpos, cfg, causal=causal)
    b, s, _, _ = o.shape
    return jnp.einsum("bsq,qd->bsd", o.reshape(b, s, cfg.q_dim), as_weight(p["w_o"]),
                      preferred_element_type=jnp.float32).astype(x.dtype)


def cross_attention(p, cfg: ModelConfig, x, memory, mem_positions):
    """Decoder→encoder attention (no causal mask, no RoPE on memory)."""
    q = _project_q(p, cfg, x, None)
    k, v = _project_kv(p, cfg, memory, None)
    sq = x.shape[1]
    qpos = jnp.arange(sq)
    kpos = mem_positions
    o = blocked_attention(q, k, v, qpos, kpos, causal=False, window=0,
                          block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv,
                          remat=cfg.remat != "none")
    b, s, _, _ = o.shape
    return jnp.einsum("bsq,qd->bsd", o.reshape(b, s, cfg.q_dim), as_weight(p["w_o"]),
                      preferred_element_type=jnp.float32).astype(x.dtype)


def decode_self_attention(p, cfg: ModelConfig, x, cache_k, cache_v, position,
                          *, window: int = 0, active=None):
    """Single-token decode against a KV cache ring/linear buffer.

    x: [b, 1, d]; cache_k/v: [b, S, kh, hd]; position: [b] int32 — the
    absolute position of each row's new token (per-slot positions enable
    continuous batching: sessions in the same decode batch sit at different
    offsets). For sliding-window caches the buffer is a ring of size
    ``window`` indexed modulo.

    ``active`` ([b] bool, optional) suppresses the cache write for inactive
    rows: parked (idle-resident) sessions ride the fused batch without their
    state advancing, which is what makes in-place hibernation-tier parking
    safe for ring buffers (a masked row would otherwise overwrite a live
    in-window entry) and costs nothing — the mask folds into the existing
    select-write.
    """
    b = x.shape[0]
    S = cache_k.shape[1]
    kh, hd, hq = cfg.num_kv_heads, cfg.head_dim, cfg.num_heads
    g = hq // kh
    position = jnp.broadcast_to(jnp.asarray(position, jnp.int32), (b,))
    q = _project_q(p, cfg, x, position[:, None])
    k_new, v_new = _project_kv(p, cfg, x, position[:, None])

    slot = (position % S) if window else jnp.minimum(position, S - 1)
    if cfg.decode_cache_scatter:          # legacy insert (A/B lever)
        rows = jnp.arange(b)
        ck = cache_k.at[rows, slot].set(k_new[:, 0])
        cv = cache_v.at[rows, slot].set(v_new[:, 0])
        if active is not None:
            act = active[:, None, None, None]
            ck = jnp.where(act, ck, cache_k)
            cv = jnp.where(act, cv, cache_v)
        cache_k, cache_v = ck, cv
    else:
        # masked write instead of a batched scatter: XLA lowers per-row
        # scatter to a serial loop on CPU (and an expensive scatter on
        # TPU), while the select is one bandwidth-bound fused op
        hit = (jnp.arange(S, dtype=jnp.int32)[None, :]
               == slot[:, None])
        if active is not None:
            hit = hit & active[:, None]
        hit = hit[..., None, None]
        cache_k = jnp.where(hit, k_new, cache_k)
        cache_v = jnp.where(hit, v_new, cache_v)

    if cfg.use_pallas_decode and not window and not cfg.attn_logits_softcap:
        # flash-decode Pallas kernel: linear buffer only (slot index IS the
        # absolute position, so the kernel's `kpos < length` ragged mask is
        # exactly the reference path's `kpos <= position`); ring buffers and
        # softcapped logits stay on the reference path
        from repro.kernels.decode_attention.decode_attention import \
            decode_attention
        o = decode_attention(
            q[:, 0],                                    # [b, hq, hd]
            jnp.moveaxis(cache_k, 1, 2),                # [b, kh, S, hd]
            jnp.moveaxis(cache_v, 1, 2),
            # clamp at the buffer: past position S-1 the linear cache holds
            # exactly S valid rows (the reference mask is slot <= position
            # over slots [0, S)); unclamped, zero-padded rows added by the
            # kernel's block_kv rounding would pass its kpos < length mask
            jnp.minimum(position + 1, S),
            block_kv=min(512, -(-S // 128) * 128),
            interpret=jax.default_backend() != "tpu")
        o = o.reshape(b, 1, cfg.q_dim).astype(x.dtype)
        out = jnp.einsum("bsq,qd->bsd", o, as_weight(p["w_o"]),
                         preferred_element_type=jnp.float32).astype(x.dtype)
        return out, cache_k, cache_v

    # absolute position of every cache slot, per row: [b, S]
    idx = jnp.arange(S, dtype=jnp.int32)
    if window:
        # ring buffer: slot i holds the latest position ≡ i (mod S) ≤ pos
        kpos = position[:, None] - ((position[:, None] - idx[None, :]) % S)
        valid = (kpos >= 0) & (kpos > position[:, None] - window)
    else:
        kpos = idx[None, :]
        valid = kpos <= position[:, None]

    qh = q.reshape(b, 1, kh, g, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qh, cache_k,
                   preferred_element_type=jnp.float32) / np.sqrt(hd)
    if cfg.attn_logits_softcap:
        s = L.softcap(s, cfg.attn_logits_softcap)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", w.astype(cache_v.dtype), cache_v,
                   preferred_element_type=jnp.float32)
    o = jnp.moveaxis(o, 3, 1).reshape(b, 1, cfg.q_dim).astype(x.dtype)
    out = jnp.einsum("bsq,qd->bsd", o, as_weight(p["w_o"]),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, cache_k, cache_v


def paged_decode_self_attention(p, cfg: ModelConfig, x, k_pages, v_pages,
                                block, position, *, active=None):
    """Single-token decode against a block-table paged KV pool.

    x: [b, 1, d]; k_pages/v_pages: [P, page, kh, hd] — this layer's slice of
    the global page pool; block: [b, PPS] int32 page ids per slot (page 0 is
    the shared scratch page — see ``repro.models.kvcache``); position: [b].

    Bit-compatibility contract with the dense path: when ``PPS * page`` equals
    the dense buffer length S, the gathered K/V rows are exactly the dense
    buffer rows and the masked-softmax math below is the same expression, so
    greedy decode is token-identical. Writes of inactive rows (and positions
    past the table) are routed to the scratch page, which is never read.
    """
    b = x.shape[0]
    page = k_pages.shape[1]
    PPS = block.shape[1]
    S = PPS * page
    kh, hd, hq = cfg.num_kv_heads, cfg.head_dim, cfg.num_heads
    g = hq // kh
    position = jnp.broadcast_to(jnp.asarray(position, jnp.int32), (b,))
    q = _project_q(p, cfg, x, position[:, None])
    k_new, v_new = _project_kv(p, cfg, x, position[:, None])

    # write the new token's K/V through the block table (one page row per
    # batch row — distinct active slots never share a page, so the batched
    # scatter has no write conflicts outside the scratch page)
    posc = jnp.minimum(position, S - 1)
    pid = jnp.take_along_axis(block, (posc // page)[:, None], axis=1)[:, 0]
    if active is not None:
        pid = jnp.where(active, pid, 0)
    off = posc % page
    k_pages = k_pages.at[pid, off].set(k_new[:, 0].astype(k_pages.dtype))
    v_pages = v_pages.at[pid, off].set(v_new[:, 0].astype(v_pages.dtype))

    if cfg.use_pallas_decode and not cfg.attn_logits_softcap:
        # paged flash-decode kernel: gathers K/V pages through the block
        # table with scalar-prefetch index maps (no [b, S] materialisation)
        from repro.kernels.decode_attention.decode_attention import \
            paged_decode_attention
        o = paged_decode_attention(
            q[:, 0], k_pages, v_pages,
            jnp.minimum(position + 1, S), block,
            interpret=jax.default_backend() != "tpu")
        o = o.reshape(b, 1, cfg.q_dim).astype(x.dtype)
        out = jnp.einsum("bsq,qd->bsd", o, as_weight(p["w_o"]),
                         preferred_element_type=jnp.float32).astype(x.dtype)
        return out, k_pages, v_pages

    # pure-XLA fallback: gather the slot's pages into a linear view, then
    # the same masked-softmax expression as the dense reference path
    k = k_pages[block].reshape(b, S, kh, hd)
    v = v_pages[block].reshape(b, S, kh, hd)
    idx = jnp.arange(S, dtype=jnp.int32)
    valid = idx[None, :] <= position[:, None]
    qh = q.reshape(b, 1, kh, g, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qh, k,
                   preferred_element_type=jnp.float32) / np.sqrt(hd)
    if cfg.attn_logits_softcap:
        s = L.softcap(s, cfg.attn_logits_softcap)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", w.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    o = jnp.moveaxis(o, 3, 1).reshape(b, 1, cfg.q_dim).astype(x.dtype)
    out = jnp.einsum("bsq,qd->bsd", o, as_weight(p["w_o"]),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, k_pages, v_pages


def decode_cross_attention(p, cfg: ModelConfig, x, mem_k, mem_v, mem_positions):
    """Cached cross attention: encoder K/V precomputed at session prefill."""
    b = x.shape[0]
    kh, hd, hq = cfg.num_kv_heads, cfg.head_dim, cfg.num_heads
    g = hq // kh
    q = _project_q(p, cfg, x, None)
    qh = q.reshape(b, 1, kh, g, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qh, mem_k,
                   preferred_element_type=jnp.float32) / np.sqrt(hd)
    s = jnp.where((mem_positions >= 0)[None, None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", w.astype(mem_v.dtype), mem_v,
                   preferred_element_type=jnp.float32)
    o = jnp.moveaxis(o, 3, 1).reshape(b, 1, cfg.q_dim).astype(x.dtype)
    return jnp.einsum("bsq,qd->bsd", o, as_weight(p["w_o"]),
                      preferred_element_type=jnp.float32).astype(x.dtype)


def project_cross_kv(p, cfg: ModelConfig, memory):
    """Precompute encoder-side K/V once per session (seamless decode path)."""
    return _project_kv(p, cfg, memory, None)
