"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block structure (the Griffin "recurrent block"):

    y1 = conv1d(W_x · x)            (depthwise causal, width 4)
    h  = RG-LRU(y1)                 (gated diagonal linear recurrence)
    y2 = GeLU(W_gate · x)
    out = W_out · (h ⊙ y2)

RG-LRU:
    r_t = σ(BlockDiag_a(x_t))          recurrence gate
    i_t = σ(BlockDiag_i(x_t))          input gate
    log a_t = -c · softplus(Λ) ⊙ r_t   (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

The sequence path runs a chunked scan: ``lax.scan`` over time chunks with a
``lax.associative_scan`` inside each chunk, so peak memory is
O(b · chunk · width) while keeping the log-depth parallel scan. The decode
path is the single-step recurrence (state = [b, width]) — this constant-size
state is exactly what makes RG-LRU sessions cheap to migrate (DESIGN.md §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models import layers as L
from repro.models.quant import as_weight

_C = 8.0
_CHUNK = 256
_NBLOCKS = 16  # block-diagonal gate heads


def rglru_init(key, cfg: ModelConfig):
    dt = L.dtype_of(cfg)
    w = cfg.lru_width or cfg.d_model
    bs = w // _NBLOCKS
    k1, k2, k3, k4, k5, k6, k7 = jax.random.split(key, 7)
    # Λ init so that a = σ(Λ)^c ∈ (0.9, 0.999) roughly (Griffin appendix)
    u = jax.random.uniform(k6, (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1(-log u / c)
    return {
        "w_x": L.dense_init(k1, cfg.d_model, w, dt),
        "w_gate": L.dense_init(k2, cfg.d_model, w, dt),
        "w_out": L.dense_init(k3, w, cfg.d_model, dt),
        "conv": (jax.random.normal(k7, (cfg.conv_width, w), jnp.float32)
                 / np.sqrt(cfg.conv_width)).astype(dt),
        "gate_a": (jax.random.normal(k4, (_NBLOCKS, bs, bs), jnp.float32)
                   / np.sqrt(bs)).astype(jnp.float32),
        "gate_i": (jax.random.normal(k5, (_NBLOCKS, bs, bs), jnp.float32)
                   / np.sqrt(bs)).astype(jnp.float32),
        "lambda": lam,
    }


def _block_diag(w, x):
    """x: [..., width] -> block-diagonal linear, blocks [_NBLOCKS, bs, bs]."""
    shape = x.shape
    xb = x.reshape(shape[:-1] + (_NBLOCKS, shape[-1] // _NBLOCKS))
    y = jnp.einsum("...nb,nbc->...nc", xb.astype(jnp.float32), w)
    return y.reshape(shape)


def _gates(p, x):
    """a_t (log-space) and sqrt(1-a²)·i_t multiplier, f32. x: [..., w]."""
    r = jax.nn.sigmoid(_block_diag(p["gate_a"], x))
    i = jax.nn.sigmoid(_block_diag(p["gate_i"], x))
    log_a = -_C * jax.nn.softplus(p["lambda"]) * r
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i
    return a, mult


def _causal_conv(p, x, state=None, length=None):
    """Depthwise causal conv, width K. x: [b, l, w].

    state: [b, K-1, w] carried inputs for decode; returns (y, new_state).
    ``length`` (traced scalar): true length of a right-padded bucket — the
    carried state must be the last K-1 *real* inputs, which sit at
    ``xp[:, length:length+K-1]`` (the causal left-pad shifts by K-1).
    """
    K = p["conv"].shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i: i + x.shape[1]] * p["conv"][i] for i in range(K))
    if length is None:
        new_state = xp[:, -(K - 1):]
    else:
        new_state = jax.lax.dynamic_slice_in_dim(xp, length, K - 1, axis=1)
    return y.astype(x.dtype), new_state


def _scan_lru(a, b, h0, *, remat=False):
    """h_t = a_t h_{t-1} + b_t over axis 1. a, b: [b, l, w] f32; h0: [b, w]."""
    B, T, W = a.shape
    chunk = min(_CHUNK, T)
    pad = (-T) % chunk
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
    nc = a.shape[1] // chunk
    ac = jnp.moveaxis(a.reshape(B, nc, chunk, W), 1, 0)
    bc = jnp.moveaxis(b.reshape(B, nc, chunk, W), 1, 0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a2 * a1, a2 * b1 + b2

    def step(h, xs):
        ai, bi = xs
        # fold the carried state into the first element
        bi = bi.at[:, 0].add(ai[:, 0] * h)
        aa, bb = jax.lax.associative_scan(combine, (ai, bi), axis=1)
        return bb[:, -1], bb

    body = jax.checkpoint(step) if remat else step
    _, hs = jax.lax.scan(body, h0, (ac, bc))
    hs = jnp.moveaxis(hs, 0, 1).reshape(B, nc * chunk, W)
    return hs[:, :T]


def rglru_block_apply(p, cfg: ModelConfig, x):
    """Sequence path. x: [b, l, d] -> [b, l, d]."""
    xw = jnp.einsum("bld,dw->blw", x, as_weight(p["w_x"]),
                    preferred_element_type=jnp.float32).astype(x.dtype)
    xw, _ = _causal_conv(p, xw)
    a, mult = _gates(p, xw)
    b = mult * xw.astype(jnp.float32)
    h0 = jnp.zeros((x.shape[0], xw.shape[-1]), jnp.float32)
    h = _scan_lru(a, b, h0, remat=cfg.remat != "none").astype(x.dtype)
    gate = jax.nn.gelu(jnp.einsum("bld,dw->blw", x, as_weight(p["w_gate"]),
                                  preferred_element_type=jnp.float32))
    out = (h.astype(jnp.float32) * gate).astype(x.dtype)
    return jnp.einsum("blw,wd->bld", out, as_weight(p["w_out"]),
                      preferred_element_type=jnp.float32).astype(x.dtype)


def rglru_block_decode(p, cfg: ModelConfig, x, conv_state, h_state):
    """Single-token path. x: [b, 1, d]; conv_state: [b, K-1, w];
    h_state: [b, w] f32. Returns (out, conv_state, h_state)."""
    xw = jnp.einsum("bld,dw->blw", x, as_weight(p["w_x"]),
                    preferred_element_type=jnp.float32).astype(x.dtype)
    xw, conv_state = _causal_conv(p, xw, conv_state)
    a, mult = _gates(p, xw)
    h = a[:, 0] * h_state + (mult * xw.astype(jnp.float32))[:, 0]
    gate = jax.nn.gelu(jnp.einsum("bld,dw->blw", x, as_weight(p["w_gate"]),
                                  preferred_element_type=jnp.float32))
    out = (h[:, None] * gate).astype(x.dtype)
    out = jnp.einsum("blw,wd->bld", out, as_weight(p["w_out"]),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, conv_state, h


def rglru_state_shapes(cfg: ModelConfig, batch: int):
    w = cfg.lru_width or cfg.d_model
    return {
        "conv": (batch, cfg.conv_width - 1, w),
        "h": (batch, w),
    }
