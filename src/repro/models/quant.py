"""Weight-only int8 quantisation for serving (beyond-paper §Perf lever).

Matrix params become ``{"q": int8, "s": f32 per-output-channel scales}``;
``as_weight`` dequantises at the einsum call site, so for scan-stacked layers
the bf16 materialisation happens per layer INSIDE the loop body (transient),
while at rest the weights cost half the HBM — which is what lets the 72B
qwen2-vl decode fit TP16 without weight-gathered serving (collective term
→ ~0) and halves the weight-read memory term.

Every weight consumer calls ``as_weight`` (no-op for plain arrays), so the
same model code serves bf16 and int8 checkpoints.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_weight(w):
    """Symmetric per-output-channel int8: reduce only the contracting (−2)
    dim, so layer-stacked weights [L, in, out] get per-(layer, channel)
    scales [L, 1, out] — scan-compatible leading axis preserved."""
    wf = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=w.ndim - 2, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "s": scale.astype(jnp.float32)}


def is_quantized(p) -> bool:
    return isinstance(p, dict) and set(p.keys()) == {"q", "s"}


def as_weight(p, dtype=jnp.bfloat16):
    """Dequantise-on-read hook used at every einsum call site."""
    if is_quantized(p):
        return (p["q"].astype(jnp.float32) * p["s"]).astype(dtype)
    return p


#: leaves never quantised: embedding/unembedding (gather/loss paths),
#: depthwise convs (indexed per-tap), gates/router (f32 numerics)
EXCLUDE = ("embed", "lm_head", "conv", "gate_a", "gate_i", "router",
           "lambda", "scale", "bias")


def _path_name(path) -> str:
    names = [str(getattr(k, "key", getattr(k, "idx", ""))) for k in path]
    return names[-1] if names else ""


def quantize_tree(params, *, min_size: int = 1 << 12):
    """Quantise every float matrix leaf (ndim ≥ 2, size ≥ min_size) of a
    param tree; small leaves (norm scales, biases, A_log, …) and EXCLUDE-
    listed names stay as-is."""
    def q(path, leaf):
        if _path_name(path) in EXCLUDE:
            return leaf
        if (hasattr(leaf, "ndim") and leaf.ndim >= 2
                and leaf.dtype in (jnp.bfloat16, jnp.float32, jnp.float16)
                and leaf.size >= min_size):
            return quantize_weight(leaf)
        return leaf

    return jax.tree_util.tree_map_with_path(q, params)


def abstract_quantize_tree(params):
    """ShapeDtypeStruct version for dry-run lowering."""
    def q(path, leaf):
        if _path_name(path) in EXCLUDE:
            return leaf
        import numpy as _np
        if leaf.ndim >= 2 and jnp.dtype(leaf.dtype) in (
                jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float32)) \
                and int(_np.prod(leaf.shape)) >= (1 << 12):
            # per-(stack, out-channel) scale: contracting (−2) dim -> 1
            sshape = tuple(1 if i == leaf.ndim - 2 else n
                           for i, n in enumerate(leaf.shape))
            return {"q": jax.ShapeDtypeStruct(leaf.shape, jnp.int8),
                    "s": jax.ShapeDtypeStruct(sshape, jnp.float32)}
        return leaf

    return jax.tree_util.tree_map_with_path(q, params)
