"""Decode-state (cache) construction per architecture family.

The cache pytree is *the* session state that AIS migration transfers between
execution anchors (see ``repro.serving.state_transfer``). Its size — reported
by ``cache_bytes`` — feeds the discovery cost predictor Γ̂ and the migration
deadline feasibility check (Eq. 11: τ_mig ≤ min(T_max, lease)).

Families:
* dense/moe/vlm : full KV buffer [L, b, S, kh, hd] (S = context) or a
                  sliding-window ring buffer (S = window).
* ssm           : conv state + SSD state — O(1) in context length.
* hybrid        : per-layer mix of RG-LRU state and local-attention rings.
* encdec        : self-attention KV + precomputed cross K/V.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models import rglru, ssd


def kv_buffer_len(cfg: ModelConfig, max_len: int) -> int:
    if cfg.sliding_window:
        return min(cfg.sliding_window, max_len)
    return max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *, abstract: bool = False):
    """Build the decode cache pytree (zeros, or ShapeDtypeStructs if abstract)."""
    dt = jnp.dtype(cfg.dtype)

    def mk(shape, dtype):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype)

    pos = mk((batch,), jnp.int32)
    L = cfg.num_layers
    if cfg.family == "ssm":
        shp = ssd.ssd_state_shapes(cfg, batch)
        layers = {
            "conv": mk((L,) + shp["conv"], dt),
            "ssm": mk((L,) + shp["ssm"], jnp.float32),
        }
        return {"layers": layers, "pos": pos}

    S = kv_buffer_len(cfg, max_len)
    kv = lambda: mk((batch, S, cfg.num_kv_heads, cfg.head_dim), dt)

    if cfg.family == "hybrid":
        shp = rglru.rglru_state_shapes(cfg, batch)
        per_layer = []
        for kind in cfg._pattern():
            if kind == "rec":
                per_layer.append({
                    "conv": mk(shp["conv"], dt),
                    "h": mk(shp["h"], jnp.float32),
                })
            else:
                per_layer.append({"k": kv(), "v": kv()})
        return {"layers": tuple(per_layer), "pos": pos}

    stacked_kv = lambda: mk((L, batch, S, cfg.num_kv_heads, cfg.head_dim), dt)
    cache = {"layers": {"k": stacked_kv(), "v": stacked_kv()}, "pos": pos}
    if cfg.family == "encdec":
        src = cfg.source_len
        cache["cross_k"] = mk((L, batch, src, cfg.num_kv_heads, cfg.head_dim), dt)
        cache["cross_v"] = mk((L, batch, src, cfg.num_kv_heads, cfg.head_dim), dt)
    return cache


def cache_bytes(cfg: ModelConfig, batch: int, max_len: int) -> int:
    """Total bytes of the decode cache (the migration payload size)."""
    tree = init_cache(cfg, batch, max_len, abstract=True)
    return int(sum(np.prod(l.shape) * jnp.dtype(l.dtype).itemsize
                   for l in jax.tree.leaves(tree)))


# ---------------------------------------------------------------------------
# paged layout (block-table KV)
# ---------------------------------------------------------------------------
#
# The paged layout decouples the slot axis from memory: K/V live in a global
# page pool of ``num_pages`` fixed-size pages shared by every slot, and each
# slot owns a block table of page ids. A slot holding ``n`` tokens costs
# ``ceil(n / page_size)`` pages instead of a full ``max_len`` reservation, so
# an engine can bind far more sessions than ``slots * max_len`` tokens of
# memory — the admission limit becomes the page pool, reported explicitly.
#
# Layout invariant: **page 0 is the shared scratch/null page.** Unallocated
# block-table entries point at it, and decode routes the writes of inactive
# slots there. It is never read: attention validity is ``index <= position``
# and positions never reach unallocated pages.

#: default page length in tokens (pow2; clamped to the context by page_len)
DEFAULT_PAGE_SIZE = 128


def supports_paging(cfg: ModelConfig) -> bool:
    """Only full-attention stacked-KV families page: their cache grows
    linearly in context. Ring buffers (sliding window) are already O(window),
    recurrent state (ssm / hybrid) is O(1), and encdec carries static cross
    K/V — those families keep the dense slot layout (and still participate
    in hibernation, which is layout-agnostic)."""
    return cfg.family in ("dense", "moe") and not cfg.sliding_window


def page_len(cfg: ModelConfig, max_len: int, page_size: int = DEFAULT_PAGE_SIZE
             ) -> int:
    """Effective page length: requested pow2 size clamped so a page never
    exceeds the context (a single oversized page would re-reserve max_len)."""
    if page_size <= 0 or page_size & (page_size - 1):
        raise ValueError(f"page_size must be a power of two, got {page_size}")
    p = page_size
    while p > 1 and p > max_len:
        p //= 2
    return p


def pages_per_slot(max_len: int, page_size: int) -> int:
    return -(-max_len // page_size)


def init_paged_cache(cfg: ModelConfig, slots: int, max_len: int,
                     num_pages: int, page_size: int, *,
                     abstract: bool = False):
    """Paged decode cache: global page pool + per-slot block tables.

    layers.k/v : [L, num_pages, page_size, kh, hd] — the shared pool
    block      : [slots, pages_per_slot(max_len, page_size)] int32 page ids
    pos        : [slots] int32

    ``"block" in cache`` is how LM.decode_step detects the paged layout.
    """
    if not supports_paging(cfg):
        raise ValueError(f"family {cfg.family} (window={cfg.sliding_window}) "
                         "does not support the paged KV layout")
    dt = jnp.dtype(cfg.dtype)

    def mk(shape, dtype):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype)

    L = cfg.num_layers
    pool = lambda: mk((L, num_pages, page_size, cfg.num_kv_heads,
                       cfg.head_dim), dt)
    pps = pages_per_slot(max_len, page_size)
    return {"layers": {"k": pool(), "v": pool()},
            "block": mk((slots, pps), jnp.int32),
            "pos": mk((slots,), jnp.int32)}


def page_bytes(cfg: ModelConfig, page_size: int) -> int:
    """Bytes of ONE page across all layers (the allocation granule)."""
    it = jnp.dtype(cfg.dtype).itemsize
    return int(2 * cfg.num_layers * page_size * cfg.num_kv_heads
               * cfg.head_dim * it)


def paged_cache_bytes(cfg: ModelConfig, slots: int, max_len: int,
                      num_pages: int, page_size: int) -> int:
    """Total bytes of the paged cache (pool + block tables + positions)."""
    tree = init_paged_cache(cfg, slots, max_len, num_pages, page_size,
                            abstract=True)
    return int(sum(np.prod(l.shape) * jnp.dtype(l.dtype).itemsize
                   for l in jax.tree.leaves(tree)))
