"""Decode-state (cache) construction per architecture family.

The cache pytree is *the* session state that AIS migration transfers between
execution anchors (see ``repro.serving.state_transfer``). Its size — reported
by ``cache_bytes`` — feeds the discovery cost predictor Γ̂ and the migration
deadline feasibility check (Eq. 11: τ_mig ≤ min(T_max, lease)).

Families:
* dense/moe/vlm : full KV buffer [L, b, S, kh, hd] (S = context) or a
                  sliding-window ring buffer (S = window).
* ssm           : conv state + SSD state — O(1) in context length.
* hybrid        : per-layer mix of RG-LRU state and local-attention rings.
* encdec        : self-attention KV + precomputed cross K/V.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models import rglru, ssd


def kv_buffer_len(cfg: ModelConfig, max_len: int) -> int:
    if cfg.sliding_window:
        return min(cfg.sliding_window, max_len)
    return max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *, abstract: bool = False):
    """Build the decode cache pytree (zeros, or ShapeDtypeStructs if abstract)."""
    dt = jnp.dtype(cfg.dtype)

    def mk(shape, dtype):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype)

    pos = mk((batch,), jnp.int32)
    L = cfg.num_layers
    if cfg.family == "ssm":
        shp = ssd.ssd_state_shapes(cfg, batch)
        layers = {
            "conv": mk((L,) + shp["conv"], dt),
            "ssm": mk((L,) + shp["ssm"], jnp.float32),
        }
        return {"layers": layers, "pos": pos}

    S = kv_buffer_len(cfg, max_len)
    kv = lambda: mk((batch, S, cfg.num_kv_heads, cfg.head_dim), dt)

    if cfg.family == "hybrid":
        shp = rglru.rglru_state_shapes(cfg, batch)
        per_layer = []
        for kind in cfg._pattern():
            if kind == "rec":
                per_layer.append({
                    "conv": mk(shp["conv"], dt),
                    "h": mk(shp["h"], jnp.float32),
                })
            else:
                per_layer.append({"k": kv(), "v": kv()})
        return {"layers": tuple(per_layer), "pos": pos}

    stacked_kv = lambda: mk((L, batch, S, cfg.num_kv_heads, cfg.head_dim), dt)
    cache = {"layers": {"k": stacked_kv(), "v": stacked_kv()}, "pos": pos}
    if cfg.family == "encdec":
        src = cfg.source_len
        cache["cross_k"] = mk((L, batch, src, cfg.num_kv_heads, cfg.head_dim), dt)
        cache["cross_v"] = mk((L, batch, src, cfg.num_kv_heads, cfg.head_dim), dt)
    return cache


def cache_bytes(cfg: ModelConfig, batch: int, max_len: int) -> int:
    """Total bytes of the decode cache (the migration payload size)."""
    tree = init_cache(cfg, batch, max_len, abstract=True)
    return int(sum(np.prod(l.shape) * jnp.dtype(l.dtype).itemsize
                   for l in jax.tree.leaves(tree)))
