"""Decoder-only / encoder-decoder LM covering all assigned families.

Layer stacking uses ``lax.scan`` over stacked layer params for homogeneous
stacks (dense / moe / ssm / encdec) to keep HLO size and compile time bounded
at production depth, and an unrolled loop for the heterogeneous hybrid
(RG-LRU) pattern. Activation rematerialisation is applied per layer according
to ``cfg.remat``.

Public surface (all pure functions of (params, batch)):
    init(key)                       -> params
    forward(params, batch)          -> (logits [b,s,V], aux)
    loss(params, batch)             -> (scalar, metrics)
    prefill(params, batch, max_len) -> (last_logits [b,V], cache)
    decode_step(params, cache, tokens [b,1]) -> (logits [b,1,V], cache)
"""

from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig, validate
from repro.models import layers as L
from repro.models import attention as A
from repro.models import moe as MOE
from repro.models import rglru as RG
from repro.models import ssd as SSD
from repro.models import kvcache as KV
from repro.sharding.ctx import constrain
from repro.models.quant import as_weight


# ---------------------------------------------------------------------------
# per-layer blocks
# ---------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig, kind: str):
    ks = jax.random.split(key, 4)
    if kind == "ssm":
        return {"norm1": L.rmsnorm_init(cfg.d_model), "ssd": SSD.ssd_init(ks[0], cfg)}
    if kind == "rec":
        return {"norm1": L.rmsnorm_init(cfg.d_model),
                "rec": RG.rglru_init(ks[0], cfg),
                "norm2": L.rmsnorm_init(cfg.d_model),
                "mlp": L.mlp_init(ks[1], cfg)}
    p = {"norm1": L.rmsnorm_init(cfg.d_model),
         "attn": A.attention_init(ks[0], cfg),
         "norm2": L.rmsnorm_init(cfg.d_model)}
    if kind == "attn_moe":
        p["moe"] = MOE.moe_init(ks[1], cfg)
    else:
        p["mlp"] = L.mlp_init(ks[1], cfg)
    if kind == "attn_cross":
        p["norm_x"] = L.rmsnorm_init(cfg.d_model)
        p["xattn"] = A.attention_init(ks[2], cfg)
    return p


def _block_seq(p, cfg: ModelConfig, kind: str, x, positions, memory=None,
               mem_positions=None, causal=True):
    """Full-sequence block. Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    x = constrain(x, "dp", None, None)
    h = L.rmsnorm_apply(p["norm1"], x, cfg.norm_eps)
    if kind == "ssm":
        y, _ = SSD.ssd_apply(p["ssd"], cfg, h)
        return x + y, aux
    if kind == "rec":
        y = RG.rglru_block_apply(p["rec"], cfg, h)
    else:
        y = A.self_attention(p["attn"], cfg, h, positions, causal=causal)
    x = x + y
    if kind == "attn_cross":
        hx = L.rmsnorm_apply(p["norm_x"], x, cfg.norm_eps)
        x = x + A.cross_attention(p["xattn"], cfg, hx, memory, mem_positions)
    h2 = L.rmsnorm_apply(p["norm2"], x, cfg.norm_eps)
    if kind == "attn_moe":
        y2, aux = MOE.moe_apply(p["moe"], cfg, h2)
    else:
        y2 = L.mlp_apply(p["mlp"], h2)
    return constrain(x + y2, "dp", None, None), aux


def _block_prefill(p, cfg: ModelConfig, kind: str, x, positions, S,
                   memory=None, mem_positions=None, length=None):
    """Sequence pass that also emits the decode cache for this layer.

    ``length`` (traced scalar) is the true prompt length when ``x`` is
    right-padded to a compile bucket: the recurrent families force their
    per-step update to the identity on padded steps and take conv states
    at ``length``, attention relies on causality (padded keys sit strictly
    after every real query) plus decode-side position masking of the buffer
    tail — either way the emitted cache equals the exact-length cache.
    """
    aux = jnp.zeros((), jnp.float32)
    x = constrain(x, "dp", None, None)
    h = L.rmsnorm_apply(p["norm1"], x, cfg.norm_eps)
    if kind == "ssm":
        y, (conv, ssm) = SSD.ssd_apply(p["ssd"], cfg, h, length=length)
        return x + y, {"conv": conv, "ssm": ssm}, aux
    if kind == "rec":
        # rerun block capturing final recurrence state
        xw = jnp.einsum("bld,dw->blw", h, as_weight(p["rec"]["w_x"]),
                        preferred_element_type=jnp.float32).astype(h.dtype)
        xw, conv_state = RG._causal_conv(p["rec"], xw, length=length)
        a, mult = RG._gates(p["rec"], xw)
        b0 = mult * xw.astype(jnp.float32)
        if length is not None:
            # padded steps: a=1, b=0 — the recurrence is an exact identity,
            # so hs[:, -1] is the state at the true end of the prompt
            valid = jnp.arange(xw.shape[1], dtype=jnp.int32) < length
            a = jnp.where(valid[None, :, None], a, 1.0)
            b0 = jnp.where(valid[None, :, None], b0, 0.0)
        h0 = jnp.zeros((h.shape[0], xw.shape[-1]), jnp.float32)
        hs = RG._scan_lru(a, b0, h0)
        gate = jax.nn.gelu(jnp.einsum("bld,dw->blw", h,
                                      as_weight(p["rec"]["w_gate"]),
                                      preferred_element_type=jnp.float32))
        out = (hs * gate).astype(h.dtype)
        y = jnp.einsum("blw,wd->bld", out, as_weight(p["rec"]["w_out"]),
                       preferred_element_type=jnp.float32).astype(h.dtype)
        x = x + y
        h2 = L.rmsnorm_apply(p["norm2"], x, cfg.norm_eps)
        x = x + L.mlp_apply(p["mlp"], h2)
        return x, {"conv": conv_state, "h": hs[:, -1]}, aux
    # attention kinds
    pos1d = positions[0] if positions.ndim == 3 else positions
    qpos = pos1d[0] if pos1d.ndim == 2 else pos1d
    k, v = A._project_kv(p["attn"], cfg, h, positions)
    q = A._project_q(p["attn"], cfg, h, positions)
    o = A.full_attention(q, k, v, qpos, qpos, cfg, causal=True)
    b, s = x.shape[0], x.shape[1]
    y = jnp.einsum("bsq,qd->bsd", o.reshape(b, s, cfg.q_dim),
                   as_weight(p["attn"]["w_o"]),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    x = x + y
    cache = _kv_to_buffer(cfg, k, v, S, length=length)
    if kind == "attn_cross":
        hx = L.rmsnorm_apply(p["norm_x"], x, cfg.norm_eps)
        x = x + A.cross_attention(p["xattn"], cfg, hx, memory, mem_positions)
        ck, cv = A.project_cross_kv(p["xattn"], cfg, memory)
        cache["cross_k"], cache["cross_v"] = ck, cv
    h2 = L.rmsnorm_apply(p["norm2"], x, cfg.norm_eps)
    if kind == "attn_moe":
        y2, aux = MOE.moe_apply(p["moe"], cfg, h2)
    else:
        y2 = L.mlp_apply(p["mlp"], h2)
    return x + y2, cache, aux


def _kv_to_buffer(cfg: ModelConfig, k, v, S, length=None):
    """Place prefill K/V [b, s, kh, hd] into the decode buffer of length S.

    Full attention: slots [0, s). Sliding window: ring layout — token at
    absolute position p lives in slot p % S.

    ``length`` (traced scalar): true prompt length of a right-padded bucket.
    Full attention needs no masking here — buffer rows past ``length`` hold
    padded-K/V garbage that decode never attends (its validity test is
    ``slot index <= position``). The ring layout DOES mask: only positions
    in ``[length - S, length)`` may land in the ring; padded and evicted
    positions are routed to a discard row so they cannot clobber live slots.
    """
    b, s = k.shape[0], k.shape[1]
    if not cfg.sliding_window:
        padk = jnp.zeros((b, S, k.shape[2], k.shape[3]), k.dtype)
        return {"k": jax.lax.dynamic_update_slice_in_dim(padk, k[:, :S], 0, 1),
                "v": jax.lax.dynamic_update_slice_in_dim(padk, v[:, :S], 0, 1)}
    if length is not None:
        pos = jnp.arange(s)
        live = (pos < length) & (pos >= length - S)
        slots = jnp.where(live, pos % S, S)       # S = discard row
        bufk = jnp.zeros((b, S + 1, k.shape[2], k.shape[3]), k.dtype)
        bufv = jnp.zeros_like(bufk)
        bufk = bufk.at[:, slots].set(k)
        bufv = bufv.at[:, slots].set(v)
        return {"k": bufk[:, :S], "v": bufv[:, :S]}
    take = min(s, S)
    ks, vs = k[:, -take:], v[:, -take:]
    slots = (jnp.arange(s - take, s)) % S
    bufk = jnp.zeros((b, S, k.shape[2], k.shape[3]), k.dtype)
    bufv = jnp.zeros_like(bufk)
    bufk = bufk.at[:, slots].set(ks)
    bufv = bufv.at[:, slots].set(vs)
    return {"k": bufk, "v": bufv}


def _keep_active(new, old, active):
    """Freeze a recurrent state leaf for inactive batch rows: parked
    (hibernation-tier) sessions share the fused decode batch but their
    state must not advance — recurrent updates, unlike position-indexed
    KV writes, mutate every row unconditionally."""
    if active is None:
        return new
    a = active.reshape(active.shape + (1,) * (new.ndim - 1))
    return jnp.where(a, new, old.astype(new.dtype))


def _block_decode(p, cfg: ModelConfig, kind: str, x, cache_layer, position,
                  active=None, block=None):
    """Single-token block. Returns (x, new_cache_layer).

    ``active`` ([b] bool) masks state updates of inactive rows; ``block``
    ([b, PPS] int32) routes attention K/V through the paged pool layout.
    """
    x = constrain(x, "dp", None, None)
    h = L.rmsnorm_apply(p["norm1"], x, cfg.norm_eps)
    if kind == "ssm":
        y, (conv, ssm) = SSD.ssd_decode(p["ssd"], cfg, h, cache_layer["conv"],
                                        cache_layer["ssm"])
        return x + y, {"conv": _keep_active(conv, cache_layer["conv"], active),
                       "ssm": _keep_active(ssm, cache_layer["ssm"], active)}
    if kind == "rec":
        y, conv, hst = RG.rglru_block_decode(p["rec"], cfg, h,
                                             cache_layer["conv"], cache_layer["h"])
        x = x + y
        h2 = L.rmsnorm_apply(p["norm2"], x, cfg.norm_eps)
        x = x + L.mlp_apply(p["mlp"], h2)
        return x, {"conv": _keep_active(conv, cache_layer["conv"], active),
                   "h": _keep_active(hst, cache_layer["h"], active)}
    window = cfg.sliding_window
    if block is not None:
        y, ck, cv = A.paged_decode_self_attention(
            p["attn"], cfg, h, cache_layer["k"], cache_layer["v"],
            block, position, active=active)
    else:
        y, ck, cv = A.decode_self_attention(p["attn"], cfg, h,
                                            cache_layer["k"],
                                            cache_layer["v"], position,
                                            window=window, active=active)
    x = x + y
    new_cache = dict(cache_layer)
    new_cache["k"], new_cache["v"] = ck, cv
    if kind == "attn_cross":
        hx = L.rmsnorm_apply(p["norm_x"], x, cfg.norm_eps)
        src = cache_layer["cross_k"].shape[1]
        x = x + A.decode_cross_attention(p["xattn"], cfg, hx,
                                         cache_layer["cross_k"],
                                         cache_layer["cross_v"],
                                         jnp.arange(src))
    h2 = L.rmsnorm_apply(p["norm2"], x, cfg.norm_eps)
    if kind == "attn_moe":
        y2, _ = MOE.moe_apply(p["moe"], cfg, h2)
    else:
        y2 = L.mlp_apply(p["mlp"], h2)
    return x + y2, new_cache


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def _scan_groups(cfg: ModelConfig) -> int:
    """Two-level scan group count: deep stacks checkpoint √L boundaries."""
    if cfg.remat == "none" or cfg.num_layers < 48:
        return 1
    for g in (8, 6, 4, 3, 2):
        if cfg.num_layers % g == 0:
            return g
    return 1


# ---------------------------------------------------------------------------
# the LM
# ---------------------------------------------------------------------------

class LM:
    """Functional language model. Hold no arrays — just the config."""

    def __init__(self, cfg: ModelConfig):
        validate(cfg)
        self.cfg = cfg

    # -- param init -----------------------------------------------------
    def _trunk_kind(self) -> str:
        if self.cfg.family == "ssm":
            return "ssm"
        if self.cfg.is_moe:
            return "attn_moe"
        return "attn"

    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        dt = L.dtype_of(cfg)
        keys = jax.random.split(key, 8)
        params: Dict[str, Any] = {
            "embed": L.embed_init(keys[0], cfg.padded_vocab, cfg.d_model, dt),
            "final_norm": L.rmsnorm_init(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = L.dense_init(keys[1], cfg.d_model,
                                             cfg.padded_vocab, dt)
        if cfg.family == "hybrid":
            lkeys = jax.random.split(keys[2], cfg.num_layers)
            params["layers"] = tuple(
                _block_init(lkeys[i], cfg, "rec" if k == "rec" else "attn")
                for i, k in enumerate(cfg._pattern()))
        elif cfg.family == "encdec":
            ekeys = jax.random.split(keys[2], cfg.encoder_layers)
            dkeys = jax.random.split(keys[3], cfg.num_layers)
            params["enc_layers"] = jax.vmap(
                lambda k: _block_init(k, cfg, "attn"))(ekeys)
            params["layers"] = jax.vmap(
                lambda k: _block_init(k, cfg, "attn_cross"))(dkeys)
            params["enc_norm"] = L.rmsnorm_init(cfg.d_model)
            params["adapter"] = L.dense_init(keys[4], cfg.d_model, cfg.d_model, dt)
        else:
            kind = self._trunk_kind()
            lkeys = jax.random.split(keys[2], cfg.num_layers)
            params["layers"] = jax.vmap(
                lambda k: _block_init(k, cfg, kind))(lkeys)
        if cfg.frontend == "vision":
            params["vision_adapter"] = L.dense_init(keys[5], cfg.d_model,
                                                    cfg.d_model, dt)
        return params

    def param_specs(self):
        return jax.eval_shape(lambda k: self.init(k), jax.random.key(0))

    # -- input embedding --------------------------------------------------
    def _embed(self, params, batch):
        cfg = self.cfg
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        x = constrain(x, "dp", None, None)
        if cfg.frontend == "vision" and "vision_embeds" in batch:
            nv = batch["vision_embeds"].shape[1]
            ve = jnp.einsum("bnd,de->bne", batch["vision_embeds"].astype(x.dtype),
                            as_weight(params["vision_adapter"]),
                            preferred_element_type=jnp.float32).astype(x.dtype)
            x = jax.lax.dynamic_update_slice_in_dim(x, ve, 0, axis=1)
        return x

    def _positions(self, batch, s):
        cfg = self.cfg
        if "positions" in batch:
            return batch["positions"]
        pos = jnp.arange(s, dtype=jnp.int32)
        if cfg.mrope_sections:
            return jnp.broadcast_to(pos[None, None], (3, 1, s))
        return pos

    def _logits(self, params, h):
        cfg = self.cfg
        head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        logits = jnp.einsum("...d,dv->...v", h, head,
                            preferred_element_type=jnp.float32)
        logits = constrain(logits, *(["dp"] + [None] * (logits.ndim - 2)
                                     + ["model"]))
        if cfg.padded_vocab != cfg.vocab_size:   # mask the padding tail
            pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
            logits = jnp.where(pad_mask, logits, -1e30)
        return L.softcap(logits, cfg.logits_softcap)

    # -- encoder ----------------------------------------------------------
    def _encode(self, params, frames):
        cfg = self.cfg
        x = jnp.einsum("bsd,de->bse", frames.astype(L.dtype_of(cfg)),
                       as_weight(params["adapter"]),
                       preferred_element_type=jnp.float32).astype(L.dtype_of(cfg))
        pos = jnp.arange(x.shape[1], dtype=jnp.int32)

        def body(h, lp):
            h, _ = _block_seq(lp, cfg, "attn", h, pos, causal=False)
            return h, None

        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["enc_layers"])
        return L.rmsnorm_apply(params["enc_norm"], x, cfg.norm_eps)

    # -- full-sequence forward (training) ---------------------------------
    def forward(self, params, batch):
        h, aux = self.forward_hidden(params, batch)
        return self._logits(params, h), aux

    def forward_hidden(self, params, batch):
        cfg = self.cfg
        x = self._embed(params, batch)
        s = x.shape[1]
        pos = self._positions(batch, s)
        memory = mem_pos = None
        if cfg.family == "encdec":
            memory = self._encode(params, batch["frames"])
            mem_pos = jnp.arange(memory.shape[1], dtype=jnp.int32)

        aux = jnp.zeros((), jnp.float32)
        if cfg.family == "hybrid":
            for lp, kind in zip(params["layers"], cfg._pattern()):
                def fn(lp_, h_, kind=kind):
                    return _block_seq(lp_, cfg, kind, h_, pos)
                x, a = _maybe_remat(fn, cfg)(lp, x)
                aux = aux + a
        else:
            kind = ("attn_cross" if cfg.family == "encdec"
                    else self._trunk_kind())

            def body(carry, lp):
                h, ax = carry
                h, a = _block_seq(lp, cfg, kind, h, pos, memory=memory,
                                  mem_positions=mem_pos)
                return (h, ax + a), None

            groups = _scan_groups(cfg)
            if groups > 1:
                # two-level (√L) checkpointing: only group boundaries are
                # saved in forward; one group's layer carries re-materialise
                # at a time in backward — stacked-carry footprint drops from
                # L·|x| to (G + L/G)·|x| (10.7 GB → ~2.4 GB for the 80-layer
                # qwen2-vl train cell).
                per = cfg.num_layers // groups
                grouped = jax.tree.map(
                    lambda p: p.reshape((groups, per) + p.shape[1:]),
                    params["layers"])

                def group_body(carry, glp):
                    out, _ = jax.lax.scan(_maybe_remat(body, cfg), carry, glp)
                    return out, None

                (x, aux), _ = jax.lax.scan(_maybe_remat(group_body, cfg),
                                           (x, aux), grouped)
            else:
                (x, aux), _ = jax.lax.scan(_maybe_remat(body, cfg), (x, aux),
                                           params["layers"])
        x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
        return x, aux

    # -- loss ---------------------------------------------------------------
    def loss(self, params, batch, *, ce_chunk: int = 512):
        """Chunked cross-entropy: logits are materialised ``ce_chunk``
        positions at a time (scan + checkpoint), never the full [b, s, V']
        slab — the unfused f32 CE pipeline over a 16k-wide sharded vocab
        otherwise holds ~17 live 1 GB buffers (observed, recurrentgemma
        train_4k). Also a real perf win: the loss becomes bandwidth-, not
        capacity-, limited."""
        cfg = self.cfg
        h, aux = self.forward_hidden(params, batch)
        labels = batch["labels"]
        b, s, d = h.shape
        cs = min(ce_chunk, s)
        if s % cs:
            cs = next(c for c in range(cs, 0, -1) if s % c == 0)
        ns = s // cs

        def chunk_ce(hc, lc):
            logits = self._logits(params, hc)           # [b, cs, V'] f32
            mask = (lc >= 0).astype(jnp.float32)
            lcc = jnp.maximum(lc, 0)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(
                logits, lcc[..., None], axis=-1)[..., 0]
            nll = (logz - gold) * mask
            return jnp.sum(nll), jnp.sum(mask)

        if ns == 1:
            tot, ntok = chunk_ce(h, labels)
        else:
            hc = jnp.moveaxis(h.reshape(b, ns, cs, d), 1, 0)
            lc = jnp.moveaxis(labels.reshape(b, ns, cs), 1, 0)

            def step(acc, xs):
                t, n = acc
                tt, nn = chunk_ce(*xs)
                return (t + tt, n + nn), None

            body = (jax.checkpoint(step) if cfg.remat != "none" else step)
            (tot, ntok), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
                (hc, lc))
        ntok = jnp.maximum(ntok, 1.0)
        ce = tot / ntok
        total = ce + 0.01 * aux
        return total, {"ce": ce, "aux": aux, "ntok": ntok}

    # -- prefill ------------------------------------------------------------
    def prefill(self, params, batch, max_len: int, adapter=None):
        """Build the decode cache for one prompt.

        ``batch["length"]`` (optional traced int32 scalar) marks the true
        prompt length when ``batch["tokens"]`` is right-padded to a compile
        bucket: the cache position, final logits, and every family's carried
        state are taken at ``length`` rather than the padded width, so the
        engine compiles O(log max_len) prefill variants instead of one per
        distinct prompt length (see InferenceEngine.prefill_session).

        ``adapter`` (optional ``(A [d, r], B [r, d])``): per-session LoRA
        delta applied to the final hidden state before the LM head — the
        KV cache is adapter-free, so exported state stays shape-identical
        to the base model's.
        """
        cfg = self.cfg
        x = self._embed(params, batch)
        s = x.shape[1]
        S = KV.kv_buffer_len(cfg, max_len)
        pos = self._positions(batch, s)
        length = batch.get("length")
        memory = mem_pos = None
        if cfg.family == "encdec":
            memory = self._encode(params, batch["frames"])
            mem_pos = jnp.arange(memory.shape[1], dtype=jnp.int32)

        if cfg.family == "hybrid":
            layers_cache = []
            for lp, kind in zip(params["layers"], cfg._pattern()):
                kk = "rec" if kind == "rec" else "attn"

                def fn(lp_, h_, kk=kk):
                    return _block_prefill(lp_, cfg, kk, h_, pos, S,
                                          length=length)
                x, cl, _ = _maybe_remat(fn, cfg)(lp, x)
                layers_cache.append(cl)
            cache = {"layers": tuple(layers_cache),
                     "pos": self._prefill_pos(x, s, length)}
        elif cfg.family == "ssm":
            def body(h, lp):
                h, cl, _ = _block_prefill(lp, cfg, "ssm", h, pos, S,
                                          length=length)
                return h, cl

            x, stacked = jax.lax.scan(_maybe_remat(body, cfg), x,
                                      params["layers"])
            cache = {"layers": stacked, "pos": self._prefill_pos(x, s, length)}
        else:
            kind = ("attn_cross" if cfg.family == "encdec"
                    else self._trunk_kind())

            def body(h, lp):
                h, cl, _ = _block_prefill(lp, cfg, kind, h, pos, S,
                                          memory=memory, mem_positions=mem_pos,
                                          length=length)
                return h, cl

            x, stacked = jax.lax.scan(_maybe_remat(body, cfg), x,
                                      params["layers"])
            cache = {"layers": {"k": stacked["k"], "v": stacked["v"]},
                     "pos": self._prefill_pos(x, s, length)}
            if cfg.family == "encdec":
                cache["cross_k"] = stacked["cross_k"]
                cache["cross_v"] = stacked["cross_v"]
        if length is None:
            x_last = x[:, -1]
        else:
            x_last = jax.lax.dynamic_index_in_dim(x, length - 1, axis=1,
                                                  keepdims=False)
        x_last = L.rmsnorm_apply(params["final_norm"], x_last, cfg.norm_eps)
        if adapter is not None:
            from repro.adapters.runtime import lora_apply_rows
            x_last = x_last + lora_apply_rows(x_last, adapter[0], adapter[1])
        return self._logits(params, x_last), cache

    @staticmethod
    def _prefill_pos(x, s, length):
        if length is None:
            return jnp.full((x.shape[0],), s, jnp.int32)
        return jnp.broadcast_to(jnp.asarray(length, jnp.int32), (x.shape[0],))

    # -- decode ---------------------------------------------------------------
    def decode_step(self, params, cache, tokens, active=None, adapter=None):
        """tokens: [b, 1] -> (logits [b, 1, V], updated cache).

        ``adapter`` (optional ``(A [E, d, r], B [E, r, d], idx [b],
        route)``): stacked LoRA tables plus the per-slot int32 adapter
        table. Each row's delta is gathered by ``idx`` and added to the
        final hidden state before the LM head; index 0 is the null
        adapter (exact zero delta), so base sessions are bit-identical
        with or without the tables.

        ``active`` ([b] bool, optional): rows whose state may advance this
        step. Inactive rows (parked sessions, empty slots) still flow through
        the batch — their logits are computed and discarded — but every cache
        leaf they own is left bit-identical, so a session can idle inside the
        fused batch indefinitely and resume exactly where it stopped.

        A cache carrying a ``"block"`` leaf selects the paged-KV layout
        (``repro.models.kvcache.init_paged_cache``): per-layer K/V are page
        pools indexed through the per-slot block table instead of dense
        [b, S] buffers.
        """
        cfg = self.cfg
        position = cache["pos"]
        block = cache.get("block")
        x = jnp.take(params["embed"], tokens, axis=0)

        if cfg.family == "hybrid":
            new_layers = []
            for lp, cl, kind in zip(params["layers"], cache["layers"],
                                    cfg._pattern()):
                kk = "rec" if kind == "rec" else "attn"
                x, ncl = _block_decode(lp, cfg, kk, x, cl, position,
                                       active=active)
                new_layers.append(ncl)
            new_cache = {"layers": tuple(new_layers), "pos": position + 1}
        else:
            kind = ("attn_cross" if cfg.family == "encdec"
                    else ("ssm" if cfg.family == "ssm"
                          else self._trunk_kind()))
            # The stacked cache rides the scan CARRY (not xs/ys): per-layer
            # dynamic_index + in-place dynamic_update keep ONE buffer alive,
            # avoiding the xs→ys double-buffer copy of the whole KV cache
            # (~2× cache bytes of temp, observed 13–33 GB/device).
            layer_cache = dict(cache["layers"])
            if cfg.family == "encdec":
                layer_cache["cross_k"] = cache["cross_k"]
                layer_cache["cross_v"] = cache["cross_v"]
            L_layers = cfg.num_layers

            def body(carry, xs):
                h, cstack = carry
                lp, idx = xs
                cl = jax.tree.map(
                    lambda c: jax.lax.dynamic_index_in_dim(
                        c, idx, axis=0, keepdims=False), cstack)
                h, ncl = _block_decode(lp, cfg, kind, h, cl, position,
                                       active=active, block=block)
                # write back only the mutated leaves (cross K/V are static)
                def upd(c, n):
                    return jax.lax.dynamic_update_index_in_dim(
                        c, n.astype(c.dtype), idx, axis=0)
                new_stack = dict(cstack)
                for key in ("k", "v", "conv", "ssm"):
                    if key in ncl and key in cstack:
                        new_stack[key] = upd(cstack[key], ncl[key])
                return (h, new_stack), None

            (x, stacked), _ = jax.lax.scan(
                body, (x, layer_cache),
                (params["layers"], jnp.arange(L_layers, dtype=jnp.int32)))
            new_cache = {"layers": {k: v for k, v in stacked.items()
                                    if not k.startswith("cross_")},
                         "pos": position + 1}
            if block is not None:
                new_cache["block"] = block
            if cfg.family == "encdec":
                new_cache["cross_k"] = cache["cross_k"]
                new_cache["cross_v"] = cache["cross_v"]
        x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
        if adapter is not None:
            from repro.adapters.runtime import lora_delta
            adp_a, adp_b, adp_idx, route = adapter
            delta = lora_delta(x[:, 0], adp_a, adp_b, adp_idx, route=route)
            x = x + delta[:, None]
        return self._logits(params, x), new_cache

    # -- cache helpers ----------------------------------------------------
    def init_cache(self, batch: int, max_len: int, *, abstract=False):
        return KV.init_cache(self.cfg, batch, max_len, abstract=abstract)

    def init_paged_cache(self, slots: int, max_len: int, num_pages: int,
                         page_size: int, *, abstract=False):
        return KV.init_paged_cache(self.cfg, slots, max_len, num_pages,
                                   page_size, abstract=abstract)
