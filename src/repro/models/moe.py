"""Mixture-of-Experts FFN.

Three interchangeable implementations (``cfg.moe_impl``):

* ``einsum``  — GShard-style capacity-buffer dispatch/combine expressed as
  one-hot einsums, chunked over tokens with ``lax.scan`` so the dispatch
  tensor stays ``O(chunk · E · C_chunk)``. This is the paper-faithful default:
  experts shard cleanly over the ``model`` mesh axis (EP) and the only
  cross-shard collective is the final all-reduce of the combined output.
  The dispatch/combine einsums cost real FLOPs — visible in the roofline
  "useful ratio" and attacked in EXPERIMENTS.md §Perf.
* ``scatter`` — dispatch via scatter-add into the capacity buffer and combine
  via gather; near-zero dispatch FLOPs, but leans on GSPMD scatter/gather
  partitioning.
* ``dense``   — every expert on every token, weighted combine. Only sane for
  smoke tests (E/k blow-up), kept as the correctness oracle.

Expert weights are stored stacked: ``w_gate/w_up/w_down: [E, d, f] / [E, f, d]``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models import layers as L
from repro.models.quant import as_weight


def moe_init(key, cfg: ModelConfig):
    dt = L.dtype_of(cfg)
    E, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    kr, k1, k2, k3 = jax.random.split(key, 4)
    scale = 1.0 / np.sqrt(d)
    return {
        "router": L.dense_init(kr, d, E, jnp.float32),
        "w_gate": (jax.random.normal(k1, (E, d, f), jnp.float32) * scale).astype(dt),
        "w_up": (jax.random.normal(k2, (E, d, f), jnp.float32) * scale).astype(dt),
        "w_down": (jax.random.normal(k3, (E, f, d), jnp.float32) / np.sqrt(f)).astype(dt),
    }


def _route(p, cfg: ModelConfig, x):
    """Router: returns (weights [?, k], expert ids [?, k], aux loss scalar)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)
    # load-balancing aux loss (Switch-style): E * sum_e f_e * P_e
    E = cfg.num_experts
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_i, E, dtype=jnp.float32), axis=1), axis=0)
    aux = E * jnp.sum(me * ce) / cfg.num_experts_per_tok
    return top_p, top_i, aux


def _expert_ffn(p, h):
    """h: [E, C, d] capacity buffers -> per-expert SwiGLU."""
    gate = jnp.einsum("ecd,edf->ecf", h, as_weight(p["w_gate"]),
                      preferred_element_type=jnp.float32)
    up = jnp.einsum("ecd,edf->ecf", h, as_weight(p["w_up"]),
                    preferred_element_type=jnp.float32)
    act = (jax.nn.silu(gate) * up).astype(h.dtype)
    return jnp.einsum("ecf,efd->ecd", act, as_weight(p["w_down"]),
                      preferred_element_type=jnp.float32).astype(h.dtype)


def _capacity(cfg: ModelConfig, tokens: int) -> int:
    c = int(np.ceil(tokens * cfg.num_experts_per_tok
                    * cfg.moe_capacity_factor / cfg.num_experts))
    return max(8, int(np.ceil(c / 8) * 8))


def _dispatch_chunk_einsum(p, cfg: ModelConfig, xt):
    """xt: [T, d] one chunk of tokens -> (out [T, d], aux)."""
    T, d = xt.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    C = _capacity(cfg, T)
    top_p, top_i, aux = _route(p, cfg, xt)

    # position of each (token, slot) assignment within its expert buffer
    onehot = jax.nn.one_hot(top_i, E, dtype=jnp.int32)        # [T, k, E]
    flat = onehot.reshape(T * k, E)
    pos = jnp.cumsum(flat, axis=0) * flat - 1                  # [T*k, E]
    pos = pos.reshape(T, k, E)
    in_cap = (pos >= 0) & (pos < C)

    # dispatch tensor [T, E, C] (bf16 zeros/ones); combine carries weights
    pos_c = jnp.clip(pos, 0, C - 1)
    disp = (jax.nn.one_hot(pos_c, C, dtype=xt.dtype)
            * (onehot * in_cap.astype(jnp.int32)).astype(xt.dtype)[..., None])
    disp = jnp.sum(disp, axis=1)                               # [T, E, C]
    comb = jnp.sum(
        jax.nn.one_hot(pos_c, C, dtype=jnp.float32)
        * (onehot.astype(jnp.float32) * in_cap * top_p[..., None])[..., None],
        axis=1)                                                # [T, E, C]

    buf = jnp.einsum("tec,td->ecd", disp, xt,
                     preferred_element_type=jnp.float32).astype(xt.dtype)
    out_buf = _expert_ffn(p, buf)
    out = jnp.einsum("tec,ecd->td", comb.astype(xt.dtype), out_buf,
                     preferred_element_type=jnp.float32).astype(xt.dtype)
    return out, aux


def _dispatch_chunk_scatter(p, cfg: ModelConfig, xt):
    """Scatter/gather dispatch: no dense one-hot matmuls."""
    T, d = xt.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    C = _capacity(cfg, T)
    top_p, top_i, aux = _route(p, cfg, xt)

    onehot = jax.nn.one_hot(top_i, E, dtype=jnp.int32)         # [T, k, E]
    pos = (jnp.cumsum(onehot.reshape(T * k, E), axis=0)
           * onehot.reshape(T * k, E) - 1)
    pos = jnp.sum(pos.reshape(T, k, E) * onehot, axis=-1)      # [T, k]
    in_cap = (pos >= 0) & (pos < C)
    slot = top_i * C + jnp.clip(pos, 0, C - 1)                 # [T, k]
    slot = jnp.where(in_cap, slot, E * C)                      # overflow bin

    buf = jnp.zeros((E * C + 1, d), xt.dtype)
    src = jnp.broadcast_to(xt[:, None], (T, k, d)).reshape(T * k, d)
    buf = buf.at[slot.reshape(-1)].add(src)
    out_buf = _expert_ffn(p, buf[:-1].reshape(E, C, d)).reshape(E * C, d)
    out_buf = jnp.concatenate([out_buf, jnp.zeros((1, d), xt.dtype)], axis=0)
    gathered = out_buf[slot.reshape(-1)].reshape(T, k, d)
    # weighted combine in f32 (CPU XLA lacks a bf16×bf16→f32 GEMV thunk)
    w = (top_p * in_cap).astype(jnp.float32)
    out = jnp.einsum("tkd,tk->td", gathered.astype(jnp.float32), w)
    return out.astype(xt.dtype), aux


def _dense_moe(p, cfg: ModelConfig, xt):
    # correctness oracle path: all-f32 math (some mixed bf16→f32 dot shapes
    # have no CPU execution thunk; this path never runs at scale)
    T, d = xt.shape
    top_p, top_i, aux = _route(p, cfg, xt)
    xf = xt.astype(jnp.float32)
    gate = jnp.einsum("td,edf->tef", xf,
                      as_weight(p["w_gate"], jnp.float32))
    up = jnp.einsum("td,edf->tef", xf, as_weight(p["w_up"], jnp.float32))
    act = jax.nn.silu(gate) * up
    yo = jnp.einsum("tef,efd->ted", act,
                    as_weight(p["w_down"], jnp.float32))        # [T, E, d]
    w = jnp.sum(jax.nn.one_hot(top_i, cfg.num_experts, dtype=jnp.float32)
                * top_p[..., None], axis=1)                     # [T, E]
    out = jnp.einsum("ted,te->td", yo, w).astype(xt.dtype)
    return out, aux


def moe_apply(p, cfg: ModelConfig, x):
    """x: [b, s, d] -> (out [b, s, d], aux_loss).

    Chunking runs over the SEQUENCE dim only: the batch dim (data-sharded)
    must stay out of the scan axis — scanning a sharded leading dim forces
    GSPMD to all-gather the whole token stream (17 GB/device f32 observed on
    the prefill_32k cells).
    """
    b, s, d = x.shape
    impl = {"einsum": _dispatch_chunk_einsum,
            "scatter": _dispatch_chunk_scatter,
            "dense": _dense_moe}[cfg.moe_impl]
    # GShard grouped dispatch: groups == batch rows (vmapped), so every
    # capacity buffer is local to its data shard. A flattened [b·t, d]
    # dispatch makes the buffer scatter / one-hot matmul cross the batch
    # sharding — GSPMD then all-reduces the whole [E, C, d] buffer per
    # chunk (~84 MB × 8192 executions ≈ 1.4 TB/device wire measured on
    # mixtral train_4k; EXPERIMENTS.md §Perf iteration 10).
    # grouping needs enough tokens per row to fill capacity buffers: at
    # decode (s == 1) the per-row min capacity C=8 × E pads the expert GEMMs
    # ~E/k× (qwen3 decode useful 0.185 → 0.014 observed) — flatten instead
    if s < 64 and cfg.moe_impl != "dense":
        out, aux = impl(p, cfg, x.reshape(b * s, d))
        return out.reshape(b, s, d), aux
    grouped = jax.vmap(lambda row: impl(p, cfg, row))
    # chunk budget is per ROW under grouped dispatch (buffers are [b_local,
    # E, C, d]); dividing by the global batch collapses chunks to a few
    # tokens and multiplies the per-chunk weight gathers ~16× (refuted
    # variant, §Perf iteration 10a)
    chunk_s = max(1, min(s, cfg.moe_chunk))
    if s % chunk_s:
        chunk_s = next(c for c in range(chunk_s, 0, -1) if s % c == 0)
    nchunks = s // chunk_s
    if nchunks == 1:
        out, aux = grouped(x)                 # [b, s, d], [b]
        return out, jnp.mean(aux)

    xc = jnp.moveaxis(x.reshape(b, nchunks, chunk_s, d), 1, 0)

    def step(acc, xi):                       # xi: [b, chunk_s, d]
        o, a = grouped(xi)
        return acc + jnp.mean(a), o

    body = jax.checkpoint(step) if cfg.remat != "none" else step
    aux, out = jax.lax.scan(body, jnp.zeros((), jnp.float32), xc)
    aux = aux / nchunks
    out = jnp.moveaxis(out, 0, 1).reshape(b, s, d)
    return out, aux
