"""SpecDecoder: the data-plane loop of a split session over two real
:class:`~repro.serving.engine.InferenceEngine` instances.

Per round (window γ):

1. DRAFT — the edge engine proposes d_1..d_γ autoregressively
   (``spec_round``), a rollback-able (γ+1)-step fused scan.
2. VERIFY — the anchored engine consumes [ℓ, d_1..d_γ] teacher-forced in
   ONE fused forward (``spec_grade``) and emits the target-greedy
   continuation y_0..y_γ.
3. ACCEPT — n = |longest prefix with d_i == y_{i-1}|; both engines
   restore their index-n snapshot and commit d_1..d_n, y_n
   (``spec_accept``). Every committed token is exactly what target-only
   greedy decode would have produced (induction over rounds), and every
   round commits ≥ 1 token — the loop cannot stall.

The decoder also implements the two continuity behaviours the split
story needs: ``migrate_verify`` (make-before-break verify re-anchor —
export/import the slot between rounds, bit-exact) and ``degrade`` /
``reattach_verify`` (airplane mode: verify loss drops to edge-only
drafting without killing the stream; re-attachment prefixes the new
verifier with the committed stream, so post-recovery tokens are again
target-greedy given the prefix).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from repro.configs.registry import draft_compatible
from repro.splitserve.placement import DEFAULT_GAMMA


def expected_round_tokens(alpha: float, gamma: int) -> float:
    """Expected committed tokens per round at per-token acceptance rate
    α (the Eq. 14-style predictor the heartbeat and bench share):
    1 + α + ... + α^γ = (1 − α^{γ+1}) / (1 − α)."""
    a = min(max(float(alpha), 0.0), 1.0)
    g = max(int(gamma), 0)
    if a >= 1.0:
        return float(g + 1)
    return (1.0 - a ** (g + 1)) / (1.0 - a)


def spec_speedup(alpha: float, gamma: int, *, rtt_verify_ms: float,
                 rtt_edge_ms: float, verify_step_ms: float = 0.0,
                 draft_step_ms: float = 0.0) -> float:
    """Predicted interactive-streaming speedup of split serving over
    target-only, per committed token. Target-only pays the verify
    anchor's RTT per streamed token; the split pays the edge RTT per
    token plus ONE verify round trip per round::

        t_target = rtt_verify + c_v
        t_split  = rtt_edge + c_d + (rtt_verify + (γ+1)·c_v) / E[n+1]

    where E[n+1] = expected_round_tokens(α, γ). The RTT terms dominate on
    real deployments (55 ms backhaul vs 2 ms access), which is what makes
    the ratio hardware-independent enough to guard in CI."""
    e = expected_round_tokens(alpha, gamma)
    t_target = rtt_verify_ms + verify_step_ms
    t_split = rtt_edge_ms + draft_step_ms \
        + (rtt_verify_ms + (gamma + 1) * verify_step_ms) / max(e, 1e-9)
    return t_target / max(t_split, 1e-9)


@dataclass
class SpecStats:
    rounds: int = 0
    drafted: int = 0
    accepted: int = 0
    committed: int = 0
    degraded_rounds: int = 0
    #: wall-clock split: where the decode time actually went
    draft_ms: float = 0.0
    verify_ms: float = 0.0

    @property
    def acceptance(self) -> float:
        return self.accepted / self.drafted if self.drafted else 0.0

    @property
    def tokens_per_round(self) -> float:
        return self.committed / self.rounds if self.rounds else 0.0


class SpecDecoder:
    """Drives one split session over a (draft, verify) engine pair."""

    def __init__(self, draft_engine, verify_engine, *,
                 gamma: int = DEFAULT_GAMMA, session_id: str = "split"):
        if not draft_compatible(draft_engine.cfg, verify_engine.cfg):
            raise ValueError(
                f"draft vocab {draft_engine.cfg.vocab_size} != target "
                f"vocab {verify_engine.cfg.vocab_size}: pairing rejected "
                f"before any tokens stream")
        self.draft = draft_engine
        self.verify: Optional[object] = verify_engine
        self.gamma = int(gamma)
        self.sid = session_id
        self.tokens: List[int] = []      # committed stream (post-prompt)
        self._prompt: Optional[np.ndarray] = None
        self.stats = SpecStats()

    # ------------------------------------------------------------------
    @property
    def degraded(self) -> bool:
        return self.verify is None

    def _committed_last(self) -> int:
        return self.tokens[-1]

    def start(self, prompt: np.ndarray) -> int:
        """Prefill both anchors. The FIRST committed token comes from the
        VERIFIER's prefill (identity with target-only decode starts at
        token 0); the draft's own prefill argmax is discarded — its slot
        is re-pointed at the committed token."""
        self._prompt = np.asarray(prompt, np.int32)
        if self.verify is None:
            raise RuntimeError("cannot start a split stream degraded; "
                               "use a plain engine for edge-only serve")
        pre = self.verify.prefill_session(self.sid, self._prompt)
        first = int(pre["first_token"])
        self.draft.prefill_session(self.sid, self._prompt)
        self.draft.override_last_token(self.sid, first)
        self.tokens = [first]
        return first

    # ------------------------------------------------------------------
    def _window(self) -> int:
        """Clamp γ so neither engine's round overruns max_len."""
        room_v = self.verify.max_len - self.verify.position_of(self.sid) \
            - 1 if self.verify is not None else self.gamma
        room_d = self.draft.max_len - self.draft.position_of(self.sid) - 1
        return max(1, min(self.gamma, room_v, room_d))

    def round(self, proposals: Optional[Sequence[int]] = None) -> List[int]:
        """One draft/verify/accept round; returns the committed tokens
        (length n+1 ∈ [1, γ+1]).

        ``proposals`` substitutes external draft tokens (the bench's
        oracle arm sweeps acceptance this way). The edge engine still
        runs — its round is charged and rolled back, then its state is
        teacher-forced onto the accepted prefix so the pair stays
        stream-consistent."""
        if self.degraded:
            return self.round_degraded()
        g = self._window()
        t0 = time.perf_counter()
        if proposals is None:
            d = self.draft.spec_round(self.sid, g)
            engine_drafted = True
        else:
            self.draft.spec_round(self.sid, g)
            self.draft.spec_abort(self.sid)
            d = [int(t) for t in list(proposals)[:g]]
            if len(d) < g:
                g = max(1, len(d))
                d = d[:g]
            engine_drafted = False
        t1 = time.perf_counter()
        y = self.verify.spec_grade(self.sid, d)
        n = 0
        while n < g and d[n] == y[n]:
            n += 1
        last = int(y[n])
        self.verify.spec_accept(self.sid, n, last)
        t2 = time.perf_counter()
        if engine_drafted:
            self.draft.spec_accept(self.sid, n, last)
        else:
            # teacher-force the accepted prefix (pad one junk token so a
            # zero-length prefix is representable; snapshots beyond n are
            # discarded by the accept)
            self.draft.spec_grade(self.sid, list(d[:n]) + [0])
            self.draft.spec_accept(self.sid, n, last)
        t3 = time.perf_counter()
        committed = [int(t) for t in d[:n]] + [last]
        self.tokens.extend(committed)
        st = self.stats
        st.rounds += 1
        st.drafted += g
        st.accepted += n
        st.committed += len(committed)
        st.draft_ms += (t1 - t0 + t3 - t2) * 1e3
        st.verify_ms += (t2 - t1) * 1e3
        return committed

    def round_degraded(self) -> List[int]:
        """Edge-only round (verify anchor lost): the draft engine's own
        greedy tokens ARE the stream — explicitly lower quality tier, but
        the session keeps streaming instead of failing."""
        g = self._window()
        t0 = time.perf_counter()
        d = self.draft.spec_round(self.sid, g)
        # commit all γ drafts: consumed ℓ, d_1..d_{γ-1}; newest = d_γ
        self.draft.spec_accept(self.sid, g - 1, d[-1])
        self.stats.draft_ms += (time.perf_counter() - t0) * 1e3
        self.tokens.extend(int(t) for t in d)
        self.stats.rounds += 1
        self.stats.degraded_rounds += 1
        self.stats.committed += g
        return [int(t) for t in d]

    def decode(self, n_tokens: int,
               proposals: Optional[Sequence[int]] = None) -> List[int]:
        """Commit at least ``n_tokens`` more tokens (rounds are atomic,
        so up to γ extra may land). ``proposals`` feeds the oracle arm —
        consumed positionally from the current stream offset."""
        start = len(self.tokens)
        while len(self.tokens) - start < n_tokens:
            if proposals is None:
                self.round()
            else:
                off = len(self.tokens) - 1      # proposals[i] drafts token i+1
                self.round(proposals=list(proposals[off:off + self.gamma]))
        return self.tokens[start:]

    # ------------------------------------------------------------------
    # continuity: verify migration, degrade, re-attach
    # ------------------------------------------------------------------
    def migrate_verify(self, new_engine) -> None:
        """Make-before-break verify re-anchor between rounds: export the
        slot from the old verifier, import into the new one (bit-exact —
        the same state-transfer primitive as session migration), then
        release the old slot. The edge draft anchor never stops."""
        if self.verify is None:
            raise RuntimeError("no verify anchor to migrate; reattach "
                               "first")
        if not draft_compatible(self.draft.cfg, new_engine.cfg):
            raise ValueError("verify migration target has mismatched "
                             "vocab; rejected before transfer")
        payload = self.verify.export_slot(self.sid)
        new_engine.import_slot(self.sid, payload)
        self.verify.release_slot(self.sid)
        self.verify = new_engine

    def degrade(self) -> None:
        """Airplane mode: drop the verify anchor. Subsequent rounds are
        edge-only (``round_degraded``)."""
        if self.verify is not None:
            try:
                self.verify.release_slot(self.sid)
            except Exception:
                pass                       # a crashed engine has no slot
        self.verify = None

    def reattach_verify(self, new_engine) -> None:
        """Recover full quality: prefill the new verifier with the
        committed stream (prompt + everything committed so far, minus
        the newest unconsumed token), then re-point its slot at the
        committed last token. Tokens from here on are target-greedy
        given the degraded-mode prefix."""
        if not draft_compatible(self.draft.cfg, new_engine.cfg):
            raise ValueError("verify re-attach target has mismatched "
                             "vocab; rejected before prefill")
        stream = np.concatenate(
            [self._prompt, np.asarray(self.tokens[:-1], np.int32)]) \
            if len(self.tokens) > 1 else self._prompt
        new_engine.prefill_session(self.sid, stream)
        new_engine.override_last_token(self.sid, self._committed_last())
        self.verify = new_engine

    def close(self) -> None:
        for eng in (self.draft, self.verify):
            if eng is not None:
                try:
                    eng.release_slot(self.sid)
                except Exception:
                    pass
