"""SplitManager: control-plane lifecycle of split sessions.

One manager attaches to an Orchestrator (``orch.splits``) and owns the
second (verify) anchor of every split session. Design invariants:

* **The session's own binding is the EDGE draft anchor** — the
  interactive data-plane path the invoker streams from. The verify
  anchor's leases live in :class:`SplitState`. Losing the verify anchor
  therefore never orphans the session or its in-flight requests: the
  split *degrades* to edge-only (explicit quality-tier event), never
  fails.
* **Atomic dual-anchor 2PC**: establishment PREPAREs both anchors
  provisionally and COMMITs both or rolls BOTH back — a half-reserved
  split is not representable, exactly like the single-anchor Eq. 4/10
  coupling.
* **Vocab compatibility is a PREPARE-time check**: a draft/target token
  -space mismatch raises ``NO_FEASIBLE_BINDING`` before any lease is
  taken, never a mid-stream decode fault.
* **Acceptance accounting**: the data plane reports per-round
  draft/accept counts (``note_round``); the heartbeat folds them into an
  EWMA and collapses the split (make-before-break re-anchor onto the
  verify tier) when the Eq. 14-style predictor says spec-decode stopped
  paying for itself.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from repro.configs.registry import draft_compatible
from repro.core.failures import FailureCause, SessionError
from repro.core.session import AISession, Binding, SessionState
from repro.core.telemetry import BoundaryTelemetry
from repro.splitserve.placement import (DEFAULT_GAMMA, SplitPlacement,
                                        propose_split, reverify)
from repro.splitserve.runtime import expected_round_tokens

#: EWMA weight of the newest acceptance sample
_EWMA = 0.3
#: collapse the split when predicted tokens/round drops below this —
#: at that point the per-round verify RTT amortization that justified
#: the split is gone (Eq. 14 reasoning on the acceptance predictor)
_MIN_ROUND_TOKENS = 1.25


@dataclass
class SplitState:
    """Book-keeping for one split session."""
    placement: SplitPlacement
    verify_binding: Optional[Binding]    # None ⇒ degraded (edge-only)
    gamma: int = DEFAULT_GAMMA
    accept_ewma: Optional[float] = None  # None until first round report
    rounds: int = 0
    drafted: int = 0
    accepted: int = 0
    degraded: bool = False
    low_streak: int = 0

    @property
    def acceptance(self) -> float:
        return self.accepted / self.drafted if self.drafted else 0.0

    def predicted_round_tokens(self) -> float:
        a = self.accept_ewma if self.accept_ewma is not None \
            else self.acceptance
        return expected_round_tokens(a, self.gamma)


class SplitManager:
    def __init__(self, orch, *, gamma: int = DEFAULT_GAMMA,
                 collapse_after: int = 2):
        self.orch = orch
        self.gamma = int(gamma)
        self.collapse_after = int(collapse_after)
        self.states: Dict[str, SplitState] = {}
        orch.splits = self

    # ------------------------------------------------------------------
    def _emit(self, session: AISession, event: str,
              detail: Optional[dict] = None) -> None:
        for sink in self.orch.split_event_sinks:
            sink(session.session_id, event, dict(detail or {}))

    def is_split(self, session_id: str) -> bool:
        return session_id in self.states

    def state_of(self, session_id: str) -> Optional[SplitState]:
        return self.states.get(session_id)

    # ------------------------------------------------------------------
    # establishment
    # ------------------------------------------------------------------
    def try_establish(self, session: AISession) -> bool:
        """Policy-gated split establishment. ``auto`` falls back to the
        single-anchor path when no feasible split EXISTS (pre-lease
        failure leaves the session state machine untouched); ``require``
        propagates the refusal. Returns True when the session committed
        as a split."""
        policy = session.asp.split_policy
        if policy == "never":
            return False
        try:
            placement = propose_split(
                session.asp, self.orch.catalog, self.orch.sites,
                self.orch.predictors, session.zone,
                analytics=self.orch.analytics, gamma=self.gamma)
        except SessionError:
            if policy == "require":
                raise
            return False                 # auto: single-anchor fallback
        self.establish_split(session, placement)
        return True

    def establish_split(self, session: AISession,
                        placement: SplitPlacement) -> None:
        """Atomic dual-anchor establishment: PREPARE both anchors,
        COMMIT both, bind the session at the EDGE draft anchor. Any
        failure rolls back every lease taken so far."""
        orch = self.orch
        session.mark_discovered()
        session.mark_anchored()
        # admission: the split's cost is the SUM of both legs
        orch.policy.admit_cost(
            session.asp, placement.draft.prediction.cost_per_1k
            + placement.verify.prediction.cost_per_1k)
        for cand in (placement.draft, placement.verify):
            region = cand.region or orch.sites[cand.site_id].spec.region
            orch.policy.check_region(session.authz_ref, region)
        # PREPARE-time draft compatibility (mid-stream is too late)
        if not draft_compatible(placement.draft.model.cfg,
                                placement.verify.model.cfg):
            raise SessionError(
                FailureCause.NO_FEASIBLE_BINDING,
                f"split PREPARE refused: draft "
                f"{placement.draft.model.model_id} vocab "
                f"{placement.draft.model.cfg.vocab_size} != target "
                f"{placement.verify.model.model_id} vocab "
                f"{placement.verify.model.cfg.vocab_size}")
        session.mark_preparing()
        coord = orch.coordinator
        prep_e = coord.prepare(
            placement.draft.model, placement.draft.site_id, session.zone,
            placement.draft.klass, slots=1,
            cache_bytes=placement.draft.model.session_state_bytes(2048))
        try:
            prep_v = coord.prepare(
                placement.verify.model, placement.verify.site_id,
                session.zone, placement.verify.klass, slots=1,
                cache_bytes=placement.verify.model.session_state_bytes(
                    2048))
        except BaseException:
            coord.abort(prep_e)          # co-reservation: both or neither
            raise
        session.mark_prepared()
        try:
            edge_b = coord.commit(prep_e, placement.draft.model)
        except BaseException:
            coord.abort(prep_e)          # idempotent belt-and-braces
            coord.abort(prep_v)
            raise
        try:
            verify_b = coord.commit(prep_v, placement.verify.model)
        except BaseException:
            coord.abort(prep_v)
            self._release_binding(edge_b)
            raise
        session.charging_ref = orch.policy.open_charging(
            session.session_id)
        session.bind(edge_b)             # data plane = the edge anchor
        orch.telemetry[session.session_id] = BoundaryTelemetry()
        self.states[session.session_id] = SplitState(
            placement=placement, verify_binding=verify_b,
            gamma=placement.gamma)
        self._emit(session, "split-established", {
            "draft": f"{placement.draft.model.model_id}"
                     f"@{placement.draft.site_id}",
            "verify": f"{placement.verify.model.model_id}"
                      f"@{placement.verify.site_id}",
            "gamma": placement.gamma,
            "verify_budget_p99_ms": placement.verify_budget.p99_ms,
            "draft_budget_p99_ms": placement.draft_budget.p99_ms,
        })

    # ------------------------------------------------------------------
    # data-plane accounting
    # ------------------------------------------------------------------
    def note_round(self, session_id: str, drafted: int,
                   accepted: int) -> None:
        """Per-round acceptance report from the serving plane."""
        st = self.states.get(session_id)
        if st is None or drafted <= 0:
            return
        st.rounds += 1
        st.drafted += int(drafted)
        st.accepted += int(accepted)
        sample = accepted / drafted
        st.accept_ewma = sample if st.accept_ewma is None else \
            (1 - _EWMA) * st.accept_ewma + _EWMA * sample

    # ------------------------------------------------------------------
    # heartbeat: renew the verify half + Eq. 14-style collapse trigger
    # ------------------------------------------------------------------
    def heartbeat(self, session: AISession) -> None:
        st = self.states.get(session.session_id)
        if st is None:
            return
        vb = st.verify_binding
        if vb is not None:
            site = self.orch.sites.get(vb.site_id)
            lease_s = self.orch.timers.lease_s
            ok = site is not None and not site.dead \
                and site.renew(vb.compute_lease_id, lease_s) \
                and self.orch.qos.renew(vb.qos_lease_id, lease_s)
            if not ok:
                self.degrade(session, reason="verify-lease-lapsed")
                return
        if st.accept_ewma is not None and not st.degraded:
            if st.predicted_round_tokens() < _MIN_ROUND_TOKENS:
                st.low_streak += 1
            else:
                st.low_streak = 0
            if st.low_streak >= self.collapse_after:
                self.collapse(session)

    # ------------------------------------------------------------------
    # degrade / recover / collapse / verify migration
    # ------------------------------------------------------------------
    def on_site_dead(self, site_id: str) -> None:
        """Supervisor crash hook, called BEFORE the orphan census. A dead
        VERIFY anchor degrades its sessions to edge-only (they stay bound
        and serving at the edge — zero orphans, zero failed in-flight); a
        dead EDGE anchor dissolves the split and leaves the session to
        the supervisor's normal re-anchoring."""
        for sid, st in list(self.states.items()):
            session = self.orch.sessions.get(sid)
            if session is None:
                continue
            vb = st.verify_binding
            if vb is not None and vb.site_id == site_id:
                self.degrade(session,
                             reason=f"verify anchor {site_id} dead")
            elif session.binding is not None \
                    and session.binding.site_id == site_id:
                self._drop_verify(st)
                del self.states[sid]
                self._emit(session, "split-dissolved",
                           {"reason": f"edge anchor {site_id} dead"})

    def degrade(self, session: AISession, *, reason: str) -> None:
        """Airplane mode: release the verify half (a dead site's release
        is a no-op) and keep streaming edge-only. The session never
        leaves the committed domain — this is a QUALITY event, not a
        failure."""
        st = self.states[session.session_id]
        if st.degraded:
            return
        self._drop_verify(st)
        st.degraded = True
        st.low_streak = 0
        self._emit(session, "split-degraded",
                   {"reason": reason, "mode": "edge-only",
                    "quality": "draft-tier"})

    def recover(self, session: AISession) -> None:
        """Re-attach a verify anchor to a degraded split: re-page the
        verify half (crashed sites are excluded by the supervisor's
        analytics verdict), PREPARE/COMMIT it, restore full quality."""
        st = self.states[session.session_id]
        if not st.degraded:
            return
        placement = reverify(
            st.placement, session.asp, self.orch.catalog, self.orch.sites,
            self.orch.predictors, session.zone,
            analytics=self.orch.analytics)
        vb = self._reserve_verify(session, placement)
        st.placement = placement
        st.verify_binding = vb
        st.degraded = False
        self._emit(session, "split-recovered", {
            "verify": f"{placement.verify.model.model_id}"
                      f"@{placement.verify.site_id}",
            "quality": "full"})

    def migrate_verify(self, session: AISession,
                       exclude_sites: tuple = ()) -> str:
        """Make-before-break re-anchor of the VERIFY tier only: the new
        verify anchor is reserved while the old one still holds, then the
        old leases release — the edge draft keeps streaming throughout.
        Returns the new verify site id."""
        st = self.states[session.session_id]
        if st.verify_binding is None:
            raise SessionError(FailureCause.NO_FEASIBLE_BINDING,
                               "cannot migrate a degraded split's verify "
                               "anchor; recover() it instead")
        excl = tuple(exclude_sites) or (st.verify_binding.site_id,)
        placement = reverify(
            st.placement, session.asp, self.orch.catalog, self.orch.sites,
            self.orch.predictors, session.zone,
            analytics=self.orch.analytics, exclude_verify_sites=excl)
        new_vb = self._reserve_verify(session, placement)
        old_vb = st.verify_binding
        st.placement = placement
        st.verify_binding = new_vb       # break only after make
        self._release_binding(old_vb)
        self._emit(session, "verify-migrated", {
            "from": old_vb.site_id, "to": new_vb.site_id})
        return new_vb.site_id

    def collapse(self, session: AISession) -> None:
        """Un-split: acceptance collapsed, so spec-decode costs more than
        it saves. Re-anchor the session onto its verify binding
        (make-before-break — bind() releases the edge half only after the
        verify binding is committed as the primary) and drop the split."""
        st = self.states.pop(session.session_id)
        vb = st.verify_binding
        if vb is None:
            self.states[session.session_id] = st
            raise SessionError(FailureCause.NO_FEASIBLE_BINDING,
                               "cannot collapse a degraded split")
        if session.state is SessionState.COMMITTED:
            session.mark_migrating()
        session.bind(vb)                 # MBB: edge leases release here
        self._emit(session, "split-collapsed", {
            "anchor": vb.site_id,
            "acceptance": round(st.acceptance, 4),
            "predicted_round_tokens":
                round(st.predicted_round_tokens(), 3)})

    # ------------------------------------------------------------------
    def on_release(self, session: AISession) -> None:
        """Session teardown: free the verify half's leases and state."""
        st = self.states.pop(session.session_id, None)
        if st is not None:
            self._drop_verify(st)

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------
    def _reserve_verify(self, session: AISession,
                        placement: SplitPlacement) -> Binding:
        """PREPARE/COMMIT only the verify half (edge half already
        committed and serving)."""
        orch = self.orch
        cand = placement.verify
        orch.policy.check_region(
            session.authz_ref,
            cand.region or orch.sites[cand.site_id].spec.region)
        prep = orch.coordinator.prepare(
            cand.model, cand.site_id, session.zone, cand.klass, slots=1,
            cache_bytes=cand.model.session_state_bytes(
                max(session.context_tokens, 2048)))
        return orch.coordinator.commit(prep, cand.model)

    def _drop_verify(self, st: SplitState) -> None:
        if st.verify_binding is not None:
            self._release_binding(st.verify_binding)
            st.verify_binding = None

    def _release_binding(self, b: Binding) -> None:
        site = self.orch.sites.get(b.site_id)
        if site is not None:
            site.release(b.compute_lease_id)
        self.orch.qos.release(b.qos_lease_id)

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        """Fleet-level split accounting (benches + supervisors)."""
        return {
            "sessions": len(self.states),
            "degraded": sum(1 for s in self.states.values() if s.degraded),
            "rounds": sum(s.rounds for s in self.states.values()),
            "acceptance": (
                sum(s.accepted for s in self.states.values())
                / max(sum(s.drafted for s in self.states.values()), 1)),
        }
