"""SplitPlacement: DISCOVER/PAGE for a two-anchor (edge draft + verify)
session.

The placement problem is the paper's Eq. 7/9 run twice under a
tier-decomposed budget: the VERIFY anchor is a normal ASP-admissible
candidate judged against the backhaul leg's share of the objectives
(``ℓ − t_verify``); the DRAFT anchor is an edge-tier model judged against
the access leg's share (``ℓ − t_edge``) and additionally constrained to
be draft-compatible with the chosen verify model (identical token space —
greedy spec-decode compares token ids, so a vocab mismatch is
structurally wrong, not merely low-acceptance). Every exclusion along the
way lands in ``notes`` so a refused split stays attributable (Eq. 12).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import List, Tuple

from repro.configs.registry import arch_tier, draft_compatible
from repro.core.asp import ASP
from repro.core.budget import SLABudget, apply_budget, decompose_tiers
from repro.core.discovery import Candidate, discover
from repro.core.failures import FailureCause, SessionError
from repro.core.paging import page

#: default draft window: tokens proposed per round. γ+1 verify steps
#: commit between 1 and γ+1 tokens per round depending on agreement.
DEFAULT_GAMMA = 4


@dataclass
class SplitPlacement:
    """A proposed two-anchor realization of one ASP."""
    draft: Candidate             # edge draft anchor (data-plane path)
    verify: Candidate            # regional/central verify anchor
    draft_budget: SLABudget      # access leg's share of the objectives
    verify_budget: SLABudget     # backhaul leg's share
    gamma: int = DEFAULT_GAMMA
    #: exclusion notes collected while proposing — the Eq. 12 audit trail
    #: of every (model, site) the split considered and rejected
    notes: Tuple[str, ...] = ()

    def to_wire(self) -> dict:
        return {
            "draft": self.draft.to_wire(),
            "verify": self.verify.to_wire(),
            "draft_budget": self.draft_budget.to_wire(),
            "verify_budget": self.verify_budget.to_wire(),
            "gamma": self.gamma,
            "notes": list(self.notes),
        }


def _zone_rtt(site, zone: str) -> float:
    rtt = site.spec.rtt_ms
    if zone in rtt:
        return rtt[zone]
    return max(rtt.values()) if rtt else 50.0


def propose_split(asp: ASP, catalog, sites, predictors, zone: str, *,
                  analytics=None, gamma: int = DEFAULT_GAMMA,
                  exclude_verify_sites: Tuple[str, ...] = ()
                  ) -> SplitPlacement:
    """Propose a SplitPlacement or raise ``SessionError`` with an
    attributable cause (no edge tier, infeasible tier budget, no
    draft-compatible model, empty admissible set on either leg).

    ``exclude_verify_sites`` lets verify-tier migration/recovery re-page
    away from the current (or crashed) verify anchor while keeping the
    edge leg untouched."""
    notes: List[str] = []
    local = {sid: s for sid, s in sites.items()
             if not getattr(s, "is_guest_view", False)}
    edge_sites = {sid: s for sid, s in local.items()
                  if s.spec.kind == "edge" and not s.dead}
    verify_sites = {sid: s for sid, s in local.items()
                    if s.spec.kind != "edge" and not s.dead
                    and sid not in exclude_verify_sites}
    if not edge_sites:
        raise SessionError(FailureCause.NO_FEASIBLE_BINDING,
                           "split: no live edge-tier site for the draft "
                           "anchor")
    if not verify_sites:
        raise SessionError(FailureCause.NO_FEASIBLE_BINDING,
                           "split: no live regional/central site for the "
                           "verify anchor")
    # ---- tier budget decomposition (Eq. 11 shares per leg) ------------
    t_edge = min(_zone_rtt(s, zone) for s in edge_sites.values())
    t_verify = min(_zone_rtt(s, zone) for s in verify_sites.values())
    budgets = decompose_tiers(asp, {"edge": t_edge, "verify": t_verify})
    draft_asp = apply_budget(asp, budgets["edge"])
    verify_asp = apply_budget(asp, budgets["verify"])

    # ---- verify anchor: normal ASP admissibility on its budget share --
    vcands = discover(verify_asp, catalog, sites, predictors, zone,
                      analytics=analytics)
    v_kept: List[Candidate] = []
    for c in vcands:
        site = local.get(c.site_id)
        if site is not None and site.spec.kind == "edge":
            notes.append(f"verify {c.model.model_id}@{c.site_id}: "
                         f"wrong-tier:edge")
            continue
        if site is not None and site.dead:
            # the site table's own liveness flag, independent of whether
            # the analytics verdict has landed yet
            notes.append(f"verify {c.model.model_id}@{c.site_id}: "
                         f"site-dead")
            continue
        if not c.admissible and c.exclusion_reason:
            notes.append(f"verify {c.model.model_id}@{c.site_id}: "
                         f"{c.exclusion_reason}")
        v_kept.append(c)
    verify = page(verify_asp, v_kept,
                  exclude_sites=tuple(exclude_verify_sites))

    # ---- draft anchor: edge-tier models compatible with the verifier --
    draft_models = []
    for entry in catalog.entries():
        if entry.model_id == verify.model.model_id:
            continue
        if arch_tier(entry.model_id) != "edge":
            notes.append(f"draft {entry.model_id}: "
                         f"wrong-tier:{arch_tier(entry.model_id)}")
            continue
        if not draft_compatible(entry.cfg, verify.model.cfg):
            notes.append(
                f"draft {entry.model_id}: vocab-mismatch "
                f"({entry.cfg.vocab_size} != "
                f"{verify.model.cfg.vocab_size})")
            continue
        draft_models.append(entry)
    if not draft_models:
        raise SessionError(
            FailureCause.NO_FEASIBLE_BINDING,
            f"split: no draft-compatible edge model for "
            f"{verify.model.model_id} ({'; '.join(notes) or 'none'})")
    dcands = discover(draft_asp, catalog, sites, predictors, zone,
                      analytics=analytics, models=draft_models)
    d_kept: List[Candidate] = []
    for c in dcands:
        site = local.get(c.site_id)
        if site is None or site.spec.kind != "edge":
            notes.append(f"draft {c.model.model_id}@{c.site_id}: "
                         f"wrong-tier:{site.spec.kind if site else 'remote'}")
            continue
        if site.dead:
            notes.append(f"draft {c.model.model_id}@{c.site_id}: "
                         f"site-dead")
            continue
        if not c.admissible and c.exclusion_reason:
            notes.append(f"draft {c.model.model_id}@{c.site_id}: "
                         f"{c.exclusion_reason}")
        d_kept.append(c)
    draft = page(draft_asp, d_kept)
    return SplitPlacement(draft=draft, verify=verify,
                          draft_budget=budgets["edge"],
                          verify_budget=budgets["verify"],
                          gamma=int(gamma), notes=tuple(notes))


def reverify(placement: SplitPlacement, asp: ASP, catalog, sites,
             predictors, zone: str, *, analytics=None,
             exclude_verify_sites: Tuple[str, ...] = ()) -> SplitPlacement:
    """Re-propose only the VERIFY half (recovery / verify-tier
    migration): the edge draft anchor stays as placed."""
    fresh = propose_split(asp, catalog, sites, predictors, zone,
                          analytics=analytics, gamma=placement.gamma,
                          exclude_verify_sites=exclude_verify_sites)
    return replace(placement, verify=fresh.verify,
                   verify_budget=fresh.verify_budget,
                   notes=placement.notes + fresh.notes)
