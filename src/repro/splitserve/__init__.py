"""Split device–RAN–cloud serving: two-anchor sessions with edge-draft
greedy speculative decode.

A split session holds TWO co-reserved anchors under one ASP: an edge
DRAFT anchor (small model, access-RTT close, the interactive data-plane
path the invoker streams from) and a regional/central VERIFY anchor (the
quality-tier model that grades each γ-token draft round in one fused
forward and keeps the committed stream bitwise identical to target-only
greedy decode). Each anchor gets its own share of the ASP latency/cost
budget via the tier-generalized decomposition in
:mod:`repro.core.budget`.

Modules:

* :mod:`~repro.splitserve.placement` — DISCOVER/PAGE for the pair
  (SplitPlacement: per-tier budgets, per-role candidates, exclusion
  notes).
* :mod:`~repro.splitserve.runtime` — SpecDecoder: the real two-engine
  draft/verify/accept loop over :class:`InferenceEngine` spec rounds,
  plus degraded edge-only operation and verify re-attachment.
* :mod:`~repro.splitserve.control` — SplitManager: atomic dual-anchor
  2PC, heartbeat lease renewal + acceptance accounting, verify-tier
  make-before-break migration, crash degrade/recover, event emission.
"""

from repro.splitserve.placement import (DEFAULT_GAMMA, SplitPlacement,
                                        propose_split)
from repro.splitserve.runtime import (SpecDecoder, SpecStats,
                                      expected_round_tokens, spec_speedup)
from repro.splitserve.control import SplitManager, SplitState

__all__ = [
    "DEFAULT_GAMMA", "SplitPlacement", "propose_split",
    "SpecDecoder", "SpecStats", "expected_round_tokens", "spec_speedup",
    "SplitManager", "SplitState",
]
