"""Multi-tenant LoRA adapter fleet.

`catalog` holds the control-plane view: versioned adapter specs
(rank, target matrices, sovereignty tags, weight fingerprints)
registered against base models. `runtime` holds the data-plane view:
stacked per-engine A/B device tables indexed by a per-slot int32
adapter table inside the fused decode scan.
"""

from repro.adapters.catalog import (  # noqa: F401
    AdapterCatalog,
    AdapterSpec,
    init_adapter_weights,
    version_key,
    weight_fingerprint,
)
from repro.adapters.runtime import (  # noqa: F401
    AdapterRuntime,
    lora_apply_rows,
    lora_delta,
)
