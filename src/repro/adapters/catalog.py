"""Versioned LoRA adapter catalog (control plane).

An adapter is a low-rank delta on the final hidden state of its base
model: ``h' = h + (h @ A) @ B`` with ``A: [d_model, rank]`` and
``B: [rank, d_model]`` (the registration scale is folded into B). The
KV cache is untouched, so adapter identity never changes payload
shapes — it travels as a string alongside the cache in migration and
hibernation exports.

The catalog is the single source of truth the whole tenant-model
contract hangs off: DISCOVER admissibility reads sovereignty tags and
base-model bindings from here, PREPARE fails fast on unknown ids, the
federation capability digest advertises ``keys()``, and engines load
weights from ``weights()``.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

#: the one target-matrix set supported today: the post-final-norm
#: hidden state feeding the LM head
ADAPTER_TARGET = "hidden"

DEFAULT_REGIONS = ("eu", "us", "apac")


def version_key(version: str):
    """Numeric-aware sort key so "10.0" outranks "9.0" (lexicographic
    string sort gets this wrong)."""
    parts = []
    for p in str(version).split("."):
        parts.append((0, int(p), "") if p.isdigit() else (1, 0, p))
    return tuple(parts)


def weight_fingerprint(a: np.ndarray, b: np.ndarray) -> str:
    h = hashlib.sha256()
    h.update(np.ascontiguousarray(np.asarray(a, np.float32)).tobytes())
    h.update(np.ascontiguousarray(np.asarray(b, np.float32)).tobytes())
    return h.hexdigest()[:16]


@dataclass(frozen=True)
class AdapterSpec:
    """Immutable descriptor of one versioned tenant adapter."""

    adapter_id: str
    version: str
    base_model_id: str
    base_model_version: str
    rank: int
    target: str = ADAPTER_TARGET
    #: sovereignty tags — the adapter may only be anchored at sites in
    #: these regions (tenant weights can carry their own residency law)
    regions: Tuple[str, ...] = DEFAULT_REGIONS
    scale: float = 1.0
    seed: int = 0
    weight_fingerprint: str = ""

    @property
    def key(self) -> str:
        return f"{self.adapter_id}@{self.version}"

    def base_key(self) -> str:
        return f"{self.base_model_id}@{self.base_model_version}"


def init_adapter_weights(spec: AdapterSpec, d_model: int):
    """Deterministic A/B weights for a spec (stand-in for a tenant
    upload; same spec always materialises bit-identical weights, so
    fingerprints agree across domains)."""
    if spec.rank < 1:
        raise ValueError(f"adapter rank must be >= 1, got {spec.rank}")
    seed = int.from_bytes(
        hashlib.sha256(spec.key.encode()).digest()[:8], "little"
    ) ^ (spec.seed & 0xFFFFFFFF)
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((d_model, spec.rank)).astype(np.float32)
    a *= 1.0 / np.sqrt(d_model)
    b = rng.standard_normal((spec.rank, d_model)).astype(np.float32)
    b *= spec.scale * 0.05 / np.sqrt(spec.rank)
    return a, b


class AdapterCatalog:
    """Registry of versioned adapters keyed ``adapter_id@version``."""

    def __init__(self) -> None:
        self._entries: Dict[str, AdapterSpec] = {}
        self._weights: Dict[str, Tuple[np.ndarray, np.ndarray]] = {}
        #: control-plane record of which sites hold each adapter hot
        self._loaded_at: Dict[str, Set[str]] = {}

    def register(
        self,
        spec: AdapterSpec,
        weights: Optional[Tuple[np.ndarray, np.ndarray]] = None,
        *,
        d_model: Optional[int] = None,
    ) -> AdapterSpec:
        """Register a spec with explicit weights, or materialise
        deterministic ones from the seed when ``d_model`` is given.
        Returns the stored spec with its weight fingerprint filled in.
        """
        if spec.key in self._entries:
            raise ValueError(f"duplicate adapter {spec.key}")
        if spec.target != ADAPTER_TARGET:
            raise ValueError(f"unsupported adapter target {spec.target!r}")
        if weights is None:
            if d_model is None:
                raise ValueError("register needs weights or d_model")
            weights = init_adapter_weights(spec, d_model)
        a = np.asarray(weights[0], np.float32)
        b = np.asarray(weights[1], np.float32)
        if a.shape[1] != spec.rank or b.shape[0] != spec.rank:
            raise ValueError(
                f"weights rank {a.shape[1]}x{b.shape[0]} != spec rank {spec.rank}"
            )
        stored = replace(spec, weight_fingerprint=weight_fingerprint(a, b))
        self._entries[stored.key] = stored
        self._weights[stored.key] = (a, b)
        self._loaded_at[stored.key] = set()
        return stored

    def get(self, adapter_id: str, version: Optional[str] = None) -> AdapterSpec:
        """Resolve an adapter, deterministically picking the highest
        registered version when none is pinned."""
        if version:
            return self._entries[f"{adapter_id}@{version}"]
        matches = [
            e for e in self._entries.values() if e.adapter_id == adapter_id
        ]
        if not matches:
            raise KeyError(adapter_id)
        return sorted(matches, key=lambda e: version_key(e.version))[-1]

    def has(self, adapter_id: str, version: Optional[str] = None) -> bool:
        try:
            self.get(adapter_id, version)
            return True
        except KeyError:
            return False

    def weights(
        self, adapter_id: str, version: Optional[str] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        return self._weights[self.get(adapter_id, version).key]

    def keys(self) -> List[str]:
        return sorted(self._entries)

    def entries(self) -> List[AdapterSpec]:
        return [self._entries[k] for k in self.keys()]

    def for_base(self, model_id: str) -> List[AdapterSpec]:
        return [e for e in self.entries() if e.base_model_id == model_id]

    # -- control-plane load bookkeeping (data plane lives in runtime) --

    def mark_loaded(self, adapter_id: str, site_id: str) -> None:
        self._loaded_at[self.get(adapter_id).key].add(site_id)

    def mark_unloaded(self, adapter_id: str, site_id: str) -> None:
        self._loaded_at[self.get(adapter_id).key].discard(site_id)

    def loaded_sites(self, adapter_id: str) -> Tuple[str, ...]:
        return tuple(sorted(self._loaded_at[self.get(adapter_id).key]))
