"""Engine-side adapter runtime (data plane).

Loaded adapters live in two stacked device tables, ``A: [E, d, r]``
and ``B: [E, r, d]``, where row 0 is the null adapter (all zeros, so
its delta is exactly zero and base sessions are bit-identical to an
adapter-free engine). Each engine slot carries an int32 index into
the tables; the fused K-step decode scan gathers rows per slot.

Two token-identical routes compute the batched delta:

- ``gather``: per-row gather + f32 einsum (XLA fallback, default off
  TPU — interpret-mode Pallas in the hot scan would dominate).
- ``grouped``: slots grouped by adapter index and pushed through the
  Pallas ``moe_gemm`` kernel — the exact MoE dispatch shape with
  "slots grouped by adapter" standing in for "tokens grouped by
  expert". Empty groups and ragged capacities fall out of the same
  padding discipline the MoE path uses.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.moe_gemm.ops import grouped_gemm


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def lora_apply_rows(h, a, b):
    """Delta for one adapter applied to every row of ``h: [b, d]``
    (prefill path — the whole batch shares one adapter)."""
    hf = h.astype(jnp.float32)
    t = hf @ a.astype(jnp.float32)
    return (t @ b.astype(jnp.float32)).astype(h.dtype)


def _delta_gather(h, A, B, idx):
    hf = h.astype(jnp.float32)
    a = A[idx].astype(jnp.float32)          # [b, d, r]
    b = B[idx].astype(jnp.float32)          # [b, r, d]
    t = jnp.einsum("bd,bdr->br", hf, a)
    return jnp.einsum("br,brd->bd", t, b).astype(h.dtype)


def _delta_grouped(h, A, B, idx):
    n, _ = h.shape
    E = A.shape[0]
    order = jnp.argsort(idx)                # stable: groups stay contiguous
    sidx = idx[order]
    # position of each row within its adapter group: offset from the
    # first occurrence of its index in the sorted vector
    start = jnp.searchsorted(sidx, sidx, side="left")
    pos = jnp.arange(n) - start
    # scatter rows into the [E, C, D] expert layout; capacity = n is
    # always enough (each slot maps to exactly one adapter), unused
    # (e, c) cells stay zero
    xg = jnp.zeros((E, n, h.shape[1]), jnp.float32)
    xg = xg.at[sidx, pos].set(h[order].astype(jnp.float32))
    t = grouped_gemm(xg, A.astype(jnp.float32),
                     block_c=128, block_f=128)        # [E, C, r]
    y = grouped_gemm(t, B.astype(jnp.float32),
                     block_c=128, block_f=128)        # [E, C, d]
    delta = y[sidx, pos]                    # back to sorted row order
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(n))
    return delta[inv].astype(h.dtype)


def lora_delta(h, A, B, idx, *, route: str = "gather"):
    """Batched per-row adapter delta for ``h: [b, d]`` under the
    per-slot int32 table ``idx: [b]``. Rows with index 0 get an exact
    zero delta."""
    if route == "grouped":
        return _delta_grouped(h, A, B, idx)
    return _delta_gather(h, A, B, idx)


class AdapterRuntime:
    """Mutable device tables for one engine.

    ``max_adapters`` tenant adapters share the table on top of the
    reserved null row. Adapters of smaller rank are zero-padded up to
    the table rank, which changes nothing numerically (extra columns
    of A meet extra zero rows of B).
    """

    def __init__(self, d_model: int, *, max_adapters: int = 8,
                 rank: int = 8, route: str = "auto") -> None:
        if route == "auto":
            route = "grouped" if _on_tpu() else "gather"
        if route not in ("gather", "grouped"):
            raise ValueError(f"unknown adapter route {route!r}")
        self.d_model = int(d_model)
        self.rank = int(rank)
        self.max_adapters = int(max_adapters)
        self.route = route
        E = self.max_adapters + 1
        self.A = jnp.zeros((E, self.d_model, self.rank), jnp.float32)
        self.B = jnp.zeros((E, self.rank, self.d_model), jnp.float32)
        self._index: Dict[str, int] = {}
        self._free: List[int] = list(range(1, E))

    def _fit(self, w: np.ndarray, shape: Tuple[int, int]) -> np.ndarray:
        w = np.asarray(w, np.float32)
        if w.shape[0] > shape[0] or w.shape[1] > shape[1]:
            raise ValueError(
                f"adapter weights {w.shape} exceed table shape {shape}")
        out = np.zeros(shape, np.float32)
        out[: w.shape[0], : w.shape[1]] = w
        return out

    def load(self, adapter_id: str, a, b) -> int:
        """Install weights for ``adapter_id``; idempotent. Returns the
        table index slots reference."""
        if adapter_id in self._index:
            return self._index[adapter_id]
        if not self._free:
            raise RuntimeError(
                f"adapter table full ({self.max_adapters} loaded)")
        a = self._fit(a, (self.d_model, self.rank))
        b = self._fit(b, (self.rank, self.d_model))
        idx = self._free.pop(0)
        self.A = self.A.at[idx].set(a)
        self.B = self.B.at[idx].set(b)
        self._index[adapter_id] = idx
        return idx

    def unload(self, adapter_id: str) -> None:
        idx = self._index.pop(adapter_id)    # KeyError if not loaded
        self.A = self.A.at[idx].set(0.0)
        self.B = self.B.at[idx].set(0.0)
        self._free.insert(0, idx)

    def index_of(self, adapter_id: str) -> int:
        """Table index for a session's adapter ("" means none)."""
        if not adapter_id:
            return 0
        if adapter_id not in self._index:
            raise KeyError(adapter_id)
        return self._index[adapter_id]

    def is_loaded(self, adapter_id: str) -> bool:
        return adapter_id in self._index

    def loaded(self) -> Tuple[str, ...]:
        return tuple(sorted(self._index))
