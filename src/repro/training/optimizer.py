"""AdamW in raw JAX (no optax dependency), ZeRO-friendly.

Optimizer state tensors (m, v) are f32 pytrees shaped like the params, so
the planner's FSDP param specs apply verbatim — GSPMD shards the optimizer
update with zero extra code (ZeRO-3 semantics fall out of sharding).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWHyper(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def lr_at(h: AdamWHyper, step):
    """Linear warmup then cosine decay to 10%."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(h.warmup_steps, 1))
    prog = jnp.clip((step - h.warmup_steps)
                    / max(h.total_steps - h.warmup_steps, 1), 0.0, 1.0)
    cos = 0.1 + 0.9 * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return h.lr * warm * cos


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), gn


def adamw_update(grads, opt_state, params, h: AdamWHyper):
    """One AdamW step. grads/params f32 pytrees. Returns (params, state, gn)."""
    grads, gn = clip_by_global_norm(grads, h.grad_clip)
    step = opt_state["step"] + 1
    lr = lr_at(h, step)
    b1c = 1.0 - h.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - h.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = h.b1 * m + (1 - h.b1) * g
        v = h.b2 * v + (1 - h.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + h.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + h.weight_decay * p
        return p - lr * delta, m, v

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, gn
