"""Sharded checkpointing with restart semantics.

Layout:  <dir>/step_<k>/
            manifest.json       — tree structure, shapes, dtypes, hashes,
                                  data cursor, mesh/plan fingerprint
            shard_<host>.npz    — this host's param/opt leaves (local shards)

On a real multi-host pod each host writes only its addressable shards; on
this CPU container there is one host, but the format and the restore path
(including integrity verification and *elastic* restore onto a different
mesh) are the production ones. Restore is lazy-resharding: leaves are loaded
as numpy then device_put with the *new* plan's shardings, so a job restarted
on a degraded device set (see ``fault_tolerance.remesh``) comes back bit-
identical modulo placement.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            elif hasattr(k, "name"):
                parts.append(str(k.name))
        out["/".join(parts)] = leaf
    return out


def save(directory: str, step: int, tree, *, extra: dict | None = None,
         host_id: int = 0):
    """Write one checkpoint. Atomic: writes to .tmp then renames."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in leaves.items()}
    shard_path = os.path.join(tmp, f"shard_{host_id}.npz")
    np.savez(shard_path, **arrays)
    digest = hashlib.sha256(open(shard_path, "rb").read()).hexdigest()
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(np.shape(v)), "dtype": str(np.asarray(v).dtype)}
                   for k, v in arrays.items()},
        "shards": {str(host_id): {"file": f"shard_{host_id}.npz",
                                  "sha256": digest}},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(directory: str, step: int, tree_like, *, shardings=None,
            host_id: int = 0):
    """Load a checkpoint into the structure of ``tree_like``.

    ``shardings``: optional pytree of NamedSharding for elastic restore onto
    a (possibly different) mesh; leaves are device_put accordingly.
    Raises on hash mismatch or structural drift (diagnosable failure,
    Eq. 12 "state transfer failure" class).
    """
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    shard = manifest["shards"][str(host_id)]
    path = os.path.join(d, shard["file"])
    digest = hashlib.sha256(open(path, "rb").read()).hexdigest()
    if digest != shard["sha256"]:
        raise IOError(f"checkpoint shard corrupt: {path}")
    data = np.load(path)
    leaves = _flatten(tree_like)
    missing = set(leaves) - set(data.files)
    if missing:
        raise ValueError(f"checkpoint missing leaves: {sorted(missing)[:5]}")
    flat_shardings = _flatten(shardings) if shardings is not None else {}

    restored = {}
    for k, like in leaves.items():
        arr = data[k]
        want = tuple(np.shape(like))
        if tuple(arr.shape) != want:
            raise ValueError(f"{k}: shape {arr.shape} != expected {want}")
        if k in flat_shardings:
            restored[k] = jax.device_put(arr, flat_shardings[k])
        else:
            restored[k] = jax.numpy.asarray(arr, dtype=like.dtype)

    flat = jax.tree_util.tree_flatten_with_path(tree_like)
    ordered = []
    for kp, _ in flat[0]:
        parts = []
        for kk in kp:
            if hasattr(kk, "key"):
                parts.append(str(kk.key))
            elif hasattr(kk, "idx"):
                parts.append(str(kk.idx))
            elif hasattr(kk, "name"):
                parts.append(str(kk.name))
        ordered.append(restored["/".join(parts)])
    return jax.tree_util.tree_unflatten(flat[1], ordered), manifest["extra"]
