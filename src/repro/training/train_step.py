"""Train step: remat + microbatched grad accumulation + AdamW.

Master params live in f32; matrix leaves are cast to the model compute dtype
(bf16) inside the loss. Gradient accumulation runs as a ``lax.scan`` over
microbatches (the planner picks the count so per-device checkpointed
residuals fit HBM), which also gives XLA a window to overlap the data-
parallel reduce of microbatch k with the compute of k+1.

Optional int8 gradient compression (error feedback) hooks in before the
optimizer — see ``repro.training.compression``.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import LM
from repro.training.optimizer import AdamWHyper, adamw_init, adamw_update
from repro.training import compression as comp


class TrainState(NamedTuple):
    params: Any          # f32 master weights
    opt: Dict[str, Any]  # m, v, step
    ef: Optional[Any] = None   # error-feedback residual (compression)


def _to_master(params):
    return jax.tree.map(lambda p: p.astype(jnp.float32), params)


def _to_compute(params, dtype):
    return jax.tree.map(
        lambda p: p.astype(dtype) if p.ndim >= 2 else p, params)


def init_train_state(lm: LM, key, *, compress: bool = False) -> TrainState:
    params = _to_master(lm.init(key))
    ef = jax.tree.map(jnp.zeros_like, params) if compress else None
    return TrainState(params=params, opt=adamw_init(params), ef=ef)


def abstract_train_state(lm: LM, *, compress: bool = False) -> TrainState:
    """ShapeDtypeStruct train state (for dry-run lowering)."""
    return jax.eval_shape(
        functools.partial(init_train_state, lm, compress=compress),
        jax.random.key(0))


def train_state_specs(plan, state: TrainState):
    """PartitionSpecs for the full train state from the param plan."""
    pspec = plan.param_specs
    return TrainState(
        params=pspec,
        opt={"m": pspec, "v": pspec,
             "step": jax.sharding.PartitionSpec()},
        ef=pspec if state.ef is not None else None)


def make_train_step(lm: LM, *, hyper: AdamWHyper = AdamWHyper(),
                    microbatches: int = 1, compress: bool = False,
                    compute_dtype=jnp.bfloat16):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params_f32, mb):
        p = _to_compute(params_f32, compute_dtype)
        loss, metrics = lm.loss(p, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def one_microbatch(params, mb):
        (loss, metrics), grads = grad_fn(params, mb)
        return grads, loss, metrics

    def train_step(state: TrainState, batch):
        params = state.params
        if microbatches > 1:
            def resh(x):
                b = x.shape[0]
                return x.reshape((microbatches, b // microbatches) + x.shape[1:])

            mbs = jax.tree.map(resh, batch)

            def acc_step(carry, mb):
                g_acc, l_acc = carry
                grads, loss, _ = one_microbatch(params, mb)
                g_acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                     g_acc, grads)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(acc_step, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
        else:
            grads, loss, _ = one_microbatch(params, batch)

        ef = state.ef
        if compress and ef is not None:
            grads, ef = comp.compress_tree(grads, ef)

        new_params, opt, gn = adamw_update(grads, state.opt, params, hyper)
        metrics = {"loss": loss, "grad_norm": gn,
                   "step": opt["step"].astype(jnp.float32)}
        return TrainState(new_params, opt, ef), metrics

    return train_step
