"""Synthetic deterministic data pipeline.

Generates a Zipf-distributed token stream with document structure (BOS/EOS,
repeated n-grams so the loss actually decreases), sharded by host: each data-
parallel worker draws a disjoint seed stream, and the iterator is resumable
from (epoch, step) — the checkpoint records the cursor so a restarted job
sees the exact same batches (fault-tolerance requirement R-restart).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    ngram_order: int = 3


class SyntheticLMStream:
    """Deterministic, resumable synthetic LM batches."""

    def __init__(self, cfg: DataConfig, *, host_id: int = 0, num_hosts: int = 1,
                 start_step: int = 0):
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.step = start_step
        if cfg.global_batch % num_hosts:
            raise ValueError("global_batch must divide across hosts")
        self._batch_per_host = cfg.global_batch // num_hosts
        # fixed n-gram transition table makes the stream learnable
        rng = np.random.default_rng(cfg.seed)
        self._table = rng.integers(0, cfg.vocab_size,
                                   size=(997,), dtype=np.int64)

    def _rng_for(self, step: int):
        return np.random.default_rng(
            (self.cfg.seed * 1_000_003 + step) * 4099 + self.host_id)

    def next_batch(self):
        cfg = self.cfg
        rng = self._rng_for(self.step)
        b, s = self._batch_per_host, cfg.seq_len
        # zipf base stream
        z = rng.zipf(cfg.zipf_a, size=(b, s)).astype(np.int64)
        toks = z % cfg.vocab_size
        # inject learnable n-gram structure: next token often table[h(prev)]
        h = np.zeros((b,), np.int64)
        for t in range(s):
            follow = rng.random(b) < 0.5
            toks[:, t] = np.where(follow, self._table[h % 997], toks[:, t])
            h = h * 31 + toks[:, t]
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = -1
        self.step += 1
        return {"tokens": toks.astype(np.int32),
                "labels": labels.astype(np.int32)}

    def state(self):
        return {"step": self.step, "host_id": self.host_id}
