"""Fault tolerance for 1000+-node operation (design + simulatable logic).

Mechanisms (all exercised by tests on the CPU container):

* **Checkpoint/restart** — ``repro.training.checkpoint`` + the resumable data
  cursor give deterministic restart; the train loop in
  ``repro.launch.train`` wires heartbeats + periodic saves.
* **Elastic re-mesh** — ``remesh_after_failure``: given the surviving device
  list, choose the largest (data × model) grid that preserves the model-
  parallel degree, rebuild the plan, and restore the latest checkpoint onto
  it (GSPMD handles the re-sharding at device_put).
* **Straggler mitigation** — ``StragglerPolicy``: per-step deadline derived
  from a running p95 of step times; a worker exceeding it is marked suspect,
  and after ``strikes`` consecutive deadline misses the controller triggers
  re-mesh without it (training) — serving-side straggler handling lives in
  the QoS scheduler (``repro.serving.scheduler``) as deadline-aware batch
  cutoffs.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class StragglerPolicy:
    """Deadline-based straggler detection over step-time telemetry."""
    factor: float = 1.8          # deadline = factor * running p95
    strikes_to_evict: int = 3
    window: int = 50
    _times: list = field(default_factory=list)
    _strikes: dict = field(default_factory=dict)

    def deadline(self) -> float:
        if len(self._times) < 5:
            return float("inf")
        return self.factor * float(np.percentile(self._times[-self.window:], 95))

    def observe(self, worker: str, step_time: float) -> str:
        """Returns 'ok' | 'suspect' | 'evict'."""
        dl = self.deadline()
        self._times.append(step_time)
        if step_time <= dl:
            self._strikes[worker] = 0
            return "ok"
        self._strikes[worker] = self._strikes.get(worker, 0) + 1
        if self._strikes[worker] >= self.strikes_to_evict:
            return "evict"
        return "suspect"


def largest_grid(n_devices: int, model_degree: int) -> tuple[int, int]:
    """Largest (data, model) grid with fixed model degree fitting n devices."""
    if n_devices < model_degree:
        raise ValueError("fewer devices than the model-parallel degree")
    data = n_devices // model_degree
    return data, model_degree


def remesh_after_failure(all_devices, failed_ids, model_degree: int):
    """Pick survivors and the new mesh shape after a failure event.

    Returns (devices_kept, (data, model)). Devices beyond the largest full
    grid are spares (kept warm for the next failure).
    """
    survivors = [d for d in all_devices if getattr(d, "id", d) not in failed_ids]
    data, model = largest_grid(len(survivors), model_degree)
    keep = survivors[: data * model]
    return keep, (data, model)
