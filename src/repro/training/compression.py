"""Gradient compression for the data-parallel reduce: int8 quantisation with
error feedback (1-bit-Adam-style residual correction, arXiv:2102.02888 family).

At 1000+ node scale the DP all-reduce of f32 grads dominates the step's
collective term (EXPERIMENTS.md §Roofline); int8 with per-tensor scales cuts
the wire bytes 4× while error feedback keeps convergence (tested in
tests/test_compression.py by training a quadratic + the tiny LM).

The quantise→dequantise pair runs *inside* the jitted step, before the grads
feed AdamW; under GSPMD the all-reduce then moves int8. ``compress_tree`` is
the public hook used by ``make_train_step(compress=True)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x, bits: int = 8):
    """Symmetric per-tensor int quantisation. Returns (q, scale)."""
    qmax = float(2 ** (bits - 1) - 1)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax / qmax, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_leaf(g, ef):
    """Error-feedback compression of one gradient leaf."""
    g = g.astype(jnp.float32) + ef
    if g.ndim < 2:          # tiny leaves: not worth compressing
        return g, jnp.zeros_like(g)
    q, scale = quantize(g)
    deq = dequantize(q, scale)
    return deq, g - deq


def compress_tree(grads, ef_tree):
    out = jax.tree.map(compress_leaf, grads, ef_tree)
    grads_c = jax.tree.map(lambda t: t[0], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    ef_new = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return grads_c, ef_new
