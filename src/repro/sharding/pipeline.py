"""Pipeline parallelism (GPipe-style) over a ``pipe`` mesh axis.

The assigned 40-cell baseline uses DP×TP (+pod); PP is provided for the
1000+-node regime where a model's layers exceed one pod's HBM even at full
TP — stages shard the layer stack, microbatches stream through
``jax.lax.ppermute`` boundaries inside ``shard_map``, and the bubble is the
usual (S−1)/(S−1+M).

Tested on small forced-host meshes in tests/test_pipeline.py; compose with
the planner by carving ``pipe`` out of the ``data`` axis:
    mesh = Mesh(devs.reshape(pipe, data, model), ("pipe", "data", "model")).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_forward(stage_fn: Callable, mesh: Mesh, *, num_microbatches: int,
                     axis: str = "pipe"):
    """Build a pipelined forward: x -> stages applied in sequence.

    ``stage_fn(stage_params, x)`` applies ONE stage's layers. Stage params
    are sharded over ``axis`` (leading dim = num_stages); activations flow
    stage-to-stage with ppermute. Returns f(stage_params, x) with x
    microbatched on the leading dim.
    """
    n_stages = mesh.shape[axis]

    def pipelined(stage_params, x):
        # x: [M, mb, ...] microbatches, replicated across the pipe axis
        M = x.shape[0]
        steps = M + n_stages - 1

        def body(params_local, xs):
            # shard_map keeps the sharded stage dim as size 1 — squeeze it
            params_local = jax.tree.map(lambda p: p[0], params_local)
            idx = jax.lax.axis_index(axis)

            def step(carry, t):
                buf, outs = carry
                # stage 0 injects microbatch t; others take the permuted buf
                mb = jnp.where(t < M, t, M - 1)
                inject = xs[mb]
                cur = jnp.where(idx == 0, inject, buf)
                cur = stage_fn(params_local, cur)
                # push to the next stage
                nxt = jax.lax.ppermute(
                    cur, axis,
                    [(i, (i + 1) % n_stages) for i in range(n_stages)])
                # last stage records its output for microbatch t-(S-1)
                out_t = t - (n_stages - 1)
                valid = (idx == n_stages - 1) & (out_t >= 0) & (out_t < M)
                outs = jax.lax.cond(
                    valid,
                    lambda o: o.at[jnp.clip(out_t, 0, M - 1)].set(cur),
                    lambda o: o, outs)
                return (nxt, outs), None

            buf0 = jnp.zeros_like(xs[0])
            outs0 = jnp.zeros_like(xs)
            (_, outs), _ = jax.lax.scan(step, (buf0, outs0),
                                        jnp.arange(steps))
            # broadcast the last stage's outputs to every pipe rank
            # (psum of the masked buffer: only the last stage contributes)
            outs = jnp.where(idx == n_stages - 1, outs, 0.0)
            return jax.lax.psum(outs, axis)

        return shard_map(
            body, mesh=mesh,
            in_specs=(P(axis), P()),          # stage params sharded, x replicated
            out_specs=P(),
            check_rep=False,
        )(stage_params, x)

    return pipelined


def stage_params_from_stack(stacked, n_stages: int):
    """Reshape layer-stacked params [L, ...] into [S, L/S, ...] stages."""
    return jax.tree.map(
        lambda p: p.reshape((n_stages, p.shape[0] // n_stages) + p.shape[1:]),
        stacked)
