"""Ambient-mesh activation sharding constraints (MaxText-style logical axes).

Model code calls ``constrain(x, "dp", None, "model", ...)`` at key points;
under a ``with mesh:`` lowering context this pins the activation layout so
GSPMD cannot drift into batch-replicated layouts inside scan bodies (observed
failure mode: 25 GB/device of batch-replicated attention residuals — see
EXPERIMENTS.md §Perf iteration 0). Outside any mesh (CPU smoke tests) it is
an identity, keeping the model code mesh-agnostic.

Dim tokens:
    "dp"    — shard over the data-parallel axes (pod+data) if divisible
    "model" — shard over the model axis if divisible
    None    — leave unsharded
"""

from __future__ import annotations

import numpy as np

import jax
from jax.interpreters import pxla
from jax.sharding import PartitionSpec as P


def current_mesh():
    m = pxla.thread_resources.env.physical_mesh
    return None if m.empty else m


def dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def constrain(x, *dims):
    mesh = current_mesh()
    if mesh is None:
        return x
    if len(dims) != x.ndim:
        raise ValueError(f"constrain: {len(dims)} dims for rank-{x.ndim}")
    axes = mesh.axis_names
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    spec = []
    for i, d in enumerate(dims):
        if d == "dp" and dp and x.shape[i] % dp_size == 0:
            spec.append(dp if len(dp) > 1 else dp[0])
        elif d == "model" and "model" in axes and \
                x.shape[i] % mesh.shape["model"] == 0:
            spec.append("model")
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))
