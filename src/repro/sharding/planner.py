"""Sharding planner: logical-axis rules → PartitionSpecs per (arch × step).

Parallelism mapping (DESIGN.md §5):

* ``data`` (and ``pod`` when multi-pod) — data parallelism; for training the
  params/optimizer are additionally sharded over ``data`` (FSDP/ZeRO-3 via
  GSPMD).
* ``model`` — tensor parallelism: attention heads / d_ff / vocab when the
  dimension divides the axis; expert parallelism for MoE when the expert
  count divides; otherwise divisibility-aware fallbacks (e.g. sequence-
  sharded KV caches → distributed flash-decode softmax).

The planner only states *intent* at function boundaries; GSPMD materialises
the collectives. The roofline pass (EXPERIMENTS.md §Roofline) reads the
result off the compiled HLO, and §Perf iterates on these rules.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def data_axes(mesh: Mesh):
    """The batch-sharding axis (pod+data when multi-pod)."""
    if "pod" in mesh.axis_names:
        return ("pod", "data")
    return ("data",)


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        return int(np.prod([mesh.shape[n] for n in name]))
    return mesh.shape[name]


def _div(n: int, mesh: Mesh, axis) -> bool:
    return n > 0 and n % _axis_size(mesh, axis) == 0


def _fit_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop any sharded dim whose size doesn't divide its mesh axes —
    jit in_shardings require exact divisibility (no implicit padding)."""
    dims = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for n, d in zip(shape, dims):
        if d is None:
            out.append(None)
        elif n % _axis_size(mesh, d) == 0:
            out.append(d)
        else:
            out.append(None)
    return P(*out)


@dataclass
class ShardingPlan:
    mesh: Mesh
    cfg: ModelConfig
    step_kind: str                       # train | prefill | decode
    param_specs: Any = None              # pytree of PartitionSpec
    batch_specs: Any = None              # dict of PartitionSpec
    cache_specs: Any = None              # pytree of PartitionSpec (decode)
    microbatches: int = 1
    notes: list = field(default_factory=list)

    def shardings(self, tree_specs):
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), tree_specs,
            is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

def _param_rule(path: str, shape, cfg: ModelConfig, mesh: Mesh, train: bool,
                notes: list, fsdp=None) -> P:
    """Choose a PartitionSpec for one param leaf.

    ``path`` is the '/'-joined key path; leading 'layers' dims are the scan
    stack and always unsharded. ``fsdp``: extra axis to shard params over
    (training ZeRO-3, or weight-gathered serving for models too big for
    model-axis shards alone).
    """
    nd = len(shape)
    # hybrid 'layers' is a tuple of per-layer dicts — leaves are NOT stacked
    stacked = ((path.startswith("enc_layers")
                or (path.startswith("layers") and cfg.family != "hybrid"))
               and nd >= 2)

    def spec(*dims):
        if stacked:
            return P(None, *dims)
        return P(*dims)

    core = shape[1:] if stacked else shape
    parts = path.split("/")
    name = parts[-1]
    if name in ("q", "s") and len(parts) >= 2:
        # int8 weight-only serving: {q, s} inherit the base weight's rule
        # (_fit_spec drops axes the size-1 scale dims can't take)
        name = parts[-2]

    # --- embeddings / unembeddings ------------------------------------
    if name == "embed":
        # vocab-sharded ONLY: a (model, data) 2-D sharding makes the token
        # gather un-partitionable (XLA "involuntary full rematerialization",
        # ~25 GB/device observed) — vocab sharding keeps the gather local
        # per shard with a small all-reduce combine. Serving may trade the
        # per-step [b,s,d] all-reduce for a replicated table (hillclimb
        # lever: serve_embed_replicated).
        if not train and cfg.serve_embed_replicated and not cfg.tie_embeddings:
            return P(None, None)
        return P("model", None)           # [V, d]
    if name == "lm_head":
        return P(fsdp, "model")           # [d, V]
    if name in ("adapter", "vision_adapter"):
        return P(fsdp, None)

    # --- MoE experts ------------------------------------------------------
    if name in ("w_gate", "w_up", "w_down") and len(core) == 3:
        E = core[0]
        if _div(E, mesh, "model"):        # expert parallelism
            return spec("model", fsdp, None)
        notes.append(f"{path}: E={E} not divisible by model axis; "
                     f"falling back to expert-TP over d_ff")
        if name == "w_down":              # [E, f, d]
            return spec(None, "model", fsdp)
        return spec(None, fsdp, "model")  # [E, d, f]
    if name == "router":
        return spec(fsdp, None)

    # --- attention projections -------------------------------------------
    if name in ("w_q", "w_k", "w_v"):
        # out dim is heads*hd; shard by model when the head count divides,
        # otherwise shard the d_model INPUT dim (weights stay distributed;
        # GSPMD inserts a partial-sum all-reduce on the projection output)
        heads = cfg.num_heads if name == "w_q" else cfg.num_kv_heads
        if _div(heads, mesh, "model"):
            return spec(fsdp, "model")
        if f"{name}: head-count fallback" not in " ".join(notes):
            notes.append(f"{name}: head-count fallback — {heads} heads not "
                         f"divisible by model axis; sharding d_model input dim")
        return spec("model", None)
    if name == "w_o":
        if _div(cfg.num_heads, mesh, "model"):
            return spec("model", fsdp)
        return spec(None, "model")

    # --- dense MLP ----------------------------------------------------------
    if name in ("w_gate", "w_up"):        # [d, f]
        return spec(fsdp, "model")
    if name == "w_down":                  # [f, d]
        return spec("model", fsdp)

    # --- SSM -----------------------------------------------------------------
    if name == "in_proj":                 # [d, 2di+2gn+nh]
        return spec(fsdp, "model")
    if name == "out_proj":                # [di, d]
        return spec("model", fsdp)
    if name == "conv":                    # [K, conv_dim]
        return spec(None, "model")

    # --- RG-LRU ---------------------------------------------------------------
    if name in ("w_x",):                  # [d, w]
        return spec(fsdp, "model")
    if name == "w_out":                   # [w, d]
        return spec("model", fsdp)
    if name == "lambda":
        return spec("model")
    if name in ("gate_a", "gate_i"):      # [nb, bs, bs]
        if _div(core[0], mesh, "model"):
            return spec("model", None, None)
        return spec(None, None, None)

    # --- 1-D / small leaves (norms, biases, A_log, D, dt_bias) --------------
    return spec(*([None] * len(core)))


def param_plan(cfg: ModelConfig, param_tree, mesh: Mesh, *, train: bool,
               notes: list, serve_fsdp: bool = False):
    """Map a param pytree (arrays or ShapeDtypeStructs) to PartitionSpecs.

    ``serve_fsdp``: weight-gathered serving — when bf16 weights / model-axis
    shards exceed the per-chip HBM budget (e.g. qwen2-vl-72b: 9 GB/chip at
    TP16), params additionally shard over the data axes and GSPMD gathers
    them per layer. Memory-correct baseline; the collective cost shows up in
    §Roofline and is hillclimb material.
    """
    fsdp = None
    if train:
        fsdp = "data"
    elif serve_fsdp:
        fsdp = data_axes(mesh) if len(data_axes(mesh)) > 1 else "data"
    flat = jax.tree_util.tree_flatten_with_path(param_tree)[0]

    def path_str(kp):
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
        return "/".join(parts)

    specs = {}
    for kp, leaf in flat:
        raw = _param_rule(path_str(kp), leaf.shape, cfg, mesh, train, notes,
                          fsdp=fsdp)
        specs[path_str(kp)] = _fit_spec(raw, leaf.shape, mesh)
    treedef = jax.tree_util.tree_structure(param_tree)
    ordered = [specs[path_str(kp)] for kp, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, ordered)


# ---------------------------------------------------------------------------
# cache rules (decode state)
# ---------------------------------------------------------------------------

def cache_plan(cfg: ModelConfig, cache_tree, mesh: Mesh, batch: int,
               notes: list):
    dp = data_axes(mesh)
    dp_ok = batch % _axis_size(mesh, dp) == 0

    def rule(path: str, shape) -> P:
        name = path.split("/")[-1]
        if name == "pos":
            return P()
        bdim = P(dp) if dp_ok else P(None)
        stacked = path.startswith("layers") and not cfg.family == "hybrid"
        # KV buffers: [L, b, S, kh, hd] (stacked) or [b, S, kh, hd] (hybrid)
        if name in ("k", "v", "cross_k", "cross_v"):
            kh = cfg.num_kv_heads
            core = shape[1:] if (stacked or name.startswith("cross")) else shape
            want_heads = (cfg.kv_shard == "heads"
                          or (cfg.kv_shard == "auto"
                              and _div(kh, mesh, "model")))
            if want_heads and _div(kh, mesh, "model"):
                spec = (bdim[0] if dp_ok else None, None, "model", None)
            else:
                # sequence-sharded KV → distributed decode softmax
                spec = (bdim[0] if dp_ok else None, "model", None, None)
                if "seq-sharded KV" not in " ".join(notes):
                    notes.append(f"kv_heads={kh} not divisible by model axis; "
                                 f"sequence-sharded KV cache")
            if stacked or name.startswith("cross"):
                return P(None, *spec)
            return P(*spec)
        if name == "ssm":                  # [L, b, nh, hp, n]
            nh = cfg.ssm_nheads
            tail = ("model", None, None) if _div(nh, mesh, "model") else (None, None, None)
            return P(None, bdim[0] if dp_ok else None, *tail)
        if name == "conv":                 # [L, b, K-1, cd] or [b, K-1, w]
            w = shape[-1]
            tail = "model" if _div(w, mesh, "model") else None
            if cfg.family == "hybrid":
                return P(bdim[0] if dp_ok else None, None, tail)
            return P(None, bdim[0] if dp_ok else None, None, tail)
        if name == "h":                    # [b, w] (hybrid RG-LRU state)
            w = shape[-1]
            tail = "model" if _div(w, mesh, "model") else None
            return P(bdim[0] if dp_ok else None, tail)
        return P(*([None] * len(shape)))

    flat = jax.tree_util.tree_flatten_with_path(cache_tree)[0]

    def path_str(kp):
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
        return "/".join(parts)

    ordered = [_fit_spec(rule(path_str(kp), leaf.shape), leaf.shape, mesh)
               for kp, leaf in flat]
    treedef = jax.tree_util.tree_structure(cache_tree)
    return jax.tree_util.tree_unflatten(treedef, ordered)


# ---------------------------------------------------------------------------
# batch rules + microbatching
# ---------------------------------------------------------------------------

def batch_plan(cfg: ModelConfig, mesh: Mesh, batch: int, notes: list):
    dp = data_axes(mesh)
    dp_ok = batch % _axis_size(mesh, dp) == 0
    b = dp if dp_ok else None
    if not dp_ok:
        notes.append(f"global_batch={batch} smaller than data axes; "
                     f"batch replicated (long-context single-session shape)")
    specs = {"tokens": P(b, None), "labels": P(b, None)}
    if cfg.frontend == "vision":
        specs["vision_embeds"] = P(b, None, None)
    if cfg.family == "encdec":
        specs["frames"] = P(b, None, None)
    return specs


def pick_microbatches(cfg: ModelConfig, mesh: Mesh, batch: int, seq: int,
                      budget_bytes: float = 4e9) -> int:
    """Grad-accumulation factor: keep per-device checkpointed residuals
    (L × bµ_local × s × d × 2B) under ``budget_bytes``."""
    dp = _axis_size(mesh, data_axes(mesh))
    b_loc = max(1, batch // dp)
    L = cfg.num_layers + cfg.encoder_layers
    v_sharded = cfg.padded_vocab // mesh.shape.get("model", 1)

    def per_mb(mb):
        bmu = max(1, b_loc // mb)
        resid = L * bmu * seq * cfg.d_model * 2          # bf16 checkpoints
        logits = bmu * seq * v_sharded * 4               # f32 loss slab
        if cfg.family == "hybrid":
            # unrolled layers: XLA keeps each layer's backward TP all-reduce
            # buffer (f32 tuple of residual-sized dx partials) live — no
            # scan-body reuse. Observed 54 × 336 MB on recurrentgemma.
            resid += L * bmu * seq * cfg.d_model * 8
        return resid + logits

    mb = 1
    while mb < b_loc and per_mb(mb) > budget_bytes:
        mb *= 2
    return min(mb, b_loc)


# ---------------------------------------------------------------------------
# top level
# ---------------------------------------------------------------------------

#: per-chip HBM budget for serving weights before weight-gathered serving
#: kicks in. Leaves room for KV cache + temps on a 16 GB chip; also bounds
#: the XLA-hoisted f32 conversion of scan-stacked weights (the CPU dry-run
#: lowers bf16 dots via f32 operand converts of the whole stack, ~2× weight
#: bytes of temp — sharding over data axes shrinks that copy 16×).
SERVE_WEIGHT_BUDGET = 3.5e9


def make_plan(cfg: ModelConfig, mesh: Mesh, step_kind: str, *, batch: int,
              seq: int, param_tree=None, cache_tree=None) -> ShardingPlan:
    notes: list = []
    plan = ShardingPlan(mesh=mesh, cfg=cfg, step_kind=step_kind)
    train = step_kind == "train"
    serve_fsdp = False
    if not train:
        per_chip = cfg.param_count() * 2 / mesh.shape["model"]
        if cfg.serve_fsdp_mode == "on":
            serve_fsdp = True
        elif cfg.serve_fsdp_mode == "off":
            serve_fsdp = False
        elif per_chip > SERVE_WEIGHT_BUDGET:
            serve_fsdp = True
            notes.append(
                f"weight-gathered serving: {per_chip/1e9:.1f} GB/chip of bf16 "
                f"weights at TP{mesh.shape['model']} exceeds the "
                f"{SERVE_WEIGHT_BUDGET/1e9:.0f} GB budget; params also "
                f"sharded over data axes")
    if param_tree is not None:
        plan.param_specs = param_plan(cfg, param_tree, mesh, train=train,
                                      notes=notes, serve_fsdp=serve_fsdp)
    plan.batch_specs = batch_plan(cfg, mesh, batch, notes)
    if cache_tree is not None:
        plan.cache_specs = cache_plan(cfg, cache_tree, mesh, batch, notes)
    if train:
        plan.microbatches = (cfg.train_microbatches or
                             pick_microbatches(cfg, mesh, batch, seq))
    plan.notes = notes
    return plan
