"""Assigned input-shape cells + ShapeDtypeStruct input builders.

Every (architecture × shape) cell is well-defined here:

    train_4k     seq=4096    global_batch=256   -> train_step
    prefill_32k  seq=32768   global_batch=32    -> prefill (serve)
    decode_32k   seq=32768   global_batch=128   -> serve_step (1 new token,
                                                   KV cache of seq_len)
    long_500k    seq=524288  global_batch=1     -> serve_step; only for
                 sub-quadratic archs (SSM / hybrid / SWA) per DESIGN.md.

``input_specs`` returns weak-type-correct ShapeDtypeStructs — shardable,
no device allocation — exactly what ``jax.jit(...).lower()`` consumes.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def cell_runnable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) per the sub-quadratic rule."""
    cell = SHAPES[shape_name]
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention decode state at 524288 tokens is "
                       "outside the contract (sub-quadratic rule; DESIGN.md)")
    if cell.kind == "decode" and not cfg.has_decode:
        return False, "encoder-only architecture has no decode step"
    return True, ""


def input_specs(cfg: ModelConfig, shape_name: str, *, scale: float = 1.0):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    ``scale`` < 1 shrinks batch/seq for small-mesh integration tests while
    keeping the same structure.
    """
    cell = SHAPES[shape_name]
    batch = max(1, int(cell.batch * scale))
    seq = max(8, int(cell.seq * scale))
    tok = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    specs = {"tokens": tok}
    if cell.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    if cell.kind in ("train", "prefill"):
        if cfg.frontend == "vision":
            nv = cfg.num_frontend_tokens or 256
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (batch, min(nv, seq), cfg.d_model), jnp.float32)
        if cfg.family == "encdec":
            src = max(8, int(cfg.source_len * (scale if scale < 1 else 1)))
            specs["frames"] = jax.ShapeDtypeStruct(
                (batch, src, cfg.d_model), jnp.float32)
    if cell.kind == "decode":
        specs["tokens"] = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    return cell, batch, seq, specs
