from repro.sharding.planner import ShardingPlan, make_plan  # noqa: F401
from repro.sharding.specs import SHAPES, ShapeCell, input_specs, cell_runnable  # noqa: F401
