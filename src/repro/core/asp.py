"""AI Service Profile (ASP) — the paper's intent contract (Section III-A).

The objective part is exactly Eq. (3):

    (ℓ_TTFB, ℓ_0.95, ℓ_0.99, ρ_min, T_max, ν_min)

— every term falsifiable from boundary telemetry (Eq. 5/13). The constraint
part restricts admissible realizations: modality/interaction mode, quality
tier, privacy/sovereignty scope, mobility class, cost envelope, and the
ordered fallback ladder (the ONLY admissible degradation path — prevents
silent model/anchor switches that would make compliance non-identifiable).
"""

from __future__ import annotations

import enum
import hashlib
import json
from dataclasses import dataclass, field, asdict
from typing import Tuple


#: wire-schema version of the ASP record. Bound into ``digest()`` so two
#: parties hashing the same intent under different field sets can never
#: collide silently; the northbound gateway refuses mismatched majors.
#: 1.1: adds ``adapter_id`` (tenant LoRA adapter binding; "" = base).
#: 1.2: adds ``split_policy`` (tiered split-serving consent; "never" =
#: single-anchor, the pre-1.2 behaviour).
ASP_SCHEMA_VERSION = "1.2"

#: admissible values of :attr:`ASP.split_policy`
SPLIT_POLICIES = ("never", "auto", "require")


class SchemaVersionError(ValueError):
    """Incompatible wire-schema major — distinct from malformed input so
    the gateway can classify it structurally, not by message text."""


class Modality(enum.Enum):
    TEXT_GEN = "text-generation"
    CODE_GEN = "code-generation"
    VISION_TEXT = "vision-language"
    SPEECH_TRANSLATION = "speech-translation"
    EMBEDDING = "embedding"


class InteractionMode(enum.Enum):
    STREAMING = "streaming"   # TTFB == time-to-first-token
    UNARY = "unary"           # TTFB == time-to-first-response


class MobilityClass(enum.Enum):
    STATIC = "static"         # continuity provisioning not required
    NOMADIC = "nomadic"       # occasional re-anchoring
    VEHICULAR = "vehicular"   # frequent handover; MBB migration mandatory


class QualityTier(enum.IntEnum):
    BASIC = 1
    STANDARD = 2
    PREMIUM = 3


@dataclass(frozen=True)
class Objectives:
    """Eq. (3) — all milliseconds except ρ (probability) and ν (tokens/s)."""
    ttfb_ms: float           # ℓ_TTFB
    p95_ms: float            # ℓ_0.95
    p99_ms: float            # ℓ_0.99
    rho_min: float           # minimum completion probability under T_max
    t_max_ms: float          # hard timeout fixing success semantics
    nu_min: float            # sustained rate proxy (tokens/s or frames/s)

    def validate(self) -> None:
        if not (0 < self.ttfb_ms <= self.p99_ms):
            raise ValueError("need 0 < ℓ_TTFB ≤ ℓ_0.99")
        if not (self.p95_ms <= self.p99_ms <= self.t_max_ms):
            raise ValueError("need ℓ_0.95 ≤ ℓ_0.99 ≤ T_max")
        if not (0.0 < self.rho_min <= 1.0):
            raise ValueError("ρ_min must be a probability in (0, 1]")
        if self.nu_min < 0:
            raise ValueError("ν_min ≥ 0")


@dataclass(frozen=True)
class ASP:
    # (a) task modality + interaction mode → admissible model families
    modality: Modality
    interaction: InteractionMode
    # measurable service objectives, Eq. (3)
    objectives: Objectives
    # (b) resolvable quality tier
    tier: QualityTier = QualityTier.STANDARD
    # (c) privacy / sovereignty scope: admissible execution regions,
    #     telemetry granularity, and whether state may cross regions
    allowed_regions: Tuple[str, ...] = ("eu", "us", "apac")
    telemetry_scope: str = "aggregate"       # aggregate | per-request | none
    state_transfer_allowed: bool = True
    # (d) mobility class → continuity provisioning
    mobility: MobilityClass = MobilityClass.STATIC
    # (e) cost envelope (currency-units per 1k tokens, and per session)
    max_cost_per_1k_tokens: float = 1.0
    max_session_cost: float = 100.0
    # (f) ordered fallback ladder: the only admissible degradation path,
    #     as (model_id, tier) pairs, most-preferred first
    fallback_ladder: Tuple[Tuple[str, int], ...] = ()
    # (g) tenant adapter binding: a LoRA adapter id multiplexed over the
    #     base model ("" = the bare base). Part of the digest, so the
    #     tenant-model contract is one identity across DISCOVER
    #     admissibility, federation advertisement, and migration
    #     fingerprints. The fallback ladder may still name full models —
    #     that is the "base+adapter at edge" vs. "full model in region"
    #     degradation choice.
    adapter_id: str = ""
    # (h) split-serving consent: whether execution may be split across
    #     tiers (edge draft + anchored verify, token-identical greedy
    #     spec-decode). "never" = single anchor only (pre-1.2 default);
    #     "auto" = split when DISCOVER finds a feasible tier budget;
    #     "require" = refuse establishment unless a split is feasible.
    split_policy: str = "never"

    def validate(self) -> None:
        self.objectives.validate()
        if not self.allowed_regions:
            raise ValueError("empty sovereignty scope admits no site")
        if self.telemetry_scope not in ("aggregate", "per-request", "none"):
            raise ValueError("unknown telemetry scope")
        if self.max_cost_per_1k_tokens <= 0:
            raise ValueError("cost envelope needs max_cost_per_1k_tokens > 0")
        if self.max_session_cost <= 0:
            raise ValueError("cost envelope needs max_session_cost > 0")
        for model_id, tier in self.fallback_ladder:
            try:
                QualityTier(int(tier))
            except (ValueError, TypeError):
                raise ValueError(
                    f"fallback ladder entry ({model_id!r}, {tier!r}) names "
                    f"no valid QualityTier") from None
        if self.split_policy not in SPLIT_POLICIES:
            raise ValueError(
                f"split_policy must be one of {SPLIT_POLICIES}, "
                f"got {self.split_policy!r}")

    # ------------------------------------------------------------------
    # wire codec (northbound exposure) + versioned digest
    # ------------------------------------------------------------------
    def to_wire(self) -> dict:
        """JSON-able record of the full intent contract, with an explicit
        ``schema_version`` so the digest stays comparable across future
        field additions (absent-vs-default is disambiguated by version)."""
        return {
            "schema_version": ASP_SCHEMA_VERSION,
            "modality": self.modality.value,
            "interaction": self.interaction.value,
            "objectives": asdict(self.objectives),
            "tier": int(self.tier),
            "allowed_regions": list(self.allowed_regions),
            "telemetry_scope": self.telemetry_scope,
            "state_transfer_allowed": self.state_transfer_allowed,
            "mobility": self.mobility.value,
            "max_cost_per_1k_tokens": self.max_cost_per_1k_tokens,
            "max_session_cost": self.max_session_cost,
            "fallback_ladder": [[m, int(t)] for m, t in self.fallback_ladder],
            "adapter_id": self.adapter_id,
            "split_policy": self.split_policy,
        }

    @classmethod
    def from_wire(cls, d: dict) -> "ASP":
        ver = str(d.get("schema_version", ""))
        if ver.split(".")[0] != ASP_SCHEMA_VERSION.split(".")[0]:
            raise SchemaVersionError(
                f"ASP schema version {ver!r} incompatible with "
                f"{ASP_SCHEMA_VERSION!r}")
        asp = cls(
            modality=Modality(d["modality"]),
            interaction=InteractionMode(d["interaction"]),
            objectives=Objectives(**d["objectives"]),
            tier=QualityTier(int(d["tier"])),
            allowed_regions=tuple(d["allowed_regions"]),
            telemetry_scope=d["telemetry_scope"],
            state_transfer_allowed=bool(d["state_transfer_allowed"]),
            mobility=MobilityClass(d["mobility"]),
            max_cost_per_1k_tokens=float(d["max_cost_per_1k_tokens"]),
            max_session_cost=float(d["max_session_cost"]),
            fallback_ladder=tuple((m, int(t))
                                  for m, t in d["fallback_ladder"]),
            # minor-version tolerance: pre-1.1/1.2 peers omit the fields
            adapter_id=str(d.get("adapter_id", "")),
            split_policy=str(d.get("split_policy", "never")),
        )
        asp.validate()
        return asp

    def digest(self) -> str:
        """Stable digest bound into the AIS record (Section III-B); hashes
        the versioned wire form, so the schema version is part of identity.
        Cached on the (frozen) instance — the digest keys every memoized
        prediction, so it must not cost a JSON dump per lookup."""
        cached = self.__dict__.get("_digest_cache")
        if cached is None:
            body = json.dumps(self.to_wire(), sort_keys=True)
            cached = hashlib.sha256(body.encode()).hexdigest()[:16]
            object.__setattr__(self, "_digest_cache", cached)
        return cached

    def continuity_required(self) -> bool:
        return self.mobility is not MobilityClass.STATIC


def default_asp(model_hint: str = "", *, tier: QualityTier = QualityTier.STANDARD,
                mobility: MobilityClass = MobilityClass.STATIC) -> ASP:
    """A reasonable interactive text-generation profile (used by examples)."""
    return ASP(
        modality=Modality.TEXT_GEN,
        interaction=InteractionMode.STREAMING,
        objectives=Objectives(ttfb_ms=300.0, p95_ms=600.0, p99_ms=900.0,
                              rho_min=0.99, t_max_ms=2000.0, nu_min=20.0),
        tier=tier,
        mobility=mobility,
        fallback_ladder=((model_hint, int(tier)),) if model_hint else (),
    )
