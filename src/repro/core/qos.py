"""Transport role: QoS flows (QFI) + steering — the v_qos(t) side of Eq. 4/10.

Models the 5G enforcement plane at the semantic level the paper requires:
finite per-path premium-flow budgets, leases with expiry, idempotent release,
and per-QFI latency classes that the simulator and predictors consume. The
mapping to a real UPF/PCF is in DESIGN.md §2; here the *contractual*
behaviour is what matters — premium treatment is a reservable, exhaustible
resource whose scarcity is a distinct failure cause (QOS_SCARCITY ≠
COMPUTE_SCARCITY, Eq. 12).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.core.clock import Clock
from repro.core.failures import FailureCause, SessionError


@dataclass(frozen=True)
class TransportClass:
    """Latency model of one QoS class on one path (ms)."""
    name: str                   # premium | assured | best-effort
    base_ms: float              # propagation + forwarding floor
    jitter_ms: float            # lognormal sigma-scale of the variable part
    p999_cap_ms: float          # enforced delay budget (premium classes)


PREMIUM = TransportClass("premium", base_ms=1.0, jitter_ms=0.3, p999_cap_ms=8.0)
ASSURED = TransportClass("assured", base_ms=1.5, jitter_ms=1.0, p999_cap_ms=25.0)
BEST_EFFORT = TransportClass("best-effort", base_ms=2.0, jitter_ms=6.0,
                             p999_cap_ms=float("inf"))


@dataclass
class QoSLease:
    lease_id: str
    qfi: int
    path: Tuple[str, str]       # (access zone, site id)
    klass: TransportClass
    expires_at: float
    confirmed: bool = False

    def valid(self, now: float) -> bool:
        return now < self.expires_at


class QoSFlowManager:
    """Per-path premium budget + QFI allocation."""

    def __init__(self, clock: Clock, *, premium_flows_per_path: int = 32,
                 assured_flows_per_path: int = 128):
        self.clock = clock
        self._budget = {"premium": premium_flows_per_path,
                        "assured": assured_flows_per_path}
        self._leases: Dict[str, QoSLease] = {}
        self._ids = itertools.count()
        self._qfis = itertools.count(1)

    def _gc(self) -> None:
        now = self.clock.now()
        for k in [k for k, l in self._leases.items() if not l.valid(now)]:
            del self._leases[k]

    def in_use(self, path: Tuple[str, str], klass: str) -> int:
        self._gc()
        return sum(1 for l in self._leases.values()
                   if l.path == path and l.klass.name == klass)

    def prepare(self, path: Tuple[str, str], klass: TransportClass,
                *, ttl_s: float) -> QoSLease:
        """Provisional QoS-flow binding. Best-effort never blocks; premium /
        assured classes draw from the finite per-path budget."""
        self._gc()
        if klass.name != "best-effort":
            if self.in_use(path, klass.name) >= self._budget[klass.name]:
                raise SessionError(
                    FailureCause.QOS_SCARCITY,
                    f"no {klass.name} flows left on path {path}")
        lease = QoSLease(
            lease_id=f"qos-{next(self._ids)}", qfi=next(self._qfis),
            path=path, klass=klass,
            expires_at=self.clock.now() + ttl_s)
        self._leases[lease.lease_id] = lease
        return lease

    def confirm(self, lease_id: str, *, lease_s: float) -> None:
        lease = self._leases.get(lease_id)
        if lease is None or not lease.valid(self.clock.now()):
            raise SessionError(FailureCause.DEADLINE_EXPIRY,
                               f"QoS lease {lease_id} expired before COMMIT")
        lease.confirmed = True
        lease.expires_at = self.clock.now() + lease_s

    def renew(self, lease_id: str, lease_s: float) -> bool:
        lease = self._leases.get(lease_id)
        if lease is None or not lease.valid(self.clock.now()):
            return False
        lease.expires_at = self.clock.now() + lease_s
        return True

    def release(self, lease_id: str) -> None:
        self._leases.pop(lease_id, None)  # idempotent

    def lease_valid(self, lease_id: str) -> bool:
        lease = self._leases.get(lease_id)
        return bool(lease and lease.valid(self.clock.now()))

    def get(self, lease_id: str) -> Optional[QoSLease]:
        return self._leases.get(lease_id)
